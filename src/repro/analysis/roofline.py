"""Three-term roofline from a compiled dry-run artifact.

    T_compute    = HLO_FLOPs   / (chips * peak_FLOPs)
    T_memory     = HLO_bytes   / (chips * HBM_bw)
    T_collective = coll_bytes  / (chips * link_bw)

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes (whole-program,
i.e. already global), the HLO text parser for collective bytes.
MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE), D = tokens
processed; the ratio MODEL_FLOPS/HLO_FLOPs exposes remat/dispatch
waste.  No pass/fail — the table feeds the §Perf iteration loop.
"""
from __future__ import annotations

import dataclasses

from repro.analysis.hlo import collective_bytes
from repro.models.config import ModelConfig, ShapeConfig

__all__ = ["HW", "V5E_HW", "RooflineReport", "analyze"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12      # bf16 / chip
    hbm_bw: float = 819e9           # B/s / chip
    link_bw: float = 50e9           # B/s / link (ICI)


V5E_HW = HW()


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: dict
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops: float
    useful_ratio: float
    bytes_per_chip: dict
    note: str = ""

    def row(self) -> dict:
        return dataclasses.asdict(self)

    def summary(self) -> str:
        return (f"{self.arch:22s} {self.shape:12s} {self.mesh:9s} "
                f"Tc={self.t_compute*1e3:9.3f}ms "
                f"Tm={self.t_memory*1e3:9.3f}ms "
                f"Tx={self.t_collective*1e3:9.3f}ms "
                f"dom={self.dominant:10s} "
                f"useful={self.useful_ratio:6.3f}")


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N·D with N = active params, D = tokens touched this step."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens          # forward only
    return 2.0 * n * shape.global_batch  # decode: 1 token / sequence


def analyze(arch: str, shape_cfg: ShapeConfig, mesh_name: str, chips: int,
            cost: dict, hlo_text: str, mem: dict, cfg: ModelConfig,
            hw: HW = V5E_HW, note: str = "") -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(hlo_text)
    t_c = flops / (chips * hw.peak_flops)
    t_m = byts / (chips * hw.hbm_bw)
    t_x = coll["total"] / (chips * hw.link_bw)
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape_cfg)
    return RooflineReport(
        arch=arch, shape=shape_cfg.name, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts, coll_bytes=coll["total"],
        coll_breakdown={k: v for k, v in coll.items()
                        if k not in ("total", "ops")},
        t_compute=t_c, t_memory=t_m, t_collective=t_x, dominant=dom,
        model_flops=mf, useful_ratio=(mf / flops if flops else 0.0),
        bytes_per_chip=mem, note=note)
