"""Collective-byte accounting from optimized (post-SPMD) HLO text.

``cost_analysis()`` has no collective term, so we parse
``compiled.as_text()``: every ``all-gather`` / ``all-reduce`` /
``reduce-scatter`` / ``all-to-all`` / ``collective-permute`` op's
result shape is summed (tuples expanded).  Conventions:

- all-reduce / all-gather / all-to-all / collective-permute: wire
  volume ~= result bytes (per participant, up to the (P-1)/P ring
  factor which we fold into the link-bandwidth constant).
- reduce-scatter: the result is 1/g of the input; we scale by the
  replica-group size ``g`` so the reported bytes are the *reduced*
  volume, comparable to an all-reduce of the same tensor.

Output: {"all-gather": bytes, ..., "total": bytes, "ops": n}.
"""
from __future__ import annotations

import re

import numpy as np

__all__ = ["collective_bytes", "shape_bytes", "count_ops"]

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*(?:\},?\{[^}]*)*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO type string, tuples included:
    'f32[16,128]' or '(bf16[4,8]{1,0}, u32[])'."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = int(np.prod([int(d) for d in dims.split(",") if d])) \
            if dims else 1
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota format [n_groups,group_size]<=[...]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).split("}")[0]
        return max(1, len([t for t in first.split(",") if t.strip()]))
    return 1


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum collective wire bytes per op kind over an HLO module."""
    out: dict[str, float] = {k: 0.0 for k in COLLECTIVES}
    n_ops = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        # result type precedes '=':   %x = TYPE opname(...)
        if "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        rhs = rhs.strip()
        kind = None
        for k in COLLECTIVES:
            # match 'bf16[...] all-gather(' and fusion-free starts only
            if re.match(rf"[^a-z]*[\w\[\],\{{\}}()\s]*\s{k}\(", rhs) or \
               re.search(rf"\s{k}\(", rhs) or rhs.startswith(k + "("):
                kind = k
                break
        if kind is None:
            continue
        if f" {kind}(" not in " " + rhs and not rhs.startswith(kind + "("):
            continue
        # the result type is the text before the op name
        head = rhs.split(kind + "(")[0]
        b = shape_bytes(head)
        if b == 0:
            continue
        if kind == "reduce-scatter":
            b *= _group_size(s)
        out[kind] += b
        n_ops += 1
    out["total"] = float(sum(out[k] for k in COLLECTIVES))
    out["ops"] = n_ops
    return out


def count_ops(hlo_text: str, names: tuple[str, ...] = ("fusion", "dot",
              "convolution", "scatter", "gather", "while")) -> dict[str, int]:
    counts = {n: 0 for n in names}
    for line in hlo_text.splitlines():
        for n in names:
            if re.search(rf"\s{n}(\.|\()", line):
                counts[n] += 1
    return counts
