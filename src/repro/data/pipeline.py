"""Deterministic, resumable, shard-aware data pipeline.

Two sources behind one interface:

- :class:`SyntheticLM` — stateless synthetic token streams: batch(step)
  is a pure function of (seed, step), so resume-after-preemption is
  exact with zero pipeline state to checkpoint, and every data-parallel
  host computes only its own shard.
- :class:`MemmapLM` — tokenized corpus in a flat uint16/uint32 binary
  (numpy memmap); deterministic strided sampling indexed by step.

Both emit next-token-prediction batches {tokens, labels} and support
``host_slice`` so each process materializes 1/N of the global batch
(the multi-host input path; on one process the slice is everything).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

__all__ = ["SyntheticLM", "MemmapLM", "make_pipeline"]


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    #: this host's slice of the global batch [lo, hi)
    host_lo: int = 0
    host_hi: int | None = None

    def __post_init__(self):
        if self.host_hi is None:
            self.host_hi = self.global_batch

    def batch(self, step: int) -> dict[str, np.ndarray]:
        """Markov-ish synthetic stream (learnable, non-uniform): token
        t+1 = (a*t + noise) % V so models show decreasing loss."""
        n = self.host_hi - self.host_lo
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_lo]))
        first = rng.integers(0, self.vocab_size, size=(n, 1))
        noise = rng.integers(0, 7, size=(n, self.seq_len))
        toks = np.zeros((n, self.seq_len + 1), np.int64)
        toks[:, :1] = first
        for t in range(self.seq_len):
            toks[:, t + 1] = (toks[:, t] * 31 + 7 + noise[:, t] % 3) \
                % self.vocab_size
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def state(self) -> dict:
        return {"kind": "synthetic", "seed": self.seed}


@dataclasses.dataclass
class MemmapLM:
    path: str
    vocab_size: int
    seq_len: int
    global_batch: int
    dtype: str = "uint16"
    seed: int = 0
    host_lo: int = 0
    host_hi: int | None = None

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=self.dtype, mode="r")
        self._n_windows = (len(self._data) - 1) // self.seq_len
        if self.host_hi is None:
            self.host_hi = self.global_batch

    def batch(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        idx = rng.integers(0, self._n_windows, size=self.global_batch)
        idx = idx[self.host_lo:self.host_hi]
        rows = np.stack([
            self._data[i * self.seq_len: i * self.seq_len + self.seq_len + 1]
            for i in idx]).astype(np.int64)
        rows %= self.vocab_size
        return {"tokens": rows[:, :-1].astype(np.int32),
                "labels": rows[:, 1:].astype(np.int32)}

    def state(self) -> dict:
        return {"kind": "memmap", "path": self.path, "seed": self.seed}


def make_pipeline(kind: str = "synthetic", **kw):
    if kind == "synthetic":
        return SyntheticLM(**kw)
    if kind == "memmap":
        return MemmapLM(**kw)
    raise KeyError(kind)
