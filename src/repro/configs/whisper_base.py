"""whisper-base [audio enc-dec] — 6L enc + 6L dec, d_model=512, 8H,
d_ff=2048, vocab=51865.  [arXiv:2212.04356]

The conv audio frontend is a STUB: input_specs() provides precomputed
frame embeddings (B, n_frames, 512).  Backbone deviations noted in
DESIGN.md: RoPE replaces learned/sinusoidal absolute positions,
RMSNorm replaces LayerNorm (pre-norm structure preserved).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, n_enc_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab_size=51865, frontend="audio",
    n_frontend_tokens=1500,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256, frontend="audio", n_frontend_tokens=30,
    dtype="float32",
)
