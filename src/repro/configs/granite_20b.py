"""granite-20b [dense] — 52L d_model=6144 48H (MQA kv=1) d_ff=24576,
vocab=49152, llama-arch code model.  [arXiv:2405.04324; hf]

MQA: the single KV head is replicated across the model axis; KV-cache
per token is 48x smaller than MHA.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b", family="dense",
    n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24576,
    vocab_size=49152,
)

SMOKE = ModelConfig(
    name="granite20b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
    vocab_size=256, dtype="float32",
)
