"""qwen1.5-32b [dense] — 64L d_model=5120 40H (GQA kv=40 == MHA)
d_ff=27392, vocab=152064, QKV bias.  [hf:Qwen/Qwen1.5 family; hf]

TP note: 40 heads over the 16-way model axis shard unevenly (GSPMD
pads 40->48); documented in the roofline table.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, d_ff=27392,
    vocab_size=152064, qkv_bias=True,
)

SMOKE = ModelConfig(
    name="qwen15-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, qkv_bias=True, dtype="float32",
)
