"""minicpm3-4b [dense, MLA] — 62L d_model=2560 40H d_ff=6400,
vocab=73448, Multi-head Latent Attention.  [hf:openbmb/MiniCPM3-4B; hf]

MLA ranks from the HF config: q_lora_rank=768, kv_lora_rank=256,
qk_nope_head_dim=64 (head_dim), qk_rope_head_dim=32.  The cache stores
(256+32) floats/token instead of 2*40*64.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=6400,
    vocab_size=73448, head_dim=64,
    use_mla=True, q_lora_rank=768, kv_lora_rank=256, rope_head_dim=32,
)

SMOKE = ModelConfig(
    name="minicpm3-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, head_dim=16,
    use_mla=True, q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8,
    dtype="float32",
)
