"""Assigned-architecture configs (one module per arch) + registry.

Every config module exposes ``CONFIG`` (the exact assigned
architecture) and ``SMOKE`` (a reduced same-family config for CPU smoke
tests).  ``get_config(name)`` / ``get_smoke(name)`` look them up;
``ARCHS`` lists all ten assigned ids.
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, SHAPES, ShapeConfig

ARCHS = [
    "granite_moe_3b_a800m",
    "qwen3_moe_235b_a22b",
    "qwen15_32b",
    "granite_3_2b",
    "granite_20b",
    "minicpm3_4b",
    "mamba2_2p7b",
    "whisper_base",
    "zamba2_1p2b",
    "internvl2_26b",
]

#: assigned ids as given (hyphenated) -> module name
ALIASES = {
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "qwen1.5-32b": "qwen15_32b",
    "granite-3-2b": "granite_3_2b",
    "granite-20b": "granite_20b",
    "minicpm3-4b": "minicpm3_4b",
    "mamba2-2.7b": "mamba2_2p7b",
    "whisper-base": "whisper_base",
    "zamba2-1.2b": "zamba2_1p2b",
    "internvl2-26b": "internvl2_26b",
}


def _module(name: str):
    name = ALIASES.get(name, name)
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCHS}")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _module(name).SMOKE


__all__ = ["ARCHS", "ALIASES", "get_config", "get_smoke", "SHAPES",
           "ShapeConfig", "ModelConfig"]
