"""zamba2-1.2b [hybrid] — 38 Mamba2 layers d_model=2048 + shared
attention block (32H, kv=32, d_ff=8192) applied every 6 layers,
vocab=32000, ssm_state=64.  [arXiv:2411.15242; hf]

Sub-quadratic overall: long_500k RUNS (the 6 shared-attention sites
hold the only KV caches).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32000, ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    ssm_groups=1, ssm_chunk=128, attn_every=6, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, ssm_state=16, ssm_head_dim=16, ssm_expand=2,
    ssm_groups=1, ssm_chunk=16, attn_every=2, tie_embeddings=True,
    dtype="float32",
)
