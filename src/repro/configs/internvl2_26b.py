"""internvl2-26b [vlm] — InternLM2-20B backbone: 48L d_model=6144 48H
(GQA kv=8) d_ff=16384, vocab=92553; InternViT frontend.
[arXiv:2404.16821; hf]

The vision frontend is a STUB: input_specs() provides precomputed
patch embeddings (B, n_patches, 6144) prepended to the text sequence.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab_size=92553, frontend="vision", n_frontend_tokens=256,
)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, frontend="vision", n_frontend_tokens=8,
    dtype="float32",
)
