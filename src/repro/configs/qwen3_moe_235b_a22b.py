"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4)
d_ff=1536/expert, vocab=151936, MoE 128 experts top-8.
[hf:Qwen/Qwen3-30B-A3B family; hf]

EP: 128 experts % 16 model shards == 0 -> true expert parallelism.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_ff=1536,
    vocab_size=151936, n_experts=128, experts_per_token=8,
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=48,
    vocab_size=256, n_experts=8, experts_per_token=2, dtype="float32",
)
