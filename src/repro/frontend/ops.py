"""The domain-specific library surface (the paper's §III DSL).

These are the operations a single-source program composes — the
AnyHLS-style image-processing library, traced instead of
template-metaprogrammed.  Each call on :class:`~.tracer.Plane`
values records one stage of the matching kind:

====================  =====================================
frontend op           stage kind (``repro.core.graph``)
====================  =====================================
``+ - * /`` etc.      ``point`` / ``pointN``
:func:`conv`          ``stencil`` (taps unrolled, zeros elided)
:func:`window`        ``stencil`` (arbitrary local operator)
:func:`reduce`        ``reduce``  (global, group-breaking)
:func:`where`         ``pointN`` select on a bool Plane
:func:`custom`        ``custom``  (opaque; embeds hand kernels)
====================  =====================================

The unary math family (:data:`sqrt`, :data:`exp`, …) are
:class:`~.tracer.PointFn` objects: on arrays they just compute, on
Planes they record — so the same helper works inside a ``@pointfn``
body and in traced top-level code.

>>> import numpy as np
>>> from repro.frontend import ops as fe
>>> def program(img):
...     blurred = fe.conv(img, np.ones((3, 3), np.float32) / 9.0)
...     return fe.sqrt(abs(img - blurred))
>>> g = fe.trace(program, fe.spec((8, 128)))
>>> len(g.graph_inputs), len(g.graph_outputs)
(1, 1)
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.frontend.diagnostics import (TraceDtypeError, TraceError,
                                        TraceShapeError, user_src)
from repro.frontend.lib import conv_taps
from repro.frontend.tracer import (InputSpec, Plane, dataflow_fn, pointfn,
                                   trace)

__all__ = [
    "spec", "trace", "dataflow_fn",
    "conv", "window", "reduce", "where", "select", "custom",
    "sqrt", "exp", "log", "abs", "tanh", "sin", "cos", "sign",
    "maximum", "minimum",
]


def spec(shape: Sequence[int], dtype: Any = jnp.float32,
         name: str | None = None) -> InputSpec:
    """Declare one traced input: shape, dtype, optional channel name
    (defaults to the traced function's parameter name)."""
    return InputSpec(tuple(int(d) for d in shape), dtype, name)


# ----------------------------------------------------------------------
# stencil ops
# ----------------------------------------------------------------------
def conv(x, taps, *, name: str | None = None, ii: float = 1.0,
         fill: float = 8.0):
    """2-D convolution with a fixed coefficient table.

    ``taps`` is a 2-D array with odd dimensions; the window is its
    shape.  Taps are unrolled into scalar multiplies with zeros elided
    (:func:`repro.frontend.lib.conv_taps`) — the constant folding an
    FPGA synthesizer applies to fixed coefficients.  Edge handling is
    zero-padding, like every stencil in the pipeline.

    On a non-Plane array input this just computes the reference
    convolution (useful for tests and docs).
    """
    taps = np.asarray(taps, np.float32)
    if taps.ndim != 2:
        raise TraceShapeError(
            f"conv taps must be 2-D, got shape {taps.shape}", user_src())
    kh, kw = taps.shape
    if kh % 2 != 1 or kw % 2 != 1:
        raise TraceShapeError(
            f"conv taps must have odd dimensions, got {taps.shape}",
            user_src())
    fn = conv_taps(taps)
    if not isinstance(x, Plane):
        from repro.core.graph import extract_patches
        return fn(extract_patches(jnp.asarray(x), (kh, kw)))
    _check_stencil_input("conv", x)
    return x.tracer.record(
        "stencil", [x], fn, key=("conv", taps.tobytes(), taps.shape),
        window=(kh, kw), name=name, ii=ii, fill=fill)


def window(x, win: tuple[int, int], fn: Callable, *,
           name: str | None = None, dtype: Any = None, ii: float = 1.0,
           fill: float = 8.0):
    """Arbitrary local operator over a ``(kh, kw)`` neighborhood.

    ``fn(patches)`` receives the ``kh*kw`` zero-padded shifted views
    stacked on axis 0 (``patches[i]`` is the view for tap ``i`` in
    row-major order) — the line-buffer contract of the ``stencil``
    stage kind.  ``fn`` must be traceable by JAX (jnp ops only) and
    must not capture Planes.
    """
    kh, kw = win
    if kh % 2 != 1 or kw % 2 != 1:
        raise TraceShapeError(
            f"window must be odd, got {win}", user_src())
    if isinstance(fn, Plane) or (callable(x) and not isinstance(x, Plane)):
        raise TraceError("window(x, (kh, kw), fn): the plane comes "
                         "first, the local function last", user_src())
    if not isinstance(x, Plane):
        from repro.core.graph import extract_patches
        return fn(extract_patches(jnp.asarray(x), (kh, kw)))
    _check_stencil_input("window", x)
    fn = fn.fn if hasattr(fn, "fn") and callable(fn.fn) else fn
    return x.tracer.record(
        "stencil", [x], fn, key=("window", id(fn)), window=(kh, kw),
        dtype=dtype, name=name, ii=ii, fill=fill)


def _check_stencil_input(op: str, x: Plane) -> None:
    x.tracer.check_alive()
    if x.ndim != 2:
        raise TraceShapeError(
            f"{op} expects a 2-D plane, got shape {x.shape}", user_src())
    if np.dtype(x.dtype) == np.dtype(bool):
        raise TraceDtypeError(
            f"{op} on a bool Plane; convert with fe.where first",
            user_src())


# ----------------------------------------------------------------------
# reductions and opaque stages
# ----------------------------------------------------------------------
def reduce(x, fn: Callable, out_shape: Sequence[int] = (), *,
           dtype: Any = None, name: str | None = None):
    """Global reduction ``fn(x) -> out_shape`` (e.g. ``jnp.sum``).

    Reductions break fusion groups — the paper's dataflow pipeline is
    feed-forward, so a global value starts a new kernel.
    """
    if not isinstance(x, Plane):
        return fn(jnp.asarray(x))
    x.tracer.check_alive()
    return x.tracer.record("reduce", [x], fn,
                           key=("reduce", id(fn), tuple(out_shape)),
                           out_shape=tuple(out_shape), dtype=dtype,
                           name=name)


def custom(fn: Callable, *xs, out_shapes=None, out_dtypes=None,
           name: str | None = None):
    """Opaque whole-array stage (embeds hand-written kernels).

    ``fn(*arrays)`` runs on whole logical arrays; it breaks fusion
    groups.  Output shapes/dtypes are inferred with
    :func:`jax.eval_shape` unless given.  Returns one Plane when there
    is a single output (inferred or ``len(out_shapes) == 1``), a tuple
    otherwise.
    """
    planes = [x for x in xs if isinstance(x, Plane)]
    if not planes:
        return fn(*xs)
    if len(planes) != len(xs):
        raise TraceError(
            "custom: every array argument must be a Plane; close "
            "constants over fn instead", user_src())
    tracer = planes[0].tracer
    tracer.check_same_trace("custom", *planes)   # shapes may differ
    if out_shapes is None:
        avals = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in planes]
        out = jax.eval_shape(fn, *avals)
        single = not isinstance(out, (tuple, list))
        outs = [out] if single else list(out)
        out_shapes = [tuple(o.shape) for o in outs]
        out_dtypes = [o.dtype for o in outs]
    else:
        single = len(out_shapes) == 1
        out_dtypes = list(out_dtypes or [planes[0].dtype] * len(out_shapes))
    result = tracer.record_custom(planes, fn, out_shapes=out_shapes,
                                  out_dtypes=out_dtypes, name=name)
    return result[0] if single and len(result) == 1 else result


# ----------------------------------------------------------------------
# select
# ----------------------------------------------------------------------
def _where3(c, a, b): return jnp.where(c, a, b)          # noqa: E704


def _where_pb(bv):
    def fn(c, a): return jnp.where(c, a, bv)             # noqa: E704
    return fn


def _where_pa(av):
    def fn(c, b): return jnp.where(c, av, b)             # noqa: E704
    return fn


def _where_ss(av, bv):
    def fn(c): return jnp.where(c, av, bv)               # noqa: E704
    return fn


def where(cond, a, b):
    """Elementwise select: ``a`` where ``cond`` else ``b``.

    ``cond`` must be a bool Plane (a comparison result); ``a``/``b``
    may be Planes or scalars.  This is the traced replacement for
    Python ``if`` on data (which raises
    :class:`~repro.frontend.diagnostics.TraceControlFlowError`).
    """
    if not isinstance(cond, Plane):
        return jnp.where(cond, a, b)
    tracer = cond.tracer
    tracer.check_alive()
    if np.dtype(cond.dtype) != np.dtype(bool):
        raise TraceDtypeError(
            f"where condition must be a bool Plane (a comparison), got "
            f"dtype {np.dtype(cond.dtype).name}", user_src())
    a_p, b_p = isinstance(a, Plane), isinstance(b, Plane)
    if a_p and b_p:
        tracer.check_compatible("where", cond, a, b)
        if np.dtype(a.dtype) == np.dtype(b.dtype):
            dtype = a.dtype
        else:
            dtype = np.promote_types(np.dtype(a.dtype), np.dtype(b.dtype))
        return tracer.pointn([cond, a, b], _where3, key=("where",),
                             dtype=dtype)
    # scalar branches keep their numeric identity (no float() coercion:
    # fe.where(mask, 1, 0) in an int pipeline stays integral), but are
    # normalized to hashable Python scalars for the CSE memo
    if a_p:
        tracer.check_compatible("where", cond, a)
        b = _where_scalar("b", b)
        return tracer.pointn([cond, a], _where_pb(b),
                             key=("where", "pb", b), dtype=a.dtype)
    if b_p:
        tracer.check_compatible("where", cond, b)
        a = _where_scalar("a", a)
        return tracer.pointn([cond, b], _where_pa(a),
                             key=("where", "pa", a), dtype=b.dtype)
    a, b = _where_scalar("a", a), _where_scalar("b", b)
    return tracer.point(cond, _where_ss(a, b),
                        key=("where", "ss", a, b),
                        dtype=jnp.result_type(a, b))


def _where_scalar(side: str, v):
    """Normalize a where() branch to a hashable Python scalar."""
    if isinstance(v, (bool, np.bool_)):
        return bool(v)
    if isinstance(v, (int, float)):
        return v
    if isinstance(v, np.generic) or (isinstance(v, np.ndarray)
                                     and v.ndim == 0):
        return v.item()
    raise TraceError(
        f"where branch {side!r} must be a Plane or a scalar, got "
        f"{type(v).__name__!r}; for array constants close over them in "
        f"a @pointfn or use fe.custom", user_src())


select = where


# ----------------------------------------------------------------------
# jnp-style unary math: compute on arrays, record on Planes
# ----------------------------------------------------------------------
@pointfn
def sqrt(a):
    return jnp.sqrt(a)


@pointfn
def exp(a):
    return jnp.exp(a)


@pointfn
def log(a):
    return jnp.log(a)


@pointfn
def abs(a):                 # noqa: A001 - fe.abs mirrors jnp.abs
    return jnp.abs(a)


@pointfn
def tanh(a):
    return jnp.tanh(a)


@pointfn
def sin(a):
    return jnp.sin(a)


@pointfn
def cos(a):
    return jnp.cos(a)


@pointfn
def sign(a):
    return jnp.sign(a)


def _max2(a, b): return jnp.maximum(a, b)                # noqa: E704
def _min2(a, b): return jnp.minimum(a, b)                # noqa: E704


def _maxc(c):
    def fn(v): return jnp.maximum(v, c)                  # noqa: E704
    return fn


def _minc(c):
    def fn(v): return jnp.minimum(v, c)                  # noqa: E704
    return fn


def maximum(a, b):
    """Elementwise max of two Planes, or of a Plane and a scalar."""
    return _extremum("maximum", a, b, _max2, _maxc)


def minimum(a, b):
    """Elementwise min of two Planes, or of a Plane and a scalar."""
    return _extremum("minimum", a, b, _min2, _minc)


def _extremum(opname, a, b, pair_fn, const_fac):
    a_p, b_p = isinstance(a, Plane), isinstance(b, Plane)
    if not a_p and not b_p:
        return pair_fn(a, b)
    if a_p and b_p:
        a.tracer.check_compatible(opname, a, b)
        return a.tracer.pointn([a, b], pair_fn, key=(opname,))
    p, c = (a, b) if a_p else (b, a)       # max/min are commutative
    return p.tracer.point(p, const_fac(float(c)),
                          key=(opname, "c", float(c)))
