"""Single-source tracing frontend: plain array code in, dataflow app out.

This package is the paper's programmer-facing layer: instead of
hand-assembling a :class:`~repro.core.graph.DataflowGraph` (naming
channels, inserting splits, minding the single-reader contract), the
user writes an ordinary Python function over arrays and the frontend
*extracts* the graph — operator overloading records point ops, the
library ops (:func:`conv`, :func:`window`, :func:`reduce`,
:func:`where`, :func:`custom`) record the structured stages, and the
standard pass pipeline canonicalizes the result.

Conventional use::

    import repro.frontend as fe
    from repro.frontend.lib import GAUSS5

    @fe.dataflow_fn
    def unsharp(img):
        blur = fe.conv(img, GAUSS5)
        return img + 1.5 * (img - blur)

    out = unsharp(frame)                  # trace+compile+run, memoized
    app = unsharp.compile(fe.spec((512, 1024)), tune="auto")
    graph = unsharp.graph_for({"img": frame})   # for StreamEngine.submit

See ``docs/frontend.md`` for the library surface, the tracing rules,
and what is (and is not) traceable.
"""
from repro.frontend.diagnostics import (TraceControlFlowError,
                                        TraceDtypeError, TraceError,
                                        TraceLeakError, TraceShapeError)
from repro.frontend.tracer import (DataflowFunction, InputSpec, Plane,
                                   PointFn, dataflow_fn, pointfn, trace)
from repro.frontend.ops import (abs, conv, cos, custom, exp, log, maximum,
                                minimum, reduce, select, sign, sin, spec,
                                sqrt, tanh, where, window)
from repro.frontend import lib

__all__ = [
    "Plane", "InputSpec", "PointFn", "pointfn", "trace", "dataflow_fn",
    "DataflowFunction", "spec",
    "conv", "window", "reduce", "where", "select", "custom",
    "sqrt", "exp", "log", "abs", "tanh", "sin", "cos", "sign",
    "maximum", "minimum", "lib",
    "TraceError", "TraceShapeError", "TraceDtypeError",
    "TraceControlFlowError", "TraceLeakError",
]
