"""Trace diagnostics: errors that point at the USER'S source line.

A tracing frontend fails in user code, not in tracer code: when a
traced program mixes shapes, branches on a traced value, or leaks a
Plane into plain Python, the useful location is the line the *user*
wrote — not a traceback through ``tracer.py`` internals.  Every stage
recorded by :mod:`repro.frontend.tracer` therefore captures the first
stack frame *outside* the frontend package at record time
(:func:`user_src`), stores it in ``Stage.meta["src"]``, and every
:class:`TraceError` carries that location in its message.

The error taxonomy mirrors Section IV-A of the paper (what the
extractor can and cannot turn into a dataflow graph):

- :class:`TraceShapeError`   — operand planes disagree on shape
- :class:`TraceDtypeError`   — e.g. arithmetic on a comparison result
- :class:`TraceControlFlowError` — data-dependent Python control flow
  (``if plane:``, ``while plane:``, ``float(plane)``, iteration)
- :class:`TraceLeakError`    — a non-Plane value where a Plane is
  required, or a Plane escaping into NumPy / plain Python
"""
from __future__ import annotations

import os
import sys

from repro.core.graph import GraphError

__all__ = [
    "TraceError",
    "TraceShapeError",
    "TraceDtypeError",
    "TraceControlFlowError",
    "TraceLeakError",
    "user_src",
]

#: directory of the frontend package itself; frames from here are
#: tracer internals, never "user code"
_FRONTEND_DIR = os.path.dirname(os.path.abspath(__file__))


class TraceError(GraphError):
    """Base class for trace-time errors; message ends with the user
    source location (``file.py:line``) when one could be captured."""

    def __init__(self, message: str, src: str | None = None):
        self.src = src
        if src:
            message = f"{message}\n  at {src}"
        super().__init__(message)


class TraceShapeError(TraceError):
    """Operand planes disagree on shape."""


class TraceDtypeError(TraceError):
    """Operand dtypes are unusable for the op (e.g. math on bool)."""


class TraceControlFlowError(TraceError):
    """Python control flow depends on a traced value."""


class TraceLeakError(TraceError):
    """A value crossed the Plane/plain-Python boundary illegally."""


def user_src() -> str | None:
    """``file.py:line`` of the innermost stack frame in USER code.

    Walks outward past every frame that lives inside the frontend
    package; the first frame outside it is the user's call site (for
    the Table-I apps that is a line in ``repro/core/apps.py`` — the
    single-source program itself).  Returns ``None`` when no such
    frame exists (e.g. called from a REPL with no file).
    """
    f = sys._getframe(1)
    while f is not None:
        # co_filename may be non-canonical (e.g. "tests/../src/…")
        # depending on how the package landed on sys.path
        filename = os.path.normpath(os.path.abspath(f.f_code.co_filename))
        if (not filename.startswith(_FRONTEND_DIR)
                and "importlib" not in filename):
            return f"{filename}:{f.f_lineno}"
        f = f.f_back
    return None
