"""Shared kernel library: every coefficient table and pointwise
formula used by the Table-I apps, defined exactly once.

Hoisted out of ``repro.core.apps`` so the traced single-source
builders, the hand-built oracle graphs, the examples, the benchmarks
and the tests all reference the *same objects*.  That sharing is
load-bearing, not just tidy: stage-function identity feeds
:meth:`repro.core.graph.DataflowGraph.signature`, so a traced app and
its hand-built oracle can only hash equal because both sides draw
their stage bodies from here.

Three families:

- **taps** — the classic stencil coefficient tables (``GAUSS3`` …),
  plus :func:`conv_taps` which unrolls a table into a patch function
  with zero-taps elided (what an FPGA synthesizer does to fixed
  coefficients).
- **local operators** — patch functions for ``stencil`` stages
  (:func:`sobel_mag`, :func:`bilateral`).
- **pointwise formulas** — ``@pointfn``-lifted elementwise math
  (:data:`luma_rec601`, :func:`harris_response`, …): call them on
  arrays to compute, on Planes to record one stage.

The canonical operator bodies (``add``, ``sub``, ``scale(c)``, …)
are re-exported from :mod:`repro.frontend.tracer` for the hand-built
graphs to use.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.frontend.tracer import (add, div, mul, neg, offset, pointfn,
                                   powc, scale, square, sub, subc)

__all__ = [
    "GAUSS3", "GAUSS5", "MEAN5", "SOBEL_X", "SOBEL_Y", "LAPLACE3",
    "JACOBI3",
    "conv_taps", "sobel_mag", "bilateral",
    "luma_rec601", "harris_response", "lam_min", "lk_vx", "lk_vy",
    # canonical elementwise ops (tracer re-exports)
    "add", "sub", "mul", "div", "square", "neg", "offset", "scale",
    "subc", "powc",
]


# ----------------------------------------------------------------------
# coefficient tables
# ----------------------------------------------------------------------
GAUSS3 = np.array([[1, 2, 1], [2, 4, 2], [1, 2, 1]], np.float32) / 16.0
GAUSS5 = np.outer([1, 4, 6, 4, 1], [1, 4, 6, 4, 1]).astype(np.float32) / 256.0
MEAN5 = np.ones((5, 5), np.float32) / 25.0
SOBEL_X = np.array([[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]], np.float32)
SOBEL_Y = SOBEL_X.T.copy()
LAPLACE3 = np.array([[0, 1, 0], [1, -4, 1], [0, 1, 0]], np.float32)
JACOBI3 = np.array([[0, 1, 0], [1, 0, 1], [0, 1, 0]], np.float32) / 4.0


def conv_taps(weights: np.ndarray) -> Callable:
    """Patch function for a fixed coefficient table.

    Taps are unrolled as scalar multiplies (zeros elided) — the same
    constant folding an FPGA synthesizer applies to fixed
    coefficients, and it keeps stage fns free of captured array
    constants (a Pallas kernel requirement).
    """
    taps = [float(v) for v in np.asarray(weights).reshape(-1)]

    def fn(p):
        acc = None
        for i, t in enumerate(taps):
            if t == 0.0:
                continue
            term = p[i] if t == 1.0 else p[i] * t
            acc = term if acc is None else acc + term
        return acc

    return fn


# ----------------------------------------------------------------------
# local (stencil) operators
# ----------------------------------------------------------------------
def sobel_mag(p):
    """Gradient magnitude from one 3x3 patch set (both Sobel taps)."""
    gx = conv_taps(SOBEL_X)(p)
    gy = conv_taps(SOBEL_Y)(p)
    return jnp.sqrt(gx * gx + gy * gy + 1e-12)


def bilateral(sigma_s: float = 2.0, sigma_r: float = 0.25) -> Callable:
    """5x5 bilateral filter patch function (range kernel unrolled)."""
    kh = kw = 5
    ds = np.array([[(i - 2) ** 2 + (j - 2) ** 2 for j in range(kw)]
                   for i in range(kh)], np.float32).reshape(-1)
    ws = [float(v) for v in np.exp(-ds / (2 * sigma_s ** 2))]
    inv2r = 1.0 / (2 * sigma_r ** 2)

    def fn(p):
        center = p[kh * kw // 2]
        sum_w = None
        sum_wp = None
        for i, wsi in enumerate(ws):  # unrolled taps (scalar consts)
            wr = jnp.exp(-(p[i] - center) ** 2 * inv2r) * wsi
            sum_w = wr if sum_w is None else sum_w + wr
            term = wr * p[i]
            sum_wp = term if sum_wp is None else sum_wp + term
        return sum_wp / (sum_w + 1e-12)

    return fn


# ----------------------------------------------------------------------
# pointwise formulas
# ----------------------------------------------------------------------
@pointfn
def luma_rec601(r, gc, b):
    """ITU-R BT.601 luma from RGB planes."""
    return 0.299 * r + 0.587 * gc + 0.114 * b


def harris_response(k: float = 0.04):
    """Harris corner response over the windowed structure tensor."""
    @pointfn
    def response(a, c, b):
        return (a * c - b * b) - k * (a + c) * (a + c)

    return response


@pointfn
def lam_min(a, c, b):
    """Smaller eigenvalue of the 2x2 structure tensor (Shi-Tomasi)."""
    tr2 = (a + c) * 0.5
    det = a * c - b * b
    return tr2 - jnp.sqrt(jnp.maximum(tr2 * tr2 - det, 0.0) + 1e-12)


def lk_vx(eps: float = 1e-3):
    """Lucas-Kanade horizontal flow from the windowed moments."""
    @pointfn
    def vx(a, c, b, tx, ty):
        det = a * c - b * b
        return jnp.where(jnp.abs(det) > eps, (-c * tx + b * ty) / det, 0.0)

    return vx


def lk_vy(eps: float = 1e-3):
    """Lucas-Kanade vertical flow from the windowed moments."""
    @pointfn
    def vy(a, c, b, tx, ty):
        det = a * c - b * b
        return jnp.where(jnp.abs(det) > eps, (b * tx - a * ty) / det, 0.0)

    return vy
