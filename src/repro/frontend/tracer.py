"""Tracing machinery: run plain array code, record a dataflow graph.

This is the paper's *graph extraction from a single-source program*
(Section IV-A) as an operator-overloading tracer.  The user writes an
ordinary Python function over :class:`Plane` values; every arithmetic
operator and every library call (:mod:`repro.frontend.ops`) records
one ``point`` / ``pointN`` / ``stencil`` / ``reduce`` / ``custom``
stage into a :class:`~repro.core.graph.DataflowGraph`.  Fan-out is
implicit — reading a Plane twice simply leaves a multi-reader channel
for the existing ``AutoSplitInsertion`` pass to make explicit.

Trace-time canonicalization:

- **CSE** — structurally identical records (same op, same operand
  channels, same constants) return the *same* Plane, so a reused
  subexpression becomes one stage with fan-out, not two stages.
- **constant folding** — scalar-only subtrees fold in plain Python
  before they ever reach a Plane, and algebraic identities
  (``x * 1``, ``x + 0``, ``x / 1``, ``x ** 1``) record nothing.
- **coalescing** — chains of recorded point ops are left for the
  ``PointFusion`` pass, which :func:`trace` runs before returning, so
  a traced graph comes back fully canonical (``validate()``-clean,
  ``reference_eval``-ready).

Stage functions are drawn from the module-level op library below
(``add``, ``sub``, ``scale(c)``, …) so that traced graphs have
*stable structural fingerprints*: two traces of the same program —
even across processes — produce the same
:meth:`~repro.core.graph.DataflowGraph.signature`, which is what the
compile cache and the persistent tuning cache key on.

>>> import numpy as np
>>> from repro.frontend.tracer import trace
>>> def program(img):
...     return 2.0 * img + 1.0
>>> g = trace(program, (8, 128))
>>> [c.name for c in g.graph_inputs], [c.name for c in g.graph_outputs]
(['img'], ['out'])
>>> x = np.ones((8, 128), np.float32)
>>> float(g.reference_eval({"img": x})["out"][0, 0])
3.0
"""
from __future__ import annotations

import dataclasses
import functools
import inspect
import numbers
from typing import Any, Callable, Mapping, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.graph import Channel, DataflowGraph
from repro.core.transform import default_pipeline
from repro.frontend.diagnostics import (TraceControlFlowError, TraceDtypeError,
                                        TraceError, TraceLeakError,
                                        TraceShapeError, user_src)

__all__ = [
    "Plane", "InputSpec", "trace", "dataflow_fn", "DataflowFunction",
    "PointFn", "pointfn",
    # canonical elementwise op library (stable fingerprints)
    "add", "sub", "mul", "div", "square", "neg",
    "offset", "scale", "subc", "rsub", "divc", "rdiv", "powc",
]


# ----------------------------------------------------------------------
# canonical elementwise ops: every traced operator maps onto exactly one
# of these, so structurally equal programs yield equal stage
# fingerprints (see graph._fn_fingerprint).  The hand-built Table-I
# oracle graphs (repro.core.handbuilt) use the same objects.
# ----------------------------------------------------------------------
def add(a, b): return a + b            # noqa: E704
def sub(a, b): return a - b            # noqa: E704
def mul(a, b): return a * b            # noqa: E704
def div(a, b): return a / b            # noqa: E704
def square(a): return a * a            # noqa: E704
def neg(a): return -a                  # noqa: E704
def _pow2(a, b): return a ** b         # noqa: E704


def offset(c):
    """``v + c`` with the scalar folded into the stage (exact closure)."""
    def fn(v): return v + c            # noqa: E704
    return fn


def scale(c):
    """``v * c`` — the paper's constant-coefficient multiply."""
    def fn(v): return v * c            # noqa: E704
    return fn


def subc(c):
    def fn(v): return v - c            # noqa: E704
    return fn


def rsub(c):
    def fn(v): return c - v            # noqa: E704
    return fn


def divc(c):
    def fn(v): return v / c            # noqa: E704
    return fn


def rdiv(c):
    def fn(v): return c / v            # noqa: E704
    return fn


def powc(c):
    def fn(v): return v ** c           # noqa: E704
    return fn


def rpowc(c):
    def fn(v): return c ** v           # noqa: E704
    return fn


def _lt(a, b): return a < b            # noqa: E704
def _le(a, b): return a <= b           # noqa: E704
def _gt(a, b): return a > b            # noqa: E704
def _ge(a, b): return a >= b           # noqa: E704
def _eq(a, b): return a == b           # noqa: E704
def _ne(a, b): return a != b           # noqa: E704


def _cmpc(op: str, c):
    if op == "lt":
        def fn(v): return v < c        # noqa: E704
    elif op == "le":
        def fn(v): return v <= c       # noqa: E704
    elif op == "gt":
        def fn(v): return v > c        # noqa: E704
    elif op == "ge":
        def fn(v): return v >= c       # noqa: E704
    elif op == "eq":
        def fn(v): return v == c       # noqa: E704
    else:
        def fn(v): return v != c       # noqa: E704
    return fn


def _and(a, b): return a & b           # noqa: E704
def _or(a, b): return a | b            # noqa: E704
def _xor(a, b): return a ^ b           # noqa: E704
def _invert(a): return ~a              # noqa: E704
def _identity(a): return a             # noqa: E704


# ----------------------------------------------------------------------
# Plane: the traced value
# ----------------------------------------------------------------------
class Plane:
    """A traced array value (the paper's *virtual image*).

    Planes are produced by :func:`trace` (one per graph input) and by
    every frontend op; each arithmetic operator on a Plane records a
    ``point``/``pointN`` stage.  Planes are symbolic — they have a
    shape and dtype but no data, so anything that would need a
    concrete value (``if plane:``, ``float(plane)``, ``np.asarray``)
    raises a :class:`~repro.frontend.diagnostics.TraceError` pointing
    at the offending user source line.
    """

    #: defeat NumPy's elementwise dispatch so ``ndarray <op> Plane``
    #: reaches our reflected operators (and fails loudly there)
    __array_priority__ = 1000
    __array_ufunc__ = None
    __slots__ = ("tracer", "channel")

    def __init__(self, tracer: "_Tracer", channel: Channel):
        self.tracer = tracer
        self.channel = channel

    # -- metadata ------------------------------------------------------
    @property
    def shape(self) -> tuple[int, ...]:
        return self.channel.shape

    @property
    def dtype(self):
        return self.channel.dtype

    @property
    def ndim(self) -> int:
        return len(self.channel.shape)

    def __repr__(self) -> str:
        return (f"Plane({self.channel.name}, shape={self.shape}, "
                f"dtype={np.dtype(self.dtype).name})")

    def astype(self, dtype) -> "Plane":
        """Record an elementwise cast to ``dtype``."""
        if np.dtype(dtype) == np.dtype(self.dtype):
            return self
        return self.tracer.point(self, _identity, key=("cast",),
                                 dtype=dtype)

    # -- arithmetic ----------------------------------------------------
    # Reflected dunders only ever see non-Plane operands (Plane-Plane
    # dispatch always resolves on the left), so each one just names the
    # scalar-closure factory for its orientation.
    def __add__(self, other):
        return self._arith("add", other, add, offset, fold_const=0.0)

    __radd__ = __add__                  # + is commutative

    def __sub__(self, other):
        return self._arith("sub", other, sub, subc, fold_const=0.0)

    def __rsub__(self, other):
        return self._arith("rsub", other, None, rsub)

    def __mul__(self, other):
        return self._arith("mul", other, mul, scale, fold_const=1.0,
                           same_fn=square)

    __rmul__ = __mul__                  # * is commutative

    def __truediv__(self, other):
        return self._arith("div", other, div, divc, fold_const=1.0,
                           inexact=True)

    def __rtruediv__(self, other):
        return self._arith("rdiv", other, None, rdiv, inexact=True)

    def __pow__(self, other):
        return self._arith("pow", other, _pow2, powc, fold_const=1.0)

    def __rpow__(self, other):
        return self._arith("rpow", other, None, rpowc)

    def __neg__(self):
        return self.tracer.point(self, neg, key=("neg",))

    def __abs__(self):
        return self.tracer.point(self, jnp.abs, key=("abs",))

    # -- comparisons (record bool planes for fe.where) -----------------
    def __lt__(self, other): return self._compare("lt", other, _lt)   # noqa: E704
    def __le__(self, other): return self._compare("le", other, _le)   # noqa: E704
    def __gt__(self, other): return self._compare("gt", other, _gt)   # noqa: E704
    def __ge__(self, other): return self._compare("ge", other, _ge)   # noqa: E704
    def __eq__(self, other): return self._compare("eq", other, _eq)   # noqa: E704
    def __ne__(self, other): return self._compare("ne", other, _ne)   # noqa: E704
    __hash__ = None   # planes compare symbolically; they are not keys

    # -- boolean planes ------------------------------------------------
    def __and__(self, other): return self._logical("and", other, _and)  # noqa: E704
    __rand__ = __and__

    def __or__(self, other): return self._logical("or", other, _or)     # noqa: E704
    __ror__ = __or__

    def __xor__(self, other): return self._logical("xor", other, _xor)  # noqa: E704
    __rxor__ = __xor__

    def __invert__(self):
        self._require_bool("~")
        return self.tracer.point(self, _invert, key=("invert",))

    # -- things a symbolic value cannot do -----------------------------
    def __bool__(self):
        raise TraceControlFlowError(
            f"Python control flow on traced {self!r}: `if`/`while`/"
            f"`and`/`or` would make the dataflow graph data-dependent. "
            f"Use fe.where(cond, a, b) to select values elementwise",
            user_src())

    def __iter__(self):
        raise TraceControlFlowError(
            f"cannot iterate over traced {self!r}: per-element access "
            f"is data-dependent control flow. Use fe.window for "
            f"neighborhoods or fe.reduce for aggregation", user_src())

    def __len__(self):
        raise TraceControlFlowError(
            f"len() of traced {self!r} is a concrete-value escape; use "
            f".shape instead", user_src())

    def __float__(self):
        raise TraceControlFlowError(
            f"float() would force traced {self!r} to a concrete value "
            f"at trace time; reduce it to a graph output instead",
            user_src())

    __int__ = __float__
    __index__ = __float__

    def __getitem__(self, idx):
        raise TraceLeakError(
            f"traced {self!r} has no element indexing; the dataflow "
            f"form only streams whole planes. Use fe.window(x, (kh, kw),"
            f" fn) for neighborhoods", user_src())

    def __array__(self, *a, **k):
        raise TraceLeakError(
            f"traced {self!r} leaked into NumPy (np.asarray or a NumPy "
            f"ufunc). Keep traced code inside fe ops, or wrap the array"
            f" function with fe.custom", user_src())

    # -- shared recording helpers --------------------------------------
    def _arith(self, opname: str, other, pair_fn: Callable | None,
               const_fac: Callable, fold_const: float | None = None,
               same_fn: Callable | None = None, inexact: bool = False):
        self._require_number(opname)
        if isinstance(other, Plane):
            if pair_fn is None:       # unreachable for reflected dunders
                raise TraceError(f"{opname}: Plane-Plane form is not "
                                 f"supported", user_src())
            other._require_number(opname)
            self.tracer.check_compatible(opname, self, other)
            dtype = _promote(self.dtype, other.dtype)
            if inexact:               # true division promotes int -> float
                dtype = _ensure_inexact(dtype)
            if (same_fn is not None and other.channel is self.channel
                    and np.dtype(dtype) == np.dtype(self.dtype)):
                return self.tracer.point(self, same_fn, key=(opname, "self"))
            return self.tracer.pointn([self, other], pair_fn,
                                      key=(opname,), dtype=dtype)
        c = _as_scalar(other)
        if c is None:
            raise TraceLeakError(
                f"{opname}: unsupported operand {type(other).__name__!r} "
                f"for a traced Plane — operands must be Planes or Python"
                f" scalars. For array constants, close over them in a "
                f"@pointfn or use fe.custom", user_src())
        # result dtype follows jnp's weak-scalar promotion (an int Plane
        # times a float scalar becomes float — plain-array semantics)
        dtype = _scalar_result_dtype(self.dtype, c)
        if inexact:
            dtype = _ensure_inexact(dtype)
        if (fold_const is not None and c == fold_const
                and np.dtype(dtype) == np.dtype(self.dtype)):
            self.tracer.log.append(
                f"fold: {opname} by {c!r} elided (identity)")
            return self
        return self.tracer.point(self, const_fac(c), key=(opname, "c", c),
                                 dtype=dtype)

    def _compare(self, opname: str, other, pair_fn: Callable):
        if isinstance(other, Plane):
            self.tracer.check_compatible(opname, self, other)
            return self.tracer.pointn([self, other], pair_fn,
                                      key=("cmp", opname),
                                      dtype=jnp.bool_)
        c = _as_scalar(other)
        if c is None:
            raise TraceLeakError(
                f"comparison {opname!r}: operand must be a Plane or a "
                f"Python scalar, got {type(other).__name__!r}", user_src())
        return self.tracer.point(self, _cmpc(opname, c),
                                 key=("cmp", opname, c), dtype=jnp.bool_)

    def _logical(self, opname: str, other, pair_fn: Callable):
        self._require_bool(opname)
        if not isinstance(other, Plane):
            raise TraceLeakError(
                f"logical {opname!r}: both operands must be bool Planes",
                user_src())
        other._require_bool(opname)
        self.tracer.check_compatible(opname, self, other)
        return self.tracer.pointn([self, other], pair_fn,
                                  key=("logical", opname),
                                  dtype=jnp.bool_)

    def _require_number(self, opname: str) -> None:
        if np.dtype(self.dtype) == np.dtype(bool):
            raise TraceDtypeError(
                f"{opname!r} on a bool Plane (a comparison result); use "
                f"fe.where(cond, a, b) to turn a mask into values",
                user_src())

    def _require_bool(self, opname: str) -> None:
        if np.dtype(self.dtype) != np.dtype(bool):
            raise TraceDtypeError(
                f"{opname!r} needs bool Planes (comparison results), got "
                f"dtype {np.dtype(self.dtype).name}", user_src())


def _promote(a, b):
    """Result dtype of a binary op, preserving the operand's dtype
    *object* when both agree (channel dtypes feed stage fingerprints,
    so ``jnp.float32`` must not silently become ``np.dtype('float32')``
    between a traced graph and its hand-built twin)."""
    if np.dtype(a) == np.dtype(b):
        return a
    return np.promote_types(np.dtype(a), np.dtype(b))


def _ensure_inexact(dtype):
    """Promote integer/bool dtypes to the default float (true division)."""
    if np.issubdtype(np.dtype(dtype), np.inexact):
        return dtype
    return jnp.float32


def _scalar_result_dtype(dtype, c):
    """Plane-dtype after an op with a Python scalar, jnp weak-type
    style: a float scalar promotes integer planes to the default
    float; otherwise the plane's dtype (object included) is kept."""
    if isinstance(c, float) and not np.issubdtype(np.dtype(dtype),
                                                  np.inexact):
        return jnp.float32
    return dtype


def _as_scalar(v) -> int | float | None:
    """Python/NumPy scalar -> int/float (intness preserved — it feeds
    dtype promotion), else None (not a scalar)."""
    if isinstance(v, (bool, np.bool_)):
        return None
    if isinstance(v, numbers.Integral):
        return int(v)
    if isinstance(v, numbers.Real):
        return float(v)
    if isinstance(v, np.ndarray) and v.ndim == 0:
        item = v.item()
        return item if isinstance(item, (int, float)) else None
    return None


# ----------------------------------------------------------------------
# the tracer context
# ----------------------------------------------------------------------
class _Tracer:
    """Records stages into a graph; owns the CSE memo and the log."""

    def __init__(self, graph: DataflowGraph, cse: bool = True):
        self.graph = graph
        self.cse = cse
        self.memo: dict[tuple, Plane] = {}
        self.log: list[str] = []
        self.finished = False

    # -- inputs --------------------------------------------------------
    def new_input(self, name: str, shape: Sequence[int], dtype) -> Plane:
        return Plane(self, self.graph.input(name, tuple(shape), dtype))

    # -- validation helpers --------------------------------------------
    def check_alive(self) -> None:
        if self.finished:
            raise TraceError(
                "this Plane's trace already finished — Planes do not "
                "outlive their trace() call", user_src())

    def check_same_trace(self, opname: str, *planes: Plane) -> None:
        self.check_alive()
        for p in planes:
            if p.tracer is not self:
                raise TraceError(
                    f"{opname}: operand {p!r} belongs to a different "
                    f"trace — Planes cannot cross trace() calls",
                    user_src())

    def check_compatible(self, opname: str, *planes: Plane) -> None:
        self.check_same_trace(opname, *planes)
        shapes = {p.shape for p in planes}
        if len(shapes) > 1:
            raise TraceShapeError(
                f"{opname}: operand shapes differ: "
                + " vs ".join(str(p.shape) for p in planes), user_src())

    # -- recording -----------------------------------------------------
    def point(self, p: Plane, fn: Callable, *, key: tuple,
              dtype=None, name: str | None = None,
              ii: float = 1.0, fill: float = 8.0) -> Plane:
        return self.record("point", [p], fn, key=key, dtype=dtype,
                           name=name, ii=ii, fill=fill)

    def pointn(self, planes: list[Plane], fn: Callable, *, key: tuple,
               dtype=None, name: str | None = None,
               ii: float = 1.0, fill: float = 8.0) -> Plane:
        if len(planes) == 1:
            return self.point(planes[0], fn, key=key, dtype=dtype,
                              name=name, ii=ii, fill=fill)
        return self.record("pointN", planes, fn, key=key, dtype=dtype,
                           name=name, ii=ii, fill=fill)

    def record(self, kind: str, planes: Sequence[Plane], fn: Callable,
               *, key: tuple, window: tuple[int, int] = (1, 1),
               dtype=None, out_shape: tuple[int, ...] | None = None,
               name: str | None = None, ii: float = 1.0,
               fill: float = 8.0) -> Plane:
        """Record one single-output stage; returns its output Plane."""
        self.check_alive()
        for p in planes:
            if p.tracer is not self:
                raise TraceError(
                    f"{kind} op: operand {p!r} belongs to a different "
                    f"trace", user_src())
        src = user_src()
        dtype = dtype if dtype is not None else planes[0].dtype
        shape = tuple(out_shape) if out_shape is not None \
            else planes[0].shape
        full_key = (kind, key, tuple(id(p.channel) for p in planes),
                    window, np.dtype(dtype).name, shape)
        if self.cse and full_key in self.memo:
            hit = self.memo[full_key]
            self.log.append(
                f"cse: reused {kind} {name or key[0]} -> "
                f"channel {hit.channel.name!r}")
            return hit
        out = self.graph.channel(shape, dtype)
        self.graph.task(name or self.graph._fresh(kind), kind, fn,
                        [p.channel for p in planes], [out],
                        window=window, ii=ii, fill=fill,
                        meta={"src": src})
        plane = Plane(self, out)
        self.memo[full_key] = plane
        return plane

    def record_custom(self, planes: Sequence[Plane], fn: Callable, *,
                      out_shapes: Sequence[tuple[int, ...]],
                      out_dtypes: Sequence[Any],
                      name: str | None = None) -> tuple[Plane, ...]:
        """Record an opaque multi-output ``custom`` stage."""
        self.check_alive()
        src = user_src()
        outs = self.graph.custom([p.channel for p in planes], fn,
                                 [tuple(s) for s in out_shapes],
                                 list(out_dtypes), name=name,
                                 meta={"src": src})
        return tuple(Plane(self, ch) for ch in outs)


# ----------------------------------------------------------------------
# pointfn: lift a plain elementwise function into the traceable library
# ----------------------------------------------------------------------
class PointFn:
    """A named elementwise function usable on arrays AND on Planes.

    Called with arrays it just computes; called with Planes it records
    ONE ``point``/``pointN`` stage whose body is the undecorated
    function (``.fn``) — so the hand-built oracle graphs and the
    traced graphs share the exact same stage functions, and their
    structural signatures can match.

    >>> from repro.frontend.tracer import pointfn
    >>> @pointfn
    ... def luma(r, g, b):
    ...     return 0.299 * r + 0.587 * g + 0.114 * b
    >>> round(luma(1.0, 1.0, 1.0), 3)     # plain call: just computes
    1.0
    """

    def __init__(self, fn: Callable):
        self.fn = fn
        functools.update_wrapper(self, fn)

    def __call__(self, *args):
        planes = [a for a in args if isinstance(a, Plane)]
        if not planes:
            return self.fn(*args)
        if len(planes) != len(args):
            raise TraceError(
                f"@pointfn {self.__name__!r} called with a mix of "
                f"Planes and scalars; close over scalars in a factory "
                f"instead (def make(c): @pointfn def f(x): ... c ...)",
                user_src())
        tracer = planes[0].tracer
        tracer.check_compatible(self.__name__, *planes)
        return tracer.pointn(list(args), self.fn,
                             key=("fn", id(self.fn)), name=self.__name__)

    def __repr__(self) -> str:
        return f"pointfn({self.__name__})"


def pointfn(fn: Callable) -> PointFn:
    """Decorator form of :class:`PointFn`."""
    return PointFn(fn)


# ----------------------------------------------------------------------
# input specs + the trace entry point
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class InputSpec:
    """Shape/dtype/name of one traced input (``fe.spec(...)``)."""

    shape: tuple[int, ...]
    dtype: Any = jnp.float32
    name: str | None = None


def _as_spec(s, param_name: str) -> InputSpec:
    if isinstance(s, InputSpec):
        return InputSpec(tuple(s.shape), s.dtype, s.name or param_name)
    if isinstance(s, (tuple, list)) and all(
            isinstance(d, (int, np.integer)) for d in s):
        return InputSpec(tuple(int(d) for d in s), jnp.float32, param_name)
    if hasattr(s, "shape") and hasattr(s, "dtype"):   # array / SDS
        return InputSpec(tuple(s.shape), s.dtype, param_name)
    raise TraceError(
        f"input spec for parameter {param_name!r} must be a shape "
        f"tuple, an fe.spec(...), or an array-like with .shape/.dtype; "
        f"got {type(s).__name__!r}")


def _positional_params(fn: Callable) -> list[str]:
    sig = inspect.signature(fn)
    params = []
    for p in sig.parameters.values():
        if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD):
            params.append(p.name)
        elif p.kind is p.VAR_POSITIONAL:
            raise TraceError(
                f"cannot trace {fn.__name__!r}: *args parameters have "
                f"no fixed input arity; spell the inputs out")
    return params


def trace(fn: Callable, *specs, name: str | None = None,
          cse: bool = True, canonicalize: bool = True) -> DataflowGraph:
    """Trace ``fn`` over symbolic Planes; return its dataflow graph.

    One :class:`InputSpec` (or bare shape tuple, or array-like) per
    positional parameter of ``fn``; graph input names default to the
    parameter names.  ``fn`` returns a Plane (output name ``out``), a
    tuple of Planes (``out0``, ``out1``, …) or a ``{name: Plane}``
    dict.  With ``canonicalize=True`` (default) the returned graph has
    already been through the standard pass pipeline — auto-split,
    dead-channel elimination, point fusion — so it validates cleanly
    and its :meth:`~repro.core.graph.DataflowGraph.signature` is the
    canonical one.  ``cse=False`` disables trace-time common-
    subexpression elimination (for differential testing; results are
    bit-identical either way).

    The trace-time log (CSE hits, constant folds, pass diagnostics)
    is attached as ``graph.frontend_log``.

    >>> import numpy as np
    >>> from repro.frontend.tracer import trace
    >>> def blur_diff(img):
    ...     doubled = img * 2.0
    ...     return doubled - img
    >>> g = trace(blur_diff, (8, 128))
    >>> out = g.reference_eval({"img": np.full((8, 128), 3.0,
    ...                                        np.float32)})
    >>> float(out["out"][0, 0])
    3.0
    """
    params = _positional_params(fn)
    if len(specs) != len(params):
        raise TraceError(
            f"{fn.__name__!r} takes {len(params)} inputs "
            f"({', '.join(params)}) but {len(specs)} spec(s) were given")
    inspecs = [_as_spec(s, p) for s, p in zip(specs, params)]
    names = [s.name for s in inspecs]
    if len(set(names)) != len(names):
        raise TraceError(f"duplicate input names: {names}")

    graph = DataflowGraph(name or fn.__name__)
    tracer = _Tracer(graph, cse=cse)
    planes = [tracer.new_input(s.name, s.shape, s.dtype) for s in inspecs]
    result = fn(*planes)

    outputs = _normalize_outputs(result)
    if not outputs:
        raise TraceLeakError(
            f"traced function {fn.__name__!r} returned no outputs "
            f"(empty tuple/dict); a dataflow app must produce at least "
            f"one output plane")
    marked: set[int] = set()
    for oname, plane in outputs.items():
        if not isinstance(plane, Plane):
            raise TraceLeakError(
                f"traced function {fn.__name__!r} returned a "
                f"{type(plane).__name__!r} for output {oname!r}; every "
                f"output must be a Plane (a value computed outside the "
                f"fe ops leaked out of the trace)")
        if plane.tracer is not tracer:
            raise TraceError(
                f"output {oname!r} belongs to a different trace")
        if oname in names:
            raise TraceError(
                f"output name {oname!r} collides with an input name")
        ch = plane.channel
        if ch.is_graph_input or id(ch) in marked:
            # returning an input (or one channel under two names): give
            # the output its own producer via an identity point stage
            plane = tracer.point(plane, _identity, key=("out", oname))
            ch = plane.channel
        marked.add(id(ch))
        graph.output(ch, oname)

    tracer.finished = True
    pass_log: list[str] = []
    if canonicalize:
        graph, pass_log = default_pipeline().run(graph)
        graph.validate()
    graph.frontend_log = tracer.log + pass_log
    return graph


def _normalize_outputs(result) -> dict[str, Any]:
    if isinstance(result, Plane):
        return {"out": result}
    if isinstance(result, (tuple, list)):
        return {f"out{i}": p for i, p in enumerate(result)}
    if isinstance(result, Mapping):
        bad = [k for k in result if not isinstance(k, str)]
        if bad:
            raise TraceError(f"output dict keys must be strings: {bad}")
        return dict(result)
    raise TraceLeakError(
        f"traced function must return Plane(s) (single, tuple, or "
        f"{{name: Plane}} dict); got {type(result).__name__!r}")


# ----------------------------------------------------------------------
# @dataflow_fn: a traced function as a servable, tunable app
# ----------------------------------------------------------------------
class DataflowFunction:
    """A traced single-source program, compile-on-demand.

    Wraps a plain array function so that *calling it on arrays* runs
    it through the full FLOWER pipeline: trace → canonicalize →
    partition → lower → host app, memoized per input-shape/backend.
    The explicit steps are also exposed: :meth:`trace` (just the
    graph), :meth:`compile` (a :class:`~repro.core.host.CompiledApp`),
    and :meth:`graph_for` (the graph matching a dict of concrete
    inputs — what :meth:`repro.runtime.engine.StreamEngine.submit`
    wants).

    Decorator keywords become default ``compile_graph`` kwargs, so
    ``@dataflow_fn(backend="pallas", tune="auto")`` gives a function
    that serves and autotunes with no explicit graph, channel, or
    split construction anywhere in user code.
    """

    def __init__(self, fn: Callable, *, name: str | None = None,
                 cse: bool = True, **compile_kwargs: Any):
        self.fn = fn
        self.name = name or fn.__name__
        self.cse = cse
        self.compile_kwargs = dict(compile_kwargs)
        self._params = _positional_params(fn)
        self._graphs: dict[tuple, DataflowGraph] = {}
        self._apps: dict[tuple, Any] = {}
        #: non-primitive compile kwargs ever seen; pinned so the id()
        #: component of a memo key can never be a recycled address
        self._pinned: list[Any] = []
        functools.update_wrapper(self, fn)

    # -- graph level ---------------------------------------------------
    def trace(self, *specs) -> DataflowGraph:
        params = self._params
        if len(specs) != len(params):
            raise TraceError(
                f"{self.name!r} takes {len(params)} inputs "
                f"({', '.join(params)}); got {len(specs)} spec(s)")
        inspecs = tuple(_as_spec(s, p) for s, p in zip(specs, params))
        key = self._spec_key(inspecs)
        if key not in self._graphs:
            self._graphs[key] = trace(self.fn, *inspecs, name=self.name,
                                      cse=self.cse)
        return self._graphs[key]

    def graph_for(self, inputs: Mapping[str, Any]) -> DataflowGraph:
        """The traced graph matching a ``{input_name: array}`` dict."""
        missing = [p for p in self._params if p not in inputs]
        if missing:
            raise TraceError(
                f"{self.name!r}: missing inputs {missing}; expected "
                f"{self._params}")
        return self.trace(*[inputs[p] for p in self._params])

    # -- app level -----------------------------------------------------
    def compile(self, *specs, **overrides: Any):
        """Compile for the given input specs; memoized.

        ``overrides`` merge over the decorator's ``compile_kwargs``
        (e.g. ``backend=``, ``tune="auto"``, ``tune_cache=``).  The
        memo keys on the *spec key* (which uniquely determines the
        memoized graph), so a warm call never rehashes the graph."""
        if len(specs) != len(self._params):
            raise TraceError(
                f"{self.name!r} takes {len(self._params)} inputs "
                f"({', '.join(self._params)}); got {len(specs)} spec(s)")
        inspecs = tuple(_as_spec(s, p)
                        for s, p in zip(specs, self._params))
        kwargs = {**self.compile_kwargs, **overrides}
        key = (self._spec_key(inspecs), self._freeze(kwargs))
        if key not in self._apps:
            from repro.core.compiler import compile_graph
            self._apps[key] = compile_graph(self.trace(*inspecs),
                                            **kwargs)
        return self._apps[key]

    def __call__(self, *args, **kwargs):
        params = self._params
        bound = list(args)
        for p in params[len(args):]:
            if p not in kwargs:
                raise TraceError(
                    f"{self.name!r}: missing input {p!r}; expected "
                    f"{params}")
            bound.append(kwargs.pop(p))
        if len(bound) != len(params) or kwargs:
            raise TraceError(
                f"{self.name!r} expects inputs {params}; got "
                f"{len(bound)} positional + extras {sorted(kwargs)}")
        # pass device arrays through untouched (no host round-trip);
        # only lift bare lists/scalars so .shape/.dtype exist
        arrays = [a if hasattr(a, "shape") and hasattr(a, "dtype")
                  else np.asarray(a) for a in bound]
        app = self.compile(*arrays)
        out = app(**dict(zip(params, arrays)))
        if set(out) == {"out"}:
            return out["out"]
        return out

    def __repr__(self) -> str:
        return f"dataflow_fn({self.name})"

    @staticmethod
    def _spec_key(inspecs: Sequence[InputSpec]) -> tuple:
        return tuple((s.name, s.shape, np.dtype(s.dtype).name)
                     for s in inspecs)

    def _freeze(self, kwargs: Mapping[str, Any]) -> tuple:
        out = []
        for k in sorted(kwargs):
            v = kwargs[k]
            if not isinstance(v, (str, int, float, bool, bytes, tuple,
                                  type(None))):
                # values with a stable structural identity key by it —
                # a Backend's cache_key() or a ScheduleConfig's JSON —
                # so equal-by-value instances share one compiled app
                ck = getattr(v, "cache_key", None)
                tj = getattr(v, "to_json", None)
                if callable(ck):
                    v = f"{type(v).__name__}:{ck()}"
                elif callable(tj):
                    try:
                        import json
                        v = type(v).__name__ + json.dumps(tj(),
                                                          sort_keys=True)
                    except (TypeError, ValueError):
                        tj = None
                if not isinstance(v, str):
                    if all(v is not p for p in self._pinned):
                        self._pinned.append(v)
                    v = f"id{id(v)}"
            out.append((k, v))
        return tuple(out)


def dataflow_fn(fn: Callable | None = None, **kwargs: Any):
    """Decorate a plain array function into a :class:`DataflowFunction`.

    Bare (``@dataflow_fn``) or configured
    (``@dataflow_fn(backend="xla", tune="auto")``).
    """
    if fn is None:
        return lambda f: DataflowFunction(f, **kwargs)
    return DataflowFunction(fn, **kwargs)
