"""The streaming serving engine: an XRT-style command queue in software.

FLOWER's generated host code sets up an XRT context, buffers and a
command queue and overlaps H2D / kernel / D2H.  This module is that
runtime layer for compiled dataflow apps, grown into a long-lived
service:

- **command queue** — a *bounded* FIFO of :class:`StreamRequest`; a
  full queue exerts backpressure on ``submit`` exactly like a finite
  FIFO in :func:`repro.core.simulate.simulate_pipeline` (block, or
  raise :class:`QueueFullError` when ``block=False``).
- **compile cache** — ``submit`` accepts raw graphs; repeated
  topologies hit :class:`~repro.runtime.cache.CompileCache` instead
  of re-tracing.
- **micro-batching** — consecutive same-signature requests are
  stacked and launched as ONE vmapped kernel with donated staging
  buffers (:class:`~repro.runtime.batching.MicroBatcher`).
- **double-buffered dispatch** — launches go into a
  :class:`~repro.runtime.slots.SlotPool` of in-flight slots (default
  2 == depth-2 FIFO).  The engine only forces a batch to host memory
  when the pool is full or the queue idles, so batch k+1 is dispatched
  while batch k is still executing — ``jax.block_until_ready``-free
  pipelining on JAX's async dispatch.
- **telemetry** — queue depth, p50/p99 latency, throughput and cache
  hit-rate, reported side-by-side with the Fig. 1
  :func:`~repro.core.simulate.analytic_latency` prediction
  (:meth:`StreamEngine.report`).
"""
from __future__ import annotations

import queue as _queue
import threading
import time
from collections import deque
from typing import Any, Mapping

import numpy as np

from repro.core.graph import DataflowGraph
from repro.core.host import CompiledApp
from repro.runtime.batching import MicroBatcher
from repro.runtime.cache import CompileCache
from repro.runtime.slots import SlotPool
from repro.runtime.telemetry import Telemetry, modeled_latency

__all__ = ["QueueFullError", "StreamRequest", "StreamEngine"]


class QueueFullError(RuntimeError):
    """The bounded request queue rejected a non-blocking submit."""


class StreamRequest:
    """Future-like handle for one submitted request."""

    def __init__(self, app: CompiledApp, inputs: Mapping[str, Any]):
        self.app = app
        self.inputs = dict(inputs)
        self.t_submit = time.perf_counter()
        self._done = threading.Event()
        self._result: dict[str, np.ndarray] | None = None
        self._error: BaseException | None = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: float | None = None) -> dict[str, np.ndarray]:
        """Block until served; return per-output host arrays."""
        if not self._done.wait(timeout):
            raise TimeoutError("request not served within timeout")
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result

    def exception(self, timeout: float | None = None) -> BaseException | None:
        if not self._done.wait(timeout):
            raise TimeoutError("request not served within timeout")
        return self._error

    # engine-side completion
    def _finish(self, result: dict[str, np.ndarray]) -> None:
        self._result = result
        self._done.set()

    def _fail(self, err: BaseException) -> None:
        self._error = err
        self._done.set()


class StreamEngine:
    """Long-lived serving engine for compiled dataflow apps.

    Usage::

        with StreamEngine(backend="pallas", max_batch=8) as eng:
            handles = [eng.submit(graph, {"x": img}) for img in imgs]
            results = [h.result() for h in handles]
            print(eng.report())

    ``max_queue`` is the FIFO depth of the request queue (the
    backpressure bound), ``max_batch`` the micro-batch width,
    ``inflight`` the number of outstanding kernel launches (2 ==
    double buffering).  ``replicas=k`` shards every padded micro-batch
    across k devices — the batch-parallel farm: each device runs one
    full pipeline replica on ``max_batch/k`` rows, and the report shows
    measured per-replica throughput next to the model's predicted
    linear scaling.  Extra keyword arguments are forwarded to
    :func:`repro.core.compiler.compile_graph` on cache misses —
    notably ``tune="auto"`` (plus an optional ``tune_cache=``), which
    makes the engine serve every topology at its *measured* schedule:
    the first submit of an app either loads the persistent
    :class:`~repro.tune.store.TuningCache` or runs the profile-guided
    search once, and all later submits reuse the tuned compiled app
    through the :class:`~repro.runtime.cache.CompileCache` — serving
    warm-starts at the tuned operating point with zero per-request
    measurement.  ``report()`` carries each app's tile provenance
    (``model`` / ``measured`` / ``cache``) so an operator can tell
    which regime a serving schedule came from.
    """

    def __init__(self, *, backend: str = "pallas", max_queue: int = 64,
                 max_batch: int = 8, inflight: int = 2, donate: bool = True,
                 replicas: int = 1,
                 cache: CompileCache | None = None,
                 telemetry: Telemetry | None = None,
                 poll_interval: float = 0.005, linger: float = 0.002,
                 autostart: bool = True, **compile_kwargs: Any):
        self.backend = backend
        self.max_queue = max_queue
        self.max_batch = max_batch
        self.replicas = replicas
        self.cache = cache or CompileCache()
        self.telemetry = telemetry or Telemetry()
        self.telemetry.replicas = replicas
        self._compile_kwargs = compile_kwargs
        self._queue: _queue.Queue[StreamRequest] = _queue.Queue(max_queue)
        self._carry: deque[StreamRequest] = deque()
        self._pool = SlotPool(inflight)
        self._batcher = MicroBatcher(max_batch=max_batch, donate=donate,
                                     replicas=replicas)
        self._apps: dict[str, CompiledApp] = {}
        self._poll = poll_interval
        self._linger = linger
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        if autostart:
            self.start()

    # ------------------------------------------------------------------
    # client side
    # ------------------------------------------------------------------
    def submit(self, graph: DataflowGraph | CompiledApp,
               inputs: Mapping[str, Any], *, block: bool = True,
               timeout: float | None = None) -> StreamRequest:
        """Enqueue one request; returns a future-like handle.

        ``graph`` may be a raw (even non-canonical) graph — it is
        compiled through the cache on this thread — or an already
        compiled app.  When the bounded queue is full, ``submit``
        blocks (bounded by ``timeout``) or, with ``block=False``,
        raises :class:`QueueFullError`: the FIFO backpressure of the
        simulator, live.
        """
        if self._stop.is_set():
            raise RuntimeError("engine is closed")
        if isinstance(graph, CompiledApp):
            app = graph
        else:
            app = self.cache.get(graph, backend=self.backend,
                                 **self._compile_kwargs)
        self._apps.setdefault(app.signature(), app)
        # validate on admission: a malformed request must fail ITS
        # submit, not poison the micro-batch it would have joined
        for ch in app.graph.graph_inputs:
            if ch.name not in inputs:
                raise ValueError(f"missing graph input {ch.name!r}")
            got = tuple(np.shape(inputs[ch.name]))
            if got != ch.shape:
                raise ValueError(f"input {ch.name!r}: expected shape "
                                 f"{ch.shape}, got {got}")
        req = StreamRequest(app, inputs)
        depth = self._queue.qsize()
        try:
            self._queue.put(req, block=block, timeout=timeout)
        except _queue.Full:
            raise QueueFullError(
                f"request queue at FIFO depth {self.max_queue}; "
                f"retry with block=True or raise max_queue") from None
        # only successful admissions count as submitted
        self.telemetry.observe_submit(depth)
        if self._stop.is_set() and (self._thread is None
                                    or not self._thread.is_alive()):
            # raced a concurrent close(): the worker is gone and will
            # never drain this request — fail it instead of hanging
            self._fail_all(RuntimeError("engine closed"))
        return req

    def report(self, n_items: int | None = None) -> dict[str, Any]:
        """Measured serving metrics + Fig. 1 model, side by side."""
        n = n_items or max(1, self.telemetry.completed)
        modeled: dict[str, Any] = {}
        for sig, app in self._apps.items():
            key = app.graph.name
            if key in modeled:               # names are arbitrary labels
                key = f"{key}@{sig[:6]}"
            modeled[key] = modeled_latency(app, n, depth=self.max_queue,
                                           replicas=self.replicas)
            modeled[key]["tile_provenance"] = sorted(
                {g.tile_source for g in app.schedule.groups
                 if g.tile is not None})
        return self.telemetry.report(cache=self.cache, modeled=modeled)

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def start(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(target=self._serve,
                                            name="stream-engine",
                                            daemon=True)
            self._thread.start()

    def close(self, wait: bool = True) -> None:
        """Stop accepting requests; drain everything already queued."""
        self._stop.set()
        if wait and self._thread is not None and self._thread.is_alive():
            self._thread.join()
        if wait:
            # a submit that raced past the closed check must not hang
            self._fail_all(RuntimeError("engine closed"))

    def __enter__(self) -> "StreamEngine":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # ------------------------------------------------------------------
    # worker side
    # ------------------------------------------------------------------
    def _serve(self) -> None:
        try:
            while True:
                # only park in a poll sleep when nothing is in flight:
                # with work outstanding, an empty queue means "retire
                # now" (useful blocking work), not "sleep"
                block = not self._pool.active and not self._stop.is_set()
                batch = self._next_batch(block=block)
                if batch:
                    self._dispatch(batch)
                elif self._pool.active:
                    self._retire(self._pool.oldest())
                elif (self._stop.is_set() and self._queue.empty()
                        and not self._carry):
                    break
        except BaseException as e:  # worker must never die silently
            self._fail_all(e)
            raise

    def _take(self, timeout: float | None) -> StreamRequest | None:
        if self._carry:
            return self._carry.popleft()
        try:
            if timeout is None:
                return self._queue.get_nowait()
            return self._queue.get(timeout=timeout)
        except _queue.Empty:
            return None

    def _next_batch(self, block: bool = True) -> list[StreamRequest]:
        """Take up to ``max_batch`` same-signature requests.

        FIFO order is preserved: the first request with a different
        signature ends the batch and is carried into the next one.  A
        short ``linger`` window lets an underfull batch wait for
        arrivals (classic micro-batching latency/throughput trade);
        draining (engine closed) skips it.
        """
        first = self._take(self._poll if block else None)
        if first is None:
            return []
        batch = [first]
        sig = first.app.signature()
        deadline = (time.perf_counter() + self._linger
                    if not self._stop.is_set() else 0.0)
        while len(batch) < self.max_batch:
            wait = deadline - time.perf_counter()
            nxt = self._take(wait if wait > 0 else None)
            if nxt is None:
                break
            if nxt.app.signature() != sig:
                self._carry.append(nxt)
                break
            batch.append(nxt)
        return batch

    def _dispatch(self, batch: list[StreamRequest]) -> None:
        app = batch[0].app
        try:
            # pad to the fixed batch width: every launch of this app
            # reuses one compiled kernel shape (no ragged re-tracing)
            outs = self._batcher.launch(app, batch, pad_to=self.max_batch)
        except BaseException as e:
            for r in batch:
                r._fail(e)
            return
        self.telemetry.observe_batch(len(batch))
        if not self._pool.free_slots():
            self._retire(self._pool.oldest())         # double-buffer rotate
        self._pool.submit((batch, outs))
        self._pool.admit()

    def _retire(self, slot: int | None) -> None:
        if slot is None:
            return
        batch, outs = self._pool.retire(slot)
        host = {k: np.asarray(v) for k, v in outs.items()}  # blocks here
        now = time.perf_counter()
        for i, req in enumerate(batch):
            req._finish({k: v[i] for k, v in host.items()})
            self.telemetry.observe_completion(now - req.t_submit)

    def _fail_all(self, err: BaseException) -> None:
        while True:
            req = self._take(None)
            if req is None:
                break
            req._fail(err)
        while self._pool.active:
            batch, _ = self._pool.retire(self._pool.oldest())
            for req in batch:
                req._fail(err)
