"""The streaming serving engine: an XRT-style command queue in software.

FLOWER's generated host code sets up an XRT context, buffers and a
command queue and overlaps H2D / kernel / D2H.  This module is that
runtime layer for compiled dataflow apps, grown into a long-lived
service built around **continuous batching**: the submit→dispatch→
complete hot path never drains between launches — new work joins
while earlier work is still in flight, the streaming idiom of the
paper's dataflow machines applied to the host side.

- **per-app admission queues** — each app (signature) gets its own
  bounded FIFO; a full queue exerts backpressure on ``submit``
  exactly like a finite FIFO in
  :func:`repro.core.simulate.simulate_pipeline` (block, or raise
  :class:`QueueFullError` when ``block=False``).  Shedding is *per
  app*: one hot graph saturating its queue cannot reject or starve
  traffic for the others.
- **weighted fairness** — batches are formed across apps by
  deficit-weighted round-robin (``app_weights`` / ``set_app_weight``):
  an app with weight 2 forms two batches per cycle to a weight-1
  app's one, and every app with queued work is visited each cycle.
- **deadline-based batch formation** — a batch closes on ``max_batch``
  OR a per-request latency budget, whichever comes first.  The budget
  adapts from the observed per-batch service time (EWMA via
  :class:`~repro.runtime.telemetry.Telemetry`): a request never waits
  longer for stragglers than a fraction of the time its batch will
  take to execute.  When the device is idle the engine is
  work-conserving and dispatches immediately — batching only ever
  delays a request when there is in-flight work to overlap with.
- **bucketed, zero-copy dispatch** — batches are padded to
  power-of-two buckets (not ``max_batch``), each bucket with its own
  compiled entry, and request rows are written directly into pinned
  staging buffers (:class:`~repro.runtime.batching.MicroBatcher`).
- **continuous slot refill** — launches go into a
  :class:`~repro.runtime.slots.SlotPool` of in-flight slots.  The
  worker *reaps* slots the moment their outputs are ready (a
  non-blocking ``is_ready`` probe) and refills them with the next
  batch, so the pool never drains to a barrier; it only blocks on the
  oldest slot when every slot is busy — ``jax.block_until_ready``-free
  pipelining on JAX's async dispatch.
- **cancellation** — a caller that times out can ``cancel()`` its
  request; cancelled requests free their queue slot immediately and
  are skipped at batch formation, so an abandoned request never leaks
  capacity.
- **telemetry** — queue depth, p50/p99 latency, throughput, shed and
  cancel counts, and a per-phase breakdown of the hot path
  (queue-wait / form / stack / launch / readback), reported
  side-by-side with the Fig. 1
  :func:`~repro.core.simulate.analytic_latency` prediction
  (:meth:`StreamEngine.report`).

See ``docs/serving.md`` for the operator-facing tour of all of this.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Mapping

import numpy as np

from repro.core.graph import DataflowGraph
from repro.core.host import CompiledApp
from repro.core.vectorize import modeled_schedule_time, schedule_features
from repro.obs.drift import resolve_drift
from repro.obs.health import SLO, HealthMonitor
from repro.obs.tracer import resolve_tracer
from repro.runtime.batching import MicroBatcher
from repro.runtime.cache import CompileCache
from repro.runtime.slots import SlotPool
from repro.runtime.telemetry import (_SERVICE_ALPHA, PHASES, Telemetry,
                                     modeled_latency)

__all__ = ["QueueFullError", "CancelledError", "StreamRequest",
           "StreamEngine"]

#: adaptive formation budget = this fraction of the service-time EWMA
_BUDGET_FRACTION = 0.5
#: clamp on the adaptive formation budget (seconds)
_BUDGET_MIN_S = 1e-4
_BUDGET_MAX_S = 2e-2


class QueueFullError(RuntimeError):
    """An app's bounded request queue rejected a submit (shed)."""


class CancelledError(RuntimeError):
    """The request was cancelled by its caller before completion."""


class StreamRequest:
    """Future-like handle for one submitted request."""

    def __init__(self, app: CompiledApp, inputs: Mapping[str, Any]):
        self.app = app
        self.inputs = dict(inputs)
        self.t_submit = time.perf_counter()
        self.t_taken: float | None = None
        #: per-request correlation id, set by a *traced* engine at
        #: submit; every span of this request's life carries it
        self.trace_id: int | None = None
        self._lock = threading.Lock()
        # the wakeup Event is allocated lazily by the first waiter: a
        # request that completes before anyone blocks on it (the common
        # case under load — callers poll handles in submission order)
        # never pays for one
        self._event: threading.Event | None = None
        self._completed = False
        self._result: dict[str, np.ndarray] | None = None
        self._error: BaseException | None = None
        self._release = None          # engine hook: free queue slot on cancel

    def done(self) -> bool:
        return self._completed

    def cancelled(self) -> bool:
        """True when the request was abandoned via :meth:`cancel`."""
        return isinstance(self._error, CancelledError)

    def _wait(self, timeout: float | None) -> bool:
        if self._completed:
            return True
        with self._lock:
            if self._completed:
                return True
            if self._event is None:
                self._event = threading.Event()
            event = self._event
        return event.wait(timeout)

    def result(self, timeout: float | None = None) -> dict[str, np.ndarray]:
        """Block until served; return per-output host arrays.

        Raises :class:`TimeoutError` when ``timeout`` expires — the
        request is still queued and will be served; call
        :meth:`cancel` to abandon it without leaking its queue slot.
        """
        if not self._wait(timeout):
            raise TimeoutError("request not served within timeout; "
                               "cancel() to abandon it")
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result

    def exception(self, timeout: float | None = None) -> BaseException | None:
        if not self._wait(timeout):
            raise TimeoutError("request not served within timeout; "
                               "cancel() to abandon it")
        return self._error

    def cancel(self) -> bool:
        """Abandon a not-yet-completed request.

        Returns True if the request was cancelled (it will never
        produce a result; ``result()`` raises :class:`CancelledError`),
        False if it had already completed.  A cancelled request frees
        its queue slot immediately; if its batch is already in flight
        the computed row is simply discarded on retirement.
        """
        with self._lock:
            if self._completed:
                return False
            self._error = CancelledError("request cancelled by caller")
            self._completed = True
            if self._event is not None:
                self._event.set()
        release, self._release = self._release, None
        if release is not None:
            release(self)
        return True

    # engine-side completion (first of finish/fail/cancel wins)
    def _finish_quiet(self, result: dict[str, np.ndarray]
                      ) -> tuple[bool, "threading.Event | None"]:
        """Claim completion WITHOUT waking waiters.

        Returns ``(won, event)``; the caller must ``event.set()`` once
        its own bookkeeping (telemetry, slot release) is consistent —
        so a client that wakes from ``result()`` and immediately calls
        ``report()`` sees its own completion counted.
        """
        with self._lock:
            if self._completed:
                return False, None
            self._result = result
            self._completed = True
            return True, self._event

    def _finish(self, result: dict[str, np.ndarray]) -> bool:
        won, event = self._finish_quiet(result)
        if event is not None:
            event.set()
        return won

    def _fail(self, err: BaseException) -> bool:
        with self._lock:
            if self._completed:
                return False
            self._error = err
            self._completed = True
            if self._event is not None:
                self._event.set()
            return True


class _AppQueue:
    """One app's bounded FIFO + fairness/shed accounting."""

    __slots__ = ("app", "q", "weight", "credit", "shed", "batches",
                 "served")

    def __init__(self, app: CompiledApp, weight: float = 1.0):
        self.app = app
        self.q: deque[StreamRequest] = deque()
        self.weight = weight
        self.credit = weight
        self.shed = 0            # admissions rejected (QueueFullError)
        self.batches = 0         # batches formed for this app
        self.served = 0          # requests taken into batches


class StreamEngine:
    """Long-lived serving engine for compiled dataflow apps.

    Usage::

        with StreamEngine(backend="pallas", max_batch=8) as eng:
            handles = [eng.submit(graph, {"x": img}) for img in imgs]
            results = [h.result() for h in handles]
            print(eng.report())

    ``max_queue`` is the FIFO depth of each *per-app* request queue
    (the backpressure bound; ``max_pending`` optionally bounds the
    total across apps), ``max_batch`` the micro-batch width cap,
    ``inflight`` the number of outstanding kernel launches (2 ==
    double buffering).  ``latency_budget`` (seconds) bounds how long
    a request may wait for its batch to fill; when ``None`` the
    budget adapts from the measured per-batch service time, seeded by
    ``linger``.  ``app_weights`` maps graph names to fairness weights
    for the deficit round-robin batch former (default 1.0 each).
    ``replicas=k`` shards every padded micro-batch across k devices —
    the batch-parallel farm: each device runs one full pipeline
    replica on ``batch/k`` rows, and the report shows measured
    per-replica throughput next to the model's predicted linear
    scaling.  Extra keyword arguments are forwarded to
    :func:`repro.core.compiler.compile_graph` on cache misses —
    notably ``tune="auto"`` (plus an optional ``tune_cache=``), which
    makes the engine serve every topology at its *measured* schedule
    through the :class:`~repro.runtime.cache.CompileCache`;
    ``report()`` carries each app's tile provenance
    (``model`` / ``measured`` / ``cache``) so an operator can tell
    which regime a serving schedule came from.

    The observability plane (PR 10, ``docs/observability.md``):
    ``slo=`` sets the :class:`~repro.obs.health.SLO` that
    :meth:`health` (and a rate-limited worker-loop sweep) evaluates
    with hysteresis; ``sentinel=True`` (with ``drift=``) arms the
    :class:`~repro.obs.sentinel.DriftSentinel` that auto-refits the
    calibrated cost model when its drift statistics decay; and
    :meth:`openmetrics` / :meth:`serve_metrics` expose everything as
    an OpenMetrics scrape with stable ``backend``/``device``/``app``
    labels.
    """

    def __init__(self, *, backend="pallas", max_queue: int = 64,
                 max_batch: int = 8, inflight: int = 2, donate: bool = True,
                 replicas: int = 1,
                 cache: CompileCache | None = None,
                 telemetry: Telemetry | None = None,
                 poll_interval: float = 0.005, linger: float = 0.002,
                 latency_budget: float | None = None,
                 bucket_pad: bool = True,
                 app_weights: Mapping[str, float] | None = None,
                 max_pending: int | None = None,
                 autostart: bool = True, trace: Any = None,
                 drift: Any = None, slo: SLO | None = None,
                 sentinel: Any = None, **compile_kwargs: Any):
        from repro.backends import resolve
        #: the resolved Backend record: its donation policy and staging
        #: slack configure the MicroBatcher, its cache_key() keys every
        #: compile below
        self.backend = resolve(backend)
        self.max_queue = max_queue
        self.max_batch = max_batch
        self.max_pending = max_pending
        self.replicas = replicas
        self.latency_budget = latency_budget
        self.cache = cache or CompileCache()
        self.telemetry = telemetry or Telemetry()
        self.telemetry.replicas = replicas
        # flight recorder + drift log, both None unless asked for
        # (trace=True/Tracer/$REPRO_TRACE, drift=True/path/DriftLog/
        # $REPRO_DRIFT_LOG) — the hot path guards every emission with
        # an `is not None` check, so the untraced engine pays nothing
        self.tracer = resolve_tracer(trace)
        self.drift = resolve_drift(drift)
        self._backend_key = self.backend.cache_key()
        # SLO health monitor: always present (engine.health() must
        # answer), objectives default to the latency budget + a 5%
        # shed-rate ceiling unless the caller passes an SLO
        self._health = HealthMonitor(
            slo if slo is not None else SLO(latency_p99_s=latency_budget),
            registry=self.telemetry.registry, tracer=self.tracer)
        # drift sentinel: off unless asked (True/SentinelPolicy/instance)
        self.sentinel = self._resolve_sentinel(sentinel)
        self._metrics_server: Any = None
        self._modeled_s: dict[str, float] = {}   # sig -> modeled s/item
        self._features: dict[str, dict] = {}     # sig -> drift features
        self._launched: set[tuple[str, int]] = set()  # warm (sig, width)
        self._compile_kwargs = compile_kwargs
        self._bucket_pad = bucket_pad
        self._weights: dict[str, float] = dict(app_weights or {})
        self._cond = threading.Condition()
        self._queues: dict[str, _AppQueue] = {}     # sig -> app queue
        self._rr: deque[str] = deque()              # round-robin order
        self._pending = 0                           # queued across apps
        self._pool = SlotPool(inflight)
        # staging_depth must EXCEED inflight: a batch is staged before
        # the oldest slot is retired, so `inflight` launches can be
        # unforced while the next one stages — and on CPU a jit call
        # zero-copy aliases the numpy staging buffer, so rewriting a
        # rotation corrupts any in-flight batch still reading it.
        # The slack above `inflight` is the backend's staging policy
        # (Backend.staging_depth; seed backends keep the historical +1).
        self._batcher = MicroBatcher(max_batch=max_batch, donate=donate,
                                     replicas=replicas,
                                     staging_depth=self.backend
                                     .staging_depth(inflight),
                                     trace=self.tracer
                                     if self.tracer is not None else False,
                                     backend=self.backend)
        self._apps: dict[str, CompiledApp] = {}
        self._io_specs: dict[str, list[tuple[str, tuple]]] = {}
        self._form_obs: dict[str, Any] = {}   # worker-only scratch
        # telemetry is flushed in bulk — per-metric lock round-trips
        # on the hot path cost as much as a small batch's kernel
        self._obs: list = []
        self._obs_lock = threading.Lock()
        self._sub_count = 0
        self._sub_depths: list[int] = []
        self._service_ewma: float | None = None  # worker-local copy
        self._poll = poll_interval
        self._linger = linger                       # adaptive-budget seed
        self._form_wait = poll_interval             # next formation deadline
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        if autostart:
            self.start()

    # ------------------------------------------------------------------
    # client side
    # ------------------------------------------------------------------
    def submit(self, graph: DataflowGraph | CompiledApp,
               inputs: Mapping[str, Any], *, block: bool = True,
               timeout: float | None = None) -> StreamRequest:
        """Enqueue one request; returns a future-like handle.

        ``graph`` may be a raw (even non-canonical) graph — it is
        compiled through the cache on this thread — or an already
        compiled app.  When the app's bounded queue is full, ``submit``
        blocks (bounded by ``timeout``) or, with ``block=False``,
        raises :class:`QueueFullError` — admission control sheds load
        for THIS app only; other apps keep their own headroom.
        """
        if self._stop.is_set():
            raise RuntimeError("engine is closed")
        if isinstance(graph, CompiledApp):
            app = graph
        elif self.tracer is not None:
            app = self.cache.get(graph, backend=self.backend,
                                 trace=self.tracer, **self._compile_kwargs)
        else:
            app = self.cache.get(graph, backend=self.backend,
                                 **self._compile_kwargs)
        sig = app.signature()
        # validate on admission: a malformed request must fail ITS
        # submit, not poison the micro-batch it would have joined
        # (the per-app (name, shape) spec is cached — the graph is
        # frozen once compiled)
        specs = self._io_specs.get(sig)
        if specs is None:
            self._apps.setdefault(sig, app)
            specs = [(ch.name, tuple(ch.shape))
                     for ch in app.graph.graph_inputs]
            self._io_specs[sig] = specs
        for name, shape in specs:
            if name not in inputs:
                raise ValueError(f"missing graph input {name!r}")
            got = getattr(inputs[name], "shape", None)
            if got != shape and tuple(np.shape(inputs[name])) != shape:
                raise ValueError(f"input {name!r}: expected shape "
                                 f"{shape}, got "
                                 f"{tuple(np.shape(inputs[name]))}")
        req = StreamRequest(app, inputs)
        if self.tracer is not None:
            req.trace_id = self.tracer.new_id()
        end = None if timeout is None else time.perf_counter() + timeout
        with self._cond:
            aq = self._queues.get(sig)
            if aq is None:
                aq = _AppQueue(app, self._weights.get(app.graph.name, 1.0))
                self._queues[sig] = aq
                self._rr.append(sig)
            while self._is_full(aq):
                if not block:
                    aq.shed += 1
                    self.telemetry.observe_shed()
                    raise QueueFullError(
                        f"app {app.graph.name!r} at FIFO depth "
                        f"{self.max_queue}; retry with block=True, raise "
                        f"max_queue, or shed load for this app")
                remaining = (None if end is None
                             else end - time.perf_counter())
                if remaining is not None and remaining <= 0:
                    aq.shed += 1
                    self.telemetry.observe_shed()
                    raise QueueFullError(
                        f"app {app.graph.name!r} still at FIFO depth "
                        f"{self.max_queue} after {timeout}s")
                self._cond.wait(remaining)
                if self._stop.is_set():
                    raise RuntimeError("engine is closed")
            req._release = self._on_cancel
            aq.q.append(req)
            self._sub_count += 1
            if len(self._sub_depths) < 100_000:
                self._sub_depths.append(self._pending)
            self._pending += 1
            self._cond.notify_all()
        if self._stop.is_set() and (self._thread is None
                                    or not self._thread.is_alive()):
            # raced a concurrent close(): the worker is gone and will
            # never drain this request — fail it instead of hanging
            self._fail_all(RuntimeError("engine closed"))
        return req

    def set_app_weight(self, name: str, weight: float) -> None:
        """Set the fairness weight for every app named ``name``."""
        with self._cond:
            self._weights[name] = weight
            for aq in self._queues.values():
                if aq.app.graph.name == name:
                    aq.weight = weight

    def report(self, n_items: int | None = None) -> dict[str, Any]:
        """Measured serving metrics + Fig. 1 model, side by side."""
        self._flush_obs()
        n = n_items or max(1, self.telemetry.completed)
        modeled: dict[str, Any] = {}
        for sig, app in self._apps.items():
            key = app.graph.name
            if key in modeled:               # names are arbitrary labels
                key = f"{key}@{sig[:6]}"
            modeled[key] = modeled_latency(app, n, depth=self.max_queue,
                                           replicas=self.replicas)
            modeled[key]["tile_provenance"] = sorted(
                {g.tile_source for g in app.schedule.groups
                 if g.tile is not None})
        out = self.telemetry.report(cache=self.cache, modeled=modeled)
        apps: dict[str, Any] = {}
        with self._cond:
            for sig, aq in self._queues.items():
                key = aq.app.graph.name
                if key in apps:
                    key = f"{key}@{sig[:6]}"
                apps[key] = {"weight": aq.weight, "queued": len(aq.q),
                             "batches": aq.batches, "served": aq.served,
                             "shed": aq.shed}
        out["apps"] = apps
        out["buckets"] = dict(self._batcher.bucket_launches)
        return out

    # ------------------------------------------------------------------
    # observability plane: health, sentinel, OpenMetrics
    # ------------------------------------------------------------------
    def _resolve_sentinel(self, sentinel: Any):
        """Normalize the ``sentinel=`` argument (None/False = off)."""
        if sentinel is None or sentinel is False:
            return None
        from repro.obs.sentinel import DriftSentinel, SentinelPolicy
        if isinstance(sentinel, DriftSentinel):
            # adopt a pre-built sentinel into this engine's telemetry
            # plane (unless the caller already wired its own sinks) so
            # its checks/refits land in the same exposition
            if sentinel.registry is None:
                sentinel.registry = self.telemetry.registry
            if sentinel.tracer is None:
                sentinel.tracer = self.tracer
            return sentinel
        if self.drift is None:
            raise ValueError("sentinel= needs drift rows: pass drift=True "
                             "(or a path/DriftLog) alongside it")
        policy = sentinel if isinstance(sentinel, SentinelPolicy) else None
        if not (sentinel is True or policy is not None):
            raise TypeError(f"sentinel must be True/False/None, a "
                            f"SentinelPolicy or a DriftSentinel; got "
                            f"{sentinel!r}")
        return DriftSentinel(self.drift, self.backend, policy=policy,
                             registry=self.telemetry.registry,
                             tracer=self.tracer)

    def health(self) -> dict[str, Any]:
        """Evaluate the SLOs now; returns the health verdict.

        ``{"state": "healthy" | "degraded" | "breach", "violated":
        [...], "objectives": {...}}`` — see
        :class:`~repro.obs.health.HealthMonitor`.  The worker also
        evaluates periodically while serving, so state transitions
        land in the tracer/registry even if nobody polls this.
        """
        self._flush_obs()
        stats = self.cache.stats
        hit_rate = stats.hit_rate if stats.requests else None
        with self._cond:
            qd = self._pending
        return self._health.evaluate(
            submitted=self.telemetry.submitted, shed=self.telemetry.shed,
            queue_depth=qd, cache_hit_rate=hit_rate)

    def _periodic(self) -> None:
        """Idle-loop upkeep: rate-limited health + sentinel sweeps.

        Failures here must never take the worker down with them — a
        sentinel refit hitting a torn store is telemetry's problem,
        not the serving path's.
        """
        try:
            stats = self.cache.stats
            self._health.maybe_evaluate(
                submitted=self.telemetry.submitted,
                shed=self.telemetry.shed, queue_depth=self._pending,
                cache_hit_rate=(stats.hit_rate if stats.requests
                                else None))
            if self.sentinel is not None:
                self.sentinel.poll()
        except Exception:
            if self.tracer is not None:
                self.tracer.instant("obs.periodic_error", cat="health")

    def metric_families(self) -> dict[str, Any]:
        """The engine's full exposition, as typed metric families.

        Everything in the telemetry registry (latency/queue/batch
        summaries, phase histograms folded into one ``phase_seconds``
        family with a ``phase`` label, health/sentinel counters) plus
        per-app admission counters and per-bucket launch counts — all
        stamped with the stable identity labels ``backend`` (the
        resolved backend's ``cache_key()``) and ``device`` kind.
        """
        from repro.obs.exporter import MetricFamily, registry_families
        from repro.tune.store import detect_device_kind
        self._flush_obs()
        base = {"backend": self._backend_key,
                "device": detect_device_kind()}
        rules = {f"phase_{p}_s": ("phase_seconds", {"phase": p})
                 for p in PHASES}
        fams = registry_families(self.telemetry.registry, labels=base,
                                 rules=rules)
        app_gauge = MetricFamily("repro_app_queued", "gauge",
                                 "requests queued per app")
        app_weight = MetricFamily("repro_app_weight", "gauge",
                                  "fairness weight per app")
        app_served = MetricFamily("repro_app_served", "counter",
                                  "requests taken into batches per app")
        app_shed = MetricFamily("repro_app_shed", "counter",
                                "admissions rejected per app")
        app_batches = MetricFamily("repro_app_batches", "counter",
                                   "batches formed per app")
        with self._cond:
            rows = [(aq.app.graph.name, sig, len(aq.q), aq.weight,
                     aq.served, aq.shed, aq.batches)
                    for sig, aq in self._queues.items()]
        for name, sig, queued, weight, served, shed, batches in rows:
            labels = dict(base, app=name, signature=sig[:12])
            app_gauge.add(queued, labels)
            app_weight.add(weight, labels)
            app_served.add(served, labels, "_total")
            app_shed.add(shed, labels, "_total")
            app_batches.add(batches, labels, "_total")
        buckets = MetricFamily("repro_bucket_launches", "counter",
                               "kernel launches per padded batch width")
        for width, n in sorted(self._batcher.bucket_launches.items()):
            buckets.add(n, dict(base, width=width), "_total")
        for fam in (app_gauge, app_weight, app_served, app_shed,
                    app_batches, buckets):
            if fam.samples:
                fams[fam.name] = fam
        if self.drift is not None and self.drift.max_rows is not None:
            rot = MetricFamily("repro_drift_rotated_rows", "counter",
                               "drift rows retired by log rotation")
            rot.add(self.drift.rotated_rows, base, "_total")
            fams[rot.name] = rot
        return fams

    def openmetrics(self) -> str:
        """The live OpenMetrics/Prometheus exposition text."""
        from repro.obs.exporter import render_openmetrics
        return render_openmetrics(self.metric_families())

    def serve_metrics(self, *, host: str = "127.0.0.1", port: int = 0):
        """Start (or return) the scrape endpoint for this engine.

        Returns the :class:`~repro.obs.exporter.MetricsHTTPServer`;
        its ``.url`` is what a Prometheus scrape config points at.
        The endpoint dies with the engine (``close()``).
        """
        if self._metrics_server is None:
            from repro.obs.exporter import MetricsHTTPServer
            self._metrics_server = MetricsHTTPServer(self.openmetrics,
                                                     host=host, port=port)
        return self._metrics_server

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def start(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._thread = threading.Thread(target=self._serve,
                                            name="stream-engine",
                                            daemon=True)
            self._thread.start()

    def close(self, wait: bool = True) -> None:
        """Stop accepting requests; drain everything already queued."""
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        if wait and self._thread is not None and self._thread.is_alive():
            self._thread.join()
        if wait:
            # a submit that raced past the closed check must not hang
            self._fail_all(RuntimeError("engine closed"))
        if self.drift is not None:
            self.drift.flush()
        if self._metrics_server is not None:
            self._metrics_server.close()
            self._metrics_server = None

    def __enter__(self) -> "StreamEngine":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()

    # ------------------------------------------------------------------
    # worker side: reap → form → dispatch, continuously
    # ------------------------------------------------------------------
    def _serve(self) -> None:
        try:
            while True:
                self._reap()                   # free completed slots now
                batch = self._form_batch()
                if batch:
                    self._dispatch(batch)
                    continue
                if self._pool.active and (self._pending == 0
                                          or self._stop.is_set()
                                          or not self._pool.free_slots()):
                    # nothing formable: finishing in-flight work is the
                    # only useful blocking thing left to do
                    self._retire(self._pool.oldest())
                    continue
                if (self._stop.is_set() and self._pending == 0
                        and not self._pool.active):
                    break
                self._flush_obs()      # idle: sync deferred telemetry
                self._periodic()       # rate-limited health + sentinel
                self._wait_for_work()
        except BaseException as e:  # worker must never die silently
            self._fail_all(e)
            raise
        finally:
            self._flush_obs()

    def _flush_obs(self) -> None:
        """Push buffered hot-path observations into shared telemetry.

        The worker buffers per-batch/per-submit observations locally
        (see ``_obs``) and flushes when idle, on backlog, and on
        shutdown; ``report()`` flushes too, so readers always see
        current numbers.  Safe from any thread.
        """
        with self._obs_lock:
            entries, self._obs = self._obs, []
        if entries:
            self.telemetry.observe_batches(entries)
        with self._cond:
            count, self._sub_count = self._sub_count, 0
            depths, self._sub_depths = self._sub_depths, []
        if count:
            self.telemetry.observe_submits(count, depths)

    def _is_full(self, aq: _AppQueue) -> bool:
        return (len(aq.q) >= self.max_queue
                or (self.max_pending is not None
                    and self._pending >= self.max_pending))

    def _form_budget(self) -> float:
        """Max time a request may wait for its batch to fill (seconds).

        Explicit ``latency_budget`` wins; otherwise adapt to a
        fraction of the observed per-batch service time — batching is
        only worth delaying a request for when the batch it joins
        amortizes more than that delay.
        """
        if self.latency_budget is not None:
            return self.latency_budget
        s = self._service_ewma          # worker-local: no lock on this path
        if s is None:
            return self._linger
        return min(max(_BUDGET_FRACTION * s, _BUDGET_MIN_S), _BUDGET_MAX_S)

    def _pick_app(self) -> _AppQueue | None:
        """Deficit-weighted round-robin over apps with queued work.

        Called under ``_cond``.  Each selection costs one credit;
        credits replenish by ``weight`` when no queued app can pay,
        so an app with weight w forms w batches per replenish cycle
        and every queued app is visited each cycle (no starvation).
        """
        live = [s for s in self._rr if self._queues[s].q]
        if not live:
            return None
        if len(live) == 1:                    # single-tenant fast path
            return self._queues[live[0]]
        for _round in range(2):
            for _ in range(len(self._rr)):
                sig = self._rr[0]
                self._rr.rotate(-1)
                aq = self._queues[sig]
                if aq.q and aq.credit >= 1.0:
                    return aq
            for q in self._queues.values():   # weighted replenish
                q.credit = min(q.credit + q.weight, max(q.weight, 1.0))
        return self._queues[live[0]]          # weight<=0 guard: plain FIFO

    def _form_batch(self) -> list[StreamRequest]:
        """Deadline-based batch formation (the continuous-batching core).

        Close a batch when it is full, the engine is draining, the
        oldest request has spent its formation budget, or the device
        is idle (work-conserving: never hold work back when there is
        nothing to overlap it with).  Otherwise leave the batch *open*
        — arriving same-app requests keep joining it — and tell the
        worker when the deadline lands.
        """
        now = time.perf_counter()
        with self._cond:
            aq = self._pick_app()
            if aq is None:
                self._form_wait = self._poll
                return []
            budget = self._form_budget()
            oldest_age = now - aq.q[0].t_submit
            if not (len(aq.q) >= self.max_batch or self._stop.is_set()
                    or oldest_age >= budget or self._pool.active == 0):
                self._form_wait = max(1e-5, budget - oldest_age)
                return []
            aq.credit = max(0.0, aq.credit - 1.0)
            batch: list[StreamRequest] = []
            while aq.q and len(batch) < self.max_batch:
                r = aq.q.popleft()
                self._pending -= 1
                if r.done():         # cancelled while queued (lost race)
                    continue
                r.t_taken = now
                batch.append(r)
            if batch:
                aq.batches += 1
                aq.served += len(batch)
            self._cond.notify_all()  # queue space freed: wake submitters
        if batch:
            # stashed for _dispatch to merge into ONE telemetry update
            # per batch (worker-thread-only scratch, no race)
            self._form_obs = {
                "queue_wait": [r.t_taken - r.t_submit for r in batch],
                "form": now - batch[0].t_submit,
            }
        return batch

    def _dispatch(self, batch: list[StreamRequest]) -> None:
        app = batch[0].app
        timings: dict[str, float] = {}
        try:
            # pad to the power-of-two bucket (or the fixed max_batch
            # width with bucket_pad=False): a 2-request batch launches
            # a 2-wide kernel, not a 32-wide one
            outs = self._batcher.launch(
                app, batch,
                pad_to=None if self._bucket_pad else self.max_batch,
                timings=timings, check_shapes=False)
        except BaseException as e:
            for r in batch:
                r._fail(e)
            return
        t_disp = time.perf_counter()
        self._form_obs.update(timings)
        with self._obs_lock:
            self._obs.append((t_disp, len(batch), self._form_obs,
                              None, None))
        self._form_obs = {}
        # stage boundary stamps for the per-request trace timeline,
        # reconstructed from the batcher's phase durations so the hot
        # path takes no extra clock reads
        t_s1 = t_disp - timings.get("launch", 0.0)
        t_s0 = t_s1 - timings.get("stack", 0.0)
        if not self._pool.free_slots():
            self._retire(self._pool.oldest())     # rotate: block on oldest
        self._pool.submit((batch, outs, t_disp, (t_s0, t_s1)))
        self._pool.admit()

    def _reap(self) -> None:
        """Retire every in-flight slot whose outputs already landed.

        Non-blocking: readiness is probed via the arrays' ``is_ready``
        (host arrays count as ready).  This is what keeps the slot
        pool continuously refilled instead of draining at a barrier.
        """
        if not self._pool.active:
            return

        def _is_ready(item: Any) -> bool:
            outs = item[1]
            return all(o.is_ready() for o in outs.values()
                       if hasattr(o, "is_ready"))

        for slot in self._pool.ready(_is_ready):
            self._retire(slot)

    def _retire(self, slot: int | None) -> None:
        if slot is None:
            return
        batch, outs, t_disp, stage_ts = self._pool.retire(slot)
        t0 = time.perf_counter()
        host = {k: np.asarray(v) for k, v in outs.items()}  # blocks here
        now = time.perf_counter()
        # claim completions quietly, record them, THEN wake waiters —
        # a caller that wakes from result() and immediately reads
        # report() must see its own completion.  Requests whose claim
        # lost to cancel() have their computed row discarded.
        done: list[float] = []
        winners: list[StreamRequest] = []
        wake: list[threading.Event] = []
        for i, req in enumerate(batch):
            won, event = req._finish_quiet(
                {k: v[i] for k, v in host.items()})
            if won:
                done.append(now - req.t_submit)
                winners.append(req)
            if event is not None:
                wake.append(event)
        svc = now - t_disp
        prev = self._service_ewma
        self._service_ewma = (svc if prev is None else
                              _SERVICE_ALPHA * svc
                              + (1.0 - _SERVICE_ALPHA) * prev)
        with self._obs_lock:
            self._obs.append((now, None, {"readback": now - t0},
                              done, svc))
            backlog = len(self._obs)
        if done:
            self._health.observe_latencies(done)
        for event in wake:
            event.set()
        # trace/drift emission AFTER waking waiters: it is retroactive
        # bookkeeping reconstructed from stamps, never waiter latency
        if self.tracer is not None or self.drift is not None:
            self._record_batch(batch, winners, host, t_disp, stage_ts,
                               t0, now, svc)
        if backlog >= 64:
            self._flush_obs()

    def _record_batch(self, batch: list[StreamRequest],
                      winners: list[StreamRequest],
                      host: dict[str, np.ndarray], t_disp: float,
                      stage_ts: tuple[float, float], t0: float,
                      now: float, svc: float) -> None:
        """Emit one retired batch's trace timelines and drift row.

        Runs on the worker thread at retirement, entirely from
        timestamps captured earlier — nothing here sat on the
        submit→launch path.  Each *winning* request (cancelled ones
        produce no timeline) gets a contiguous async phase chain
        ``queue_wait → form → stack → launch → execute → readback``
        tiling exactly [t_submit, complete] under its trace id.
        """
        app = batch[0].app
        sig = app.signature()
        width = next(iter(host.values())).shape[0] if host else len(batch)
        t_s0, t_s1 = stage_ts
        tr = self.tracer
        if tr is not None:
            name = app.graph.name
            for req in winners:
                aid = req.trace_id
                if aid is None:        # submitted before tracing was on
                    continue
                tt = req.t_taken if req.t_taken is not None else t_s0
                tr.async_event("request", "b", aid, ts=req.t_submit,
                               cat="request", app=name, batch=len(batch),
                               width=width)
                tr.async_span("queue_wait", aid, req.t_submit, tt,
                              cat="request")
                tr.async_span("form", aid, tt, t_s0, cat="request")
                tr.async_span("stack", aid, t_s0, t_s1, cat="request")
                tr.async_span("launch", aid, t_s1, t_disp, cat="request")
                tr.async_span("execute", aid, t_disp, t0, cat="request")
                tr.async_span("readback", aid, t0, now, cat="request")
                tr.async_event("request", "e", aid, ts=now, cat="request")
            tr.counter("engine.inflight", self._pool.active)
        if self.drift is not None:
            modeled = self._modeled_s.get(sig)
            if modeled is None:
                modeled = self._modeled_s[sig] = modeled_schedule_time(
                    app.schedule)
                self._features[sig] = schedule_features(app.schedule)
            kind = "launch"
            if (sig, width) not in self._launched:
                self._launched.add((sig, width))
                kind = "compile"       # cold (sig, width): svc includes jit
            # the features behind `modeled * width`, so the calibration
            # fit (repro.tune.calibrate) can re-score this launch under
            # candidate constants; `compile` rows keep them too but the
            # fit excludes that kind by default (jit time pollutes svc)
            features = dict(self._features[sig])
            if width != 1:
                features["items"] = int(width)
            self.drift.record(
                kind, sig,
                [list(shape) for _n, shape in self._io_specs.get(sig, [])],
                self.backend.name, modeled * width, svc,
                app=app.graph.name, width=width, batch=len(batch),
                backend_key=self._backend_key, features=features)

    def _wait_for_work(self) -> None:
        """Park until new work arrives or the formation deadline lands."""
        with self._cond:
            if self._stop.is_set() and self._pending:
                return
            self._cond.wait(min(self._form_wait, self._poll))
        self._form_wait = self._poll

    def _on_cancel(self, req: StreamRequest) -> None:
        """Cancel hook: free the queue slot a cancelled request holds."""
        self.telemetry.observe_cancel()
        with self._cond:
            aq = self._queues.get(req.app.signature())
            if aq is None:
                return
            try:
                aq.q.remove(req)
            except ValueError:
                return               # already taken into a batch
            self._pending -= 1
            self._cond.notify_all()  # its queue slot is free right now

    def _fail_all(self, err: BaseException) -> None:
        with self._cond:
            doomed = [r for aq in self._queues.values() for r in aq.q]
            for aq in self._queues.values():
                aq.q.clear()
            self._pending = 0
            self._cond.notify_all()
        for r in doomed:
            r._fail(err)
        while self._pool.active:
            batch = self._pool.retire(self._pool.oldest())[0]
            for r in batch:
                r._fail(err)
