"""The training orchestrator: data -> step -> metrics -> checkpoints,
with preemption, straggler and elastic-restart handling.

``Trainer`` owns no model logic — it wires the generated step function
(runtime.steps), the data pipeline, the async checkpointer and the
fault machinery together; exactly the boilerplate FLOWER's host-code
generation removes from the user.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.optim.compression import ef_init
from repro.runtime.fault import PreemptionGuard, StragglerMonitor
from repro.runtime.steps import make_train_step

__all__ = ["Trainer", "TrainerConfig"]


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    log_every: int = 10
    compress_grads: bool = False
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, opt_cfg: AdamWConfig,
                 tcfg: TrainerConfig, data, mesh=None,
                 state_shardings=None):
        self.cfg, self.opt_cfg, self.tcfg = cfg, opt_cfg, tcfg
        self.data = data
        self.mesh = mesh
        self.ckpt = Checkpointer(tcfg.ckpt_dir, keep=tcfg.ckpt_keep)
        self.monitor = StragglerMonitor(n_hosts=jax.process_count())
        step_fn = make_train_step(cfg, opt_cfg, mesh=mesh,
                                  compress_grads=tcfg.compress_grads)
        jit_kw: dict[str, Any] = {"donate_argnums": (0,)}
        if state_shardings is not None:
            jit_kw["in_shardings"] = (state_shardings, None)
            jit_kw["out_shardings"] = (state_shardings, None)
        self.step_fn = jax.jit(step_fn, **jit_kw)
        self.state = self._init_or_restore(state_shardings)
        self.history: list[dict] = []

    # -- state ----------------------------------------------------------
    def _fresh_state(self):
        params = M.init(self.cfg, jax.random.PRNGKey(self.tcfg.seed))
        state = {"params": params, "opt": adamw_init(params)}
        if self.tcfg.compress_grads:
            state["ef"] = ef_init(params)
        return state

    def _init_or_restore(self, shardings):
        latest = self.ckpt.latest_step()
        if latest is None:
            return self._fresh_state()
        like = jax.eval_shape(self._fresh_state)
        state = self.ckpt.restore(like, step=latest, shardings=shardings)
        return state

    @property
    def step(self) -> int:
        return int(jax.device_get(self.state["opt"]["step"]))

    # -- loop -----------------------------------------------------------
    def run(self, steps: int | None = None) -> list[dict]:
        steps = steps if steps is not None else self.tcfg.total_steps
        with PreemptionGuard() as guard:
            while self.step < steps:
                t0 = time.perf_counter()
                batch = {k: jnp.asarray(v)
                         for k, v in self.data.batch(self.step).items()}
                self.state, metrics = self.step_fn(self.state, batch)
                metrics = {k: float(jax.device_get(v))
                           for k, v in metrics.items()}
                dt = time.perf_counter() - t0
                metrics["step_time_s"] = dt
                metrics["step"] = self.step
                self.history.append(metrics)
                flagged = self.monitor.observe(np.array([dt]))
                if flagged:
                    metrics["stragglers"] = flagged
                if self.step % self.tcfg.log_every == 0:
                    print(f"step {self.step:6d} "
                          f"loss {metrics['loss']:8.4f} "
                          f"|g| {metrics['grad_norm']:8.3f} "
                          f"lr {metrics['lr']:.2e} "
                          f"{dt*1e3:8.1f} ms")
                if self.step % self.tcfg.ckpt_every == 0:
                    self.ckpt.save(self.state, self.step)
                if guard.preempted:
                    print("preemption notice: synchronous final save")
                    self.ckpt.save(self.state, self.step, blocking=True)
                    break
        self.ckpt.wait()
        return self.history
