"""Serving telemetry: measured metrics side-by-side with the Fig. 1 model.

The latency simulator (:mod:`repro.core.simulate`) predicts what a
FIFO pipeline *should* do; a live engine measures what it *does*.
This module holds both ends: :class:`Telemetry` aggregates queue
depth, per-request latency percentiles, throughput and batch sizes
from a running :class:`~repro.runtime.engine.StreamEngine`, and
:func:`modeled_latency` produces the matching analytic + simulated
predictions for the app being served, so every engine report shows
``measured`` next to ``modeled`` — the paper's performance model
validated against live traffic instead of a synthetic sweep.

Samples live in a :class:`~repro.obs.metrics.MetricsRegistry` — one
:class:`~repro.obs.metrics.Histogram` per sample stream (latency,
queue depth, batch size, one per hot-path phase) and one
:class:`~repro.obs.metrics.Counter` per event count — instead of
private lists, so an operator can enumerate everything the engine
measures through the registry.  The histograms are **uniform
reservoirs** (deterministically seeded), not first-N buffers: a
multi-hour serving run's p99 reflects the whole run, where the old
first-``_MAX_SAMPLES`` truncation froze percentiles on whatever the
warm-up era looked like.
"""
from __future__ import annotations

import threading
import time
from typing import Any

import numpy as np

from repro.core.simulate import TaskTiming, analytic_latency, simulate_pipeline
from repro.obs.metrics import MetricsRegistry

__all__ = ["PHASES", "Telemetry", "modeled_latency"]

#: the submit→complete hot path, phase by phase: time spent queued
#: before being taken into a batch, waiting for the batch to form,
#: staging rows into the pinned batch buffers, dispatching the kernel,
#: and forcing outputs back to host memory
PHASES = ("queue_wait", "form", "stack", "launch", "readback")

#: reservoir capacity for each sample stream (latency, depths, ...)
_MAX_SAMPLES = 100_000

#: EWMA smoothing for the observed per-batch service time that drives
#: the engine's adaptive batch-formation budget
_SERVICE_ALPHA = 0.2

#: cap on items fed to the O(S*n) discrete simulator in reports
_SIM_ITEMS_CAP = 512


def modeled_latency(app: Any, n_items: int, depth: int = 2,
                    replicas: int = 1) -> dict[str, float]:
    """Fig. 1 predictions for serving ``n_items`` requests through ``app``.

    Tasks are the app's scheduled stages bracketed by the generated
    read/write (H2D/D2H) tasks, exactly as the fusion cost model
    scores them; ``depth`` is the FIFO depth of the engine's bounded
    queues.  Returns the closed-form ``sequential`` / ``dataflow``
    cycles plus the finite-depth discrete simulation
    (``dataflow_sim``), so backpressure effects are visible too.

    ``replicas > 1`` adds the batch-parallel-farm prediction: k
    identical pipelines each drain ``ceil(n/k)`` items, so the
    replicated latency is the dataflow latency of the per-replica
    share — linear scaling in the drain term (the farm's workers
    share no channels), with the fill paid once per replica in
    parallel.  ``replica_scaling_modeled`` is the predicted speedup of
    the farm over one replica.
    """
    tasks = ([TaskTiming("read", ii=1.0, fill=32.0)]
             + [TaskTiming(s.name, ii=s.ii, fill=s.fill)
                for s in app.schedule.order]
             + [TaskTiming("write", ii=1.0, fill=32.0)])
    n = max(1, n_items)
    out = dict(analytic_latency(tasks, n))
    sim = simulate_pipeline(tasks, min(n, _SIM_ITEMS_CAP),
                            depth=max(1, depth))
    out["dataflow_sim"] = sim["dataflow_sim"]
    if replicas > 1:
        per_replica = -(-n // replicas)
        out["dataflow_replicated"] = analytic_latency(
            tasks, per_replica)["dataflow"]
        out["replica_scaling_modeled"] = (out["dataflow"]
                                          / out["dataflow_replicated"])
    return out


class Telemetry:
    """Thread-safe metric aggregation for a serving engine.

    All samples and counters live in ``self.registry`` (a
    :class:`~repro.obs.metrics.MetricsRegistry`, shareable across
    components); :class:`Telemetry` keeps only the EWMA state and the
    first/last completion stamps that throughput needs.  Metric names:
    ``latency_s``, ``queue_depth``, ``batch_size``, ``phase_<p>_s``
    (histograms) and ``submitted`` / ``completed`` / ``shed`` /
    ``cancelled`` (counters) — the same values the snapshot reports,
    queryable individually.
    """

    def __init__(self, registry: MetricsRegistry | None = None,
                 max_samples: int = _MAX_SAMPLES, seed: int = 0) -> None:
        self._lock = threading.Lock()
        self.registry = registry if registry is not None else MetricsRegistry()
        reg, cap = self.registry, max_samples
        self._latency = reg.histogram("latency_s", cap, seed)
        self._queue_depth = reg.histogram("queue_depth", cap, seed)
        self._batch_size = reg.histogram("batch_size", cap, seed)
        self._phases = {p: reg.histogram(f"phase_{p}_s", cap, seed)
                        for p in PHASES}
        self._max_samples = cap
        self._seed = seed
        self._c_submitted = reg.counter("submitted")
        self._c_completed = reg.counter("completed")
        self._c_shed = reg.counter("shed")
        self._c_cancelled = reg.counter("cancelled")
        self._service_ewma_s: float | None = None
        self._t_first: float | None = None
        self._t_last: float | None = None
        #: device-farm width the served throughput is spread over;
        #: owned by the engine (it sets this to its ``replicas``) so
        #: reports show per-replica throughput next to the modeled
        #: linear scaling
        self.replicas = 1

    # -- counters (registry-backed, read like plain attributes) --------
    @property
    def submitted(self) -> int:
        return self._c_submitted.value

    @property
    def completed(self) -> int:
        return self._c_completed.value

    @property
    def shed(self) -> int:
        return self._c_shed.value

    @property
    def cancelled(self) -> int:
        return self._c_cancelled.value

    # -- observation hooks ---------------------------------------------
    def observe_submit(self, queue_depth: int) -> None:
        self._c_submitted.inc()
        self._queue_depth.observe(queue_depth)

    def observe_batch(self, size: int) -> None:
        self._batch_size.observe(size)

    def _phase(self, phase: str):
        h = self._phases.get(phase)
        if h is None:
            # double-checked under the lock: a snapshot() iterating
            # the phase table concurrently with the worker's flush
            # must never see the dict resize mid-iteration
            with self._lock:
                h = self._phases.get(phase)
                if h is None:
                    h = self._phases[phase] = self.registry.histogram(
                        f"phase_{phase}_s", self._max_samples, self._seed)
        return h

    def observe_phase(self, phase: str, seconds: float) -> None:
        """Record time spent in one hot-path phase (see :data:`PHASES`)."""
        self._phase(phase).observe(seconds)

    def observe_service(self, seconds: float) -> None:
        """Record one batch's dispatch→ready service time (EWMA'd).

        The engine adapts its batch-formation budget from this: a
        request should never wait longer for stragglers than a
        fraction of the time the batch will take to execute anyway.
        """
        with self._lock:
            prev = self._service_ewma_s
            self._service_ewma_s = (seconds if prev is None else
                                    _SERVICE_ALPHA * seconds
                                    + (1.0 - _SERVICE_ALPHA) * prev)

    def observe_batch_events(self, *, batch_size: int | None = None,
                             phases: dict[str, Any] | None = None,
                             completions: list[float] | None = None,
                             service_s: float | None = None) -> None:
        """Record one batch's worth of observations in one call.

        ``phases`` values may be a scalar duration or a list of
        per-request durations.  (Histograms carry their own fine-
        grained locks; the shared Telemetry lock only guards the EWMA
        and throughput stamps.)
        """
        self.observe_batches([(time.perf_counter(), batch_size, phases,
                               completions, service_s)])

    def observe_batches(self, entries: list) -> None:
        """Bulk-ingest buffered per-batch observations.

        Each entry is ``(t_observed, batch_size, phases, completions,
        service_s)``; ``t_observed`` preserves the original wall-clock
        of the observation so throughput spans stay correct under
        deferred flushing.
        """
        n_done = 0
        for now, batch_size, phases, completions, service_s in entries:
            if batch_size is not None:
                self._batch_size.observe(batch_size)
            if phases:
                for p, vals in phases.items():
                    h = self._phase(p)
                    if isinstance(vals, (int, float)):
                        h.observe(float(vals))
                    else:
                        h.extend(vals)
            if completions:
                n_done += len(completions)
                self._latency.extend(completions)
                with self._lock:
                    # min/max (not first/latest writer): two threads
                    # flushing out of order must not shrink the span
                    if self._t_first is None or now < self._t_first:
                        self._t_first = now
                    if self._t_last is None or now > self._t_last:
                        self._t_last = now
            if service_s is not None:
                self.observe_service(service_s)
        if n_done:
            self._c_completed.inc(n_done)

    def observe_submits(self, count: int, queue_depths: list[int]) -> None:
        """Bulk-ingest buffered submit observations."""
        self._c_submitted.inc(count)
        self._queue_depth.extend(queue_depths)

    def observe_shed(self) -> None:
        """One request rejected by admission control (QueueFullError)."""
        self._c_shed.inc()

    def observe_cancel(self) -> None:
        """One request abandoned by its caller before completion."""
        self._c_cancelled.inc()

    @property
    def service_ewma_s(self) -> float | None:
        """Smoothed per-batch service time, or None before any batch."""
        with self._lock:
            return self._service_ewma_s

    def observe_completion(self, latency_s: float) -> None:
        now = time.perf_counter()
        with self._lock:
            if self._t_first is None:
                self._t_first = now
            self._t_last = now
        self._c_completed.inc()
        self._latency.observe(latency_s)

    def reset(self) -> None:
        """Zero all samples and counters (keeps ``replicas``).

        Lets a benchmark or operator mark the start of a measurement
        window after warmup — compile latencies from first-launch
        bucket warming would otherwise dominate small-sample p99s.
        Reservoir RNGs are re-seeded, so the window replays
        deterministically.
        """
        self.registry.reset()
        with self._lock:
            self._service_ewma_s = None
            self._t_first = self._t_last = None

    # -- aggregation ---------------------------------------------------
    @staticmethod
    def _pct(xs: list[float], q: float) -> float:
        return float(np.percentile(np.asarray(xs), q)) if xs else 0.0

    def snapshot(self, *, flat: bool = False) -> dict[str, Any]:
        """Measured serving metrics so far (JSON-safe).

        Percentile keys from an **empty** latency reservoir come back
        ``None`` with ``latency_samples == 0`` — never an ``inf``/NaN
        that breaks a JSON consumer, and never a fake ``0.0`` that
        reads as a zero-latency engine.  Non-finite observations (a
        hung launch's clock) are filtered before every percentile.
        Safe to call from any thread, concurrently with the worker's
        bulk-ingest flush.  ``flat=True`` returns one level of dotted
        keys (``phases.launch.p99_ms``) for CSV/JSON sinks.
        """
        lat = self._latency.finite_samples()
        depths = self._queue_depth.finite_samples()
        sizes = self._batch_size.finite_samples()
        completed = self._c_completed.value
        with self._lock:
            span = ((self._t_last - self._t_first)
                    if (self._t_first is not None and completed > 1)
                    else 0.0)
            ewma = self._service_ewma_s
            phase_items = list(self._phases.items())
        tput = (completed - 1) / span if span else 0.0
        phases = {}
        for p, h in phase_items:
            xs = h.finite_samples()
            if xs:
                phases[p] = {"mean_ms": float(np.mean(xs)) * 1e3,
                             "p99_ms": self._pct(xs, 99) * 1e3,
                             "count": h.count}
        out = {
            "submitted": self._c_submitted.value,
            "completed": completed,
            "shed": self._c_shed.value,
            "cancelled": self._c_cancelled.value,
            "service_ewma_ms": ((ewma or 0.0) * 1e3),
            "phases": phases,
            "throughput_rps": tput,
            "replicas": self.replicas,
            "throughput_per_replica_rps": tput / self.replicas,
            "latency_samples": len(lat),
            "latency_p50_ms": self._pct(lat, 50) * 1e3 if lat else None,
            "latency_p99_ms": self._pct(lat, 99) * 1e3 if lat else None,
            "latency_mean_ms": float(np.mean(lat)) * 1e3 if lat else None,
            "queue_depth_mean": (float(np.mean(depths))
                                 if depths else 0.0),
            "queue_depth_max": (int(max(depths)) if depths else 0),
            "batch_size_mean": (float(np.mean(sizes))
                                if sizes else 0.0),
        }
        if flat:
            from repro.obs.exporter import flatten_report
            return flatten_report(out)
        return out

    def report(self, *, cache: Any = None,
               modeled: dict[str, Any] | None = None) -> dict[str, Any]:
        """``measured`` metrics next to the Fig. 1 ``modeled`` prediction."""
        out: dict[str, Any] = {"measured": self.snapshot()}
        if cache is not None:
            out["cache"] = cache.stats.as_dict()
        if modeled is not None:
            out["modeled"] = modeled
        return out
