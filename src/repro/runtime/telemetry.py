"""Serving telemetry: measured metrics side-by-side with the Fig. 1 model.

The latency simulator (:mod:`repro.core.simulate`) predicts what a
FIFO pipeline *should* do; a live engine measures what it *does*.
This module holds both ends: :class:`Telemetry` aggregates queue
depth, per-request latency percentiles, throughput and batch sizes
from a running :class:`~repro.runtime.engine.StreamEngine`, and
:func:`modeled_latency` produces the matching analytic + simulated
predictions for the app being served, so every engine report shows
``measured`` next to ``modeled`` — the paper's performance model
validated against live traffic instead of a synthetic sweep.
"""
from __future__ import annotations

import threading
import time
from typing import Any

import numpy as np

from repro.core.simulate import TaskTiming, analytic_latency, simulate_pipeline

__all__ = ["PHASES", "Telemetry", "modeled_latency"]

#: the submit→complete hot path, phase by phase: time spent queued
#: before being taken into a batch, waiting for the batch to form,
#: staging rows into the pinned batch buffers, dispatching the kernel,
#: and forcing outputs back to host memory
PHASES = ("queue_wait", "form", "stack", "launch", "readback")

#: cap on per-request samples kept in memory (reservoir of latest)
_MAX_SAMPLES = 100_000

#: EWMA smoothing for the observed per-batch service time that drives
#: the engine's adaptive batch-formation budget
_SERVICE_ALPHA = 0.2

#: cap on items fed to the O(S*n) discrete simulator in reports
_SIM_ITEMS_CAP = 512


def modeled_latency(app: Any, n_items: int, depth: int = 2,
                    replicas: int = 1) -> dict[str, float]:
    """Fig. 1 predictions for serving ``n_items`` requests through ``app``.

    Tasks are the app's scheduled stages bracketed by the generated
    read/write (H2D/D2H) tasks, exactly as the fusion cost model
    scores them; ``depth`` is the FIFO depth of the engine's bounded
    queues.  Returns the closed-form ``sequential`` / ``dataflow``
    cycles plus the finite-depth discrete simulation
    (``dataflow_sim``), so backpressure effects are visible too.

    ``replicas > 1`` adds the batch-parallel-farm prediction: k
    identical pipelines each drain ``ceil(n/k)`` items, so the
    replicated latency is the dataflow latency of the per-replica
    share — linear scaling in the drain term (the farm's workers
    share no channels), with the fill paid once per replica in
    parallel.  ``replica_scaling_modeled`` is the predicted speedup of
    the farm over one replica.
    """
    tasks = ([TaskTiming("read", ii=1.0, fill=32.0)]
             + [TaskTiming(s.name, ii=s.ii, fill=s.fill)
                for s in app.schedule.order]
             + [TaskTiming("write", ii=1.0, fill=32.0)])
    n = max(1, n_items)
    out = dict(analytic_latency(tasks, n))
    sim = simulate_pipeline(tasks, min(n, _SIM_ITEMS_CAP),
                            depth=max(1, depth))
    out["dataflow_sim"] = sim["dataflow_sim"]
    if replicas > 1:
        per_replica = -(-n // replicas)
        out["dataflow_replicated"] = analytic_latency(
            tasks, per_replica)["dataflow"]
        out["replica_scaling_modeled"] = (out["dataflow"]
                                          / out["dataflow_replicated"])
    return out


class Telemetry:
    """Thread-safe metric aggregation for a serving engine."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._latencies_s: list[float] = []
        self._queue_depths: list[int] = []
        self._batch_sizes: list[int] = []
        self._phases_s: dict[str, list[float]] = {p: [] for p in PHASES}
        self._service_ewma_s: float | None = None
        self._t_first: float | None = None
        self._t_last: float | None = None
        self.completed = 0
        self.submitted = 0
        self.shed = 0
        self.cancelled = 0
        #: device-farm width the served throughput is spread over;
        #: owned by the engine (it sets this to its ``replicas``) so
        #: reports show per-replica throughput next to the modeled
        #: linear scaling
        self.replicas = 1

    # -- observation hooks ---------------------------------------------
    def observe_submit(self, queue_depth: int) -> None:
        with self._lock:
            self.submitted += 1
            if len(self._queue_depths) < _MAX_SAMPLES:
                self._queue_depths.append(queue_depth)

    def observe_batch(self, size: int) -> None:
        with self._lock:
            if len(self._batch_sizes) < _MAX_SAMPLES:
                self._batch_sizes.append(size)

    def observe_phase(self, phase: str, seconds: float) -> None:
        """Record time spent in one hot-path phase (see :data:`PHASES`)."""
        with self._lock:
            samples = self._phases_s.setdefault(phase, [])
            if len(samples) < _MAX_SAMPLES:
                samples.append(seconds)

    def observe_service(self, seconds: float) -> None:
        """Record one batch's dispatch→ready service time (EWMA'd).

        The engine adapts its batch-formation budget from this: a
        request should never wait longer for stragglers than a
        fraction of the time the batch will take to execute anyway.
        """
        with self._lock:
            prev = self._service_ewma_s
            self._service_ewma_s = (seconds if prev is None else
                                    _SERVICE_ALPHA * seconds
                                    + (1.0 - _SERVICE_ALPHA) * prev)

    def observe_batch_events(self, *, batch_size: int | None = None,
                             phases: dict[str, Any] | None = None,
                             completions: list[float] | None = None,
                             service_s: float | None = None) -> None:
        """Record one batch's worth of observations under ONE lock.

        The serve loop's per-batch bookkeeping (batch size, phase
        durations, per-request completion latencies, service EWMA)
        previously cost a lock acquisition per metric per request —
        measurable against sub-100us kernels.  ``phases`` values may
        be a scalar duration or a list of per-request durations.
        """
        now = time.perf_counter()
        with self._lock:
            if batch_size is not None \
                    and len(self._batch_sizes) < _MAX_SAMPLES:
                self._batch_sizes.append(batch_size)
            if phases:
                for p, vals in phases.items():
                    samples = self._phases_s.setdefault(p, [])
                    room = _MAX_SAMPLES - len(samples)
                    if room <= 0:
                        continue
                    if isinstance(vals, (int, float)):
                        samples.append(float(vals))
                    else:
                        samples.extend(vals[:room])
            if completions:
                if self._t_first is None:
                    self._t_first = now
                self._t_last = now
                self.completed += len(completions)
                room = _MAX_SAMPLES - len(self._latencies_s)
                if room > 0:
                    self._latencies_s.extend(completions[:room])
            if service_s is not None:
                prev = self._service_ewma_s
                self._service_ewma_s = (service_s if prev is None else
                                        _SERVICE_ALPHA * service_s
                                        + (1.0 - _SERVICE_ALPHA) * prev)

    def observe_batches(self, entries: list) -> None:
        """Bulk-ingest buffered per-batch observations under ONE lock.

        Each entry is ``(t_observed, batch_size, phases, completions,
        service_s)`` with the same semantics as
        :meth:`observe_batch_events`; ``t_observed`` preserves the
        original wall-clock of the observation so throughput spans
        stay correct under deferred flushing.
        """
        with self._lock:
            for now, batch_size, phases, completions, service_s in entries:
                if batch_size is not None \
                        and len(self._batch_sizes) < _MAX_SAMPLES:
                    self._batch_sizes.append(batch_size)
                if phases:
                    for p, vals in phases.items():
                        samples = self._phases_s.setdefault(p, [])
                        room = _MAX_SAMPLES - len(samples)
                        if room <= 0:
                            continue
                        if isinstance(vals, (int, float)):
                            samples.append(float(vals))
                        else:
                            samples.extend(vals[:room])
                if completions:
                    if self._t_first is None:
                        self._t_first = now
                    self._t_last = now
                    self.completed += len(completions)
                    room = _MAX_SAMPLES - len(self._latencies_s)
                    if room > 0:
                        self._latencies_s.extend(completions[:room])
                if service_s is not None:
                    prev = self._service_ewma_s
                    self._service_ewma_s = (
                        service_s if prev is None else
                        _SERVICE_ALPHA * service_s
                        + (1.0 - _SERVICE_ALPHA) * prev)

    def observe_submits(self, count: int, queue_depths: list[int]) -> None:
        """Bulk-ingest buffered submit observations under ONE lock."""
        with self._lock:
            self.submitted += count
            room = _MAX_SAMPLES - len(self._queue_depths)
            if room > 0:
                self._queue_depths.extend(queue_depths[:room])

    def observe_shed(self) -> None:
        """One request rejected by admission control (QueueFullError)."""
        with self._lock:
            self.shed += 1

    def observe_cancel(self) -> None:
        """One request abandoned by its caller before completion."""
        with self._lock:
            self.cancelled += 1

    @property
    def service_ewma_s(self) -> float | None:
        """Smoothed per-batch service time, or None before any batch."""
        with self._lock:
            return self._service_ewma_s

    def observe_completion(self, latency_s: float) -> None:
        now = time.perf_counter()
        with self._lock:
            if self._t_first is None:
                self._t_first = now
            self._t_last = now
            self.completed += 1
            if len(self._latencies_s) < _MAX_SAMPLES:
                self._latencies_s.append(latency_s)

    def reset(self) -> None:
        """Zero all samples and counters (keeps ``replicas``).

        Lets a benchmark or operator mark the start of a measurement
        window after warmup — compile latencies from first-launch
        bucket warming would otherwise dominate small-sample p99s.
        """
        with self._lock:
            self._latencies_s.clear()
            self._queue_depths.clear()
            self._batch_sizes.clear()
            self._phases_s = {p: [] for p in PHASES}
            self._service_ewma_s = None
            self._t_first = self._t_last = None
            self.completed = self.submitted = 0
            self.shed = self.cancelled = 0

    # -- aggregation ---------------------------------------------------
    @staticmethod
    def _pct(xs: list[float], q: float) -> float:
        return float(np.percentile(np.asarray(xs), q)) if xs else 0.0

    def snapshot(self) -> dict[str, Any]:
        """Measured serving metrics so far."""
        with self._lock:
            lat = list(self._latencies_s)
            span = ((self._t_last - self._t_first)
                    if (self._t_first is not None and self.completed > 1)
                    else 0.0)
            tput = (self.completed - 1) / span if span else 0.0
            phases = {
                p: {"mean_ms": float(np.mean(xs)) * 1e3,
                    "p99_ms": self._pct(xs, 99) * 1e3,
                    "count": len(xs)}
                for p, xs in self._phases_s.items() if xs
            }
            return {
                "submitted": self.submitted,
                "completed": self.completed,
                "shed": self.shed,
                "cancelled": self.cancelled,
                "service_ewma_ms": ((self._service_ewma_s or 0.0) * 1e3),
                "phases": phases,
                "throughput_rps": tput,
                "replicas": self.replicas,
                "throughput_per_replica_rps": tput / self.replicas,
                "latency_p50_ms": self._pct(lat, 50) * 1e3,
                "latency_p99_ms": self._pct(lat, 99) * 1e3,
                "latency_mean_ms": float(np.mean(lat)) * 1e3 if lat else 0.0,
                "queue_depth_mean": (float(np.mean(self._queue_depths))
                                     if self._queue_depths else 0.0),
                "queue_depth_max": (max(self._queue_depths)
                                    if self._queue_depths else 0),
                "batch_size_mean": (float(np.mean(self._batch_sizes))
                                    if self._batch_sizes else 0.0),
            }

    def report(self, *, cache: Any = None,
               modeled: dict[str, Any] | None = None) -> dict[str, Any]:
        """``measured`` metrics next to the Fig. 1 ``modeled`` prediction."""
        out: dict[str, Any] = {"measured": self.snapshot()}
        if cache is not None:
            out["cache"] = cache.stats.as_dict()
        if modeled is not None:
            out["modeled"] = modeled
        return out
