"""Continuous-batching serving scheduler.

Real serving fleets don't run lock-step batches: requests arrive and
finish at different times.  This scheduler keeps a fixed pool of
decode *slots* (the jitted decode step never re-compiles), admits new
requests into free slots between steps, and retires sequences on EOS
or length budget — the dataflow view of serving: the decode step is a
pipeline stage, slots are its channels.

Per-slot state lives in the shared cache via a position vector: every
slot decodes against its own history length (the attention bias uses
per-slot lengths, not the global index), so sequences of different
ages coexist in one batch.

Pure-JAX + host scheduling; works with every assigned architecture
that exposes attention caches (SSM-state archs need per-slot state
reset on admit, also handled).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.runtime.slots import SlotPool

__all__ = ["Request", "ContinuousBatcher"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                   # -1: run to the length budget
    # filled by the batcher:
    tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    """Fixed-slot continuous batching on top of prefill/decode.

    Simplification vs a full paged server: prompts are prefilled one
    slot at a time (B=1 prefill into the slot's cache rows), decode
    runs across all active slots every step.  Cache layout is the
    stacked (layers, B, ...) tree from ``M.init_cache``.
    """

    def __init__(self, cfg: ModelConfig, params, n_slots: int,
                 max_len: int, dtype=jnp.float32):
        self.cfg, self.params = cfg, params
        self.n_slots, self.max_len = n_slots, max_len
        self.cache = M.init_cache(cfg, n_slots, max_len, dtype=dtype)
        # per-slot sequence lengths (host copy is the scheduler truth)
        self.lengths = np.zeros(n_slots, np.int32)
        # slot occupancy / admission queue / retirement: the machinery
        # shared with the dataflow StreamEngine (see runtime/slots.py)
        self.pool: SlotPool = SlotPool(n_slots)
        self._decode = jax.jit(self._decode_step)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.pool.submit(req)

    @property
    def active(self) -> int:
        return self.pool.active

    @property
    def queue(self):
        return self.pool.queue

    @property
    def slot_req(self) -> list[Request | None]:
        return self.pool.slots

    @property
    def finished(self) -> list[Request]:
        return self.pool.finished

    # ------------------------------------------------------------------
    def _admit(self) -> None:
        """Prefill queued requests into free slots (one at a time)."""
        for slot, req in self.pool.admit():
            prompt = jnp.asarray(req.prompt, jnp.int32)[None]
            tmp_cache = M.init_cache(self.cfg, 1, self.max_len,
                                     dtype=jnp.float32)
            logits, tmp_cache = M.prefill(self.params, self.cfg, prompt,
                                          tmp_cache)
            self._copy_slot(tmp_cache, slot)
            tok = int(jnp.argmax(logits[0], -1))
            req.tokens.append(tok)
            self.lengths[slot] = len(req.prompt)

    def _copy_slot(self, src_cache, slot: int) -> None:
        """Copy a B=1 cache into slot ``slot`` of the pool cache."""

        def copy(pool, one):
            if pool.ndim == 0 or one.ndim == 0 or pool.ndim != one.ndim:
                return pool
            # the batch axis is the one where pool has n_slots, the
            # B=1 cache has 1, and every other dim matches (axis 1 for
            # stacked (layers, B, ...) leaves, axis 0 for enc_out).
            axis = None
            for a in range(pool.ndim):
                if (pool.shape[a] == self.n_slots and one.shape[a] == 1
                        and pool.shape[:a] == one.shape[:a]
                        and pool.shape[a + 1:] == one.shape[a + 1:]):
                    axis = a
                    break
            if axis is None:
                return pool
            idx = [slice(None)] * pool.ndim
            idx[axis] = slice(slot, slot + 1)
            return pool.at[tuple(idx)].set(one.astype(pool.dtype))

        self.cache = jax.tree.map(
            copy, self.cache,
            {k: v for k, v in src_cache.items() if k != "index"}
            | {"index": jnp.zeros((), jnp.int32)})

    # ------------------------------------------------------------------
    def _decode_step(self, params, cache, tokens, lengths):
        """One decode step with PER-SLOT lengths: the model's vector
        cache-index path writes each slot's KV at its own position and
        masks attention per slot (see layers.attention_block)."""
        cache = dict(cache)
        cache["index"] = lengths
        logits, cache = M.decode_step(params, self.cfg, tokens, cache)
        return logits, cache

    def step(self) -> int:
        """Admit, decode once for all active slots, retire finished.

        Returns the number of tokens produced this step."""
        self._admit()
        if self.active == 0:
            return 0
        tokens = np.zeros(self.n_slots, np.int32)
        for i, r in enumerate(self.slot_req):
            if r is not None:
                tokens[i] = r.tokens[-1]
        logits, new_cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.lengths))
        # keep host lengths authoritative (the jitted step +1s them all,
        # including idle slots; we re-install our own vector next step)
        self.cache = new_cache
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        produced = 0
        for i, r in enumerate(self.slot_req):
            if r is None:
                continue
            self.lengths[i] += 1
            r.tokens.append(int(nxt[i]))
            produced += 1
            over = len(r.tokens) >= r.max_new_tokens
            eos = r.eos_id >= 0 and int(nxt[i]) == r.eos_id
            if over or eos or self.lengths[i] >= self.max_len - 1:
                r.done = True
        # continuous refill: reap every finished sequence's slot (the
        # machinery shared with StreamEngine's in-flight launch pool),
        # then the next _admit() backfills them without a drain barrier
        for slot in self.pool.ready(lambda r: r.done):
            self.pool.retire(slot)
            self.lengths[slot] = 0
        return produced

    def run_to_completion(self, max_steps: int = 10_000) -> list[Request]:
        steps = 0
        while (self.queue or self.active) and steps < max_steps:
            self.step()
            steps += 1
        return self.finished
