"""Fault-tolerance machinery: stragglers, heartbeats, preemption.

At 1000+ nodes, something is always broken.  The framework's posture:

- **Checkpoint/restart** is the base mechanism (async, atomic, elastic
  — see repro.checkpoint).  The Trainer auto-saves every N steps and
  on SIGTERM (preemption notice), and resumes from the newest intact
  checkpoint, on any mesh shape.
- **Straggler mitigation**: per-host step-time EWMA; hosts slower than
  ``factor`` x the fleet median for ``patience`` consecutive windows
  are flagged for replacement.  (On real fleets the replacement is an
  external scheduler action; here the monitor's decisions are unit-
  tested against synthetic traces.)
- **Heartbeats**: liveness registry with a deadline; dead hosts
  trigger an elastic-restart decision (shrink to the survivors'
  mesh, restore, continue).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable

import numpy as np

__all__ = ["StragglerMonitor", "HeartbeatRegistry", "PreemptionGuard"]


@dataclasses.dataclass
class StragglerMonitor:
    n_hosts: int
    alpha: float = 0.2          # EWMA smoothing
    factor: float = 1.5         # slower than factor x median => suspect
    patience: int = 3           # consecutive suspect windows => straggler

    def __post_init__(self):
        self.ewma = np.zeros(self.n_hosts)
        self.strikes = np.zeros(self.n_hosts, dtype=int)
        self._seen = np.zeros(self.n_hosts, dtype=bool)

    def observe(self, host_step_times: np.ndarray) -> list[int]:
        """Feed one step's per-host wall times; returns flagged hosts.

        Strikes count *consecutive raw* slow windows (a single spike
        resets next step); the EWMA is kept for reporting/telemetry.
        """
        t = np.asarray(host_step_times, dtype=float)
        new = ~self._seen
        self.ewma[new] = t[new]
        self._seen |= True
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * t
        med = np.median(t)
        suspect = t > self.factor * med
        self.strikes = np.where(suspect, self.strikes + 1, 0)
        return list(np.nonzero(self.strikes >= self.patience)[0])


@dataclasses.dataclass
class HeartbeatRegistry:
    n_hosts: int
    deadline_s: float = 60.0
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        now = self.clock()
        self.last = np.full(self.n_hosts, now)

    def beat(self, host: int) -> None:
        self.last[host] = self.clock()

    def dead_hosts(self) -> list[int]:
        now = self.clock()
        return list(np.nonzero(now - self.last > self.deadline_s)[0])

    def survivors(self) -> list[int]:
        dead = set(self.dead_hosts())
        return [h for h in range(self.n_hosts) if h not in dead]


class PreemptionGuard:
    """SIGTERM -> set a flag the training loop polls; the loop then
    checkpoints synchronously and exits cleanly (cloud preemption
    contract).  Context-manager restores the previous handler."""

    def __init__(self):
        self.preempted = False
        self._prev = None

    def __enter__(self):
        def handler(signum, frame):
            self.preempted = True

        self._prev = signal.signal(signal.SIGTERM, handler)
        return self

    def __exit__(self, *exc):
        signal.signal(signal.SIGTERM, self._prev)
        return False
