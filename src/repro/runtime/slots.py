"""Fixed-slot scheduling machinery shared by the serving runtimes.

Both serving schedulers in this package are the same shape: a FIFO
admission queue feeding a fixed pool of *slots*, with items retired
out of slots as they complete.

- :class:`~repro.runtime.batcher.ContinuousBatcher` uses the pool for
  decode slots (a slot = one sequence's rows of the KV cache),
- :class:`~repro.runtime.engine.StreamEngine` uses it for in-flight
  micro-batch launches (a slot = one outstanding kernel dispatch;
  ``n_slots=2`` is exactly the double buffering of a depth-2 FIFO).

:class:`SlotPool` is that shared core: bounded occupancy, FIFO
admission, admission-order retirement bookkeeping.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Callable

__all__ = ["SlotPool"]


class SlotPool:
    """A fixed pool of serving slots fed from a FIFO admission queue."""

    def __init__(self, n_slots: int) -> None:
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = n_slots
        self.slots: list[Any | None] = [None] * n_slots
        self.queue: deque[Any] = deque()
        self.finished: list[Any] = []
        self._order: deque[int] = deque()   # admission order of busy slots

    # -- admission -----------------------------------------------------
    def submit(self, item: Any) -> None:
        """Enqueue an item for admission into the next free slot."""
        self.queue.append(item)

    def admit(self) -> list[tuple[int, Any]]:
        """Move queued items into free slots (FIFO); return admissions."""
        admitted: list[tuple[int, Any]] = []
        for slot in self.free_slots():
            if not self.queue:
                break
            item = self.queue.popleft()
            self.slots[slot] = item
            self._order.append(slot)
            admitted.append((slot, item))
        return admitted

    # -- occupancy -----------------------------------------------------
    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def busy(self) -> bool:
        """True while anything is queued or occupying a slot."""
        return bool(self.queue) or self.active > 0

    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def oldest(self) -> int | None:
        """Slot id of the earliest-admitted busy slot (FIFO retire order)."""
        return self._order[0] if self._order else None

    def ready(self, is_ready: Callable[[Any], bool]) -> list[int]:
        """Busy slots (admission order) whose item can retire *now*.

        The continuous-batching schedulers use this to refill freed
        slots as items complete instead of draining the whole pool at
        a barrier: the engine polls in-flight launches with a
        non-blocking readiness probe, the LM batcher retires finished
        sequences, and in both cases ``admit()`` immediately backfills
        the freed slots from the queue.
        """
        return [s for s in self._order if is_ready(self.slots[s])]

    # -- retirement ----------------------------------------------------
    def retire(self, slot: int) -> Any:
        """Free ``slot``; its item moves to ``finished`` and is returned."""
        item = self.slots[slot]
        if item is None:
            raise ValueError(f"slot {slot} is not occupied")
        self.slots[slot] = None
        self._order.remove(slot)
        self.finished.append(item)
        return item
