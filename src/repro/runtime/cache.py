"""Compile cache keyed by canonical graph signatures.

Tracing + lowering a dataflow graph is the expensive part of
``compile_graph`` (seconds for a Pallas app); a serving engine that
re-traced per request would spend its life in the compiler.  The
:class:`CompileCache` memoizes :func:`repro.core.compiler.compile_graph`
on ``(DataflowGraph.signature(), backend, options)`` — a *structural*
key, so a topologically identical graph built elsewhere (renamed
channels included) still hits.

Canonicalization caveat: the pass pipeline rewrites graphs in place,
so a graph's signature can legitimately change once across its first
compile (e.g. auto-split inserts a stage).  The cache therefore
registers the *post-canonicalization* signature as an alias of the
same entry — resubmitting either form hits.  The pipeline is
idempotent (property-tested in tests/test_graph.py), so there are at
most two keys per app.

Tuning integration: compile options are part of the key, and
``tune="auto"`` is just another option — the first miss runs the
profile-guided search (or loads the persistent
:class:`~repro.tune.store.TuningCache`), and every later submit of the
same topology reuses the *tuned* app, so a serving engine warm-starts
at the measured operating point.  Option values that carry a
``to_json`` method (e.g. :class:`~repro.tune.store.ScheduleConfig`)
are keyed by their JSON form, so two equal configs built by different
processes still map to one entry.
"""
from __future__ import annotations

import dataclasses
import threading
import weakref
from collections import OrderedDict
from typing import Any, Callable

from repro.backends import resolve
from repro.core.compiler import compile_graph
from repro.core.graph import DataflowGraph
from repro.core.host import CompiledApp

__all__ = ["CacheStats", "CompileCache"]


def _opt_repr(v: Any) -> str:
    """Stable string form of a compile option for cache keying.

    Values exposing ``to_json`` (tuning configs, specs grown later)
    are keyed structurally so equal-by-value instances from different
    builders share an entry; everything else falls back to ``repr``.
    """
    to_json = getattr(v, "to_json", None)
    if callable(to_json):
        try:
            import json
            return v.__class__.__name__ + json.dumps(to_json(),
                                                     sort_keys=True)
        except (TypeError, ValueError):
            pass
    return repr(v)


@dataclasses.dataclass
class CacheStats:
    """Compile-cache counters, accounted **per compile event**.

    ``hits``/``misses`` count *unique resolutions*: the first time a
    given graph object (per backend/options) is resolved against the
    structural table, it either reuses an existing compile (hit) or
    triggers one (miss).  Re-submitting the same object — every
    request of a serving stream — is a ``requests`` tick only, so a
    batched engine serving one app N times reports 1 miss and N
    requests, not N-1 phantom hits: ``hit_rate`` measures how often
    the cache avoided a compile, not how often it was asked.
    """

    hits: int = 0
    misses: int = 0
    evictions: int = 0
    requests: int = 0

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def as_dict(self) -> dict[str, float]:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "requests": self.requests,
                "hit_rate": self.hit_rate}


class _PendingCompile:
    """Future for an in-flight trace: same-key callers wait, not re-trace."""

    def __init__(self) -> None:
        self._done = threading.Event()
        self._app: CompiledApp | None = None
        self._err: BaseException | None = None

    def resolve(self, app: CompiledApp) -> None:
        self._app = app
        self._done.set()

    def fail(self, err: BaseException) -> None:
        self._err = err
        self._done.set()

    def wait(self) -> CompiledApp:
        self._done.wait()
        if self._err is not None:
            raise self._err
        assert self._app is not None
        return self._app


class CompileCache:
    """LRU cache of :class:`CompiledApp` keyed by graph signature.

    Thread-safe: the serving engine compiles on submitter threads.
    Tracing happens OUTSIDE the table lock — a miss installs a
    per-key :class:`_PendingCompile`, so concurrent submits of the
    same graph trace exactly once (one miss; waiters that are
    *distinct* graph objects count as hits, repeats of the same
    object count as ``requests`` — see :class:`CacheStats`) while
    hits for other, already-compiled apps proceed unstalled.
    """

    def __init__(self, maxsize: int = 64,
                 compile_fn: Callable[..., CompiledApp] = compile_graph):
        self.maxsize = maxsize
        self.stats = CacheStats()
        self._compile = compile_fn
        self._entries: OrderedDict[tuple, CompiledApp] = OrderedDict()
        self._pending: dict[tuple, _PendingCompile] = {}
        # identity fast path: a graph OBJECT already served maps straight
        # to its app without re-hashing the structure on every request
        # (assumes graphs are not mutated once submitted for serving)
        self._by_graph: weakref.WeakKeyDictionary[DataflowGraph, dict] = \
            weakref.WeakKeyDictionary()
        # per-object locks: canonicalization passes rewrite a graph IN
        # PLACE during its first compile, so a concurrent get() on the
        # same object must not read its structure mid-rewrite
        self._graph_locks: weakref.WeakKeyDictionary[DataflowGraph, Any] = \
            weakref.WeakKeyDictionary()
        self._lock = threading.RLock()

    def __len__(self) -> int:
        return len(self._entries)

    @staticmethod
    def _key(sig: str, backend_key: str, opts: dict[str, Any]) -> tuple:
        return (sig, backend_key, tuple(sorted((k, _opt_repr(v))
                                               for k, v in opts.items())))

    def get(self, graph: DataflowGraph, backend="pallas",
            **compile_kwargs: Any) -> CompiledApp:
        """Return a compiled app for ``graph``, tracing at most once.

        ``backend`` is a registered name or a
        :class:`~repro.backends.Backend`; the entry is keyed by the
        resolved record's :meth:`~repro.backends.Backend.cache_key`
        (name + digest of capabilities and constants), so re-registering
        a name with different constants never serves stale kernels.
        """
        backend = resolve(backend)
        # ``trace`` is observability plumbing, not a compile option: a
        # Tracer's repr is identity-based, so keying it would split the
        # cache per tracer instance for semantically identical compiles
        trace = compile_kwargs.pop("trace", None)
        okey = (backend.cache_key(), tuple(sorted((k, _opt_repr(v))
                                           for k, v in compile_kwargs.items())))
        with self._lock:
            self.stats.requests += 1
            per = self._by_graph.get(graph)
            if per is not None and okey in per:
                # repeat of an already-resolved object: a served
                # request, not a fresh cache consultation (hit/miss
                # are per compile event — see CacheStats)
                return per[okey]
            glock = self._graph_locks.get(graph)
            if glock is None:
                glock = self._graph_locks[graph] = threading.Lock()
        with glock:
            return self._get_slow(graph, okey, backend, compile_kwargs,
                                  trace=trace)

    def _get_slow(self, graph: DataflowGraph, okey: tuple, backend,
                  compile_kwargs: dict[str, Any],
                  trace: Any = None) -> CompiledApp:
        """Signature lookup / trace under the per-graph-object lock."""
        with self._lock:
            per = self._by_graph.get(graph)
            if per is not None and okey in per:   # a peer just filled it
                return per[okey]     # same object: same compile event
            key = self._key(graph.signature(), backend.cache_key(),
                            compile_kwargs)
            app = self._entries.get(key)
            if app is not None:
                self._entries.move_to_end(key)
                self.stats.hits += 1
                self._by_graph.setdefault(graph, {})[okey] = app
                return app
            pending = self._pending.get(key)
            if pending is None:
                self._pending[key] = pending = _PendingCompile()
                self.stats.misses += 1
                owner = True
            else:
                self.stats.hits += 1        # someone else is tracing it
                owner = False
        if not owner:
            app = pending.wait()
            with self._lock:
                self._by_graph.setdefault(graph, {})[okey] = app
            return app
        try:
            # only forward trace= when set: custom compile_fns need not
            # grow the parameter to keep working untraced
            if trace is not None:
                compile_kwargs = dict(compile_kwargs, trace=trace)
            app = self._compile(graph, backend=backend, **compile_kwargs)
        except BaseException as e:
            with self._lock:
                del self._pending[key]
            pending.fail(e)
            raise
        with self._lock:
            self._entries[key] = app
            # alias: the canonicalized graph's signature (module doc)
            canon = self._key(app.graph.signature(), backend.cache_key(),
                              compile_kwargs)
            self._entries.setdefault(canon, app)
            while len(self._entries) > self.maxsize:
                self._entries.popitem(last=False)
                self.stats.evictions += 1
            self._by_graph.setdefault(graph, {})[okey] = app
            del self._pending[key]
        pending.resolve(app)
        return app

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._by_graph.clear()
