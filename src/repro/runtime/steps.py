"""Step-function builders: train / prefill / decode.

This is the generated "host code" (FLOWER C4) for the LM system: from
a ModelConfig + mesh, build the jitted, sharded, donated step functions
with every buffer's placement derived from the declarative param axes.
The model code never mentions the mesh; the launcher never mentions
model internals.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import model as M
from repro.models import layers as L
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim.adamw import AdamWConfig, adamw_apply, adamw_init
from repro.optim.compression import ef_init, ef_roundtrip
from repro.parallel.sharding import (ShardingRules, TRAIN_RULES,
                                     SERVE_RULES, make_activation_fn,
                                     make_param_shardings, spec_for_axes)

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step",
           "train_state_shardings", "batch_specs", "abstract_train_state",
           "abstract_cache", "cache_shardings"]


# ----------------------------------------------------------------------
# abstract state + shardings
# ----------------------------------------------------------------------
def abstract_train_state(cfg: ModelConfig, compress_grads: bool = False
                         ) -> Any:
    """ShapeDtypeStructs of the full train state (no allocation)."""

    def build():
        params = M.init(cfg, jax.random.PRNGKey(0))
        state = {"params": params, "opt": adamw_init(params)}
        if compress_grads:
            state["ef"] = ef_init(params)
        return state

    return jax.eval_shape(build)


def train_state_shardings(cfg: ModelConfig, mesh: Mesh,
                          rules: ShardingRules = TRAIN_RULES,
                          compress_grads: bool = False,
                          notes: list[str] | None = None) -> Any:
    axes = M.param_axes(cfg)
    shapes = jax.eval_shape(lambda: M.init(cfg, jax.random.PRNGKey(0)))
    p_sh = make_param_shardings(mesh, axes, rules, shapes, notes)
    state_sh = {"params": p_sh,
                "opt": {"master": p_sh, "m": p_sh, "v": p_sh,
                        "step": NamedSharding(mesh, P())}}
    if compress_grads:
        state_sh["ef"] = p_sh
    return state_sh


def batch_specs(cfg: ModelConfig, shape: ShapeConfig
                ) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of one shape
    cell (the dry-run contract)."""
    B, S = shape.global_batch, shape.seq_len
    f32 = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        n_extra = cfg.n_frontend_tokens if cfg.family in ("vlm",) else 0
        S_text = S - n_extra
        out = {"tokens": jax.ShapeDtypeStruct((B, S_text), jnp.int32),
               "labels": jax.ShapeDtypeStruct((B, S_text), jnp.int32)}
        if cfg.family == "vlm":
            out["extra_embeds"] = jax.ShapeDtypeStruct(
                (B, n_extra, cfg.d_model), f32)
        if cfg.family == "encdec":
            out["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frontend_tokens, cfg.d_model), f32)
        return out
    if shape.kind == "prefill":
        n_extra = cfg.n_frontend_tokens if cfg.family in ("vlm",) else 0
        out = {"tokens": jax.ShapeDtypeStruct((B, S - n_extra), jnp.int32)}
        if cfg.family == "vlm":
            out["extra_embeds"] = jax.ShapeDtypeStruct(
                (B, n_extra, cfg.d_model), f32)
        if cfg.family == "encdec":
            out["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_frontend_tokens, cfg.d_model), f32)
        return out
    # decode: one new token against a cache of length S
    return {"token": jax.ShapeDtypeStruct((B,), jnp.int32)}


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    rules: ShardingRules) -> Any:
    specs = batch_specs(cfg, shape)
    out = {}
    for k, v in specs.items():
        axes: tuple[str | None, ...]
        if v.ndim == 1:
            axes = ("batch",)
        elif v.ndim == 2:
            axes = ("batch", "seq")
        else:
            axes = ("batch", "seq", None)
        out[k] = NamedSharding(mesh, spec_for_axes(mesh, rules, axes,
                                                   tuple(v.shape)))
    return out


def abstract_cache(cfg: ModelConfig, shape: ShapeConfig) -> Any:
    return jax.eval_shape(
        lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len,
                             dtype=jnp.dtype(cfg.dtype)))


def cache_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    rules: ShardingRules = SERVE_RULES) -> Any:
    """KV caches: batch over (pod, data), heads over model; SSM states:
    batch over (pod, data), inner dim over model."""
    aval = abstract_cache(cfg, shape)

    def spec(path_key: str, v: jax.ShapeDtypeStruct) -> NamedSharding:
        name = path_key
        if v.ndim == 0 or "index" in name:
            return NamedSharding(mesh, P())
        if "enc_out" in name:
            axes = ("batch", "seq", None)
        elif "conv" in name:
            axes = ("layers", "batch", None, "ssm_inner")
        elif "ssm" in name:
            axes = ("layers", "batch", "ssm_inner", None, None)
        elif "c_kv" in name or "k_rope" in name:
            # latent cache: shard the long seq dim over the model axis
            axes = ("layers", "batch", "seq_model", None)
        else:  # k / v attention caches (layers, B, Hkv, S, D)
            msize = mesh.shape.get("model", 1)
            if v.ndim >= 3 and v.shape[2] % msize == 0:
                axes = ("layers", "batch", "kv_heads", "seq", None)
            else:
                # kv heads don't divide the model axis (MQA/GQA-small):
                # shard the cache length instead — decode attention
                # reduces over seq, XLA inserts the psum.
                axes = ("layers", "batch", None, "seq_model", None)
        axes = axes[:v.ndim] if len(axes) >= v.ndim else \
            (None,) * (v.ndim - len(axes)) + axes
        rules_sm = rules.replace(seq_model="model")
        return NamedSharding(mesh, spec_for_axes(mesh, rules_sm, axes,
                                                 tuple(v.shape)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(aval)
    out = []
    for path, v in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append(spec(key, v))
    return jax.tree.unflatten(treedef, out)


# ----------------------------------------------------------------------
# step builders
# ----------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    mesh: Mesh | None = None,
                    rules: ShardingRules = TRAIN_RULES,
                    compress_grads: bool = False):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def train_step(state: dict, batch: dict) -> tuple[dict, dict]:
        def compute(params, mbatch):
            if mesh is not None:
                with L.activation_rules(make_activation_fn(mesh, rules)):
                    return M.loss_fn(params, cfg, mbatch)
            return M.loss_fn(params, cfg, mbatch)

        mb = max(cfg.microbatches, 1)
        if mb == 1:
            (loss, metrics), grads = jax.value_and_grad(
                compute, has_aux=True)(state["params"], batch)
        else:
            # gradient accumulation: peak activation memory / mb
            split = jax.tree.map(
                lambda x: x.reshape(mb, x.shape[0] // mb, *x.shape[1:]),
                batch)

            def mb_step(acc, mbatch):
                (l, met), g = jax.value_and_grad(
                    compute, has_aux=True)(state["params"], mbatch)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(jnp.float32) / mb, acc, g)
                return acc, (l, met)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32),
                state["params"])
            grads, (losses, mets) = jax.lax.scan(mb_step, zeros, split)
            loss = losses.mean()
            metrics = jax.tree.map(lambda x: x.mean(), mets)
        new_state = dict(state)
        if compress_grads:
            grads, new_state["ef"] = ef_roundtrip(grads, state["ef"])
        new_params, new_opt, opt_metrics = adamw_apply(
            opt_cfg, state["params"], grads, state["opt"])
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        metrics = {**metrics, **opt_metrics, "total_loss": loss}
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, mesh: Mesh | None = None,
                      rules: ShardingRules = SERVE_RULES):
    def prefill_step(params, batch, cache):
        def run():
            return M.prefill(params, cfg, batch["tokens"], cache,
                             enc_embeds=batch.get("enc_embeds"),
                             extra_embeds=batch.get("extra_embeds"))

        if mesh is not None:
            with L.activation_rules(make_activation_fn(mesh, rules)):
                return run()
        return run()

    return prefill_step


def make_decode_step(cfg: ModelConfig, mesh: Mesh | None = None,
                     rules: ShardingRules = SERVE_RULES):
    def decode_step(params, batch, cache):
        def run():
            return M.decode_step(params, cfg, batch["token"], cache)

        if mesh is not None:
            with L.activation_rules(make_activation_fn(mesh, rules)):
                return run()
        return run()

    return decode_step
