"""Micro-batching: stack same-signature requests, launch ONE kernel.

Per-request dispatch pays the host-side launch overhead once per
item; a serving engine under load amortizes it by stacking requests
whose apps share a :meth:`~repro.core.host.CompiledApp.signature`
along a new leading axis and launching a single ``vmap``-ped kernel.

Two host-side overheads are engineered out of the hot path:

- **bucketed pad shapes** — padding every batch to ``max_batch``
  makes a 2-request batch pay a 32-wide launch.  ``launch`` instead
  pads to the next power-of-two *bucket* (rounded to a replica
  multiple), and each ``(signature, bucket)`` pair gets its own
  jitted entry in :attr:`_fns` — a small, fixed family of compiled
  shapes per app instead of one oversized one.  ``bucket_launches``
  records which buckets actually ran.
- **zero-copy staging** — request rows are written directly into
  *pinned* per-bucket staging buffers (allocated once, rotated
  ``staging_depth`` deep to stay clear of in-flight transfers)
  instead of re-stacking a fresh host array per batch: one
  ``memcpy`` per row, no per-batch allocation, the software analogue
  of FLOWER's reused XRT buffer objects between command-queue runs.

The batched callable is built per bucket (jit keeps it warm) with
every input donated — the staged device buffers are never reused, so
their HBM can be recycled in place.

With ``replicas > 1`` the padded batch is additionally *sharded* over
a 1-D device mesh: replica ``r`` executes rows ``[r*B/k, (r+1)*B/k)``
of every staging buffer — the batch-parallel farm (FastFlow's
``ff_farm`` worker replication, FLOWER's kernel replication) on top of
the same single-launch dispatch.  Bucket widths are held to a
multiple of the replica count so every launch keeps one compiled
kernel shape per replica.
"""
from __future__ import annotations

import time
import warnings
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.host import CompiledApp
from repro.obs.tracer import resolve_tracer

__all__ = ["MicroBatcher"]


class MicroBatcher:
    """Stacks same-signature requests and launches one batched kernel.

    ``launch`` is asynchronous: it returns the stacked device outputs
    without blocking, so the engine can keep further batches in flight
    (slot-pool pipelining) before forcing the first to host memory.
    """

    def __init__(self, max_batch: int = 8, donate: bool = True,
                 replicas: int = 1, replica_axis: str = "replica",
                 devices: list | None = None, staging_depth: int = 2,
                 trace: Any = None, backend=None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        if max_batch % replicas != 0:
            raise ValueError(
                f"max_batch={max_batch} must divide evenly over "
                f"replicas={replicas}: every replica serves "
                f"max_batch/replicas rows of the padded batch")
        if staging_depth < 1:
            raise ValueError(
                f"staging_depth must be >= 1, got {staging_depth}")
        self.max_batch = max_batch
        self.donate = donate
        self.replicas = replicas
        self.replica_axis = replica_axis
        # donation is categorically ignored on CPU (XLA warns on every
        # call); resolve it per-platform up front so CPU never builds a
        # donating entry — swapping the entry later would recompile it.
        # The decision itself is the backend's donation policy
        # (Backend.resolve_donate); the registry default reproduces the
        # old inline probe bit-for-bit.
        try:
            plat = ((devices[0] if devices else jax.devices()[0])
                    .platform)
        except Exception:
            plat = "cpu"
        from repro.backends import resolve
        self.backend = resolve(backend) if backend is not None else None
        if self.backend is not None:
            self._donate = self.backend.resolve_donate(donate, plat)
        else:
            self._donate = donate and plat != "cpu"
        #: how many launches of one (sig, width) bucket get distinct
        #: staging buffers before the first is rewritten; keep STRICTLY
        #: greater than the number of concurrently unforced launches —
        #: JAX's CPU backend zero-copy aliases aligned numpy inputs, so
        #: rewriting a rotation mutates the device-side view of any
        #: batch that has not finished executing yet
        self.staging_depth = staging_depth
        self._mesh = None
        if replicas > 1:
            from repro.parallel.sharding import replica_mesh
            self._mesh = replica_mesh(replicas, axis=replica_axis,
                                      devices=devices)
        #: jitted batched kernels, one per (signature, bucket width)
        self._fns: dict[tuple[str, int], Callable] = {}
        #: buckets whose first launch already probed donation support
        self._probed: set[tuple[str, int]] = set()
        #: pinned staging buffers: (sig, width) -> staging_depth
        #: rotations of per-input host arrays
        self._staging: dict[tuple[str, int], list[list[np.ndarray]]] = {}
        self._staging_clock: dict[tuple[str, int], int] = {}
        #: width -> number of launches that used that bucket
        self.bucket_launches: dict[int, int] = {}
        #: flight recorder for per-bucket stack/launch spans (None =
        #: untraced; ``False`` opts out even of the global tracer)
        self.tracer = resolve_tracer(trace) if trace is not False else None

    # ------------------------------------------------------------------
    # bucketed pad widths
    # ------------------------------------------------------------------
    def bucket(self, n: int) -> int:
        """Padded width for an ``n``-request batch.

        Next power of two >= ``n``, rounded up to a replica multiple
        and capped at ``max_batch`` — so a 2-request batch launches a
        2-wide kernel, not a ``max_batch``-wide one, and the set of
        compiled batch shapes per app stays logarithmic.
        """
        if n < 1:
            raise ValueError(f"bucket width needs n >= 1, got {n}")
        w = 1
        while w < n:
            w <<= 1
        w = -(-w // self.replicas) * self.replicas
        return min(w, self.max_batch)

    def batched_fn(self, app: CompiledApp, width: int | None = None) -> Callable:
        """The jitted, vmapped, input-donating kernel for one bucket.

        Keyed on ``(signature, width)`` so every bucket keeps its own
        compiled entry (``width=None`` keys a single generic entry
        that jit re-specializes per shape).  With replicas, batch-dim
        shardings on every input/output place each replica's rows on
        its own device; XLA then runs the k copies of the kernel
        concurrently with no cross-device traffic (the farm has no
        inter-worker channels).
        """
        key = (app.signature(), width if width is not None else -1)
        fn = self._fns.get(key)
        if fn is None:
            fn = self._build_fn(app, donate=self._donate)
            self._fns[key] = fn
        return fn

    def _build_fn(self, app: CompiledApp, donate: bool) -> Callable:
        donate_argnums = (tuple(range(len(app.input_names)))
                          if donate else ())
        kwargs: dict[str, Any] = dict(donate_argnums=donate_argnums)
        if self._mesh is not None:
            batch_row = NamedSharding(self._mesh, P(self.replica_axis))
            kwargs["in_shardings"] = tuple(
                batch_row for _ in app.input_names)
            kwargs["out_shardings"] = tuple(
                batch_row for _ in app.output_names)
        return jax.jit(jax.vmap(app.fn), **kwargs)

    def _call(self, app: CompiledApp, width: int,
              args: Sequence[np.ndarray]) -> Any:
        """Invoke one bucket's kernel; steady state is a bare call.

        CPU resolved donation away at construction, so the common
        path is a single dict lookup + call.  On other backends the
        first launch of each bucket runs under a warning probe: if the
        backend reports it ignored donation anyway (the catch/emit
        machinery costs more than a small batch's kernel), the
        bucket's entry is rebuilt without donation — one extra compile
        there, zero warning overhead ever after.  Backends that honor
        donation never warn and keep their donating entry.
        """
        key = (app.signature(), width)
        fn = self.batched_fn(app, width)
        if not self._donate or key in self._probed:
            return fn(*args)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            outs = fn(*args)
        donation_ignored = False
        for rec in caught:
            if "donated" in str(rec.message):
                donation_ignored = True
            else:                      # not ours: let it through
                warnings.warn_explicit(rec.message, rec.category,
                                       rec.filename, rec.lineno)
        if donation_ignored:
            self._fns[key] = self._build_fn(app, donate=False)
        self._probed.add(key)
        return outs

    # ------------------------------------------------------------------
    # zero-copy staging
    # ------------------------------------------------------------------
    def _staging_bufs(self, app: CompiledApp, width: int) -> list[np.ndarray]:
        """The next rotation of pinned staging buffers for one bucket."""
        key = (app.signature(), width)
        rotations = self._staging.get(key)
        if rotations is None:
            rotations = [
                [np.zeros((width,) + tuple(ch.shape), np.dtype(ch.dtype))
                 for ch in app.graph.graph_inputs]
                for _ in range(self.staging_depth)
            ]
            self._staging[key] = rotations
            self._staging_clock[key] = 0
        clock = self._staging_clock[key]
        self._staging_clock[key] = clock + 1
        return rotations[clock % self.staging_depth]

    def stack(self, app: CompiledApp, requests: Sequence[Any],
              pad_to: int | None = None,
              check_shapes: bool = True) -> list[np.ndarray]:
        """Write each request's inputs into the pinned staging buffers.

        Rows land directly in a preallocated ``(width, *shape)`` host
        buffer (one memcpy per row — no per-batch allocation or
        restack); rows beyond ``len(requests)`` keep whatever the
        previous batch staged (padding rows are computed but sliced
        away, so their values are irrelevant).  ``pad_to`` forces a
        width; by default the power-of-two :meth:`bucket` is used.
        The returned buffers are valid until ``staging_depth`` more
        batches of the same (signature, width) are staged.  Rejects an
        empty request list and per-request shape mismatches with
        precise errors instead of letting the row copy fail obscurely
        — the engine's batch formation can race to empty at shutdown,
        and a 0-d/scalar channel input must stage into a ``(B,)``
        buffer, not crash.
        """
        if not requests:
            raise ValueError(
                "cannot stack an empty request batch (engine shutdown "
                "race?); callers must skip empty batches")
        width = max(pad_to or 0, self.bucket(len(requests)), len(requests))
        width = -(-width // self.replicas) * self.replicas
        args = self._staging_bufs(app, width)
        for j, ch in enumerate(app.graph.graph_inputs):
            buf = args[j]
            name = ch.name
            if check_shapes:
                shape = tuple(ch.shape)
                for idx, r in enumerate(requests):
                    row = np.asarray(r.inputs[name])
                    if row.shape != shape:
                        raise ValueError(
                            f"request[{idx}] input {name!r}: expected "
                            f"shape {shape}, got {row.shape}")
                    buf[idx, ...] = row
            else:
                # engine path: rows were shape-checked at submit();
                # numpy's row assignment casts + copies in one shot
                for idx, r in enumerate(requests):
                    buf[idx, ...] = r.inputs[name]
        return args

    def launch(self, app: CompiledApp, requests: Sequence[Any],
               pad_to: int | None = None,
               timings: dict[str, float] | None = None,
               check_shapes: bool = True) -> dict[str, jnp.ndarray]:
        """Dispatch one batched kernel; return stacked outputs, unblocked.

        ``requests`` need only expose ``.inputs`` (a name->array dict);
        they must all share ``app``'s signature.  The batch is padded
        to its power-of-two bucket (or ``pad_to``); output rows beyond
        ``len(requests)`` are padding and must be ignored by the
        caller.  ``timings``, when given, receives the host-side
        ``stack`` (staging-copy) and ``launch`` (dispatch) phase
        durations in seconds.
        """
        if len(requests) > self.max_batch:
            raise ValueError(
                f"batch of {len(requests)} exceeds max_batch={self.max_batch}")
        if not requests:
            raise ValueError(
                "cannot stack an empty request batch (engine shutdown "
                "race?); callers must skip empty batches")
        t0 = time.perf_counter()
        args = self.stack(app, requests, pad_to=pad_to,
                          check_shapes=check_shapes)
        width = args[0].shape[0] if args else len(requests)
        t1 = time.perf_counter()
        outs = self._call(app, width, args)
        t2 = time.perf_counter()
        self.bucket_launches[width] = self.bucket_launches.get(width, 0) + 1
        if timings is not None:
            timings["stack"] = t1 - t0
            timings["launch"] = t2 - t1
        if self.tracer is not None:
            # retroactive complete spans from the stamps above — the
            # recording itself adds nothing between stack and dispatch
            self.tracer.complete("batch.stack", t0, t1 - t0,
                                 cat="batcher", app=app.graph.name,
                                 width=width, rows=len(requests))
            self.tracer.complete("batch.launch", t1, t2 - t1,
                                 cat="batcher", app=app.graph.name,
                                 width=width)
        return dict(zip(app.output_names, outs))
