"""Micro-batching: stack same-signature requests, launch ONE kernel.

Per-request dispatch pays the host-side launch overhead once per
item; a serving engine under load amortizes it by stacking requests
whose apps share a :meth:`~repro.core.host.CompiledApp.signature`
along a new leading axis and launching a single ``vmap``-ped kernel.
The batched callable is built once per signature (jit keeps it warm)
with every input donated — the stacked staging buffers are created
per batch and never reused, so their HBM can be recycled in place,
the launcher-level analogue of the paper's buffer reuse between
command-queue runs.

With ``replicas > 1`` the padded batch is additionally *sharded* over
a 1-D device mesh: replica ``r`` executes rows ``[r*B/k, (r+1)*B/k)``
of every staging buffer — the batch-parallel farm (FastFlow's
``ff_farm`` worker replication, FLOWER's kernel replication) on top of
the same single-launch dispatch.  The padded width is held to a
multiple of the replica count so every launch keeps one compiled
kernel shape per replica.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.host import CompiledApp

__all__ = ["MicroBatcher"]


class MicroBatcher:
    """Stacks same-signature requests and launches one batched kernel.

    ``launch`` is asynchronous: it returns the stacked device outputs
    without blocking, so the engine can keep a second batch in flight
    (double buffering) before forcing the first to host memory.
    """

    def __init__(self, max_batch: int = 8, donate: bool = True,
                 replicas: int = 1, replica_axis: str = "replica",
                 devices: list | None = None):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        if max_batch % replicas != 0:
            raise ValueError(
                f"max_batch={max_batch} must divide evenly over "
                f"replicas={replicas}: every replica serves "
                f"max_batch/replicas rows of the padded batch")
        self.max_batch = max_batch
        self.donate = donate
        self.replicas = replicas
        self.replica_axis = replica_axis
        self._mesh = None
        if replicas > 1:
            from repro.parallel.sharding import replica_mesh
            self._mesh = replica_mesh(replicas, axis=replica_axis,
                                      devices=devices)
        self._fns: dict[str, Callable] = {}

    def batched_fn(self, app: CompiledApp) -> Callable:
        """The jitted, vmapped, input-donating kernel for ``app``.

        With replicas, batch-dim shardings on every input/output place
        each replica's rows on its own device; XLA then runs the k
        copies of the kernel concurrently with no cross-device traffic
        (the farm has no inter-worker channels).
        """
        sig = app.signature()
        fn = self._fns.get(sig)
        if fn is None:
            donate_argnums = (tuple(range(len(app.input_names)))
                              if self.donate else ())
            kwargs: dict[str, Any] = dict(donate_argnums=donate_argnums)
            if self._mesh is not None:
                batch_row = NamedSharding(self._mesh, P(self.replica_axis))
                kwargs["in_shardings"] = tuple(
                    batch_row for _ in app.input_names)
                kwargs["out_shardings"] = tuple(
                    batch_row for _ in app.output_names)
            fn = jax.jit(jax.vmap(app.fn), **kwargs)
            self._fns[sig] = fn
        return fn

    def stack(self, app: CompiledApp, requests: Sequence[Any],
              pad_to: int | None = None) -> list[np.ndarray]:
        """Stack each graph input across requests along a leading axis.

        With ``pad_to`` the batch is padded (repeating the last row) to
        a fixed width, so every launch reuses ONE compiled kernel shape
        instead of re-tracing per ragged batch size; the width is
        always rounded up to a multiple of the replica count.  Rejects
        an empty request list and per-request shape mismatches with
        precise errors instead of letting ``np.stack`` fail obscurely —
        the engine's ``_next_batch`` can race to empty at shutdown, and
        a 0-d/scalar channel input must stack to a ``(B,)`` staging
        buffer, not crash.
        """
        if not requests:
            raise ValueError(
                "cannot stack an empty request batch (engine shutdown "
                "race?); callers must skip empty batches")
        width = max(pad_to or 0, len(requests))
        width = -(-width // self.replicas) * self.replicas
        args = []
        for ch in app.graph.graph_inputs:
            # stack on the host (one memcpy per row) so the launch
            # transfers ONE contiguous staging buffer instead of
            # dispatching a per-row device op
            rows = []
            for idx, r in enumerate(requests):
                row = np.asarray(r.inputs[ch.name], dtype=np.dtype(ch.dtype))
                if row.shape != tuple(ch.shape):
                    raise ValueError(
                        f"request[{idx}] input {ch.name!r}: expected "
                        f"shape {tuple(ch.shape)}, got {row.shape}")
                rows.append(row)
            rows.extend(rows[-1:] * (width - len(rows)))
            args.append(np.stack(rows))
        return args

    def launch(self, app: CompiledApp, requests: Sequence[Any],
               pad_to: int | None = None) -> dict[str, jnp.ndarray]:
        """Dispatch one batched kernel; return stacked outputs, unblocked.

        ``requests`` need only expose ``.inputs`` (a name->array dict);
        they must all share ``app``'s signature.  Output rows beyond
        ``len(requests)`` are padding and must be ignored by the caller.
        """
        if len(requests) > self.max_batch:
            raise ValueError(
                f"batch of {len(requests)} exceeds max_batch={self.max_batch}")
        args = self.stack(app, requests, pad_to=pad_to)
        with warnings.catch_warnings():
            # CPU/interpret backends ignore donation; stay quiet about it
            warnings.filterwarnings("ignore", message=".*donated.*")
            outs = self.batched_fn(app)(*args)
        return dict(zip(app.output_names, outs))
