"""Micro-batching: stack same-signature requests, launch ONE kernel.

Per-request dispatch pays the host-side launch overhead once per
item; a serving engine under load amortizes it by stacking requests
whose apps share a :meth:`~repro.core.host.CompiledApp.signature`
along a new leading axis and launching a single ``vmap``-ped kernel.
The batched callable is built once per signature (jit keeps it warm)
with every input donated — the stacked staging buffers are created
per batch and never reused, so their HBM can be recycled in place,
the launcher-level analogue of the paper's buffer reuse between
command-queue runs.
"""
from __future__ import annotations

import warnings
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.host import CompiledApp

__all__ = ["MicroBatcher"]


class MicroBatcher:
    """Stacks same-signature requests and launches one batched kernel.

    ``launch`` is asynchronous: it returns the stacked device outputs
    without blocking, so the engine can keep a second batch in flight
    (double buffering) before forcing the first to host memory.
    """

    def __init__(self, max_batch: int = 8, donate: bool = True):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.max_batch = max_batch
        self.donate = donate
        self._fns: dict[str, Callable] = {}

    def batched_fn(self, app: CompiledApp) -> Callable:
        """The jitted, vmapped, input-donating kernel for ``app``."""
        sig = app.signature()
        fn = self._fns.get(sig)
        if fn is None:
            donate_argnums = (tuple(range(len(app.input_names)))
                              if self.donate else ())
            fn = jax.jit(jax.vmap(app.fn), donate_argnums=donate_argnums)
            self._fns[sig] = fn
        return fn

    def stack(self, app: CompiledApp, requests: Sequence[Any],
              pad_to: int | None = None) -> list[np.ndarray]:
        """Stack each graph input across requests along a leading axis.

        With ``pad_to`` the batch is padded (repeating the last row) to
        a fixed width, so every launch reuses ONE compiled kernel shape
        instead of re-tracing per ragged batch size.
        """
        width = max(pad_to or 0, len(requests))
        args = []
        for ch in app.graph.graph_inputs:
            # stack on the host (one memcpy per row) so the launch
            # transfers ONE contiguous staging buffer instead of
            # dispatching a per-row device op
            rows = [np.asarray(r.inputs[ch.name],
                               dtype=np.dtype(ch.dtype)) for r in requests]
            rows.extend(rows[-1:] * (width - len(rows)))
            args.append(np.stack(rows))
        return args

    def launch(self, app: CompiledApp, requests: Sequence[Any],
               pad_to: int | None = None) -> dict[str, jnp.ndarray]:
        """Dispatch one batched kernel; return stacked outputs, unblocked.

        ``requests`` need only expose ``.inputs`` (a name->array dict);
        they must all share ``app``'s signature.  Output rows beyond
        ``len(requests)`` are padding and must be ignored by the caller.
        """
        if len(requests) > self.max_batch:
            raise ValueError(
                f"batch of {len(requests)} exceeds max_batch={self.max_batch}")
        args = self.stack(app, requests, pad_to=pad_to)
        with warnings.catch_warnings():
            # CPU/interpret backends ignore donation; stay quiet about it
            warnings.filterwarnings("ignore", message=".*donated.*")
            outs = self.batched_fn(app)(*args)
        return dict(zip(app.output_names, outs))
