"""Serving runtimes for compiled dataflow apps (the XRT layer).

``engine.py`` is the dataflow serving engine (:class:`StreamEngine`);
``cache.py``/``batching.py``/``telemetry.py``/``slots.py`` are its
parts.  The LM-serving scheduler (``batcher.py``) and training loops
(``trainer.py``, ``steps.py``, ``fault.py``) live beside it and are
imported directly — they pull in the model stack, which this package
namespace deliberately does not.
"""
from repro.runtime.batching import MicroBatcher
from repro.runtime.cache import CacheStats, CompileCache
from repro.runtime.engine import (CancelledError, QueueFullError,
                                  StreamEngine, StreamRequest)
from repro.runtime.slots import SlotPool
from repro.runtime.telemetry import PHASES, Telemetry, modeled_latency

__all__ = [
    "MicroBatcher", "CacheStats", "CompileCache", "CancelledError",
    "QueueFullError", "StreamEngine", "StreamRequest", "SlotPool",
    "Telemetry", "PHASES", "modeled_latency",
]
