"""The backend registry: ``register()`` once, ``resolve()`` everywhere.

One process-global table of :class:`~repro.backends.spec.Backend`
records.  Every subsystem that used to compare backend names —
lowering, tuning, serving, replication, the LM kernels — now resolves
a name (or passes a ``Backend`` straight through) and reads the
declarative record.  Adding a target is a :func:`register` call, not
a repo-wide grep (see ``docs/backends.md`` for the walkthrough).
"""
from __future__ import annotations

import threading

from repro.backends.spec import Backend, UnsupportedBackendError

__all__ = ["register", "resolve", "resolve_calibrated", "get", "names",
           "backends", "unregister", "use_pallas_kernels"]

_lock = threading.Lock()
_registry: dict[str, Backend] = {}


def register(backend: Backend, *, replace: bool = False) -> Backend:
    """Add ``backend`` to the registry; returns it for chaining.

    Re-registering an existing name is an error unless
    ``replace=True`` — two subsystems silently fighting over one name
    is exactly the drift this layer exists to kill.
    """
    if not isinstance(backend, Backend):
        raise TypeError(f"register() takes a Backend, got "
                        f"{type(backend).__name__}")
    with _lock:
        if backend.name in _registry and not replace:
            raise ValueError(
                f"backend {backend.name!r} is already registered; pass "
                f"replace=True to substitute it")
        _registry[backend.name] = backend
    return backend


def unregister(name: str) -> None:
    """Remove a backend (tests registering throwaway targets)."""
    with _lock:
        _registry.pop(name, None)


def resolve(backend) -> Backend:
    """Normalize a backend argument into a :class:`Backend`.

    Accepts a registered name or a ``Backend`` instance (passed
    through, registered or not — ad-hoc specs are legal for tests and
    experiments).  Unknown names raise the typed
    :class:`UnsupportedBackendError` listing what IS registered.
    """
    if isinstance(backend, Backend):
        return backend
    if isinstance(backend, str):
        with _lock:
            be = _registry.get(backend)
        if be is not None:
            return be
        raise UnsupportedBackendError(
            f"unknown backend {backend!r}; registered backends: "
            f"{names()}", backend=str(backend), missing=("registered",))
    raise UnsupportedBackendError(
        f"backend must be a name or a Backend spec, got "
        f"{type(backend).__name__}", missing=("registered",))


def resolve_calibrated(backend, calibrate="auto", **kwargs) -> Backend:
    """Resolve ``backend``, swapping in its calibrated spec if one applies.

    The registry stays the single resolution point: callers that honor
    a ``calibrate=`` argument (``compile_graph``, ``tune_graph``,
    ``replicate_app``) route it here instead of each re-implementing
    the lookup.  ``calibrate=None``/``False`` (or no persisted/fittable
    calibration for this backend + device kind) returns the registered
    record *unchanged* — same object, same digest, so uncalibrated
    compile/tuning cache keys are bit-stable across this feature.  A
    hit returns a copy via :meth:`~repro.backends.spec.Backend.with_spec`
    whose digest reflects the fitted constants, giving calibrated runs
    their own cache namespace.  ``kwargs`` pass through to
    :func:`repro.tune.calibrate.resolve_calibration` (``store=``,
    ``device_kind=``, ``drift=``).
    """
    be = resolve(backend)
    if calibrate is None or calibrate is False:
        return be
    # lazy import: backends must stay importable without the tune
    # package (which imports core, which imports backends)
    from repro.tune.calibrate import resolve_calibration
    spec = resolve_calibration(be, calibrate, **kwargs)
    if spec is None or spec is be.spec:
        return be
    return be.with_spec(spec)


def get(name: str) -> Backend | None:
    """The registered backend named ``name``, or ``None``."""
    with _lock:
        return _registry.get(name)


def names() -> tuple[str, ...]:
    """Registered backend names, registration order."""
    with _lock:
        return tuple(_registry)


def use_pallas_kernels(impl: str, *, auto_native: bool = True) -> bool:
    """Resolve the LM kernels' ``impl=`` knob against the registry.

    ``impl="pallas"`` always selects the Pallas kernels; ``"ref"`` (or
    any other value) never does; ``"auto"`` asks whether the registered
    ``pallas`` backend is native on the current platform
    (:meth:`~repro.backends.spec.Backend.is_native` — the one device
    probe shared with the dataflow stack, replacing the per-module
    ``jax.default_backend() == "tpu"`` copies).  ``auto_native=False``
    restricts to the explicit request — for dispatchers that have a
    better portable path than the reference oracle (e.g. the chunked
    XLA attention scan).
    """
    if impl == "pallas":
        return True
    if impl == "auto" and auto_native:
        return resolve("pallas").is_native()
    return False


def backends() -> tuple[Backend, ...]:
    """Every registered backend, registration order."""
    with _lock:
        return tuple(_registry.values())
