"""The seed backends: the registry entries the repo ships with.

``xla``, ``xla_staged`` and ``pallas`` are the paper's three lowering
regimes (portable baseline, AnyHLS-style staged baseline, the fused
streaming artifact) with behaviour bit-identical to the pre-registry
if/elif chains.  ``pallas_gpu`` is the proof that a fourth target is a
registry entry, not a repo-wide grep: it registers, reports its
capabilities, and is rejected with a typed
:class:`~repro.backends.spec.UnsupportedBackendError` — never a crash
— when asked to lower something it cannot serve (a stencil stage, or
any stage on a host without a GPU).
"""
from __future__ import annotations

from typing import Any, Callable

from repro.backends.registry import register
from repro.backends.spec import Backend, STAGE_KINDS

__all__ = ["XLA", "XLA_STAGED", "PALLAS", "PALLAS_GPU", "SEED_BACKENDS"]


# ----------------------------------------------------------------------
# lower hooks: thin adapters over the kernel generators in core.fusion
# ----------------------------------------------------------------------
def _lower_xla(group, *, backend: Backend, spec: Any,
               vector_factor: int | None, interpret: bool,
               valid_rows: tuple[int, int] | None,
               staged: bool = False) -> Callable:
    from repro.core.fusion import lower_group_xla
    return lower_group_xla(group, staged=staged, valid_rows=valid_rows)


def _lower_xla_staged(group, **kw) -> Callable:
    # trivial (custom/reduce) groups are single opaque stages: there is
    # nothing to stage *between*, and the plain composition is what the
    # pre-registry chain ran for them on every backend
    return _lower_xla(group, staged=not group.is_trivial, **kw)


def _lower_pallas(group, *, backend: Backend, spec: Any,
                  vector_factor: int | None, interpret: bool,
                  valid_rows: tuple[int, int] | None) -> Callable:
    from repro.core.fusion import lower_group_pallas, lower_group_xla
    if group.is_trivial:
        # custom/reduce singletons have no streaming tile structure;
        # they run as host-composed jnp on every backend
        return lower_group_xla(group, staged=False, valid_rows=valid_rows)
    return lower_group_pallas(group, spec, vector_factor, interpret,
                              valid_rows=valid_rows)


def _tuner_measure(graph, backend, config, **kw) -> float:
    """Default measurement harness: lower under ``config`` and time on
    the live backend (:func:`repro.tune.search.default_measure`).
    Lazy import: the spec layer must not depend on the tuner."""
    from repro.tune.search import default_measure
    return default_measure(graph, backend, config, **kw)


# ----------------------------------------------------------------------
# the registered seeds
# ----------------------------------------------------------------------
XLA = register(Backend(
    name="xla",
    description="portable baseline: stages composed as jnp ops, "
                "XLA's own fuser handles them",
    capabilities=frozenset(STAGE_KINDS) | {"tuning", "replication"},
    native_platforms=(),          # no pallas kernels: interpret is inert
    lower=_lower_xla,
    measure=_tuner_measure,
))

XLA_STAGED = register(Backend(
    name="xla_staged",
    description="AnyHLS/no-dataflow baseline: optimization barrier "
                "after every stage, each intermediate round-trips HBM",
    capabilities=frozenset(STAGE_KINDS)
    | {"tuning", "replication", "staged_hbm"},
    native_platforms=(),
    lower=_lower_xla_staged,
    measure=_tuner_measure,
))

PALLAS = register(Backend(
    name="pallas",
    description="THE paper artifact: one fused streaming Pallas kernel "
                "per fusion group (interpreted off-TPU)",
    capabilities=frozenset(STAGE_KINDS)
    | {"tuning", "replication", "fused_streaming"},
    native_platforms=("tpu",),
    lower=_lower_pallas,
    measure=_tuner_measure,
))

#: registered but capability-gated: declares what a Mosaic-GPU/Triton
#: lowering WILL serve (elementwise pipelines first), requires a GPU,
#: and has no lower hook yet — every rejection is a typed
#: UnsupportedBackendError naming the missing capability or platform.
PALLAS_GPU = register(Backend(
    name="pallas_gpu",
    description="Mosaic GPU / Triton target (stub): elementwise "
                "pipelines only, gated on a GPU host",
    capabilities=frozenset({"point", "pointN", "split", "tuning"}),
    native_platforms=("gpu", "cuda", "rocm"),
    requires_platform="gpu",
    lower=None,
    measure=_tuner_measure,
))

#: the lowerable seed trio — what tests/benchmarks sweep; the gated
#: ``pallas_gpu`` stub is registered but intentionally NOT in this
#: tuple (it cannot lower on non-GPU hosts)
SEED_BACKENDS = ("xla", "xla_staged", "pallas")
