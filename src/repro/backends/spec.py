"""The :class:`Backend` spec: one declarative record per target.

FLOWER lowers one dataflow program to different targets (the Avnet
Ultra96 SoC vs. the Alveo U280 card) through a single canonical
pipeline; the per-target decisions — lowering strategy, datapath
constants, memory budgets, measurement harness — live in *flow*
descriptions, not sprinkled through the compiler.  This module is the
software analogue (after edalize's flow classes): a ``Backend`` is a
frozen dataclass naming

- **identity** — ``name`` and a stable :meth:`digest` over
  capabilities + constants, so caches keyed on a backend can never
  serve an incompatible target;
- **capabilities** — the set of stage kinds (``point``, ``stencil``,
  ``custom``, ...) and features the target can lower.  Asking for
  anything outside the set raises the single typed
  :class:`UnsupportedBackendError` naming what is missing — never a
  bare ``KeyError`` deep inside a lowering;
- **hardware constants** — lane width, sublane rows, default tile cap
  and the :class:`~repro.core.vectorize.TPUSpec` memory/compute
  budgets that the vectorizer's sweep and the scheduler's fusion
  budget read (subsuming the ad-hoc ``TPUSpec`` plumbing);
- **hooks** — ``lower`` (group -> callable kernel), ``measure`` (the
  autotuner's timing harness) and policies the serving runtime used
  to re-derive locally: donation (:class:`MicroBatcher
  <repro.runtime.batching.MicroBatcher>`), staging depth slack, and
  interpret-vs-compiled resolution.

Backends are registered once (:mod:`repro.backends.registry`) and
resolved everywhere else; no other module may compare backend names.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Callable

from repro.core.graph import GraphError

__all__ = ["Backend", "UnsupportedBackendError", "STAGE_KINDS"]

#: every stage kind a DataflowGraph can contain; a backend's
#: capability set is validated against this vocabulary
STAGE_KINDS = ("point", "pointN", "split", "stencil", "custom", "reduce")

#: non-stage capability flags a backend may declare
FEATURE_CAPS = ("fused_streaming", "staged_hbm", "replication", "tuning")


class UnsupportedBackendError(GraphError):
    """A backend cannot serve the request — and says exactly why.

    Raised for an unknown backend name, a stage kind outside the
    backend's capability set, or a registered-but-gated backend whose
    device requirement is not met.  ``missing`` carries the
    capability (or requirement) that was absent so tooling can react
    programmatically; the message names it for humans.
    """

    def __init__(self, message: str, *, backend: str = "",
                 missing: tuple[str, ...] = ()):
        super().__init__(message)
        self.backend = backend
        self.missing = tuple(missing)


def _default_platform() -> str:
    """The platform JAX would run on ("cpu" / "tpu" / "gpu" / ...)."""
    try:
        import jax
        return jax.default_backend()
    except Exception:  # pragma: no cover - no jax backend at all
        return "cpu"


@dataclasses.dataclass(frozen=True)
class Backend:
    """Declarative description of one lowering target.

    Instances are immutable; behavioural variation lives in the
    ``lower`` / ``measure`` hooks and the policy fields, never in
    call-site string comparisons.  Two backends with equal
    capabilities and constants share a :meth:`digest`, so compile and
    tuning caches keyed on :meth:`cache_key` transfer between them
    exactly when that is safe.
    """

    name: str
    #: one-line human description (docs/backends.md table)
    description: str = ""
    #: stage kinds + feature flags this backend can lower
    capabilities: frozenset = frozenset(STAGE_KINDS)
    #: platforms where this backend's kernels compile natively
    #: (outside them, pallas-style backends run interpreted)
    native_platforms: tuple = ()
    #: platform the backend *requires* to lower at all (``None`` =
    #: runs anywhere); a gated backend registers and reports its
    #: capabilities but refuses to lower off-target
    requires_platform: str | None = None

    # -- hardware constants (subsume the ad-hoc TPUSpec plumbing) ------
    #: VPU/MXU lane width: fused tiles are ``lane * vector_factor`` wide
    lane: int = 128
    #: sublane rows (float32): tile heights align to this
    sublane: int = 8
    #: default (th, tw) cap for tile selection
    default_max_tile: tuple = (256, 1024)
    #: memory-space / bandwidth / clock budgets (VMEM, HBM, ...)
    spec: Any = None

    # -- hooks ---------------------------------------------------------
    #: ``lower(group, *, backend, spec, vector_factor, interpret,
    #: valid_rows) -> Callable`` producing the group's kernel; ``None``
    #: marks a registered-but-gated stub
    lower: Callable | None = None
    #: ``measure(graph, backend, config, **kw) -> seconds`` for the
    #: autotuner; ``None`` falls back to
    #: :func:`repro.tune.search.default_measure`
    measure: Callable | None = None

    # -- runtime policies ---------------------------------------------
    #: buffer-donation policy for the MicroBatcher: ``"auto"`` donates
    #: except on platforms that ignore it (probing once per bucket
    #: elsewhere), ``"never"`` disables donation outright
    donation: str = "auto"
    #: extra staging-buffer rotations beyond the in-flight depth the
    #: engine must keep (zero-copy aliasing safety margin)
    staging_slack: int = 1

    def __post_init__(self):
        caps = frozenset(self.capabilities)
        object.__setattr__(self, "capabilities", caps)
        vocab = set(STAGE_KINDS) | set(FEATURE_CAPS)
        unknown = caps - vocab
        if unknown:
            raise ValueError(
                f"backend {self.name!r} declares unknown capabilities "
                f"{sorted(unknown)}; known: {sorted(vocab)}")
        if self.donation not in ("auto", "never"):
            raise ValueError(
                f"backend {self.name!r}: donation policy must be 'auto' "
                f"or 'never', got {self.donation!r}")
        if self.spec is None:
            from repro.core.vectorize import V5E
            object.__setattr__(self, "spec", V5E)

    # ------------------------------------------------------------------
    # identity
    # ------------------------------------------------------------------
    def constants(self) -> dict[str, Any]:
        """The tuning-relevant constants, JSON-ready."""
        spec_fields = sorted(
            (f, repr(getattr(self.spec, f)))
            for f in getattr(self.spec, "__dataclass_fields__", ()))
        return {"lane": self.lane, "sublane": self.sublane,
                "default_max_tile": list(self.default_max_tile),
                "spec": spec_fields}

    def to_json(self) -> dict[str, Any]:
        """Structural form for cache keying (see ``CompileCache``)."""
        return {"name": self.name,
                "capabilities": sorted(self.capabilities),
                "native_platforms": list(self.native_platforms),
                "requires_platform": self.requires_platform,
                "donation": self.donation,
                "staging_slack": self.staging_slack,
                "constants": self.constants()}

    def digest(self) -> str:
        """Stable digest of capabilities + constants.

        Compile and tuning caches key on this (via
        :meth:`cache_key`): a backend whose capability set or hardware
        constants change gets a fresh cache namespace, so a schedule
        measured for one target is never served to an incompatible
        one.
        """
        blob = json.dumps(self.to_json(), sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:12]

    def cache_key(self) -> str:
        """``name@digest`` — the string caches store for this backend."""
        return f"{self.name}@{self.digest()}"

    def with_spec(self, spec: Any) -> "Backend":
        """A copy of this record carrying ``spec`` as its constants.

        The calibration path (:func:`repro.backends.resolve_calibrated`)
        uses this to swap a fitted
        :class:`~repro.tune.calibrate.CalibratedSpec` in: the copy's
        :meth:`digest` — and therefore every compile/tuning cache key —
        reflects the new constants, while the registered (uncalibrated)
        record and its digest are untouched.
        """
        if spec is self.spec:
            return self
        return dataclasses.replace(self, spec=spec)

    # ------------------------------------------------------------------
    # capability gating
    # ------------------------------------------------------------------
    def supports(self, *caps: str) -> bool:
        return all(c in self.capabilities for c in caps)

    def missing(self, *caps: str) -> tuple[str, ...]:
        return tuple(sorted(set(caps) - self.capabilities))

    def require(self, *caps: str, context: str = "") -> None:
        """Raise :class:`UnsupportedBackendError` naming absent caps."""
        absent = self.missing(*caps)
        if absent:
            where = f" ({context})" if context else ""
            raise UnsupportedBackendError(
                f"backend {self.name!r} does not support "
                f"{', '.join(absent)}{where}; its capabilities are "
                f"{sorted(self.capabilities)}",
                backend=self.name, missing=absent)

    def available(self) -> bool:
        """True when the backend's platform requirement is met here."""
        if self.requires_platform is None:
            return True
        return _default_platform() == self.requires_platform

    def is_native(self) -> bool:
        """True when kernels compile natively on the current platform."""
        return _default_platform() in self.native_platforms

    # ------------------------------------------------------------------
    # policy resolution (the decisions consumers used to re-derive)
    # ------------------------------------------------------------------
    def resolve_interpret(self, interpret: bool | None) -> bool:
        """Resolve the interpret-vs-compiled mode.

        An explicit ``True``/``False`` wins; ``None`` defers to the
        backend: interpreted unless its kernels compile natively on
        the current platform (a pallas backend on a real TPU runs
        compiled; everywhere else — and for the XLA backends, which
        have no pallas kernels at all — the historical interpreted
        default is kept).
        """
        if interpret is not None:
            return bool(interpret)
        return not self.is_native()

    def resolve_donate(self, donate: bool, platform: str | None = None) -> bool:
        """Whether the batcher should build donating entries.

        ``donation="never"`` wins outright; ``"auto"`` donates except
        on CPU, where XLA categorically ignores donation and warns on
        every call.
        """
        if not donate or self.donation == "never":
            return False
        plat = platform if platform is not None else _default_platform()
        return plat != "cpu"

    def staging_depth(self, inflight: int) -> int:
        """Staging rotations the engine must allocate for ``inflight``
        concurrently unforced launches (zero-copy aliasing margin)."""
        return inflight + self.staging_slack

    # ------------------------------------------------------------------
    # lowering
    # ------------------------------------------------------------------
    def lower_group(self, group, *, spec: Any = None,
                    vector_factor: int | None = None,
                    interpret: bool | None = None,
                    valid_rows: tuple[int, int] | None = None) -> Callable:
        """Capability-check ``group`` then hand it to the lower hook.

        Every stage kind in the group must be in the capability set
        and the platform requirement must hold; violations raise the
        typed :class:`UnsupportedBackendError` before any lowering
        machinery runs.
        """
        kinds = {st.kind for st in group.stages}
        self.require(*sorted(kinds),
                     context="stages " + ",".join(s.name
                                                  for s in group.stages))
        if not self.available():
            raise UnsupportedBackendError(
                f"backend {self.name!r} requires platform "
                f"{self.requires_platform!r} but this host runs "
                f"{_default_platform()!r}; it is registered (capabilities "
                f"{sorted(self.capabilities)}) but cannot lower here",
                backend=self.name,
                missing=(f"platform:{self.requires_platform}",))
        if self.lower is None:
            raise UnsupportedBackendError(
                f"backend {self.name!r} has no lowering hook; it is a "
                f"registered stub awaiting an implementation",
                backend=self.name, missing=("lower",))
        return self.lower(group, backend=self,
                          spec=spec if spec is not None else self.spec,
                          vector_factor=vector_factor,
                          interpret=self.resolve_interpret(interpret),
                          valid_rows=valid_rows)

    def __repr__(self) -> str:  # keep logs/keys short and readable
        return f"Backend({self.name!r})"
