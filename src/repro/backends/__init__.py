"""Backend abstraction layer: one registry drives lowering, tuning,
serving, and replication.

The public surface:

- :class:`Backend` — the declarative per-target spec (capabilities,
  lane/sublane/VMEM constants, ``lower`` and ``measure`` hooks,
  donation/staging/interpret policies),
- :func:`register` / :func:`resolve` / :func:`names` /
  :func:`backends` — the process-global registry,
- :class:`UnsupportedBackendError` — the single typed rejection,
- :data:`SEED_BACKENDS` — the lowerable seed trio
  (``xla``, ``xla_staged``, ``pallas``); ``pallas_gpu`` is registered
  as a capability-gated stub,
- :func:`current_platform` — the one device probe shared by the
  dataflow stack and the LM kernels.

Everything else in the repo resolves a backend here and reads the
record; see ``docs/backends.md`` for the anatomy and the
add-a-backend walkthrough.
"""
from repro.backends.registry import (backends, get, names, register,
                                     resolve, resolve_calibrated,
                                     unregister, use_pallas_kernels)
from repro.backends.spec import (Backend, STAGE_KINDS,
                                 UnsupportedBackendError,
                                 _default_platform as current_platform)
from repro.backends.seeds import (PALLAS, PALLAS_GPU, SEED_BACKENDS, XLA,
                                  XLA_STAGED)

__all__ = [
    "Backend", "UnsupportedBackendError", "STAGE_KINDS",
    "register", "resolve", "resolve_calibrated", "get", "names",
    "backends", "unregister",
    "current_platform", "use_pallas_kernels",
    "XLA", "XLA_STAGED", "PALLAS", "PALLAS_GPU", "SEED_BACKENDS",
]
