"""Canonicalization passes (FLOWER's *automatic transformations*).

The paper's headline claim is that the programmer writes the natural
single-source program and the compiler rewrites it into the canonical
dataflow form — nobody hand-inserts ``split`` stages or prunes dead
arms.  This module is that mid-end: a tiny pass manager in the style
of LLVM/MLIR (and of the transformation catalogue in "Transformations
of High-Level Synthesis Codes for High-Performance Computing").

Every pass takes a :class:`~repro.core.graph.DataflowGraph`, rewrites
it **in place** (so Channel/Stage objects held by the caller stay
valid), and returns ``(graph, diagnostics)`` where ``diagnostics`` is
a human-readable list of what was changed.  :class:`PassPipeline`
chains passes and tags each diagnostic with the pass name; the
scheduler surfaces them through ``Schedule.describe()``.

Built-in passes:

- :class:`AutoSplitInsertion` — rewrite every multi-reader channel
  into an explicit ``split`` stage (the canonical-form transformation
  of paper Section IV-A; without it the validator rejects the graph).
- :class:`DeadChannelElimination` — drop channels that are never read
  (and the stages that only feed them), prune dead ``split`` arms, and
  collapse single-arm splits into a wire.
- :class:`PointFusion` — compose adjacent ``point``/``pointN`` stages
  into one stage so the scheduler sees fewer FIFO hops (the classical
  producer/consumer elementwise fusion; bit-exact because function
  composition preserves op order).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Protocol, Sequence, runtime_checkable

from repro.core.graph import Channel, DataflowGraph, Stage

__all__ = [
    "Pass",
    "PassPipeline",
    "AutoSplitInsertion",
    "DeadChannelElimination",
    "PointFusion",
    "default_pipeline",
]

#: stage kinds PointFusion may compose
_POINT_KINDS = frozenset({"point", "pointN"})


@runtime_checkable
class Pass(Protocol):
    """A graph-to-graph rewrite with human-readable diagnostics."""

    name: str

    def run(self, graph: DataflowGraph
            ) -> tuple[DataflowGraph, list[str]]: ...


@dataclasses.dataclass
class PassPipeline:
    """Run a sequence of passes, collecting tagged diagnostics.

    With a :class:`~repro.obs.tracer.Tracer` passed as ``tracer``,
    every pass runs inside a ``compile.pass.<name>`` span carrying its
    rewrite count — per-pass timing and diagnostics in the flight
    recorder, the compile-side analogue of the engine's phase spans.
    """

    passes: tuple[Pass, ...]

    def run(self, graph: DataflowGraph, tracer=None
            ) -> tuple[DataflowGraph, list[str]]:
        diags: list[str] = []
        for p in self.passes:
            if tracer is None:
                graph, d = p.run(graph)
            else:
                with tracer.span(f"compile.pass.{p.name}", cat="compile",
                                 graph=graph.name) as sp:
                    graph, d = p.run(graph)
                    sp.set(rewrites=len(d))
            diags.extend(f"[{p.name}] {line}" for line in d)
        return graph, diags


def default_pipeline(extra: Sequence[Pass] = ()) -> PassPipeline:
    """The canonicalization pipeline ``compile_graph`` runs by default."""
    return PassPipeline((AutoSplitInsertion(), DeadChannelElimination(),
                         PointFusion(), *extra))


# ----------------------------------------------------------------------
# AutoSplitInsertion
# ----------------------------------------------------------------------
class AutoSplitInsertion:
    """Make fan-out explicit: k readers of one channel -> one ``split``.

    For every channel read more than once, insert a ``split`` stage
    that copies the channel into one fresh channel per read site and
    rewire each reader onto its private copy.  A reader consuming the
    same channel at several input positions gets one copy per
    position.  After this pass the single-writer/single-reader channel
    contract holds and ``validate()`` accepts the graph.
    """

    name = "auto-split"

    def run(self, graph: DataflowGraph) -> tuple[DataflowGraph, list[str]]:
        diags: list[str] = []
        for ch in list(graph.channels):
            if len(ch.consumers) <= 1:
                continue
            sites = [(st, i) for st in dict.fromkeys(ch.consumers)
                     for i, ic in enumerate(st.inputs) if ic is ch]
            copies: list[Channel] = []
            for st, i in sites:
                cp = Channel(f"{ch.name}.{len(copies)}", ch.shape, ch.dtype)
                cp.consumers = [st]
                st.inputs[i] = cp
                graph.channels.append(cp)
                copies.append(cp)
            split = Stage(f"autosplit_{ch.name}", "split", None,
                          [ch], copies)
            for cp in copies:
                cp.producer = split
            ch.consumers = [split]
            graph.stages.append(split)
            diags.append(
                f"channel {ch.name!r} read {len(sites)}x by "
                f"{sorted({st.name for st, _ in sites})}; inserted "
                f"{split.name!r} with {len(copies)} arms")
        return graph, diags


# ----------------------------------------------------------------------
# DeadChannelElimination
# ----------------------------------------------------------------------
class DeadChannelElimination:
    """Remove channels nobody reads and the stages that only feed them.

    Iterates to a fixpoint: pruning a stage can orphan its input
    channels, which may in turn kill their producers.  ``split`` arms
    are pruned individually, and a split left with a single live arm
    is collapsed into a plain wire (reader moved onto the split's
    input) unless the arm is a graph output.  Unread graph inputs are
    dropped from the graph (they become unused launcher buffers).
    """

    name = "dead-channel"

    def run(self, graph: DataflowGraph) -> tuple[DataflowGraph, list[str]]:
        diags: list[str] = []
        changed = True
        while changed:
            changed = False
            for ch in list(graph.channels):
                if ch not in graph.channels:   # sibling removed this sweep
                    continue
                if ch.consumers or ch.is_graph_output:
                    continue
                st = ch.producer
                if st is None:
                    graph.channels.remove(ch)
                    diags.append(
                        f"removed unread {'input ' if ch.is_graph_input else ''}"
                        f"channel {ch.name!r}")
                    changed = True
                elif st.kind == "split" and len(st.outputs) > 1:
                    st.outputs.remove(ch)
                    graph.channels.remove(ch)
                    diags.append(f"pruned dead arm {ch.name!r} of split "
                                 f"{st.name!r}")
                    changed = True
                elif all(not o.consumers and not o.is_graph_output
                         for o in st.outputs):
                    for o in st.outputs:
                        graph.channels.remove(o)
                    for ic in st.inputs:
                        ic.consumers.remove(st)
                    graph.stages.remove(st)
                    diags.append(f"removed dead stage {st.name!r} "
                                 f"(outputs {[o.name for o in st.outputs]} "
                                 f"never read)")
                    changed = True
            for st in list(graph.stages):
                if (st.kind == "split" and len(st.outputs) == 1
                        and not st.outputs[0].is_graph_output):
                    out, src = st.outputs[0], st.inputs[0]
                    for reader in list(out.consumers):
                        for i, ic in enumerate(reader.inputs):
                            if ic is out:
                                reader.inputs[i] = src
                    src.consumers = [c for c in src.consumers if c is not st]
                    src.consumers.extend(out.consumers)
                    graph.channels.remove(out)
                    graph.stages.remove(st)
                    diags.append(f"collapsed single-arm split {st.name!r} "
                                 f"into a wire")
                    changed = True
        return graph, diags


# ----------------------------------------------------------------------
# PointFusion
# ----------------------------------------------------------------------
class PointFusion:
    """Compose producer/consumer elementwise stages into one stage.

    An edge ``p -> c`` is fused when both stages are ``point``/
    ``pointN``, the connecting channel has ``c`` as its only reader
    and is not a graph output.  The consumer absorbs the producer: its
    input list splices in the producer's inputs at the edge position
    and its ``fn`` becomes the composition (including the intermediate
    dtype cast, so reference semantics are preserved bit-exactly).
    """

    name = "point-fusion"

    def run(self, graph: DataflowGraph) -> tuple[DataflowGraph, list[str]]:
        diags: list[str] = []
        while True:
            edge = self._find_edge(graph)
            if edge is None:
                break
            prod, cons, ch = edge
            pos = next(i for i, ic in enumerate(cons.inputs) if ic is ch)
            cons.fn = _compose(prod.fn, len(prod.inputs), cons.fn, pos, ch)
            cons.inputs[pos:pos + 1] = prod.inputs
            for ic in prod.inputs:
                ic.consumers = [cons if c is prod else c
                                for c in ic.consumers]
            graph.stages.remove(prod)
            graph.channels.remove(ch)
            old = cons.name
            cons.name = f"{prod.name}+{cons.name}"
            cons.kind = "point" if len(cons.inputs) == 1 else "pointN"
            # a fully pipelined fused datapath issues at the slower of
            # the two rates and pays both fill latencies
            cons.ii = max(prod.ii, cons.ii)
            cons.fill = prod.fill + cons.fill
            diags.append(f"fused {prod.name!r} into {old!r} "
                         f"(channel {ch.name!r} eliminated)")
        return graph, diags

    @staticmethod
    def _find_edge(graph: DataflowGraph
                   ) -> tuple[Stage, Stage, Channel] | None:
        for st in graph.stages:
            if st.kind not in _POINT_KINDS:
                continue
            ch = st.outputs[0]
            if ch.is_graph_output or len(ch.consumers) != 1:
                continue
            cons = ch.consumers[0]
            # cons is st on a (invalid, pre-validate) self-loop: never
            # fuse it away — validate() must see the cycle and raise
            if cons.kind in _POINT_KINDS and cons is not st:
                return st, cons, ch
        return None


def _compose(p_fn: Callable, n_p: int, c_fn: Callable, pos: int,
             mid: Channel) -> Callable:
    dtype = mid.dtype

    def fused(*args):
        inner = p_fn(*args[pos:pos + n_p]).astype(dtype)
        return c_fn(*args[:pos], inner, *args[pos + n_p:])

    return fused
