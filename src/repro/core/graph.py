"""Dataflow graph extraction and validation (FLOWER contribution C1).

The paper extracts a dataflow graph from a single-source program: every
DSL call creates a *task* (here: :class:`Stage`), every virtual image /
``channel`` becomes an edge (:class:`Channel`).  The compiler validates
that the graph is acyclic and that every channel is written exactly once
and read exactly once (fan-out must be explicit via a ``split`` stage),
mirroring Section IV-A of the paper.

Stages are *untimed* descriptions of computation on whole logical
arrays; the scheduler (:mod:`repro.core.schedule`) decides tiling and the
lowering (:mod:`repro.core.fusion`) turns fusion groups into either a
fused streaming Pallas kernel or an XLA chain.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
from typing import Any, Callable, Sequence

import jax.numpy as jnp
import numpy as np

__all__ = [
    "Channel",
    "Stage",
    "DataflowGraph",
    "GraphError",
    "CycleError",
    "ChannelContractError",
]


class GraphError(ValueError):
    """Base class for dataflow-graph validation errors."""


class CycleError(GraphError):
    """The dataflow graph contains a cycle."""


class ChannelContractError(GraphError):
    """A channel violates the single-writer / single-reader contract."""


@dataclasses.dataclass(eq=False)
class Channel:
    """An edge of the dataflow graph (the paper's ``channel``).

    A channel that has no producer is a *graph input* (it will be fed
    from HBM by a generated read task); a channel marked as output is a
    *graph output* (drained to HBM by a generated write task).
    """

    name: str
    shape: tuple[int, ...]
    dtype: Any
    producer: "Stage | None" = None
    consumers: list["Stage"] = dataclasses.field(default_factory=list)
    is_graph_input: bool = False
    is_graph_output: bool = False
    #: memory-bundle id (paper: AXI bundle ``mem1..4``); assigned by the
    #: scheduler for graph I/O channels only.
    bundle: int | None = None
    #: FIFO depth (double buffering by default, like ``depth = 2``).
    depth: int = 2

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Channel({self.name}, {self.shape}, {np.dtype(self.dtype).name},"
                f" in={self.is_graph_input}, out={self.is_graph_output})")

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape)) * np.dtype(self.dtype).itemsize


@dataclasses.dataclass(eq=False)
class Stage:
    """A node of the dataflow graph (the paper's *task*).

    ``kind`` determines how the stage is scheduled and lowered:

    - ``point``:    elementwise, ``fn(x) -> y`` (shape preserving)
    - ``pointN``:   elementwise over N inputs, ``fn(x1..xN) -> y``
    - ``stencil``:  local operator with window ``(kh, kw)``;
                    ``fn(patches)`` where ``patches`` has shape
                    ``(kh*kw, *tile)`` holding the shifted views
                    (line-buffer analogue)
    - ``split``:    1 input -> k identical outputs (explicit fan-out)
    - ``reduce``:   global reduction ``fn(x) -> scalar/vector``
    - ``custom``:   opaque whole-array function (breaks fusion groups;
                    used to embed hand-written Pallas kernels)
    """

    name: str
    kind: str
    fn: Callable[..., Any] | None
    inputs: list[Channel]
    outputs: list[Channel]
    #: stencil window (kh, kw); (1, 1) for non-stencil stages.
    window: tuple[int, int] = (1, 1)
    #: per-item issue interval in cycles for the latency simulator.
    ii: float = 1.0
    #: pipeline fill latency in cycles for the latency simulator.
    fill: float = 8.0
    #: extra metadata (e.g. custom lowering hooks).
    meta: dict = dataclasses.field(default_factory=dict)

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Stage({self.name}:{self.kind})"

    @property
    def halo(self) -> tuple[int, int]:
        return ((self.window[0] - 1) // 2, (self.window[1] - 1) // 2)


class DataflowGraph:
    """A FLOWER dataflow graph under construction.

    The builder methods mirror the AnyHLS image-processing DSL
    (``iteration_point``, ``split_image``, ...) from the paper's running
    example.  Calling them *is* the graph extraction: the user writes a
    single-source program, and the graph falls out of the calls.

    Explicit channels (``graph.channel(...)`` + ``graph.task(...)``)
    are supported too, matching the paper's ``static mut chan`` style;
    with them the user can construct invalid graphs, which
    :meth:`validate` rejects with precise errors.
    """

    def __init__(self, name: str = "app") -> None:
        self.name = name
        self.stages: list[Stage] = []
        self.channels: list[Channel] = []
        self._counter = 0

    # ------------------------------------------------------------------
    # channel / task primitives (explicit wiring, paper-style)
    # ------------------------------------------------------------------
    def _fresh(self, prefix: str) -> str:
        self._counter += 1
        return f"{prefix}{self._counter}"

    def channel(self, shape: Sequence[int], dtype: Any = jnp.float32,
                name: str | None = None) -> Channel:
        ch = Channel(name or self._fresh("chan"), tuple(shape), dtype)
        self.channels.append(ch)
        return ch

    def input(self, name: str, shape: Sequence[int],
              dtype: Any = jnp.float32) -> Channel:
        """Declare a graph input (an HBM-resident image/tensor)."""
        ch = self.channel(shape, dtype, name=name)
        ch.is_graph_input = True
        return ch

    def output(self, ch: Channel, name: str | None = None) -> Channel:
        """Mark a channel as a graph output (drained to HBM)."""
        if name is not None:
            ch.name = name
        ch.is_graph_output = True
        return ch

    def task(self, name: str, kind: str, fn: Callable | None,
             inputs: Sequence[Channel], outputs: Sequence[Channel],
             window: tuple[int, int] = (1, 1), *, ii: float = 1.0,
             fill: float = 8.0, meta: dict | None = None) -> Stage:
        st = Stage(name, kind, fn, list(inputs), list(outputs),
                   window=window, ii=ii, fill=fill, meta=meta or {})
        for ch in inputs:
            ch.consumers.append(st)
        for ch in outputs:
            if ch.producer is not None:
                raise ChannelContractError(
                    f"channel {ch.name!r} written by both "
                    f"{ch.producer.name!r} and {st.name!r}")
            ch.producer = st
        self.stages.append(st)
        return st

    # ------------------------------------------------------------------
    # DSL builders (implicit wiring; these mirror the AnyHLS library)
    # ------------------------------------------------------------------
    def point(self, x: Channel, fn: Callable, name: str | None = None,
              dtype: Any = None, **kw) -> Channel:
        """``iteration_point``: out[x, y] = fn(in[x, y])."""
        out = self.channel(x.shape, dtype or x.dtype)
        self.task(name or self._fresh("point"), "point", fn, [x], [out], **kw)
        return out

    def point2(self, a: Channel, b: Channel, fn: Callable,
               name: str | None = None, dtype: Any = None, **kw) -> Channel:
        """``iteration_point2``: out = fn(a, b) elementwise."""
        if a.shape != b.shape:
            raise GraphError(
                f"point2 stage {_stage_label(name)}: elementwise inputs "
                f"must agree on shape — expected both {a.shape} "
                f"({a.name!r}), got {b.shape} ({b.name!r})"
                f"{_src_note(kw.get('meta'))}")
        out = self.channel(a.shape, dtype or a.dtype)
        self.task(name or self._fresh("point2"), "pointN", fn, [a, b], [out], **kw)
        return out

    def pointn(self, chans: Sequence[Channel], fn: Callable,
               name: str | None = None, dtype: Any = None, **kw) -> Channel:
        shapes = {c.shape for c in chans}
        if len(shapes) != 1:
            got = ", ".join(f"{c.name!r}={c.shape}" for c in chans)
            raise GraphError(
                f"pointn stage {_stage_label(name)}: elementwise inputs "
                f"must agree on one shape, got {got}"
                f"{_src_note(kw.get('meta'))}")
        out = self.channel(chans[0].shape, dtype or chans[0].dtype)
        self.task(name or self._fresh("pointn"), "pointN", fn, list(chans),
                  [out], **kw)
        return out

    def stencil(self, x: Channel, window: tuple[int, int], fn: Callable,
                name: str | None = None, dtype: Any = None, **kw) -> Channel:
        """Local operator: ``fn(patches)`` with patches ``(kh*kw, *tile)``.

        Edge handling is zero-padding (the scheduler materializes the
        halo; see :mod:`repro.core.fusion`).
        """
        if window[0] % 2 != 1 or window[1] % 2 != 1:
            raise GraphError(
                f"stencil stage {_stage_label(name)}: window must be odd "
                f"so the halo is symmetric — expected odd (kh, kw), got "
                f"{window}{_src_note(kw.get('meta'))}")
        if len(x.shape) != 2:
            raise GraphError(
                f"stencil stage {_stage_label(name)}: expects a 2-D "
                f"plane, got input {x.name!r} of shape {x.shape}"
                f"{_src_note(kw.get('meta'))}")
        out = self.channel(x.shape, dtype or x.dtype)
        self.task(name or self._fresh("stencil"), "stencil", fn, [x], [out],
                  window=window, **kw)
        return out

    def split(self, x: Channel, k: int = 2, name: str | None = None,
              **kw) -> tuple[Channel, ...]:
        """``split_image``: explicit fan-out of a channel to k copies."""
        outs = tuple(self.channel(x.shape, x.dtype) for _ in range(k))
        self.task(name or self._fresh("split"), "split", None, [x],
                  list(outs), **kw)
        return outs

    def reduce(self, x: Channel, fn: Callable, out_shape: Sequence[int] = (),
               name: str | None = None, dtype: Any = None, **kw) -> Channel:
        out = self.channel(tuple(out_shape), dtype or x.dtype)
        self.task(name or self._fresh("reduce"), "reduce", fn, [x], [out], **kw)
        return out

    def custom(self, chans: Sequence[Channel], fn: Callable,
               out_shapes: Sequence[tuple[int, ...]],
               out_dtypes: Sequence[Any] | None = None,
               name: str | None = None, meta: dict | None = None,
               **kw) -> tuple[Channel, ...]:
        """Opaque whole-array stage (embeds hand-written kernels)."""
        out_dtypes = out_dtypes or [chans[0].dtype] * len(out_shapes)
        outs = tuple(self.channel(s, d) for s, d in zip(out_shapes, out_dtypes))
        self.task(name or self._fresh("custom"), "custom", fn, list(chans),
                  list(outs), meta=meta, **kw)
        return outs

    # ------------------------------------------------------------------
    # validation (paper Section IV-A) and topological sort
    # ------------------------------------------------------------------
    @property
    def graph_inputs(self) -> list[Channel]:
        return [c for c in self.channels if c.is_graph_input]

    @property
    def graph_outputs(self) -> list[Channel]:
        return [c for c in self.channels if c.is_graph_output]

    def validate(self) -> None:
        """Check the canonical-form contract; raise GraphError if violated."""
        for ch in self.channels:
            n_writers = 0 if ch.producer is None else 1
            if ch.is_graph_input and n_writers:
                raise ChannelContractError(
                    f"graph input {ch.name!r} must not have a producer "
                    f"(written by {ch.producer.name!r})")
            if not ch.is_graph_input and ch.producer is None:
                raise ChannelContractError(
                    f"channel {ch.name!r} is never written and is not a "
                    f"graph input")
            n_readers = len(ch.consumers)
            if n_readers > 1:
                names = [s.name for s in ch.consumers]
                raise ChannelContractError(
                    f"channel {ch.name!r} is read {n_readers} times by "
                    f"{names}; insert an explicit split stage")
            if n_readers == 0 and not ch.is_graph_output:
                raise ChannelContractError(
                    f"channel {ch.name!r} is never read and is not a graph "
                    f"output")
            if ch.is_graph_output and ch.is_graph_input:
                raise ChannelContractError(
                    f"channel {ch.name!r} cannot be both graph input and "
                    f"output")
        self.toposort()  # raises CycleError on cycles

    def toposort(self) -> list[Stage]:
        """Kahn's algorithm; deterministic (insertion order tie-break).

        This is the paper's scheduling step: the generated top-level
        kernel calls tasks in this order so every channel is written
        before it is read.  Stages disconnected from the rest still get
        scheduled (the paper: "tasks that are isolated from the rest of
        the graph ... execute in parallel with the rest").
        """
        indeg: dict[Stage, int] = {}
        for st in self.stages:
            indeg[st] = sum(1 for ch in st.inputs if ch.producer is not None)
        ready = collections.deque(st for st in self.stages if indeg[st] == 0)
        order: list[Stage] = []
        while ready:
            st = ready.popleft()
            order.append(st)
            for ch in st.outputs:
                for consumer in ch.consumers:
                    indeg[consumer] -= 1
                    if indeg[consumer] == 0:
                        ready.append(consumer)
        if len(order) != len(self.stages):
            placed = set(order)
            stuck = [s for s in self.stages if s not in placed]
            chans = sorted({ch.name for s in stuck for ch in s.inputs
                            if ch.producer is not None
                            and ch.producer not in placed})
            raise CycleError(
                f"dataflow graph has a cycle through stages "
                f"{[s.name for s in stuck]} (channels {chans})")
        return order

    # ------------------------------------------------------------------
    # canonical signature (the compile-cache key)
    # ------------------------------------------------------------------
    def signature(self) -> str:
        """Canonical structural digest of the graph.

        Two graphs get the same signature iff they have the same
        topology, shapes, dtypes, stencil windows, FIFO depths, graph
        I/O channel names (the compiled app's calling convention) and
        stage bodies (a best-effort bytecode+closure fingerprint; see
        :func:`_fn_fingerprint`).  *Internal* channel and stage names
        do not matter, so a relabeled copy of a graph hits the compile
        cache (:class:`repro.runtime.cache.CompileCache`).  Signatures
        are computed in topological order, so they are stable across
        construction orderings of the same DAG.
        """
        ids: dict[Channel, int] = {}

        def cid(ch: Channel) -> str:
            if ch not in ids:
                ids[ch] = len(ids)
            return f"c{ids[ch]}"

        # graph I/O channel NAMES are part of the signature: they are
        # the compiled app's calling convention (input/output keywords),
        # so two graphs differing only in I/O names must not share an
        # app.  Internal channel names stay excluded.
        lines = [f"in {cid(ch)} name={ch.name} {ch.shape} "
                 f"{np.dtype(ch.dtype).name} depth={ch.depth}"
                 for ch in self.graph_inputs]
        for st in self.toposort():
            ins = ",".join(cid(c) for c in st.inputs)
            outs = ",".join(
                f"{cid(c)}:{c.shape}:{np.dtype(c.dtype).name}:d{c.depth}"
                for c in st.outputs)
            lines.append(f"stage {st.kind} w={st.window} "
                         f"fn={_fn_fingerprint(st.fn)} [{ins}]->[{outs}]")
        lines.extend(f"out {cid(ch)} name={ch.name}"
                     for ch in self.graph_outputs)
        return hashlib.sha256("\n".join(lines).encode()).hexdigest()[:16]

    # ------------------------------------------------------------------
    # reference semantics: execute the graph stage-by-stage with numpy-ish
    # jnp ops on whole arrays.  This is the oracle every backend is
    # checked against.
    # ------------------------------------------------------------------
    def reference_eval(self, inputs: dict[str, Any]) -> dict[str, Any]:
        self.validate()
        env: dict[Channel, Any] = {}
        for ch in self.graph_inputs:
            if ch.name not in inputs:
                raise GraphError(f"missing graph input {ch.name!r}")
            val = jnp.asarray(inputs[ch.name], dtype=ch.dtype)
            if tuple(val.shape) != ch.shape:
                raise GraphError(
                    f"input {ch.name!r}: expected shape {ch.shape}, got "
                    f"{tuple(val.shape)}")
            env[ch] = val
        for st in self.toposort():
            vals = [env[c] for c in st.inputs]
            outs = _apply_stage_reference(st, vals)
            for ch, v in zip(st.outputs, outs):
                env[ch] = v.astype(ch.dtype)
        return {ch.name: env[ch] for ch in self.graph_outputs}


def _stage_label(name: str | None) -> str:
    return repr(name) if name else "<unnamed>"


def _src_note(meta: dict | None) -> str:
    """Render the user source location a traced stage carries.

    The tracing frontend (:mod:`repro.frontend`) records the user's
    ``file.py:line`` in ``Stage.meta["src"]`` at record time; stage
    validation errors append it so a bad traced program points at the
    line the user wrote, not at tracer internals.
    """
    src = (meta or {}).get("src")
    return f" (traced at {src})" if src else ""


def _fn_fingerprint(fn: Any, _depth: int = 0) -> str:
    """Best-effort structural fingerprint of a stage function.

    Hashes the bytecode, code constants, referenced global/attribute
    names (with the globals resolved to their current values, so
    ``lambda x: jnp.abs(x)`` and ``lambda x: jnp.exp(x)`` differ),
    argument defaults, and (recursively) the closure cells.  Values
    without a stable value-based repr fall back to ``id()`` —
    conservative: the signature then only matches the exact same
    function object, which can cost cache hits but never returns a
    wrong kernel.

    Stability matters *across processes*: the persistent
    :class:`repro.tune.store.TuningCache` keys on this digest, so the
    fingerprint must not depend on memory addresses.  Nested code
    objects (genexprs, inner lambdas) therefore hash structurally via
    :func:`_code_fingerprint` — their default ``repr`` embeds an
    ``at 0x…`` address that would silently break every cross-process
    cache hit for stages like ``lambda p: sum(p[i] for i in range(9))``.
    """
    if fn is None:
        return "none"
    code = getattr(fn, "__code__", None)
    if code is None or _depth > 4:
        name = (getattr(fn, "__qualname__", None)
                or getattr(fn, "__name__", None))
        if name:
            return f"{getattr(fn, '__module__', '')}.{name}"
        return f"id{id(fn)}"
    parts = [_code_fingerprint(code), repr(code.co_names)]
    fglobals = getattr(fn, "__globals__", {})
    for name in code.co_names:
        if name in fglobals:
            parts.append(_const_fingerprint(fglobals[name], _depth + 1))
    for dflt in (fn.__defaults__ or ()):
        parts.append(_const_fingerprint(dflt, _depth + 1))
    for dflt in (fn.__kwdefaults__ or {}).values():
        parts.append(_const_fingerprint(dflt, _depth + 1))
    for cell in (fn.__closure__ or ()):
        parts.append(_const_fingerprint(cell.cell_contents, _depth + 1))
    return hashlib.sha256("|".join(parts).encode()).hexdigest()[:12]


def _code_fingerprint(code: Any) -> str:
    """Address-free digest of a code object, nested code included."""
    parts = [code.co_code.hex(), repr(code.co_names),
             repr(code.co_varnames)]
    for c in code.co_consts:
        if hasattr(c, "co_code"):           # nested genexpr/lambda/comp
            parts.append(_code_fingerprint(c))
        else:
            parts.append(repr(c))
    return hashlib.sha256("|".join(parts).encode()).hexdigest()[:12]


def _const_fingerprint(v: Any, depth: int) -> str:
    if callable(v):
        return _fn_fingerprint(v, depth)
    if isinstance(v, (list, tuple)):
        return "[" + ",".join(_const_fingerprint(x, depth) for x in v) + "]"
    if isinstance(v, np.ndarray):
        return hashlib.sha256(v.tobytes()).hexdigest()[:12] + str(v.shape)
    if hasattr(v, "__array__") and hasattr(v, "shape"):  # jax arrays
        a = np.asarray(v)
        return hashlib.sha256(a.tobytes()).hexdigest()[:12] + str(a.shape)
    r = repr(v)
    if " at 0x" in r:              # default object repr: identity only
        return f"id{id(v)}"
    return r


def extract_patches(x: jnp.ndarray, window: tuple[int, int]) -> jnp.ndarray:
    """Zero-padded shifted views, shape ``(kh*kw, *x.shape)``.

    This is the reference semantics of a stencil stage's input: the
    FPGA line buffer delivering the window, in tile form.
    """
    kh, kw = window
    ph, pw = (kh - 1) // 2, (kw - 1) // 2
    xp = jnp.pad(x, ((ph, ph), (pw, pw)))
    h, w = x.shape
    views = [xp[i:i + h, j:j + w] for i in range(kh) for j in range(kw)]
    return jnp.stack(views, axis=0)


def _apply_stage_reference(st: Stage, vals: list[Any]) -> list[Any]:
    if st.kind == "point":
        return [st.fn(vals[0])]
    if st.kind == "pointN":
        return [st.fn(*vals)]
    if st.kind == "stencil":
        return [st.fn(extract_patches(vals[0], st.window))]
    if st.kind == "split":
        return [vals[0] for _ in st.outputs]
    if st.kind == "reduce":
        return [st.fn(vals[0])]
    if st.kind == "custom":
        out = st.fn(*vals)
        return list(out) if isinstance(out, (tuple, list)) else [out]
    raise GraphError(f"unknown stage kind {st.kind!r}")
