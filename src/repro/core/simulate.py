"""Cycle-level FIFO-pipeline latency model (reproduces paper Fig. 1).

The paper's central performance claim: a kernel compiled *without* the
dataflow transformation executes its tasks sequentially under one FSM
(latency ~= sum of task latencies), while the dataflow-transformed
kernel runs tasks as a FIFO-connected pipeline (latency ~= latency of
the slowest task + pipeline fill).

We model a task as a server with issue interval ``ii`` (cycles/item)
and pipeline-fill latency ``fill``; channels are FIFOs of finite
``depth``.  Two models:

- :func:`analytic_latency` — closed forms for both executions.
- :func:`simulate_pipeline` — discrete recurrence with backpressure,
  for finite FIFO depths and per-item jitter (straggler studies).

The same model yields the TPU reading: grid steps of the fused Pallas
kernel are the "items"; DMA-in, compute stages and DMA-out are the
tasks; Mosaic's double buffering is the depth-2 FIFO.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["TaskTiming", "analytic_latency", "simulate_pipeline"]


@dataclasses.dataclass(frozen=True)
class TaskTiming:
    name: str
    ii: float = 1.0       # cycles per item (issue interval)
    fill: float = 8.0     # pipeline-fill latency in cycles


def analytic_latency(tasks: list[TaskTiming], n_items: int
                     ) -> dict[str, float]:
    """Closed-form latencies (cycles) for both execution styles.

    sequential (no dataflow): tasks run one after another over the full
    stream::

        T_seq = sum_i (fill_i + n * ii_i)

    dataflow (pipelined): every task runs concurrently; the stream
    drains at the rate of the slowest task::

        T_flow = sum_i fill_i + n * max_i ii_i

    ``n_items=0`` is legal (an idle pipeline): both latencies collapse
    to the fill terms, and a fully zero-cost pipeline reports speedup
    1.0 instead of dividing by zero.
    """
    if n_items < 0:
        raise ValueError(f"n_items must be >= 0, got {n_items}")
    t_seq = sum(t.fill + n_items * t.ii for t in tasks)
    t_flow = sum(t.fill for t in tasks) + n_items * max(t.ii for t in tasks)
    return {"sequential": t_seq, "dataflow": t_flow,
            "speedup": t_seq / t_flow if t_flow > 0 else 1.0}


def simulate_pipeline(tasks: list[TaskTiming], n_items: int,
                      depth: int = 2, jitter: float = 0.0,
                      seed: int = 0) -> dict[str, float]:
    """Discrete recurrence with finite-FIFO backpressure.

    ``c[s, k]`` = cycle when task ``s`` finishes item ``k``::

        c[s, k] = max(c[s-1, k],            # data available
                      c[s, k-1],            # server busy
                      c[s+1, k-depth])      # room in output FIFO
                  + ii_s (+ jitter)

    plus each task's one-time ``fill``.  With ``depth>=1`` and constant
    ii this converges to the analytic dataflow latency; with jittered
    service times it quantifies how FIFO depth absorbs stalls (the
    paper's "when a task stalls ... other tasks continue running as
    long as there is enough data in their input buffers").
    """
    if n_items < 1:
        raise ValueError(f"simulate_pipeline needs n_items >= 1, "
                         f"got {n_items}")
    rng = np.random.default_rng(seed)
    S = len(tasks)
    c = np.zeros((S, n_items))
    ii = np.array([t.ii for t in tasks])
    fill = np.array([t.fill for t in tasks])
    jit = (rng.exponential(jitter, size=(S, n_items))
           if jitter > 0 else np.zeros((S, n_items)))
    for k in range(n_items):
        for s in range(S):
            ready = c[s - 1, k] if s > 0 else 0.0
            busy = c[s, k - 1] if k > 0 else fill[:s + 1].sum()
            # backpressure: the *downstream* task must have accepted
            # item k-depth before we may emit item k into the FIFO
            room = c[s + 1, k - depth] if (s + 1 < S and k >= depth) else 0.0
            c[s, k] = max(ready, busy, room) + ii[s] + jit[s, k]
    total = float(c[-1, -1])
    seq = float(sum(t.fill + (n_items * t.ii) for t in tasks)
                + jit.sum())
    # steady rate over the back half: items n//2 .. n-1 span
    # n-1-n//2 completion intervals (NOT n-n//2 — fenceposts).  For
    # constant ii and depth >= 1 this equals max_i ii_i exactly.
    intervals = n_items - 1 - n_items // 2
    if intervals > 0:
        steady = float((c[-1, -1] - c[-1, n_items // 2]) / intervals)
    else:
        steady = total / n_items
    return {"dataflow_sim": total, "sequential": seq,
            "speedup": seq / total, "steady_rate": steady}
