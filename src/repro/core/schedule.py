"""Scheduling: convex DAG fusion, halo accumulation, depths, bundles.

This is FLOWER contribution C2 (top-level kernel generation) plus C3c
(memory-bundle assignment).  Given a :class:`DataflowGraph`, the
scheduler

1. canonicalizes the graph through the pass pipeline
   (:mod:`repro.core.transform`) unless ``strict=True``,
2. topologically sorts the stages (write-before-read order),
3. partitions them into *fusion groups* by **convex-subgraph DAG
   fusion**: every tile-streamable stage starts in its own group and
   groups are merged pairwise — best latency win first, as scored by
   :func:`repro.core.simulate.analytic_latency` — as long as the union
   stays convex (no path leaves the group and re-enters, so the fused
   kernel never deadlocks on an external dependency) and its
   double-buffered working set still fits VMEM
   (:func:`repro.core.vectorize.choose_tile` is the budget oracle).
   Diamond- and branch-shaped DAGs therefore collapse into ONE fused
   streaming kernel instead of fragmenting into per-branch chains;
   ``custom`` and ``reduce`` stages stay group-breaking singletons,
4. computes the *cumulative halo* each channel must carry so that
   downstream stencils have their windows available inside the fused
   kernel (the line-buffer analysis),
5. assigns memory bundles to graph I/O channels so parallel DAG paths
   use distinct HBM buffers (paper Fig. 4: ``mem1..4``),
6. budgets VMEM: each live channel inside a group costs
   ``tile_bytes * depth`` (depth-2 FIFO == double buffering).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.graph import Channel, DataflowGraph, GraphError, Stage
from repro.core.simulate import TaskTiming, analytic_latency
from repro.core.transform import Pass, PassPipeline, default_pipeline
from repro.obs.tracer import maybe_span

__all__ = ["FusionGroup", "Schedule", "build_schedule"]

#: stage kinds that can be fused into one streaming kernel
FUSIBLE_KINDS = frozenset({"point", "pointN", "stencil", "split"})

#: items used by the merge cost model (plane size is tile-agnostic here)
_COST_ITEMS = 1 << 20


@dataclasses.dataclass
class FusionGroup:
    """A set of stages lowered to a single streaming kernel."""

    stages: list[Stage]
    #: channels entering the group (read from HBM by the kernel)
    inputs: list[Channel]
    #: channels leaving the group (written to HBM by the kernel)
    outputs: list[Channel]
    #: channels internal to the group (VMEM-only; the FIFO channels)
    internal: list[Channel]
    #: per-channel cumulative halo (hy, hx) required inside the kernel
    halo: dict[Channel, tuple[int, int]]
    #: selected tile (th, tw); filled in by the vectorizer
    tile: tuple[int, int] | None = None
    #: vector factor behind the selected tile (tw == 128 * vector_factor);
    #: set by choose_tile/select_tile alongside ``tile``
    vector_factor: int | None = None
    #: why this tile was chosen: "model" (analytic sweep), "forced"
    #: (explicit vector_factor=), "measured"/"cache"/"config" (the
    #: autotuner, fresh / from the TuningCache / an explicit
    #: ScheduleConfig).  Rendered by :meth:`Schedule.describe`.
    tile_source: str = "model"

    @property
    def is_trivial(self) -> bool:
        """Groups of one non-fusible stage (custom / reduce)."""
        return len(self.stages) == 1 and self.stages[0].kind not in FUSIBLE_KINDS

    def vmem_bytes(self, tile: tuple[int, int] | None = None) -> int:
        """Double-buffered VMEM working set for a candidate tile.

        Every channel live inside the kernel holds an expanded tile of
        ``(th + 2hy, tw + 2hx)`` elements at FIFO depth ``ch.depth``;
        stencil stages additionally materialize their ``kh*kw`` shifted
        views (the register-file cost of the window).
        """
        tile = tile or self.tile
        if tile is None:
            raise GraphError("no tile selected for group")
        th, tw = tile
        total = 0
        for ch in self.inputs + self.outputs + self.internal:
            hy, hx = self.halo.get(ch, (0, 0))
            total += (th + 2 * hy) * (tw + 2 * hx) * _itemsize(ch) * ch.depth
        for st in self.stages:
            if st.kind == "stencil":
                kh, kw = st.window
                out = st.outputs[0]
                hy, hx = self.halo.get(out, (0, 0))
                total += kh * kw * (th + 2 * hy) * (tw + 2 * hx) * _itemsize(out)
        return total


def _itemsize(ch: Channel) -> int:
    return np.dtype(ch.dtype).itemsize


@dataclasses.dataclass
class Schedule:
    """The partitioned program: what the lowering turns into kernels.

    Produced by :func:`build_schedule`; carried by every
    :class:`~repro.core.host.CompiledApp` as ``app.schedule``.  Holds
    the (post-canonicalization) graph, the stage execution order, the
    fusion groups with their selected tiles, the memory-bundle map,
    and the human-readable diagnostics trail of every decision the
    compiler made on the way here.
    """

    graph: DataflowGraph
    order: list[Stage]
    groups: list[FusionGroup]
    #: bundle id per graph-I/O channel (paper: AXI bundles)
    bundles: dict[Channel, int]
    n_bundles: int
    #: human-readable log from the pass pipeline + the fusion search
    diagnostics: list[str] = dataclasses.field(default_factory=list)

    def features(self, items: int = 1) -> dict:
        """Cost-model features of the selected tiles, drift-row ready.

        Delegates to :func:`repro.core.vectorize.schedule_features`:
        per modeled group, the (grid, bytes/step, per-kind compute
        steps) triple that makes the analytic model linear in the
        hardware constants' reciprocals.  Every drift row the engine,
        the tuner and the benchmarks persist carries this dict so the
        calibration fit (:mod:`repro.tune.calibrate`) can re-estimate
        the constants offline.
        """
        from repro.core.vectorize import schedule_features
        return schedule_features(self, items=items)

    def describe(self) -> str:
        """Render the schedule: kernels, FIFOs, tiles + provenance.

        Each fused kernel line reports its selected tile and *why* it
        was chosen (``via model`` — analytic sweep, ``via forced`` —
        explicit ``vector_factor=``, ``via measured`` / ``via cache``
        / ``via config`` — the autotuner; see ``docs/tuning.md``),
        followed by the pass-pipeline and ``[tune]`` diagnostics.
        """
        lines = [f"schedule for {self.graph.name!r}: "
                 f"{len(self.order)} stages -> {len(self.groups)} kernels"]
        for gi, g in enumerate(self.groups):
            kind = "custom" if g.is_trivial else "dataflow"
            names = ",".join(s.name for s in g.stages)
            lines.append(f"  kernel[{gi}] ({kind}): {names}")
            lines.append(f"    inputs={[c.name for c in g.inputs]} "
                         f"outputs={[c.name for c in g.outputs]} "
                         f"fifo={[c.name for c in g.internal]}")
            if g.tile is not None:
                lines.append(f"    tile={g.tile} "
                             f"vector_factor={g.vector_factor} "
                             f"via {g.tile_source}")
        lines.append("  bundles: " + ", ".join(
            f"{c.name}->mem{b}" for c, b in self.bundles.items()))
        if self.diagnostics:
            lines.append("  passes:")
            lines.extend(f"    {d}" for d in self.diagnostics)
        return "\n".join(lines)


def build_schedule(graph: DataflowGraph, n_bundles: int = 4, *,
                   canonicalize: bool = True, strict: bool = False,
                   passes: Sequence[Pass] | PassPipeline | None = None,
                   spec=None, vector_factor: int | None = None,
                   group_vector_factors: Sequence[int | None] | None = None,
                   max_tile: tuple[int, int] | None = None,
                   tile_source: str = "measured", trace=None,
                   backend=None) -> Schedule:
    """Canonicalize, validate and partition ``graph`` into fusion groups.

    ``strict=True`` skips canonicalization and enforces the paper's
    explicit canonical form (multi-reader channels raise).  ``passes``
    overrides the default pipeline; ``spec`` feeds the VMEM feasibility
    check of the fusion search (default: the resolved ``backend``'s
    spec, else TPU v5e).  ``backend`` (a name or
    :class:`~repro.backends.Backend`) supplies the lane/sublane widths
    and default tile cap the vectorizer budgets with.  ``vector_factor``
    forces one datapath width for every group; ``None`` (the default)
    sweeps the factor per group through the DMA cost model
    (:func:`repro.core.vectorize.select_tile`) and logs the choice in
    the schedule diagnostics.

    ``group_vector_factors`` is the autotuner's entry point (see
    :mod:`repro.tune`): one factor per fusion group in schedule order
    (``None`` entries for trivial groups), applied with provenance
    label ``tile_source``; ``max_tile`` caps the tile shape handed to
    :func:`repro.core.vectorize.choose_tile`.  A length mismatch —
    e.g. a stale cached config after the partition changed — falls
    back to the analytic sweep with a diagnostic instead of failing.

    >>> from repro.core.graph import DataflowGraph
    >>> g = DataflowGraph("doc")
    >>> x = g.input("img", (64, 256))
    >>> _ = g.output(g.point(x, lambda v: v + 1.0), "out")
    >>> sched = build_schedule(g)
    >>> len(sched.groups), sched.groups[0].tile_source
    (1, 'model')
    >>> tuned = build_schedule(g, group_vector_factors=[1])
    >>> tuned.groups[0].tile[1], tuned.groups[0].tile_source
    (128, 'measured')
    """
    diagnostics: list[str] = []
    if canonicalize and not strict:
        pipeline = passes if isinstance(passes, PassPipeline) else (
            PassPipeline(tuple(passes)) if passes is not None
            else default_pipeline())
        graph, diagnostics = pipeline.run(graph, tracer=trace)
    graph.validate()
    order = graph.toposort()
    with maybe_span(trace, "compile.partition", cat="compile",
                    graph=graph.name, stages=len(order)) as sp:
        groups, fusion_diags = _partition_groups(graph, order, spec,
                                                 vector_factor,
                                                 backend=backend)
        sp.set(groups=len(groups))
    diagnostics.extend(fusion_diags)
    diagnostics.extend(_select_tiles(groups, spec, vector_factor,
                                     group_vf=group_vector_factors,
                                     max_tile=max_tile, source=tile_source,
                                     trace=trace, backend=backend))
    bundles = _assign_bundles(graph, n_bundles)
    return Schedule(graph, order, groups, bundles, n_bundles, diagnostics)


def _select_tiles(groups: list[FusionGroup], spec,
                  vector_factor: int | None,
                  group_vf: Sequence[int | None] | None = None,
                  max_tile: tuple[int, int] | None = None,
                  source: str = "measured", trace=None,
                  backend=None) -> list[str]:
    """Per-group tile/vector-factor selection (post-partition).

    Three modes, in precedence order: ``group_vf`` pins each group
    individually (the autotuner applying a measured/cached config,
    labeled ``source``), ``vector_factor`` pins every group to one
    factor (the paper's explicit knob), and ``None``/``None`` sweeps
    per group through the cost model — different plane widths in one
    graph can land on different datapath widths.
    """
    from repro.core.vectorize import select_tile
    diags: list[str] = []
    if group_vf is not None and len(group_vf) != len(groups):
        diags.append(f"[vectorize] tuned config has {len(group_vf)} "
                     f"group factors but the partition produced "
                     f"{len(groups)} groups; falling back to the "
                     f"analytic sweep")
        group_vf = None
    for gi, g in enumerate(groups):
        if g.is_trivial:
            continue
        forced = vector_factor
        g.tile_source = "forced" if vector_factor is not None else "model"
        if group_vf is not None and group_vf[gi] is not None:
            forced = group_vf[gi]
            g.tile_source = source
        try:
            tile, sweep = select_tile(g, spec, forced, max_tile,
                                      trace=trace, backend=backend)
        except ValueError:
            # a persistent tuned config can outlive the partitioner or
            # the spec it was measured under (same group count, changed
            # plane/budget); an explicit vector_factor= stays a hard
            # error, but a stale CACHED factor degrades to the sweep
            if group_vf is None or group_vf[gi] is None:
                raise
            names = ",".join(s.name for s in g.stages)
            diags.append(f"[vectorize] {{{names}}}: tuned "
                         f"vector_factor={forced} no longer feasible; "
                         f"falling back to the analytic sweep")
            g.tile_source = "model"
            tile, sweep = select_tile(g, spec, vector_factor,
                                      max_tile, trace=trace,
                                      backend=backend)
        names = ",".join(s.name for s in g.stages)
        if sweep is not None:
            tried = ",".join(
                f"vf{r['vector_factor']}="
                + (f"{r['modeled_s'] * 1e6:.1f}us" if r["feasible"]
                   else "infeasible")
                for r in sweep)
            diags.append(f"[vectorize] {{{names}}}: swept {tried} -> "
                         f"vector_factor={g.vector_factor} tile={tile}")
        else:
            diags.append(f"[vectorize] {{{names}}}: {g.tile_source} "
                         f"vector_factor={g.vector_factor} tile={tile}")
    return diags


# ----------------------------------------------------------------------
# convex-subgraph DAG fusion
# ----------------------------------------------------------------------
def _is_fusible(st: Stage) -> bool:
    return (st.kind in FUSIBLE_KINDS
            and all(len(c.shape) == 2 for c in st.inputs + st.outputs))


def _partition_groups(graph: DataflowGraph, order: list[Stage],
                      spec=None, vector_factor: int | None = None,
                      backend=None
                      ) -> tuple[list[FusionGroup], list[str]]:
    """Grow maximal convex fusion groups over the stage DAG.

    Seeds one group per stage, then repeatedly merges the pair of
    edge-adjacent groups with the largest modeled latency win
    (``analytic_latency``: a merge removes one HBM write+read
    round-trip and lets both halves drain at the slower rate instead
    of sequentially).  A merge is legal iff both groups are fusible on
    the same plane shape, the union is *convex* in the DAG — no path
    between two member stages passes through an outside stage — and
    :func:`~repro.core.vectorize.choose_tile` can still fit the
    double-buffered union in VMEM.
    """
    n = len(order)
    pos = {st: i for i, st in enumerate(order)}

    succ: list[set[int]] = [set() for _ in range(n)]
    for i, st in enumerate(order):
        for ch in st.outputs:
            for c in ch.consumers:
                succ[i].add(pos[c])

    # reach[i]: bitmask of stages strictly reachable from i
    reach = [0] * n
    for i in reversed(range(n)):
        m = 0
        for j in succ[i]:
            m |= (1 << j) | reach[j]
        reach[i] = m

    owner = list(range(n))                      # stage idx -> group id
    members: dict[int, int] = {i: 1 << i for i in range(n)}
    fusible = [_is_fusible(st) for st in order]
    shape: dict[int, tuple[int, ...]] = {
        i: order[i].outputs[0].shape if order[i].outputs else ()
        for i in range(n)}

    def is_convex(union: int) -> bool:
        above = 0
        for i in _bits(union):
            above |= reach[i]
        for x in _bits(above & ~union):
            if reach[x] & union:
                return False
        return True

    def make_group(mask: int) -> FusionGroup:
        g = FusionGroup([order[i] for i in _bits(mask)], [], [], [], {})
        _classify_channels(g, graph)
        g.halo = _halo_analysis(g)
        return g

    # masks are immutable ints: memoize the per-candidate work so each
    # merge round only evaluates unions it has not seen before
    _fits_cache: dict[int, bool] = {}
    _lat_cache: dict[int, float] = {}

    def fits_vmem(mask: int) -> bool:
        # feasibility floor: a forced factor must fit every merged
        # group; in auto-sweep mode the narrowest datapath (vf=1) is
        # the existence check — select_tile widens afterwards.
        if mask not in _fits_cache:
            from repro.core.vectorize import choose_tile
            g = make_group(mask)
            try:
                choose_tile(g, spec, vector_factor or 1, backend=backend)
                _fits_cache[mask] = True
            except ValueError:
                _fits_cache[mask] = False
        return _fits_cache[mask]

    def latency(mask: int) -> float:
        if mask not in _lat_cache:
            tasks = ([TaskTiming("read", ii=1.0, fill=32.0)]
                     + [TaskTiming(order[i].name, ii=order[i].ii,
                                   fill=order[i].fill) for i in _bits(mask)]
                     + [TaskTiming("write", ii=1.0, fill=32.0)])
            _lat_cache[mask] = analytic_latency(tasks,
                                                _COST_ITEMS)["dataflow"]
        return _lat_cache[mask]

    n_merges = 0
    while True:
        pairs: set[tuple[int, int]] = set()
        for i in range(n):
            for j in succ[i]:
                ga, gb = owner[i], owner[j]
                if ga != gb:
                    pairs.add((min(ga, gb), max(ga, gb)))
        best: tuple[float, int, int, int] | None = None
        for ga, gb in sorted(pairs):
            if not (fusible[ga] and fusible[gb]):
                continue
            if shape[ga] != shape[gb]:
                continue
            union = members[ga] | members[gb]
            if not is_convex(union):
                continue
            if not fits_vmem(union):
                continue
            gain = latency(members[ga]) + latency(members[gb]) \
                - latency(union)
            if best is None or gain > best[0]:
                best = (gain, ga, gb, union)
        if best is None:
            break
        _, ga, gb, union = best
        members[ga] = union
        del members[gb]
        for i in _bits(union):
            owner[i] = ga
        n_merges += 1

    groups = [make_group(members[g]) for g in _order_groups(members, succ)]
    diags = [f"[convex-fusion] {n} stages -> {len(groups)} groups "
             f"({n_merges} merges)"]
    for g in groups:
        if len(g.stages) > 1:
            diags.append(
                f"[convex-fusion] fused {{{','.join(s.name for s in g.stages)}}}"
                f" into one streaming kernel")
    return groups, diags


def _order_groups(members: dict[int, int], succ: list[set[int]]
                  ) -> list[int]:
    """Topological order of the (convex => acyclic) group DAG.

    Deterministic: ready groups are taken lowest-member-index first,
    so the result is stable across runs.
    """
    owner = {i: g for g, mask in members.items() for i in _bits(mask)}
    gsucc: dict[int, set[int]] = {g: set() for g in members}
    indeg: dict[int, int] = {g: 0 for g in members}
    for i, js in enumerate(succ):
        for j in js:
            a, b = owner[i], owner[j]
            if a != b and b not in gsucc[a]:
                gsucc[a].add(b)
                indeg[b] += 1
    ready = sorted(g for g in members if indeg[g] == 0)
    out: list[int] = []
    while ready:
        g = ready.pop(0)
        out.append(g)
        for nb in sorted(gsucc[g]):
            indeg[nb] -= 1
            if indeg[nb] == 0:
                ready.append(nb)
        ready.sort()
    return out


def _bits(mask: int):
    while mask:
        low = mask & -mask
        yield low.bit_length() - 1
        mask ^= low


def _classify_channels(g: FusionGroup, graph: DataflowGraph) -> None:
    inside = set(g.stages)
    seen: set[Channel] = set()
    for st in g.stages:
        for ch in st.inputs:
            if ch in seen:
                continue
            seen.add(ch)
            if ch.producer not in inside:
                g.inputs.append(ch)
        for ch in st.outputs:
            if ch in seen:
                continue
            seen.add(ch)
            consumers_inside = ch.consumers and all(
                c in inside for c in ch.consumers)
            if ch.is_graph_output or not consumers_inside:
                g.outputs.append(ch)
            else:
                g.internal.append(ch)


# ----------------------------------------------------------------------
# halo (line-buffer) analysis
# ----------------------------------------------------------------------
def _halo_analysis(g: FusionGroup) -> dict[Channel, tuple[int, int]]:
    """Cumulative halo per channel, by backward DP over the group.

    ``halo(ch) = max over consumers st of halo(st.output) + st.halo``;
    group outputs carry halo (0, 0).  This is exactly the line-buffer
    depth a chained FPGA stencil pipeline needs, expressed in tiles.
    """
    halo: dict[Channel, tuple[int, int]] = {}
    inside = set(g.stages)
    for ch in g.outputs:
        halo[ch] = (0, 0)
    for st in reversed(g.stages):  # reverse topo order within the group
        out_halos = [halo.get(ch, (0, 0)) for ch in st.outputs]
        oh = (max(h[0] for h in out_halos), max(h[1] for h in out_halos))
        ih = (oh[0] + st.halo[0], oh[1] + st.halo[1])
        for ch in st.inputs:
            prev = halo.get(ch, (0, 0))
            cand = ih if ch.producer in inside or ch in g.inputs else (0, 0)
            halo[ch] = (max(prev[0], cand[0]), max(prev[1], cand[1]))
    return halo


# ----------------------------------------------------------------------
# memory bundles (paper Fig. 4)
# ----------------------------------------------------------------------
def _assign_bundles(graph: DataflowGraph, n_bundles: int) -> dict[Channel, int]:
    """Assign distinct HBM "bundles" to parallel I/O paths.

    Heuristic matching the paper: I/O channels on *different* branches
    of the DAG should land on different bundles so their transfers do
    not serialize on one interface.  We walk graph I/O in order and
    round-robin, but force siblings (channels touching the same stage)
    apart when possible.
    """
    io = graph.graph_inputs + graph.graph_outputs
    bundles: dict[Channel, int] = {}
    nxt = 0
    for ch in io:
        taken = set()
        peers = ch.consumers + ([ch.producer] if ch.producer else [])
        for st in peers:
            for other in st.inputs + st.outputs:
                if other in bundles:
                    taken.add(bundles[other])
        b = nxt % n_bundles
        for _ in range(n_bundles):
            if b not in taken:
                break
            b = (b + 1) % n_bundles
        bundles[ch] = b
        ch.bundle = b
        nxt += 1
    return bundles
