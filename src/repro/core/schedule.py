"""Scheduling: fusion groups, halo accumulation, channel depths, bundles.

This is FLOWER contribution C2 (top-level kernel generation) plus C3c
(memory-bundle assignment).  Given a validated :class:`DataflowGraph`,
the scheduler

1. topologically sorts the stages (write-before-read order),
2. partitions them into *fusion groups* — maximal chains of
   tile-streamable stages (point / pointN / stencil / split) that will
   become ONE fused streaming kernel (the paper's top-level kernel with
   ``#pragma HLS DATAFLOW``); ``custom`` and ``reduce`` stages are
   group-breaking and run as their own kernels,
3. computes the *cumulative halo* each channel must carry so that
   downstream stencils have their windows available inside the fused
   kernel (the line-buffer analysis),
4. assigns memory bundles to graph I/O channels so parallel DAG paths
   use distinct HBM buffers (paper Fig. 4: ``mem1..4``),
5. budgets VMEM: each live channel inside a group costs
   ``tile_bytes * depth`` (depth-2 FIFO == double buffering).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.graph import Channel, DataflowGraph, GraphError, Stage

__all__ = ["FusionGroup", "Schedule", "build_schedule"]

#: stage kinds that can be fused into one streaming kernel
FUSIBLE_KINDS = frozenset({"point", "pointN", "stencil", "split"})


@dataclasses.dataclass
class FusionGroup:
    """A set of stages lowered to a single streaming kernel."""

    stages: list[Stage]
    #: channels entering the group (read from HBM by the kernel)
    inputs: list[Channel]
    #: channels leaving the group (written to HBM by the kernel)
    outputs: list[Channel]
    #: channels internal to the group (VMEM-only; the FIFO channels)
    internal: list[Channel]
    #: per-channel cumulative halo (hy, hx) required inside the kernel
    halo: dict[Channel, tuple[int, int]]
    #: selected tile (th, tw); filled in by the vectorizer
    tile: tuple[int, int] | None = None

    @property
    def is_trivial(self) -> bool:
        """Groups of one non-fusible stage (custom / reduce)."""
        return len(self.stages) == 1 and self.stages[0].kind not in FUSIBLE_KINDS

    def vmem_bytes(self, tile: tuple[int, int] | None = None) -> int:
        """Double-buffered VMEM working set for a candidate tile.

        Every channel live inside the kernel holds an expanded tile of
        ``(th + 2hy, tw + 2hx)`` elements at FIFO depth ``ch.depth``;
        stencil stages additionally materialize their ``kh*kw`` shifted
        views (the register-file cost of the window).
        """
        tile = tile or self.tile
        if tile is None:
            raise GraphError("no tile selected for group")
        th, tw = tile
        total = 0
        for ch in self.inputs + self.outputs + self.internal:
            hy, hx = self.halo.get(ch, (0, 0))
            total += (th + 2 * hy) * (tw + 2 * hx) * _itemsize(ch) * ch.depth
        for st in self.stages:
            if st.kind == "stencil":
                kh, kw = st.window
                out = st.outputs[0]
                hy, hx = self.halo.get(out, (0, 0))
                total += kh * kw * (th + 2 * hy) * (tw + 2 * hx) * _itemsize(out)
        return total


def _itemsize(ch: Channel) -> int:
    return np.dtype(ch.dtype).itemsize


@dataclasses.dataclass
class Schedule:
    graph: DataflowGraph
    order: list[Stage]
    groups: list[FusionGroup]
    #: bundle id per graph-I/O channel (paper: AXI bundles)
    bundles: dict[Channel, int]
    n_bundles: int

    def describe(self) -> str:
        lines = [f"schedule for {self.graph.name!r}: "
                 f"{len(self.order)} stages -> {len(self.groups)} kernels"]
        for gi, g in enumerate(self.groups):
            kind = "custom" if g.is_trivial else "dataflow"
            names = ",".join(s.name for s in g.stages)
            lines.append(f"  kernel[{gi}] ({kind}): {names}")
            lines.append(f"    inputs={[c.name for c in g.inputs]} "
                         f"outputs={[c.name for c in g.outputs]} "
                         f"fifo={[c.name for c in g.internal]}")
        lines.append("  bundles: " + ", ".join(
            f"{c.name}->mem{b}" for c, b in self.bundles.items()))
        return "\n".join(lines)


def build_schedule(graph: DataflowGraph, n_bundles: int = 4) -> Schedule:
    graph.validate()
    order = graph.toposort()
    groups = _partition_groups(order)
    for g in groups:
        _classify_channels(g, graph)
        g.halo = _halo_analysis(g)
    bundles = _assign_bundles(graph, n_bundles)
    return Schedule(graph, order, groups, bundles, n_bundles)


# ----------------------------------------------------------------------
# group partitioning
# ----------------------------------------------------------------------
def _partition_groups(order: list[Stage]) -> list[FusionGroup]:
    """Greedy partitioning of the topo order into fusion groups.

    A stage joins the current group iff it is fusible, works on the
    same 2-D plane shape as the group, and *all* of its non-graph-input
    producers are already inside the group (so the group stays a
    contiguous subgraph and channel writes precede reads inside the
    fused kernel).
    """
    groups: list[FusionGroup] = []
    current: list[Stage] = []
    current_shape: tuple[int, ...] | None = None

    def flush() -> None:
        nonlocal current, current_shape
        if current:
            groups.append(FusionGroup(current, [], [], [], {}))
        current = []
        current_shape = None

    for st in order:
        fusible = (st.kind in FUSIBLE_KINDS
                   and all(len(c.shape) == 2 for c in st.inputs + st.outputs))
        if not fusible:
            flush()
            groups.append(FusionGroup([st], [], [], [], {}))
            continue
        shape = st.outputs[0].shape
        producers_inside = all(
            ch.producer is None or ch.producer in current
            for ch in st.inputs)
        if current and (shape != current_shape or not producers_inside):
            flush()
        current.append(st)
        current_shape = shape
    flush()
    return groups


def _classify_channels(g: FusionGroup, graph: DataflowGraph) -> None:
    inside = set(g.stages)
    seen: set[Channel] = set()
    for st in g.stages:
        for ch in st.inputs:
            if ch in seen:
                continue
            seen.add(ch)
            if ch.producer not in inside:
                g.inputs.append(ch)
        for ch in st.outputs:
            if ch in seen:
                continue
            seen.add(ch)
            consumers_inside = ch.consumers and all(
                c in inside for c in ch.consumers)
            if ch.is_graph_output or not consumers_inside:
                g.outputs.append(ch)
            else:
                g.internal.append(ch)


# ----------------------------------------------------------------------
# halo (line-buffer) analysis
# ----------------------------------------------------------------------
def _halo_analysis(g: FusionGroup) -> dict[Channel, tuple[int, int]]:
    """Cumulative halo per channel, by backward DP over the group.

    ``halo(ch) = max over consumers st of halo(st.output) + st.halo``;
    group outputs carry halo (0, 0).  This is exactly the line-buffer
    depth a chained FPGA stencil pipeline needs, expressed in tiles.
    """
    halo: dict[Channel, tuple[int, int]] = {}
    inside = set(g.stages)
    for ch in g.outputs:
        halo[ch] = (0, 0)
    for st in reversed(g.stages):  # reverse topo order within the group
        out_halos = [halo.get(ch, (0, 0)) for ch in st.outputs]
        oh = (max(h[0] for h in out_halos), max(h[1] for h in out_halos))
        ih = (oh[0] + st.halo[0], oh[1] + st.halo[1])
        for ch in st.inputs:
            prev = halo.get(ch, (0, 0))
            cand = ih if ch.producer in inside or ch in g.inputs else (0, 0)
            halo[ch] = (max(prev[0], cand[0]), max(prev[1], cand[1]))
    return halo


# ----------------------------------------------------------------------
# memory bundles (paper Fig. 4)
# ----------------------------------------------------------------------
def _assign_bundles(graph: DataflowGraph, n_bundles: int) -> dict[Channel, int]:
    """Assign distinct HBM "bundles" to parallel I/O paths.

    Heuristic matching the paper: I/O channels on *different* branches
    of the DAG should land on different bundles so their transfers do
    not serialize on one interface.  We walk graph I/O in order and
    round-robin, but force siblings (channels touching the same stage)
    apart when possible.
    """
    io = graph.graph_inputs + graph.graph_outputs
    bundles: dict[Channel, int] = {}
    nxt = 0
    for ch in io:
        taken = set()
        peers = ch.consumers + ([ch.producer] if ch.producer else [])
        for st in peers:
            for other in st.inputs + st.outputs:
                if other in bundles:
                    taken.add(bundles[other])
        b = nxt % n_bundles
        for _ in range(n_bundles):
            if b not in taken:
                break
            b = (b + 1) % n_bundles
        bundles[ch] = b
        ch.bundle = b
        nxt += 1
    return bundles
