"""FLOWER dataflow compiler: the paper's primary contribution, in JAX.

Layers (see DESIGN.md §3):
  graph.py     — dataflow-graph extraction & validation       (C1)
  transform.py — canonicalization pass pipeline               (C1b)
  schedule.py  — toposort, convex DAG fusion, halo, bundles    (C2, C3c)
  vectorize.py — tile / vector-factor selection                (C3b)
  fusion.py    — top-level kernel generation (pallas/xla)      (C2, C3a)
  host.py      — host-code generation (launcher, buffers)      (C4)
  compiler.py  — the driver: canonicalize→validate→partition→lower
  simulate.py  — FIFO pipeline latency model (paper Fig. 1)
"""
from repro.core.graph import (Channel, ChannelContractError, CycleError,
                              DataflowGraph, GraphError, Stage)
from repro.core.transform import (AutoSplitInsertion, DeadChannelElimination,
                                  Pass, PassPipeline, PointFusion,
                                  default_pipeline)
from repro.core.schedule import FusionGroup, Schedule, build_schedule
from repro.core.fusion import BACKENDS, lower_graph, lower_group
from repro.core.host import CompiledApp, LaunchHandle, build_host_app
from repro.core.compiler import compile_graph
from repro.core.simulate import TaskTiming, analytic_latency, simulate_pipeline
from repro.core.vectorize import (TPUSpec, V5E, choose_tile, plane_features,
                                  schedule_features, select_tile,
                                  sweep_vector_factor)

__all__ = [
    "Channel", "ChannelContractError", "CycleError", "DataflowGraph",
    "GraphError", "Stage", "Pass", "PassPipeline", "AutoSplitInsertion",
    "DeadChannelElimination", "PointFusion", "default_pipeline",
    "FusionGroup", "Schedule", "build_schedule",
    "BACKENDS", "lower_graph", "lower_group", "CompiledApp",
    "LaunchHandle", "build_host_app", "compile_graph", "TaskTiming",
    "analytic_latency",
    "simulate_pipeline", "TPUSpec", "V5E", "choose_tile", "select_tile",
    "sweep_vector_factor", "plane_features", "schedule_features",
]
