"""Top-level kernel generation (FLOWER contribution C2).

Lowers a :class:`FusionGroup` to one of three backends:

- ``xla``        — the stages composed as ordinary jnp ops; XLA's own
                   fuser handles them (portable backend #1).
- ``xla_staged`` — same, but with ``lax.optimization_barrier`` after
                   every stage so each intermediate materializes to HBM.
                   This reproduces the paper's *AnyHLS / no-dataflow*
                   baseline: disjoint per-stage kernels with a global
                   memory round-trip between stages.
- ``pallas``     — THE paper artifact: one fused streaming kernel.  The
                   grid walks output tiles; each grid step DMAs an
                   (optionally halo-expanded) tile of every group input
                   HBM→VMEM (the generated *read task* / burst
                   transfer), pushes it through all stages in
                   topological order inside VMEM (tasks connected by
                   depth-2 FIFOs == Pallas' double-buffered pipeline),
                   and DMAs the output tile back (the *write task*).

Boundary semantics are zero-padding and are *bit-exact* across all
three backends: inside the fused kernel, every stage output is masked
to zero outside the logical image domain, which reproduces exactly the
reference's per-stage ``jnp.pad`` behaviour at tile borders.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.graph import (Channel, DataflowGraph, GraphError, Stage,
                              _apply_stage_reference)
from repro.core.schedule import FusionGroup, Schedule, build_schedule
from repro.core.vectorize import TPUSpec, V5E, select_tile

__all__ = ["lower_group", "lower_graph", "BACKENDS"]

#: the lowerable seed backends (kept as a tuple for the historical
#: sweep idiom); the authoritative list is the registry
#: (:func:`repro.backends.names`), which also holds gated stubs
BACKENDS = ("xla", "xla_staged", "pallas")


# ----------------------------------------------------------------------
# XLA backends
# ----------------------------------------------------------------------
def lower_group_xla(group: FusionGroup, staged: bool = False,
                    valid_rows: tuple[int, int] | None = None) -> Callable:
    """Compose the group's stages as whole-array jnp ops.

    With ``staged=True`` an optimization barrier follows every stage, so
    XLA cannot fuse across stages — each intermediate round-trips
    through HBM, exactly like AnyHLS' disjoint IP blocks.

    ``valid_rows=(r0, r1)`` narrows the logical image to that row band:
    every stage output is zeroed outside it, reproducing the per-stage
    zero-padding semantics of a *window* of a larger plane.  The
    replicator (:mod:`repro.parallel.replicate`) uses this for shards
    at the global top/bottom edge.
    """

    def run(env_in: dict[Channel, Any]) -> dict[Channel, Any]:
        env = dict(env_in)
        for st in group.stages:
            vals = [env[c] for c in st.inputs]
            outs = _apply_stage_reference(st, vals)
            outs = [o.astype(c.dtype) for o, c in zip(outs, st.outputs)]
            if valid_rows is not None:
                outs = [_window_rows(o, valid_rows) for o in outs]
            if staged:
                outs = list(lax.optimization_barrier(tuple(outs)))
            for ch, v in zip(st.outputs, outs):
                env[ch] = v
        return {ch: env[ch] for ch in group.outputs}

    return run


def _window_rows(x, valid_rows: tuple[int, int]):
    """Zero rows of a 2-D plane outside the [r0, r1) band."""
    if getattr(x, "ndim", 0) != 2:
        return x
    r0, r1 = valid_rows
    rows = lax.broadcasted_iota(jnp.int32, x.shape, 0)
    return jnp.where((rows >= r0) & (rows < r1), x, jnp.zeros_like(x))


# ----------------------------------------------------------------------
# Pallas streaming backend (the generated top-level kernel)
# ----------------------------------------------------------------------
def lower_group_pallas(group: FusionGroup, spec: TPUSpec = V5E,
                       vector_factor: int | None = None,
                       interpret: bool = True,
                       valid_rows: tuple[int, int] | None = None) -> Callable:
    if group.is_trivial:
        raise GraphError("cannot pallas-lower a custom/reduce group")
    tile = group.tile or select_tile(group, spec, vector_factor)[0]
    th, tw = tile
    H, W = group.stages[0].outputs[0].shape
    Hp, Wp = _round_up(H, th), _round_up(W, tw)
    grid = (Hp // th, Wp // tw)
    rows = valid_rows if valid_rows is not None else (0, H)

    in_specs = []
    for ch in group.inputs:
        hy, hx = group.halo.get(ch, (0, 0))
        in_specs.append(_element_block_spec(
            (th + 2 * hy, tw + 2 * hx),
            functools.partial(_in_index, th=th, tw=tw)))
    out_specs = [pl.BlockSpec((th, tw), lambda i, j: (i, j))
                 for _ in group.outputs]
    out_shapes = [jax.ShapeDtypeStruct((Hp, Wp), ch.dtype)
                  for ch in group.outputs]

    kernel = functools.partial(
        _group_kernel, group=group, tile=tile, plane=(H, W),
        n_in=len(group.inputs), rows=rows)

    call = pl.pallas_call(
        kernel, grid=grid, in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shapes, interpret=interpret)

    def run(env_in: dict[Channel, Any]) -> dict[Channel, Any]:
        ins = []
        for ch in group.inputs:
            hy, hx = group.halo.get(ch, (0, 0))
            x = jnp.asarray(env_in[ch], dtype=ch.dtype)
            # The generated read task: zero-pad by the cumulative halo
            # and up to a whole number of tiles; each grid step then
            # bursts a contiguous (th+2hy, tw+2hx) block into VMEM.
            x = jnp.pad(x, ((hy, Hp - H + hy), (hx, Wp - W + hx)))
            ins.append(x)
        outs = call(*ins)
        if not isinstance(outs, (tuple, list)):
            outs = (outs,)
        return {ch: o[:H, :W] for ch, o in zip(group.outputs, outs)}

    return run


def _element_block_spec(shape: tuple[int, int], index_map) -> pl.BlockSpec:
    """Element-indexed BlockSpec across the pallas API generations.

    jax >= 0.5 spells it ``pl.Element(n)`` per dimension; jax 0.4.x
    spells the same semantics (index map returns element offsets, not
    block indices) ``indexing_mode=pl.Unblocked()``.
    """
    if hasattr(pl, "Element"):
        return pl.BlockSpec(tuple(pl.Element(s) for s in shape), index_map)
    return pl.BlockSpec(shape, index_map, indexing_mode=pl.Unblocked())


def _in_index(i, j, *, th, tw):
    # Element-indexed: the block's top-left corner in the *padded* input
    # is (i*th, j*tw); with the host-side pad of (hy, hx) this centers
    # the halo window on the output tile.
    return (i * th, j * tw)


def _group_kernel(*refs, group: FusionGroup, tile: tuple[int, int],
                  plane: tuple[int, int], n_in: int,
                  rows: tuple[int, int]) -> None:
    th, tw = tile
    H, W = plane
    in_refs, out_refs = refs[:n_in], refs[n_in:]
    i = pl.program_id(0)
    j = pl.program_id(1)

    env: dict[Channel, Any] = {}
    for ch, ref in zip(group.inputs, in_refs):
        env[ch] = ref[...]

    halo = group.halo
    for st in group.stages:  # already in topological order
        oh = _stage_out_halo(st, halo)
        vals = []
        for ch in st.inputs:
            need = (oh[0] + st.halo[0], oh[1] + st.halo[1])
            vals.append(_crop(env[ch], halo.get(ch, (0, 0)), need, th, tw))
        outs = _apply_stage_tile(st, vals, oh, th, tw)
        for ch, v in zip(st.outputs, outs):
            ch_halo = halo.get(ch, (0, 0))
            v = _crop(v, oh, ch_halo, th, tw).astype(ch.dtype)
            # zero outside the logical image: reproduces per-stage
            # zero-padding semantics bit-exactly at tile borders.
            env[ch] = _mask_to_image(v, ch_halo, i, j, th, tw, rows, W)

    for ch, ref in zip(group.outputs, out_refs):
        ref[...] = _crop(env[ch], halo.get(ch, (0, 0)), (0, 0), th, tw)


def _stage_out_halo(st: Stage, halo: dict[Channel, tuple[int, int]]
                    ) -> tuple[int, int]:
    hs = [halo.get(ch, (0, 0)) for ch in st.outputs]
    return (max(h[0] for h in hs), max(h[1] for h in hs))


def _crop(x, have: tuple[int, int], need: tuple[int, int],
          th: int, tw: int):
    dy, dx = have[0] - need[0], have[1] - need[1]
    if dy < 0 or dx < 0:
        raise GraphError(f"halo underflow: have {have}, need {need}")
    if dy == 0 and dx == 0:
        return x
    return x[dy:dy + th + 2 * need[0], dx:dx + tw + 2 * need[1]]


def _apply_stage_tile(st: Stage, vals: list, oh: tuple[int, int],
                      th: int, tw: int) -> list:
    if st.kind == "point":
        return [st.fn(vals[0])]
    if st.kind == "pointN":
        return [st.fn(*vals)]
    if st.kind == "split":
        return [vals[0] for _ in st.outputs]
    if st.kind == "stencil":
        kh, kw = st.window
        x = vals[0]  # (th + 2(oh+sh), tw + 2(ow+sw))
        out_h, out_w = th + 2 * oh[0], tw + 2 * oh[1]
        views = [x[di:di + out_h, dj:dj + out_w]
                 for di in range(kh) for dj in range(kw)]
        patches = jnp.stack(views, axis=0)
        return [st.fn(patches)]
    raise GraphError(f"stage kind {st.kind!r} is not tile-streamable")


def _mask_to_image(v, oh: tuple[int, int], i, j, th: int, tw: int,
                   row_band: tuple[int, int], W: int):
    eh, ew = th + 2 * oh[0], tw + 2 * oh[1]
    r0, r1 = row_band
    rows = lax.broadcasted_iota(jnp.int32, (eh, ew), 0) + i * th - oh[0]
    cols = lax.broadcasted_iota(jnp.int32, (eh, ew), 1) + j * tw - oh[1]
    ok = (rows >= r0) & (rows < r1) & (cols >= 0) & (cols < W)
    return jnp.where(ok, v, jnp.zeros_like(v))


# ----------------------------------------------------------------------
# whole-graph lowering
# ----------------------------------------------------------------------
def lower_group(group: FusionGroup, backend, spec: TPUSpec | None = None,
                vector_factor: int | None = None,
                interpret: bool | None = None,
                valid_rows: tuple[int, int] | None = None) -> Callable:
    """Lower one fusion group through the backend registry.

    ``backend`` is a registered name or a
    :class:`~repro.backends.Backend` spec; the resolved record
    capability-checks the group's stage kinds, resolves the
    interpret-vs-compiled mode, and dispatches its ``lower`` hook.
    ``valid_rows`` applies to trivial groups too: a 2-D custom/reduce
    output outside the row band must read as zero downstream
    (``_window_rows`` no-ops on non-2-D outputs).
    """
    from repro.backends import resolve
    be = resolve(backend)
    return be.lower_group(group, spec=spec, vector_factor=vector_factor,
                          interpret=interpret, valid_rows=valid_rows)


def lower_graph(graph: DataflowGraph, backend="pallas",
                schedule: Schedule | None = None,
                spec: TPUSpec | None = None,
                vector_factor: int | None = None,
                interpret: bool | None = None, *,
                canonicalize: bool = True, strict: bool = False,
                max_tile: tuple[int, int] | None = None,
                valid_rows: tuple[int, int] | None = None,
                ) -> tuple[Callable, Schedule]:
    """Lower a whole dataflow graph; returns ``(run, schedule)``.

    ``run`` maps ``{input_name: array} -> {output_name: array}`` and is
    jit-compatible.  One source program, any backend — the paper's
    portability claim (Fig. 8/9) maps to ``backend=`` here: a
    registered name or a :class:`~repro.backends.Backend` spec, whose
    constants also seed the schedule (VMEM budget, tile cap) when no
    explicit ``spec``/``max_tile`` is passed.  Unless a pre-built
    ``schedule`` is passed (the compiler driver and the autotuner both
    pass one, with tiles already selected and provenance-labeled), the
    graph first goes through the canonicalization pass pipeline
    (``strict=True`` to enforce the explicit canonical form instead;
    see :func:`repro.core.schedule.build_schedule`); ``max_tile`` then
    caps the tile shapes the schedule may select.
    """
    from repro.backends import resolve
    be = resolve(backend)
    sched = schedule or build_schedule(graph, canonicalize=canonicalize,
                                       strict=strict, spec=spec,
                                       vector_factor=vector_factor,
                                       max_tile=max_tile, backend=be)
    graph = sched.graph
    fns = [be.lower_group(g, spec=spec, vector_factor=vector_factor,
                          interpret=interpret, valid_rows=valid_rows)
           for g in sched.groups]

    def run(inputs: dict[str, Any]) -> dict[str, Any]:
        env: dict[Channel, Any] = {}
        for ch in graph.graph_inputs:
            env[ch] = jnp.asarray(inputs[ch.name], dtype=ch.dtype)
        for fn, g in zip(fns, sched.groups):
            outs = fn({ch: env[ch] for ch in g.inputs})
            env.update(outs)
        return {ch.name: env[ch] for ch in graph.graph_outputs}

    return run, sched


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
