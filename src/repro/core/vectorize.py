"""Tile / vector-factor selection (FLOWER contribution C3b).

On the FPGA, FLOWER widens the datapath (``int4`` channels for vector
factor 4) to match the 512-bit memory bus.  The TPU analogue: pick the
streamed tile so its minor dimension is a multiple of the 128-lane VPU
(and MXU) width, its second-minor a multiple of the 8-row sublane, and
the double-buffered working set of the whole fused group fits in VMEM.

The *vector factor* maps to how many 128-lane vectors a tile row
carries (``tw == 128 * vector_factor``); the *burst length* maps to
the tile byte count per DMA (bigger tiles == longer HBM bursts ==
better DMA efficiency, up to the VMEM budget).

Two entry points:

- :func:`choose_tile` — the paper's *explicit* knob: the caller fixes
  the vector factor, we fit the tallest tile that holds the VMEM
  budget, or raise when the factor cannot fit the plane / ``max_tile``.
- :func:`select_tile` — the *automatic* mode used by the compiler
  driver: sweep every feasible vector factor through a DMA-efficiency
  cost model (:func:`modeled_plane_time`) and keep the fastest.  The
  sweep is what replaces a hardcoded default: wide tiles amortize the
  per-burst overhead, but over-wide tiles pay for padded columns when
  the plane width is not a multiple, and the model prices both.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.schedule import FusionGroup

__all__ = ["TPUSpec", "choose_tile", "select_tile", "sweep_vector_factor",
           "modeled_plane_time", "modeled_schedule_time", "scale_spec",
           "plane_features", "schedule_features",
           "vmem_report", "DEFAULT_MAX_TILE"]

LANE = 128     # VPU/MXU lane width (registry default; see _constants)
SUBLANE = 8    # float32 sublane rows

#: default (th, tw) cap for choose_tile/select_tile; the autotuner
#: (:mod:`repro.tune`) searches over alternative caps (the tile-height
#: axis of the schedule space)
DEFAULT_MAX_TILE = (256, 1024)


def _constants(backend, spec, max_tile) -> tuple:
    """Resolve (spec, max_tile, lane, sublane) for a tile decision.

    With ``backend`` (a name or :class:`~repro.backends.Backend`), the
    lane width, sublane rows, VMEM budgets and tile cap come from the
    resolved record — the single source of per-target constants;
    explicit ``spec``/``max_tile`` arguments still win.  Without one,
    the module-level defaults apply (identical values for the seed
    backends, so legacy call sites are bit-compatible).
    """
    if backend is None:
        return (spec or V5E,
                tuple(max_tile) if max_tile is not None else DEFAULT_MAX_TILE,
                LANE, SUBLANE)
    from repro.backends import resolve
    be = resolve(backend)
    return (spec or be.spec,
            tuple(max_tile) if max_tile is not None
            else tuple(be.default_max_tile),
            be.lane, be.sublane)


@dataclasses.dataclass(frozen=True)
class TPUSpec:
    """Per-chip hardware constants (TPU v5e by default)."""

    vmem_bytes: int = 96 * 2**20        # budget (of 128 MiB physical)
    hbm_bytes: int = 16 * 2**30
    peak_flops_bf16: float = 197e12
    hbm_bw: float = 819e9
    ici_bw_per_link: float = 50e9
    clock_hz: float = 940e6
    #: fixed per-grid-step cost (DMA issue / burst setup) the sweep
    #: amortizes by widening tiles
    step_overhead_s: float = 1e-6


V5E = TPUSpec()


def choose_tile(group: FusionGroup, spec: TPUSpec | None = None,
                vector_factor: int = 1,
                max_tile: tuple[int, int] | None = None,
                backend=None) -> tuple[int, int]:
    """Pick (th, tw) for a fusion group at a fixed vector factor.

    ``tw`` is exactly ``lane * vector_factor`` — the paper's explicit
    vectorization knob sets the datapath width.  ``th`` starts at the
    largest hardware-aligned height ``<= max_tile[0]`` bounded by the
    plane, then shrinks until the double-buffered VMEM budget holds.
    The lane width, sublane rows, tile cap and VMEM budget come from
    the resolved ``backend`` (explicit ``spec``/``max_tile`` override).

    Raises :class:`ValueError` when the requested factor cannot fit —
    either because ``lane * vector_factor`` exceeds ``max_tile[1]`` or
    the lane-rounded plane width, or because even the minimal
    ``(sublane, tw)`` tile blows the VMEM budget.
    """
    spec, max_tile, lane, sublane = _constants(backend, spec, max_tile)
    if vector_factor < 1:
        raise ValueError(f"vector_factor must be >= 1, got {vector_factor}")
    shape = group.stages[0].outputs[0].shape
    if len(shape) != 2:
        raise ValueError(f"generic fusion tiles 2-D planes, got {shape}")
    H, W = shape
    tw = lane * vector_factor
    # clamp BEFORE committing to the factor: a tile wider than the
    # lane-rounded plane only streams padding, and max_tile is a hard
    # cap — the old code applied the factor after clamping and silently
    # exceeded both.
    cap_tw = min(_round_up(W, lane), max(lane, (max_tile[1] // lane) * lane))
    if tw > cap_tw:
        raise ValueError(
            f"vector_factor={vector_factor} needs a {tw}-lane-wide tile, "
            f"but the widest feasible tile is {cap_tw} "
            f"(plane width {W} -> {_round_up(W, lane)} lane-rounded, "
            f"max_tile[1]={max_tile[1]})")
    th = min(_round_up(H, sublane),
             max(sublane, (max_tile[0] // sublane) * sublane))

    while group.vmem_bytes((th, tw)) > spec.vmem_bytes:
        if th > sublane:
            th = max(sublane, th // 2)
        else:
            raise ValueError(
                f"group {[s.name for s in group.stages]} cannot fit VMEM "
                f"budget {spec.vmem_bytes} even at minimal tile "
                f"({sublane}, {tw}) for vector_factor={vector_factor}: "
                f"{group.vmem_bytes((th, tw))} bytes")
    group.tile = (th, tw)
    group.vector_factor = vector_factor
    return group.tile


def modeled_plane_time(group: FusionGroup, tile: tuple[int, int],
                       spec: TPUSpec = V5E) -> float:
    """Modeled seconds to stream the whole plane through the kernel.

    Per grid step the kernel bursts every (halo-expanded) input tile
    HBM->VMEM, computes, and bursts the output tiles back; DMA and
    compute overlap (double buffering), and each step pays a fixed
    issue overhead.  Padded rows/columns are priced: the grid covers
    the tile-rounded plane, so an over-wide tile on a narrow plane
    streams dead columns.

    A calibrated spec (:class:`repro.tune.calibrate.CalibratedSpec`)
    may carry an ``ii_scale`` mapping stage kinds to fitted multipliers
    of their issue intervals; any spec without one prices every stage
    at its declared ``ii`` exactly as before.
    """
    th, tw = tile
    H, W = group.stages[0].outputs[0].shape
    grid = (_round_up(H, th) // th) * (_round_up(W, tw) // tw)
    bytes_step = 0
    for ch in group.inputs:
        hy, hx = group.halo.get(ch, (0, 0))
        bytes_step += (th + 2 * hy) * (tw + 2 * hx) * np.dtype(ch.dtype).itemsize
    for ch in group.outputs:
        bytes_step += th * tw * np.dtype(ch.dtype).itemsize
    dma_s = bytes_step / spec.hbm_bw
    scale = dict(getattr(spec, "ii_scale", ()) or ())
    if scale:
        steps = sum(st.ii * scale.get(st.kind, 1.0) for st in group.stages)
    else:
        steps = sum(st.ii for st in group.stages)
    compute_s = steps * th * tw / spec.clock_hz
    return grid * (spec.step_overhead_s + max(dma_s, compute_s))


def plane_features(group: FusionGroup, tile: tuple[int, int]) -> dict:
    """Spec-independent features behind :func:`modeled_plane_time`.

    The model is, per fusion group,

    ``t = grid * (step_overhead_s + max(bytes_step / hbm_bw,
    sum_kind(steps[kind] * ii_scale[kind]) / clock_hz))``

    so recording ``grid`` (DMA issue count), ``bytes_step`` (HBM bytes
    per step) and ``steps`` (per-stage-kind issue-interval cycles per
    step, already multiplied by the tile area) into every drift row
    makes the modeled time *linear in the constants' reciprocals* —
    exactly what the calibration fit
    (:func:`repro.tune.calibrate.calibrate`) regresses from measured
    times.  :func:`repro.obs.drift.predict_features` is the inverse:
    it reconstitutes the modeled seconds from these features under any
    spec, bit-identically to :func:`modeled_plane_time`.
    """
    th, tw = tile
    H, W = group.stages[0].outputs[0].shape
    grid = (_round_up(H, th) // th) * (_round_up(W, tw) // tw)
    bytes_step = 0
    for ch in group.inputs:
        hy, hx = group.halo.get(ch, (0, 0))
        bytes_step += (th + 2 * hy) * (tw + 2 * hx) * np.dtype(ch.dtype).itemsize
    for ch in group.outputs:
        bytes_step += th * tw * np.dtype(ch.dtype).itemsize
    steps: dict[str, float] = {}
    for st in group.stages:
        steps[st.kind] = steps.get(st.kind, 0.0) + float(st.ii)
    return {"grid": grid, "bytes_step": bytes_step,
            "steps": {k: v * th * tw for k, v in sorted(steps.items())}}


def schedule_features(schedule, items: int = 1) -> dict:
    """Whole-app drift-row features: one entry per modeled group.

    Trivial (custom/reduce) groups carry no tile and score zero in
    :func:`modeled_schedule_time`, so they contribute no features
    either.  ``items`` scales the prediction (a width-``n`` batched
    launch does the plane ``n`` times); it rides in the feature dict so
    a drift row stays self-describing.
    """
    groups = [plane_features(g, g.tile) for g in schedule.groups
              if not g.is_trivial and g.tile is not None]
    feats = {"groups": groups}
    if items != 1:
        feats["items"] = int(items)
    return feats


def sweep_vector_factor(group: FusionGroup, spec: TPUSpec | None = None,
                        max_tile: tuple[int, int] | None = None,
                        candidates: tuple[int, ...] | None = None,
                        trace=None, backend=None) -> list[dict]:
    """Cost-model sweep over vector factors; one record per candidate.

    Default candidates run 1..cap (every factor the plane/max_tile can
    hold, plus one infeasible sentinel so callers can check that
    feasibility is monotone).  Each record carries ``vector_factor``,
    ``feasible``, the chosen ``tile``, ``modeled_s`` and the
    :func:`plane_features` behind the modeled time (``features`` — what
    benchmark drift rows persist for the calibration fit).  ``trace``
    (a :class:`~repro.obs.tracer.Tracer`) wraps the sweep in a
    ``compile.vectorize.sweep`` span recording how many candidates
    were scored and how many were feasible.
    """
    spec, max_tile, lane, _ = _constants(backend, spec, max_tile)
    if trace is not None:
        with trace.span("compile.vectorize.sweep", cat="compile",
                        group=",".join(s.name for s in group.stages)) as sp:
            records = sweep_vector_factor(group, spec, max_tile, candidates,
                                          backend=backend)
            sp.set(candidates=len(records),
                   feasible=sum(1 for r in records if r["feasible"]))
            return records
    shape = group.stages[0].outputs[0].shape
    H, W = shape
    cap_tw = min(_round_up(W, lane), max(lane, (max_tile[1] // lane) * lane))
    if candidates is None:
        candidates = tuple(range(1, cap_tw // lane + 2))
    records: list[dict] = []
    prev = (group.tile, group.vector_factor)
    try:
        for vf in candidates:
            try:
                tile = choose_tile(group, spec, vf, max_tile,
                                   backend=backend)
            except ValueError as e:
                records.append({"vector_factor": vf, "feasible": False,
                                "tile": None, "modeled_s": float("inf"),
                                "reason": str(e)})
                continue
            records.append({"vector_factor": vf, "feasible": True,
                            "tile": tile,
                            "modeled_s": modeled_plane_time(group, tile,
                                                            spec),
                            "features": plane_features(group, tile)})
    finally:
        # the sweep only *scores*; choose_tile/select_tile commit.
        # Without the restore, a standalone sweep would pin the group
        # to the last candidate tried, not the chosen tile.
        group.tile, group.vector_factor = prev
    return records


def select_tile(group: FusionGroup, spec: TPUSpec | None = None,
                vector_factor: int | None = None,
                max_tile: tuple[int, int] | None = None,
                trace=None, backend=None,
                ) -> tuple[tuple[int, int], list[dict] | None]:
    """Pick the group's tile; sweep the vector factor when not forced.

    ``vector_factor=None`` runs :func:`sweep_vector_factor` and keeps
    the fastest feasible candidate (ties break toward the wider tile —
    longer bursts).  An explicit factor forwards to
    :func:`choose_tile`.  Returns ``(tile, sweep_records)`` with
    ``sweep_records=None`` in forced mode; the group's ``tile`` and
    ``vector_factor`` fields are set either way.  ``trace`` threads a
    flight recorder into the sweep.
    """
    if vector_factor is not None:
        return choose_tile(group, spec, vector_factor, max_tile,
                           backend=backend), None
    records = sweep_vector_factor(group, spec, max_tile, trace=trace,
                                  backend=backend)
    feasible = [r for r in records if r["feasible"]]
    if not feasible:
        raise ValueError(
            f"no feasible vector factor for group "
            f"{[s.name for s in group.stages]}: "
            f"{records[0].get('reason', 'no candidates')}")
    best = min(feasible, key=lambda r: (r["modeled_s"], -r["vector_factor"]))
    group.tile = best["tile"]
    group.vector_factor = best["vector_factor"]
    return group.tile, records


def scale_spec(spec: TPUSpec, vmem_fraction: float) -> TPUSpec:
    """Shrink a spec's VMEM budget — the *fusion budget* knob.

    The partitioner only merges groups whose double-buffered working
    set fits ``spec.vmem_bytes``, so scaling the budget changes which
    stages fuse, not just how they tile.  The autotuner searches over
    fractions because the model's VMEM budget is a proxy (real kernels
    pay scratch and compiler overheads the closed form cannot see).
    """
    if not 0.0 < vmem_fraction <= 1.0:
        raise ValueError(f"vmem_fraction must be in (0, 1], got "
                         f"{vmem_fraction}")
    if vmem_fraction == 1.0:
        return spec
    return dataclasses.replace(spec,
                               vmem_bytes=int(spec.vmem_bytes * vmem_fraction))


def modeled_schedule_time(schedule, spec: TPUSpec = V5E) -> float:
    """Whole-app modeled seconds: sum of per-group plane times.

    Groups execute back-to-back at app granularity (each drains to HBM
    before the next starts), so the app-level model is additive over
    :func:`modeled_plane_time`; trivial (custom/reduce) groups carry no
    tile and score zero.  This is the ranking prior the autotuner uses
    to order joint candidates before measuring them.
    """
    total = 0.0
    for g in schedule.groups:
        if g.is_trivial or g.tile is None:
            continue
        total += modeled_plane_time(g, g.tile, spec)
    return total


def vmem_report(group: FusionGroup) -> dict:
    th, tw = group.tile
    return {
        "tile": group.tile,
        "vector_factor": tw // LANE,
        "vmem_bytes": group.vmem_bytes(),
        "n_channels": len(group.inputs) + len(group.outputs)
        + len(group.internal),
        "burst_bytes": max(
            (th + 2 * hy) * (tw + 2 * hx)
            * np.dtype(ch.dtype).itemsize
            for ch in group.inputs
            for hy, hx in [group.halo.get(ch, (0, 0))]
        ) if group.inputs else 0,
    }


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
