"""Tile / vector-factor selection (FLOWER contribution C3b).

On the FPGA, FLOWER widens the datapath (``int4`` channels for vector
factor 4) to match the 512-bit memory bus.  The TPU analogue: pick the
streamed tile so its minor dimension is a multiple of the 128-lane VPU
(and MXU) width, its second-minor a multiple of the 8-row sublane, and
the double-buffered working set of the whole fused group fits in VMEM.

The *vector factor* maps to how many 128-lane vectors a tile row
carries; the *burst length* maps to the tile byte count per DMA
(bigger tiles == longer HBM bursts == better DMA efficiency, up to the
VMEM budget).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.schedule import FusionGroup

__all__ = ["TPUSpec", "choose_tile", "vmem_report"]

LANE = 128     # VPU/MXU lane width
SUBLANE = 8    # float32 sublane rows


@dataclasses.dataclass(frozen=True)
class TPUSpec:
    """Per-chip hardware constants (TPU v5e by default)."""

    vmem_bytes: int = 96 * 2**20        # budget (of 128 MiB physical)
    hbm_bytes: int = 16 * 2**30
    peak_flops_bf16: float = 197e12
    hbm_bw: float = 819e9
    ici_bw_per_link: float = 50e9


V5E = TPUSpec()


def choose_tile(group: FusionGroup, spec: TPUSpec = V5E,
                vector_factor: int = 1,
                max_tile: tuple[int, int] = (256, 1024)) -> tuple[int, int]:
    """Pick (th, tw) for a fusion group.

    Start from the largest hardware-aligned tile `<= max_tile` bounded
    by the plane shape; shrink rows first (keeps lane utilization),
    then lanes, until the double-buffered VMEM budget holds.
    ``vector_factor`` forces the minor dim to ``128 * vector_factor``
    at minimum — the paper's explicit vectorization knob.
    """
    shape = group.stages[0].outputs[0].shape
    if len(shape) != 2:
        raise ValueError(f"generic fusion tiles 2-D planes, got {shape}")
    H, W = shape
    tw = min(_round_up(min(W, max_tile[1]), LANE), _round_up(W, LANE))
    tw = max(tw, LANE * vector_factor)
    th = min(_round_up(min(H, max_tile[0]), SUBLANE), _round_up(H, SUBLANE))

    while group.vmem_bytes((th, tw)) > spec.vmem_bytes:
        if th > SUBLANE:
            th = max(SUBLANE, th // 2)
        elif tw > LANE * vector_factor:
            tw = max(LANE * vector_factor, tw // 2)
        else:
            raise ValueError(
                f"group {[s.name for s in group.stages]} cannot fit VMEM "
                f"budget {spec.vmem_bytes} even at minimal tile "
                f"({SUBLANE}, {LANE * vector_factor}): "
                f"{group.vmem_bytes((th, tw))} bytes")
    group.tile = (th, tw)
    return group.tile


def vmem_report(group: FusionGroup) -> dict:
    th, tw = group.tile
    return {
        "tile": group.tile,
        "vector_factor": tw // LANE,
        "vmem_bytes": group.vmem_bytes(),
        "n_channels": len(group.inputs) + len(group.outputs)
        + len(group.internal),
        "burst_bytes": max(
            (th + 2 * hy) * (tw + 2 * hx)
            * np.dtype(ch.dtype).itemsize
            for ch in group.inputs
            for hy, hx in [group.halo.get(ch, (0, 0))]
        ) if group.inputs else 0,
    }


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
