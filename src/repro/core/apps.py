"""The paper's benchmark applications (Table I), in the stage DSL.

Each builder returns a :class:`DataflowGraph` for one application, on
single-channel float32 planes (RGB apps take three planes).  Stage
counts match Table I's "compute" stages; the scheduler adds the
read/write staging implicitly (the paper: "+2 memory stages for burst
transfers").

These graphs are consumed by examples/, benchmarks/fig5_app_latency.py,
benchmarks/fig6_opt_ladder.py and the test-suite — one source program
per app, every backend.
"""
from __future__ import annotations

from typing import Callable

import jax.numpy as jnp
import numpy as np

from repro.core.graph import DataflowGraph

__all__ = ["APPS", "build_app", "compile_app"]


# ----------------------------------------------------------------------
# small stencil helpers (patches: (kh*kw, th, tw), row-major taps)
# ----------------------------------------------------------------------
def _conv(weights: np.ndarray) -> Callable:
    # Taps are unrolled as scalar multiplies (zeros elided) — the same
    # constant folding an FPGA synthesizer applies to fixed
    # coefficients, and it keeps stage fns free of captured array
    # constants (a Pallas kernel requirement).
    taps = [float(v) for v in weights.reshape(-1)]

    def fn(p):
        acc = None
        for i, t in enumerate(taps):
            if t == 0.0:
                continue
            term = p[i] if t == 1.0 else p[i] * t
            acc = term if acc is None else acc + term
        return acc

    return fn


GAUSS3 = np.array([[1, 2, 1], [2, 4, 2], [1, 2, 1]], np.float32) / 16.0
GAUSS5 = np.outer([1, 4, 6, 4, 1], [1, 4, 6, 4, 1]).astype(np.float32) / 256.0
MEAN5 = np.ones((5, 5), np.float32) / 25.0
SOBEL_X = np.array([[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]], np.float32)
SOBEL_Y = SOBEL_X.T.copy()
LAPLACE3 = np.array([[0, 1, 0], [1, -4, 1], [0, 1, 0]], np.float32)
JACOBI3 = np.array([[0, 1, 0], [1, 0, 1], [0, 1, 0]], np.float32) / 4.0


def _sobel_mag(p):
    gx = _conv(SOBEL_X)(p)
    gy = _conv(SOBEL_Y)(p)
    return jnp.sqrt(gx * gx + gy * gy + 1e-12)


def _bilateral(sigma_s: float = 2.0, sigma_r: float = 0.25) -> Callable:
    kh = kw = 5
    ds = np.array([[(i - 2) ** 2 + (j - 2) ** 2 for j in range(kw)]
                   for i in range(kh)], np.float32).reshape(-1)
    ws = [float(v) for v in np.exp(-ds / (2 * sigma_s ** 2))]
    inv2r = 1.0 / (2 * sigma_r ** 2)

    def fn(p):
        center = p[kh * kw // 2]
        sum_w = None
        sum_wp = None
        for i, wsi in enumerate(ws):  # unrolled taps (scalar consts)
            wr = jnp.exp(-(p[i] - center) ** 2 * inv2r) * wsi
            sum_w = wr if sum_w is None else sum_w + wr
            term = wr * p[i]
            sum_wp = term if sum_wp is None else sum_wp + term
        return sum_wp / (sum_w + 1e-12)

    return fn


# ----------------------------------------------------------------------
# application builders
# ----------------------------------------------------------------------
def mean_filter(h: int, w: int) -> DataflowGraph:
    g = DataflowGraph("mean_filter")
    x = g.input("img", (h, w))
    g.output(g.stencil(x, (5, 5), _conv(MEAN5), name="mean5"), "out")
    return g


def gaussian_blur(h: int, w: int) -> DataflowGraph:
    g = DataflowGraph("gaussian_blur")
    x = g.input("img", (h, w))
    g.output(g.stencil(x, (5, 5), _conv(GAUSS5), name="gauss5"), "out")
    return g


def bilateral_filter(h: int, w: int) -> DataflowGraph:
    g = DataflowGraph("bilateral_filter")
    x = g.input("img", (h, w))
    g.output(g.stencil(x, (5, 5), _bilateral(), name="bilateral5",
                       ii=4.0, fill=64.0), "out")
    return g


def sobel_luma(h: int, w: int) -> DataflowGraph:
    g = DataflowGraph("sobel_luma")
    r = g.input("r", (h, w))
    gr = g.input("g", (h, w))
    b = g.input("b", (h, w))
    luma = g.pointn([r, gr, b],
                    lambda r, gc, b: 0.299 * r + 0.587 * gc + 0.114 * b,
                    name="luma")
    g.output(g.stencil(luma, (3, 3), _sobel_mag, name="sobel"), "out")
    return g


def unsharp_mask(h: int, w: int, amount: float = 1.5) -> DataflowGraph:
    g = DataflowGraph("unsharp_mask")
    x = g.input("img", (h, w))
    x1, x2, x3 = g.split(x, 3)
    blur = g.stencil(x1, (5, 5), _conv(GAUSS5), name="blur")
    diff = g.point2(x2, blur, lambda a, b: a - b, name="highpass")
    g.output(g.point2(x3, diff, lambda a, d: a + amount * d, name="sharpen"),
             "out")
    return g


def filter_chain(h: int, w: int) -> DataflowGraph:
    g = DataflowGraph("filter_chain")
    x = g.input("img", (h, w))
    c = x
    for i in range(3):
        c = g.stencil(c, (3, 3), _conv(GAUSS3), name=f"filt{i + 1}")
    g.output(c, "out")
    return g


def jacobi(h: int, w: int) -> DataflowGraph:
    g = DataflowGraph("jacobi")
    x = g.input("img", (h, w))
    g.output(g.stencil(x, (3, 3), _conv(JACOBI3), name="jacobi3"), "out")
    return g


def laplace(h: int, w: int) -> DataflowGraph:
    g = DataflowGraph("laplace")
    x = g.input("img", (h, w))
    g.output(g.stencil(x, (3, 3), _conv(LAPLACE3), name="laplace3"), "out")
    return g


def square(h: int, w: int) -> DataflowGraph:
    g = DataflowGraph("square")
    x = g.input("img", (h, w))
    g.output(g.point(x, lambda v: v * v, name="square"), "out")
    return g


def sobel(h: int, w: int) -> DataflowGraph:
    g = DataflowGraph("sobel")
    x = g.input("img", (h, w))
    g.output(g.stencil(x, (3, 3), _sobel_mag, name="sobel3"), "out")
    return g


def harris(h: int, w: int, k: float = 0.04) -> DataflowGraph:
    g = DataflowGraph("harris")
    x = g.input("img", (h, w))
    x1, x2 = g.split(x, 2)
    ix = g.stencil(x1, (3, 3), _conv(SOBEL_X), name="Ix")
    iy = g.stencil(x2, (3, 3), _conv(SOBEL_Y), name="Iy")
    ixa, ixb = g.split(ix, 2, name="splitIx")
    iya, iyb = g.split(iy, 2, name="splitIy")
    ixx = g.point(ixa, lambda a: a * a, name="Ixx")
    iyy = g.point(iya, lambda a: a * a, name="Iyy")
    ixy = g.point2(ixb, iyb, lambda a, b: a * b, name="Ixy")
    wxx = g.stencil(ixx, (5, 5), _conv(GAUSS5), name="WIxx")
    wyy = g.stencil(iyy, (5, 5), _conv(GAUSS5), name="WIyy")
    wxy = g.stencil(ixy, (5, 5), _conv(GAUSS5), name="WIxy")
    resp = g.pointn(
        [wxx, wyy, wxy],
        lambda a, c, b: (a * c - b * b) - k * (a + c) * (a + c),
        name="response")
    g.output(resp, "out")
    return g


def shi_tomasi(h: int, w: int) -> DataflowGraph:
    g = DataflowGraph("shi_tomasi")
    x = g.input("img", (h, w))
    x1, x2 = g.split(x, 2)
    ix = g.stencil(x1, (3, 3), _conv(SOBEL_X), name="Ix")
    iy = g.stencil(x2, (3, 3), _conv(SOBEL_Y), name="Iy")
    ixa, ixb = g.split(ix, 2, name="splitIx")
    iya, iyb = g.split(iy, 2, name="splitIy")
    ixx = g.point(ixa, lambda a: a * a, name="Ixx")
    iyy = g.point(iya, lambda a: a * a, name="Iyy")
    ixy = g.point2(ixb, iyb, lambda a, b: a * b, name="Ixy")
    wxx = g.stencil(ixx, (5, 5), _conv(GAUSS5), name="WIxx")
    wyy = g.stencil(iyy, (5, 5), _conv(GAUSS5), name="WIyy")
    wxy = g.stencil(ixy, (5, 5), _conv(GAUSS5), name="WIxy")

    def lam_min(a, c, b):
        tr2 = (a + c) * 0.5
        det = a * c - b * b
        return tr2 - jnp.sqrt(jnp.maximum(tr2 * tr2 - det, 0.0) + 1e-12)

    g.output(g.pointn([wxx, wyy, wxy], lam_min, name="score"), "out")
    return g


def optical_flow_lk(h: int, w: int, eps: float = 1e-3) -> DataflowGraph:
    """Lucas-Kanade optical flow (paper Fig. 4): 16 compute stages."""
    g = DataflowGraph("optical_flow_lk")
    f1 = g.input("f1", (h, w))
    f2 = g.input("f2", (h, w))
    f1a, f1b, f1c = g.split(f1, 3, name="split_f1")
    # normalized derivative taps (sobel/8 ~= centered difference)
    ix = g.stencil(f1a, (3, 3), _conv(SOBEL_X / 8.0), name="Ix")    # 1
    iy = g.stencil(f1b, (3, 3), _conv(SOBEL_Y / 8.0), name="Iy")    # 2
    it = g.point2(f2, f1c, lambda b, a: b - a, name="It")           # 3
    ix1, ix2, ix3 = g.split(ix, 3, name="split_Ix")
    iy1, iy2, iy3 = g.split(iy, 3, name="split_Iy")
    it1, it2 = g.split(it, 2, name="split_It")
    ixx = g.point(ix1, lambda a: a * a, name="IxIx")                # 4
    iyy = g.point(iy1, lambda a: a * a, name="IyIy")                # 5
    ixy = g.point2(ix2, iy2, lambda a, b: a * b, name="IxIy")       # 6
    ixt = g.point2(ix3, it1, lambda a, b: a * b, name="IxIt")       # 7
    iyt = g.point2(iy3, it2, lambda a, b: a * b, name="IyIt")       # 8
    wxx = g.stencil(ixx, (5, 5), _conv(GAUSS5), name="WIxx")        # 9
    wyy = g.stencil(iyy, (5, 5), _conv(GAUSS5), name="WIyy")        # 10
    wxy = g.stencil(ixy, (5, 5), _conv(GAUSS5), name="WIxy")        # 11
    wxt = g.stencil(ixt, (5, 5), _conv(GAUSS5), name="WIxt")        # 12
    wyt = g.stencil(iyt, (5, 5), _conv(GAUSS5), name="WIyt")        # 13
    wxx1, wxx2 = g.split(wxx, 2)
    wyy1, wyy2 = g.split(wyy, 2)
    wxy1, wxy2 = g.split(wxy, 2)
    wxt1, wxt2 = g.split(wxt, 2)
    wyt1, wyt2 = g.split(wyt, 2)

    def vx(a, c, b, tx, ty):
        det = a * c - b * b
        return jnp.where(jnp.abs(det) > eps, (-c * tx + b * ty) / det, 0.0)

    def vy(a, c, b, tx, ty):
        det = a * c - b * b
        return jnp.where(jnp.abs(det) > eps, (b * tx - a * ty) / det, 0.0)

    g.output(g.pointn([wxx1, wyy1, wxy1, wxt1, wyt1], vx, name="Vx"),  # 14
             "vx")
    g.output(g.pointn([wxx2, wyy2, wxy2, wxt2, wyt2], vy, name="Vy"),  # 15
             "vy")
    return g


#: name -> (builder, table-I stage count, n_inputs)
APPS: dict[str, tuple[Callable[..., DataflowGraph], int, int]] = {
    "mean_filter": (mean_filter, 1, 1),
    "gaussian_blur": (gaussian_blur, 1, 1),
    "bilateral_filter": (bilateral_filter, 1, 1),
    "sobel_luma": (sobel_luma, 2, 3),
    "unsharp_mask": (unsharp_mask, 3, 1),
    "filter_chain": (filter_chain, 3, 1),
    "jacobi": (jacobi, 1, 1),
    "optical_flow_lk": (optical_flow_lk, 16, 2),
    "harris": (harris, 9, 1),
    "shi_tomasi": (shi_tomasi, 9, 1),
    "laplace": (laplace, 1, 1),
    "square": (square, 1, 1),
    "sobel": (sobel, 1, 1),
}


def build_app(name: str, h: int = 1024, w: int = 1024) -> DataflowGraph:
    if name not in APPS:
        raise KeyError(f"unknown app {name!r}; choose from {sorted(APPS)}")
    return APPS[name][0](h, w)


def compile_app(name: str, h: int = 1024, w: int = 1024,
                backend: str = "pallas", **kw):
    """Build + compile a Table-I app through the full pass pipeline."""
    from repro.core.compiler import compile_graph
    return compile_graph(build_app(name, h, w), backend=backend, **kw)
