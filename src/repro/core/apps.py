"""The paper's benchmark applications (Table I), as single-source
traced programs.

Each builder is now exactly what the paper promises: a plain Python
array function — operators for point math, :func:`fe.conv` /
:func:`fe.window` for local operators, shared formulas from
:mod:`repro.frontend.lib` — handed to :func:`fe.trace`, which
extracts, canonicalizes and validates the dataflow graph.  No
channels, no ``split`` stages, no reader/writer bookkeeping anywhere
below.

The hand-assembled stage-DSL graphs live on in
:mod:`repro.core.handbuilt` as the equivalence oracle (lightly
adapted: stage bodies now come from the shared library — see that
module's docstring): for every app the traced graph's canonical
:meth:`DataflowGraph.signature` equals the hand-built one's, and
outputs agree bit-exactly on every backend
(``tests/test_frontend.py``).

These graphs are consumed by examples/, benchmarks/fig5_app_latency.py,
benchmarks/fig6_opt_ladder.py and the test-suite — one source program
per app, every backend.
"""
from __future__ import annotations

from typing import Callable

import repro.frontend as fe
from repro.core.graph import DataflowGraph
from repro.core.handbuilt import HAND_BUILT
from repro.frontend import lib
from repro.frontend.lib import (GAUSS3, GAUSS5, JACOBI3, LAPLACE3, MEAN5,
                                SOBEL_X, SOBEL_Y, bilateral, conv_taps,
                                sobel_mag)

__all__ = ["APPS", "HAND_BUILT", "build_app", "compile_app"]

# back-compat aliases: these helpers lived here before they were
# hoisted into the shared kernel library (repro.frontend.lib)
_conv = conv_taps
_sobel_mag = sobel_mag
_bilateral = bilateral


# ----------------------------------------------------------------------
# application builders (traced single-source programs)
# ----------------------------------------------------------------------
def mean_filter(h: int, w: int) -> DataflowGraph:
    def mean_filter_src(img):
        return fe.conv(img, MEAN5)

    return fe.trace(mean_filter_src, (h, w), name="mean_filter")


def gaussian_blur(h: int, w: int) -> DataflowGraph:
    def gaussian_blur_src(img):
        return fe.conv(img, GAUSS5)

    return fe.trace(gaussian_blur_src, (h, w), name="gaussian_blur")


def bilateral_filter(h: int, w: int) -> DataflowGraph:
    def bilateral_src(img):
        return fe.window(img, (5, 5), lib.bilateral(), ii=4.0, fill=64.0)

    return fe.trace(bilateral_src, (h, w), name="bilateral_filter")


def sobel_luma(h: int, w: int) -> DataflowGraph:
    def sobel_luma_src(r, g, b):
        luma = lib.luma_rec601(r, g, b)
        return fe.window(luma, (3, 3), lib.sobel_mag)

    return fe.trace(sobel_luma_src, (h, w), (h, w), (h, w),
                    name="sobel_luma")


def unsharp_mask(h: int, w: int, amount: float = 1.5) -> DataflowGraph:
    def unsharp_src(img):
        blur = fe.conv(img, GAUSS5)
        return img + amount * (img - blur)

    return fe.trace(unsharp_src, (h, w), name="unsharp_mask")


def filter_chain(h: int, w: int) -> DataflowGraph:
    def filter_chain_src(img):
        c = img
        for _ in range(3):
            c = fe.conv(c, GAUSS3)
        return c

    return fe.trace(filter_chain_src, (h, w), name="filter_chain")


def jacobi(h: int, w: int) -> DataflowGraph:
    def jacobi_src(img):
        return fe.conv(img, JACOBI3)

    return fe.trace(jacobi_src, (h, w), name="jacobi")


def laplace(h: int, w: int) -> DataflowGraph:
    def laplace_src(img):
        return fe.conv(img, LAPLACE3)

    return fe.trace(laplace_src, (h, w), name="laplace")


def square(h: int, w: int) -> DataflowGraph:
    def square_src(img):
        return img * img

    return fe.trace(square_src, (h, w), name="square")


def sobel(h: int, w: int) -> DataflowGraph:
    def sobel_src(img):
        return fe.window(img, (3, 3), lib.sobel_mag)

    return fe.trace(sobel_src, (h, w), name="sobel")


def harris(h: int, w: int, k: float = 0.04) -> DataflowGraph:
    def harris_src(img):
        ix = fe.conv(img, SOBEL_X)
        iy = fe.conv(img, SOBEL_Y)
        ixx = ix * ix
        iyy = iy * iy
        ixy = ix * iy
        wxx = fe.conv(ixx, GAUSS5)
        wyy = fe.conv(iyy, GAUSS5)
        wxy = fe.conv(ixy, GAUSS5)
        return lib.harris_response(k)(wxx, wyy, wxy)

    return fe.trace(harris_src, (h, w), name="harris")


def shi_tomasi(h: int, w: int) -> DataflowGraph:
    def shi_tomasi_src(img):
        ix = fe.conv(img, SOBEL_X)
        iy = fe.conv(img, SOBEL_Y)
        ixx = ix * ix
        iyy = iy * iy
        ixy = ix * iy
        wxx = fe.conv(ixx, GAUSS5)
        wyy = fe.conv(iyy, GAUSS5)
        wxy = fe.conv(ixy, GAUSS5)
        return lib.lam_min(wxx, wyy, wxy)

    return fe.trace(shi_tomasi_src, (h, w), name="shi_tomasi")


def optical_flow_lk(h: int, w: int, eps: float = 1e-3) -> DataflowGraph:
    """Lucas-Kanade optical flow (paper Fig. 4): 16 compute stages."""
    def optical_flow_lk_src(f1, f2):
        ix = fe.conv(f1, SOBEL_X / 8.0)   # sobel/8 ~= centered difference
        iy = fe.conv(f1, SOBEL_Y / 8.0)
        it = f2 - f1
        ixx = ix * ix
        iyy = iy * iy
        ixy = ix * iy
        ixt = ix * it
        iyt = iy * it
        wxx = fe.conv(ixx, GAUSS5)
        wyy = fe.conv(iyy, GAUSS5)
        wxy = fe.conv(ixy, GAUSS5)
        wxt = fe.conv(ixt, GAUSS5)
        wyt = fe.conv(iyt, GAUSS5)
        vx = lib.lk_vx(eps)(wxx, wyy, wxy, wxt, wyt)
        vy = lib.lk_vy(eps)(wxx, wyy, wxy, wxt, wyt)
        return {"vx": vx, "vy": vy}

    return fe.trace(optical_flow_lk_src, (h, w), (h, w),
                    name="optical_flow_lk")


#: name -> (builder, table-I stage count, n_inputs)
APPS: dict[str, tuple[Callable[..., DataflowGraph], int, int]] = {
    "mean_filter": (mean_filter, 1, 1),
    "gaussian_blur": (gaussian_blur, 1, 1),
    "bilateral_filter": (bilateral_filter, 1, 1),
    "sobel_luma": (sobel_luma, 2, 3),
    "unsharp_mask": (unsharp_mask, 3, 1),
    "filter_chain": (filter_chain, 3, 1),
    "jacobi": (jacobi, 1, 1),
    "optical_flow_lk": (optical_flow_lk, 16, 2),
    "harris": (harris, 9, 1),
    "shi_tomasi": (shi_tomasi, 9, 1),
    "laplace": (laplace, 1, 1),
    "square": (square, 1, 1),
    "sobel": (sobel, 1, 1),
}


def build_app(name: str, h: int = 1024, w: int = 1024) -> DataflowGraph:
    if name not in APPS:
        raise KeyError(f"unknown app {name!r}; choose from {sorted(APPS)}")
    return APPS[name][0](h, w)


def compile_app(name: str, h: int = 1024, w: int = 1024,
                backend="pallas", **kw):
    """Build + compile a Table-I app through the full pass pipeline.

    ``backend`` is a registered name or a
    :class:`~repro.backends.Backend` spec, forwarded verbatim to
    :func:`repro.core.compiler.compile_graph`.
    """
    from repro.core.compiler import compile_graph
    return compile_graph(build_app(name, h, w), backend=backend, **kw)
