"""The pass-based compiler driver: validate -> canonicalize -> partition
-> lower, behind one entry point.

This is the façade the rest of the repo (examples, benchmarks, DSL
apps) builds on.  The phases:

1. **canonicalize** — run the :mod:`repro.core.transform` pass
   pipeline (auto-split insertion, dead-channel elimination, point
   fusion) so the programmer may write the natural non-canonical
   program; ``strict=True`` skips this and enforces the paper's
   explicit canonical form instead (multi-reader channels raise
   :class:`~repro.core.graph.ChannelContractError`),
2. **validate** — single-writer/single-reader contract + acyclicity,
3. **partition** — convex-subgraph DAG fusion into streaming kernels
   (:func:`repro.core.schedule.build_schedule`),
4. **lower** — per-group kernel generation for the chosen backend
   (:func:`repro.core.fusion.lower_graph`) plus generated host code
   (:func:`repro.core.host.build_host_app`).

Pass diagnostics ride along on ``Schedule.diagnostics`` and show up in
``Schedule.describe()`` / ``CompiledApp.schedule.describe()``.
"""
from __future__ import annotations

from typing import Any, Sequence

from jax.sharding import Mesh

from repro.core.fusion import lower_graph
from repro.core.graph import DataflowGraph
from repro.core.host import CompiledApp, build_host_app
from repro.core.schedule import Schedule, build_schedule
from repro.core.transform import Pass, PassPipeline
from repro.core.vectorize import TPUSpec, V5E

__all__ = ["compile_graph"]


def compile_graph(graph: DataflowGraph, backend: str = "pallas", *,
                  strict: bool = False, canonicalize: bool = True,
                  passes: Sequence[Pass] | PassPipeline | None = None,
                  mesh: Mesh | None = None,
                  data_axis: str | Sequence[str] = "data",
                  donate: Sequence[str] = (), spec: TPUSpec = V5E,
                  vector_factor: int | None = None, interpret: bool = True,
                  jit: bool = True) -> CompiledApp:
    """Compile a dataflow graph end-to-end into a :class:`CompiledApp`.

    One source program, any backend — ``backend`` is one of
    ``repro.core.fusion.BACKENDS`` (``xla``, ``xla_staged``,
    ``pallas``).  ``strict=True`` disables the canonicalization
    pipeline and rejects non-canonical graphs exactly like the seed
    validator did; ``passes`` substitutes a custom pass list for the
    default pipeline.  ``mesh``/``data_axis``/``donate`` configure the
    generated host launcher (see :mod:`repro.core.host`).

    ``vector_factor`` is the paper's explicit vectorization knob: it
    pins every fused kernel's tile minor dimension to ``128 * factor``
    (raising when a group cannot fit it).  The default ``None`` sweeps
    the factor per group through the DMA cost model
    (:func:`repro.core.vectorize.select_tile`); the chosen factors show
    up in ``app.schedule.describe()``.
    """
    sched: Schedule = build_schedule(
        graph, canonicalize=canonicalize, strict=strict, passes=passes,
        spec=spec, vector_factor=vector_factor)
    run, sched = lower_graph(sched.graph, backend, schedule=sched,
                             spec=spec, vector_factor=vector_factor,
                             interpret=interpret)
    return build_host_app(sched, run, backend=backend, mesh=mesh,
                          data_axis=data_axis, donate=donate, jit=jit)
