"""The pass-based compiler driver: validate -> canonicalize -> partition
-> lower, behind one entry point.

This is the façade the rest of the repo (examples, benchmarks, DSL
apps) builds on.  The phases:

1. **canonicalize** — run the :mod:`repro.core.transform` pass
   pipeline (auto-split insertion, dead-channel elimination, point
   fusion) so the programmer may write the natural non-canonical
   program; ``strict=True`` skips this and enforces the paper's
   explicit canonical form instead (multi-reader channels raise
   :class:`~repro.core.graph.ChannelContractError`),
2. **validate** — single-writer/single-reader contract + acyclicity,
3. **partition** — convex-subgraph DAG fusion into streaming kernels
   (:func:`repro.core.schedule.build_schedule`),
4. **lower** — per-group kernel generation for the chosen backend
   (:func:`repro.core.fusion.lower_graph`) plus generated host code
   (:func:`repro.core.host.build_host_app`).

Schedule parameters (tile shape, per-group vector factor, fusion
budget) come from one of three regimes, in increasing fidelity: the
analytic cost-model sweep (the default), an explicit
``vector_factor=``, or the profile-guided autotuner
(``tune="auto"``, :mod:`repro.tune`) which *measures* model-ranked
candidates on the live backend and persists winners in an on-disk
:class:`~repro.tune.store.TuningCache`.  Pass diagnostics — including
the tile-provenance lines saying which regime picked each tile — ride
along on ``Schedule.diagnostics`` and show up in
``Schedule.describe()`` / ``CompiledApp.schedule.describe()``.

See ``docs/architecture.md`` for the layer map and ``docs/tuning.md``
for every schedule knob.
"""
from __future__ import annotations

from typing import Any, Sequence

from jax.sharding import Mesh

from repro.core.fusion import lower_graph
from repro.core.graph import DataflowGraph
from repro.core.host import CompiledApp, build_host_app
from repro.core.schedule import Schedule, build_schedule
from repro.core.transform import Pass, PassPipeline
from repro.core.vectorize import TPUSpec
from repro.obs.tracer import maybe_span, resolve_tracer

__all__ = ["compile_graph"]


def compile_graph(graph: DataflowGraph, backend="pallas", *,
                  strict: bool = False, canonicalize: bool = True,
                  passes: Sequence[Pass] | PassPipeline | None = None,
                  mesh: Mesh | None = None,
                  data_axis: str | Sequence[str] = "data",
                  donate: Sequence[str] = (), spec: TPUSpec | None = None,
                  vector_factor: int | None = None,
                  max_tile: tuple[int, int] | None = None,
                  tune: Any = None, tune_cache: Any = None,
                  calibrate: Any = None,
                  interpret: bool | None = None, jit: bool = True,
                  trace: Any = None) -> CompiledApp:
    """Compile a dataflow graph end-to-end into a :class:`CompiledApp`.

    One source program, any backend — ``backend`` is a registered name
    (:func:`repro.backends.names`) or a
    :class:`~repro.backends.Backend` spec; the resolved record drives
    the lowering hook, the vectorizer's lane/VMEM constants, and the
    interpret-vs-compiled decision (``interpret=None`` defers to
    :meth:`~repro.backends.Backend.resolve_interpret`: compiled on the
    backend's native platforms, interpreted elsewhere).
    ``strict=True`` disables the canonicalization
    pipeline and rejects non-canonical graphs exactly like the seed
    validator did; ``passes`` substitutes a custom pass list for the
    default pipeline.  ``mesh``/``data_axis``/``donate`` configure the
    generated host launcher (see :mod:`repro.core.host`).

    ``vector_factor`` is the paper's explicit vectorization knob: it
    pins every fused kernel's tile minor dimension to ``128 * factor``
    (raising when a group cannot fit it).  The default ``None`` sweeps
    the factor per group through the DMA cost model
    (:func:`repro.core.vectorize.select_tile`); ``max_tile`` caps the
    swept tile shape.  The chosen factors — and which regime chose
    them — show up in ``app.schedule.describe()``.

    ``tune`` upgrades selection from *modeled* to *measured*:

    - ``"auto"`` — consult the persistent
      :class:`~repro.tune.store.TuningCache` (``tune_cache``, default
      on-disk location); on a miss, run the profile-guided search
      (:func:`repro.tune.search.tune_graph`: analytic top-k prior,
      then timed on the live backend) and persist the winner.  A
      second compile of the same app on the same device kind performs
      **zero** measurements.
    - a :class:`~repro.tune.store.ScheduleConfig` — apply a known
      config verbatim (e.g. exported from another machine's cache).

    ``tune`` and ``vector_factor`` are mutually exclusive — one is a
    measurement, the other an override.

    ``calibrate`` swaps the backend's datasheet constants for fitted
    ones (:mod:`repro.tune.calibrate`): ``"auto"`` loads the
    :class:`~repro.tune.calibrate.CalibratedSpec` persisted for this
    backend + device kind (fitting one from the drift log when enough
    rows have accumulated), a spec instance applies verbatim, and the
    default ``None`` keeps the seed constants — bit-identical
    schedules and cache keys to every release before this knob
    existed.  A calibrated compile carries a different backend digest,
    so its tuning/compile cache entries never mix with uncalibrated
    ones.  An explicit ``spec=`` still wins over calibration.

    ``trace`` plugs the compile into the flight recorder
    (:mod:`repro.obs`): ``True`` records into a private
    :class:`~repro.obs.tracer.Tracer`, an explicit tracer records
    there, and the default ``None`` consults the process-global tracer
    (``repro.obs.install`` / ``$REPRO_TRACE``) — so an untraced
    process pays nothing.  Every pass, the partitioner, each group's
    vectorize sweep, the lowering and the host build get their own
    ``compile.*`` spans.

    >>> from repro.core.graph import DataflowGraph
    >>> g = DataflowGraph("doc")
    >>> x = g.input("img", (8, 128))
    >>> _ = g.output(g.point(x, lambda v: v * 3.0), "out")
    >>> app = compile_graph(g, backend="xla")
    >>> sorted(app.input_names), sorted(app.output_names)
    (['img'], ['out'])
    >>> import numpy as np
    >>> float(app(img=np.ones((8, 128), np.float32))["out"][0, 0])
    3.0
    """
    if tune == "model":                 # explicit name for the default
        tune = None
    if tune is not None and vector_factor is not None:
        raise ValueError(
            "tune= and vector_factor= are mutually exclusive: the tuner "
            "owns the vector factors it measures")
    if tune is not None and max_tile is not None:
        raise ValueError(
            "tune= and max_tile= are mutually exclusive: the tile cap is "
            "one of the tuner's search axes (and part of the cached "
            "config); pass max_tile_candidates to tune_graph instead")
    from repro.backends import resolve_calibrated
    be = resolve_calibrated(backend, calibrate)
    spec = spec or be.spec
    interpret = be.resolve_interpret(interpret)
    tracer = resolve_tracer(trace)
    with maybe_span(tracer, "compile", cat="compile", graph=graph.name,
                    backend=be.name) as top:
        tuned = None
        if tune is not None:
            from repro.tune.search import resolve_tuning, tuned_schedule_kwargs
            with maybe_span(tracer, "compile.tune", cat="compile",
                            graph=graph.name):
                tuned = resolve_tuning(graph, be, tune=tune, spec=spec,
                                       cache=tune_cache, interpret=interpret,
                                       strict=strict, canonicalize=canonicalize,
                                       passes=passes, trace=tracer)
        if tuned is not None:
            config, source, notes = tuned
            sched: Schedule = build_schedule(
                graph, canonicalize=canonicalize, strict=strict, passes=passes,
                trace=tracer, backend=be,
                **tuned_schedule_kwargs(config, source, spec))
            sched.diagnostics.extend(notes)
        else:
            sched = build_schedule(
                graph, canonicalize=canonicalize, strict=strict, passes=passes,
                spec=spec, vector_factor=vector_factor, max_tile=max_tile,
                trace=tracer, backend=be)
        with maybe_span(tracer, "compile.lower", cat="compile",
                        graph=graph.name, backend=be.name):
            run, sched = lower_graph(sched.graph, be, schedule=sched,
                                     spec=spec, vector_factor=vector_factor,
                                     interpret=interpret)
        with maybe_span(tracer, "compile.host", cat="compile",
                        graph=graph.name):
            app = build_host_app(sched, run, backend=be, mesh=mesh,
                                 data_axis=data_axis, donate=donate, jit=jit)
        top.set(kernels=len(sched.groups), stages=len(sched.order))
    return app
