"""Hand-built Table-I graphs: the equivalence oracle for the frontend.

These are the stage-DSL app builders — explicit channels, explicit
``split`` stages, hand-picked stage names — kept as the ground truth
the traced single-source builders in :mod:`repro.core.apps` are
checked against: the test-suite asserts that every traced app's
canonicalized :meth:`~repro.core.graph.DataflowGraph.signature`
equals its hand-built twin's, and that outputs agree bit-exactly
(atol=0) on every backend.

Two deliberate adaptations from the pre-frontend builders (semantics
are unchanged; the graphs here are *not* verbatim git history):

- Stage *functions* come from the shared kernel library
  (:mod:`repro.frontend.lib`) instead of inline lambdas — the same
  objects the tracer records — because signature equality hashes
  stage bodies, and because each coefficient table and pointwise
  formula should exist exactly once.
- ``unsharp_mask`` expresses ``a + amount * d`` as two canonical
  stages (``amplify`` = scale, ``sharpen`` = add) rather than one
  fused lambda, mirroring how operator tracing records it; point
  fusion collapses both forms to the same canonical graph.
"""
from __future__ import annotations

from typing import Callable

from repro.core.graph import DataflowGraph
from repro.frontend.lib import (GAUSS3, GAUSS5, JACOBI3, LAPLACE3, MEAN5,
                                SOBEL_X, SOBEL_Y, add, bilateral, conv_taps,
                                harris_response, lam_min, lk_vx, lk_vy,
                                luma_rec601, mul, scale, sobel_mag, square,
                                sub)

__all__ = ["HAND_BUILT"]


def mean_filter(h: int, w: int) -> DataflowGraph:
    g = DataflowGraph("mean_filter")
    x = g.input("img", (h, w))
    g.output(g.stencil(x, (5, 5), conv_taps(MEAN5), name="mean5"), "out")
    return g


def gaussian_blur(h: int, w: int) -> DataflowGraph:
    g = DataflowGraph("gaussian_blur")
    x = g.input("img", (h, w))
    g.output(g.stencil(x, (5, 5), conv_taps(GAUSS5), name="gauss5"), "out")
    return g


def bilateral_filter(h: int, w: int) -> DataflowGraph:
    g = DataflowGraph("bilateral_filter")
    x = g.input("img", (h, w))
    g.output(g.stencil(x, (5, 5), bilateral(), name="bilateral5",
                       ii=4.0, fill=64.0), "out")
    return g


def sobel_luma(h: int, w: int) -> DataflowGraph:
    g = DataflowGraph("sobel_luma")
    r = g.input("r", (h, w))
    gr = g.input("g", (h, w))
    b = g.input("b", (h, w))
    luma = g.pointn([r, gr, b], luma_rec601.fn, name="luma")
    g.output(g.stencil(luma, (3, 3), sobel_mag, name="sobel"), "out")
    return g


def unsharp_mask(h: int, w: int, amount: float = 1.5) -> DataflowGraph:
    g = DataflowGraph("unsharp_mask")
    x = g.input("img", (h, w))
    x1, x2, x3 = g.split(x, 3)
    blur = g.stencil(x1, (5, 5), conv_taps(GAUSS5), name="blur")
    diff = g.point2(x2, blur, sub, name="highpass")
    amp = g.point(diff, scale(amount), name="amplify")
    g.output(g.point2(x3, amp, add, name="sharpen"), "out")
    return g


def filter_chain(h: int, w: int) -> DataflowGraph:
    g = DataflowGraph("filter_chain")
    x = g.input("img", (h, w))
    c = x
    for i in range(3):
        c = g.stencil(c, (3, 3), conv_taps(GAUSS3), name=f"filt{i + 1}")
    g.output(c, "out")
    return g


def jacobi(h: int, w: int) -> DataflowGraph:
    g = DataflowGraph("jacobi")
    x = g.input("img", (h, w))
    g.output(g.stencil(x, (3, 3), conv_taps(JACOBI3), name="jacobi3"), "out")
    return g


def laplace(h: int, w: int) -> DataflowGraph:
    g = DataflowGraph("laplace")
    x = g.input("img", (h, w))
    g.output(g.stencil(x, (3, 3), conv_taps(LAPLACE3), name="laplace3"),
             "out")
    return g


def square_app(h: int, w: int) -> DataflowGraph:
    g = DataflowGraph("square")
    x = g.input("img", (h, w))
    g.output(g.point(x, square, name="square"), "out")
    return g


def sobel(h: int, w: int) -> DataflowGraph:
    g = DataflowGraph("sobel")
    x = g.input("img", (h, w))
    g.output(g.stencil(x, (3, 3), sobel_mag, name="sobel3"), "out")
    return g


def harris(h: int, w: int, k: float = 0.04) -> DataflowGraph:
    g = DataflowGraph("harris")
    x = g.input("img", (h, w))
    x1, x2 = g.split(x, 2)
    ix = g.stencil(x1, (3, 3), conv_taps(SOBEL_X), name="Ix")
    iy = g.stencil(x2, (3, 3), conv_taps(SOBEL_Y), name="Iy")
    ixa, ixb = g.split(ix, 2, name="splitIx")
    iya, iyb = g.split(iy, 2, name="splitIy")
    ixx = g.point(ixa, square, name="Ixx")
    iyy = g.point(iya, square, name="Iyy")
    ixy = g.point2(ixb, iyb, mul, name="Ixy")
    wxx = g.stencil(ixx, (5, 5), conv_taps(GAUSS5), name="WIxx")
    wyy = g.stencil(iyy, (5, 5), conv_taps(GAUSS5), name="WIyy")
    wxy = g.stencil(ixy, (5, 5), conv_taps(GAUSS5), name="WIxy")
    resp = g.pointn([wxx, wyy, wxy], harris_response(k).fn, name="response")
    g.output(resp, "out")
    return g


def shi_tomasi(h: int, w: int) -> DataflowGraph:
    g = DataflowGraph("shi_tomasi")
    x = g.input("img", (h, w))
    x1, x2 = g.split(x, 2)
    ix = g.stencil(x1, (3, 3), conv_taps(SOBEL_X), name="Ix")
    iy = g.stencil(x2, (3, 3), conv_taps(SOBEL_Y), name="Iy")
    ixa, ixb = g.split(ix, 2, name="splitIx")
    iya, iyb = g.split(iy, 2, name="splitIy")
    ixx = g.point(ixa, square, name="Ixx")
    iyy = g.point(iya, square, name="Iyy")
    ixy = g.point2(ixb, iyb, mul, name="Ixy")
    wxx = g.stencil(ixx, (5, 5), conv_taps(GAUSS5), name="WIxx")
    wyy = g.stencil(iyy, (5, 5), conv_taps(GAUSS5), name="WIyy")
    wxy = g.stencil(ixy, (5, 5), conv_taps(GAUSS5), name="WIxy")
    g.output(g.pointn([wxx, wyy, wxy], lam_min.fn, name="score"), "out")
    return g


def optical_flow_lk(h: int, w: int, eps: float = 1e-3) -> DataflowGraph:
    """Lucas-Kanade optical flow (paper Fig. 4): 16 compute stages."""
    g = DataflowGraph("optical_flow_lk")
    f1 = g.input("f1", (h, w))
    f2 = g.input("f2", (h, w))
    f1a, f1b, f1c = g.split(f1, 3, name="split_f1")
    # normalized derivative taps (sobel/8 ~= centered difference)
    ix = g.stencil(f1a, (3, 3), conv_taps(SOBEL_X / 8.0), name="Ix")   # 1
    iy = g.stencil(f1b, (3, 3), conv_taps(SOBEL_Y / 8.0), name="Iy")   # 2
    it = g.point2(f2, f1c, sub, name="It")                             # 3
    ix1, ix2, ix3 = g.split(ix, 3, name="split_Ix")
    iy1, iy2, iy3 = g.split(iy, 3, name="split_Iy")
    it1, it2 = g.split(it, 2, name="split_It")
    ixx = g.point(ix1, square, name="IxIx")                            # 4
    iyy = g.point(iy1, square, name="IyIy")                            # 5
    ixy = g.point2(ix2, iy2, mul, name="IxIy")                         # 6
    ixt = g.point2(ix3, it1, mul, name="IxIt")                         # 7
    iyt = g.point2(iy3, it2, mul, name="IyIt")                         # 8
    wxx = g.stencil(ixx, (5, 5), conv_taps(GAUSS5), name="WIxx")       # 9
    wyy = g.stencil(iyy, (5, 5), conv_taps(GAUSS5), name="WIyy")       # 10
    wxy = g.stencil(ixy, (5, 5), conv_taps(GAUSS5), name="WIxy")       # 11
    wxt = g.stencil(ixt, (5, 5), conv_taps(GAUSS5), name="WIxt")       # 12
    wyt = g.stencil(iyt, (5, 5), conv_taps(GAUSS5), name="WIyt")       # 13
    wxx1, wxx2 = g.split(wxx, 2)
    wyy1, wyy2 = g.split(wyy, 2)
    wxy1, wxy2 = g.split(wxy, 2)
    wxt1, wxt2 = g.split(wxt, 2)
    wyt1, wyt2 = g.split(wyt, 2)
    g.output(g.pointn([wxx1, wyy1, wxy1, wxt1, wyt1], lk_vx(eps).fn,  # 14
                      name="Vx"), "vx")
    g.output(g.pointn([wxx2, wyy2, wxy2, wxt2, wyt2], lk_vy(eps).fn,  # 15
                      name="Vy"), "vy")
    return g


#: name -> hand-built builder (the oracle twin of ``repro.core.apps.APPS``)
HAND_BUILT: dict[str, Callable[..., DataflowGraph]] = {
    "mean_filter": mean_filter,
    "gaussian_blur": gaussian_blur,
    "bilateral_filter": bilateral_filter,
    "sobel_luma": sobel_luma,
    "unsharp_mask": unsharp_mask,
    "filter_chain": filter_chain,
    "jacobi": jacobi,
    "optical_flow_lk": optical_flow_lk,
    "harris": harris,
    "shi_tomasi": shi_tomasi,
    "laplace": laplace,
    "square": square_app,
    "sobel": sobel,
}
