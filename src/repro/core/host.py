"""Host-code generation (FLOWER contribution C4).

The paper generates all XRT boilerplate (context, buffers, ``setArg``,
kernel launch, H2D/D2H copies) from the same single source as the
device code.  The TPU analogue of "host code" is the *launcher*: buffer
placement & sharding, donation, the jitted step function, and the
compile artifacts.  :func:`build_host_app` derives all of it from the
scheduled dataflow graph — the user never writes glue code, and
host/device can never drift apart.  The user-facing entry point is
:func:`repro.core.compiler.compile_graph`, which runs the full
pipeline (canonicalize -> validate -> partition -> lower) and finishes
here.

For fidelity (and debuggability) :meth:`CompiledApp.host_program`
renders the generated launch plan as an XRT-style listing, mirroring
the paper's Section IV-C example.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.graph import DataflowGraph
from repro.core.schedule import Schedule

__all__ = ["CompiledApp", "LaunchHandle", "build_host_app"]


@dataclasses.dataclass
class LaunchHandle:
    """Future-like handle for one asynchronously dispatched execution.

    Holds the (possibly still in-flight) device arrays; ``result()``
    blocks until they are ready.  The software analogue of waiting on
    an XRT event from ``enqueueTask``.
    """

    outputs: dict[str, Any]

    def done(self) -> bool:
        """True when every output buffer has landed (non-blocking)."""
        return all(o.is_ready() for o in self.outputs.values()
                   if hasattr(o, "is_ready"))

    def result(self) -> dict[str, Any]:
        """Block until the computation finishes; return the outputs."""
        jax.block_until_ready(self.outputs)
        return self.outputs


@dataclasses.dataclass
class BufferDecl:
    name: str
    shape: tuple[int, ...]
    dtype: str
    direction: str        # "in" | "out"
    bundle: int | None
    donated: bool


@dataclasses.dataclass
class CompiledApp:
    """A fully-lowered dataflow application (device + generated host)."""

    graph: DataflowGraph
    schedule: Schedule
    #: the resolved :class:`~repro.backends.Backend` record this app
    #: was lowered for (``app.backend.name`` for the display string)
    backend: Any
    fn: Callable                        # jitted: (*inputs) -> tuple(outputs)
    lowered: Any
    compiled: Any
    buffers: list[BufferDecl]
    input_names: list[str]
    output_names: list[str]
    mesh: Mesh | None = None

    def __call__(self, **inputs: Any) -> dict[str, Any]:
        args = [inputs[n] for n in self.input_names]
        outs = self.fn(*args)
        return dict(zip(self.output_names, outs))

    def launch(self, **inputs: Any) -> "LaunchHandle":
        """Asynchronously dispatch one execution (the XRT ``enqueueTask``).

        Returns immediately with a future-like :class:`LaunchHandle` —
        JAX's async dispatch means the device works while the host
        keeps queuing.  The serving engine
        (:class:`repro.runtime.engine.StreamEngine`) builds its
        double-buffered pipeline on exactly this: launch item k+1
        before blocking on item k.
        """
        args = [inputs[n] for n in self.input_names]
        outs = self.fn(*args)
        return LaunchHandle(dict(zip(self.output_names, outs)))

    def signature(self) -> str:
        """Cache/batching identity: canonical graph digest + backend.

        Requests whose apps share a signature are interchangeable for
        the micro-batcher (same topology, shapes, stage bodies and
        backend), and repeated compiles of such graphs hit the
        :class:`repro.runtime.cache.CompileCache`.  The backend half is
        :meth:`~repro.backends.Backend.cache_key` — name plus a digest
        of capabilities and constants — so two registrations under one
        name with different constants never collide.  Memoized: the
        graph is post-canonicalization and does not change under an
        already-compiled app, and the serving engine calls this on
        every request.
        """
        sig = getattr(self, "_signature", None)
        if sig is None:
            from repro.backends import resolve
            sig = f"{self.graph.signature()}:{resolve(self.backend).cache_key()}"
            self._signature = sig
        return sig

    # -- introspection -------------------------------------------------
    def cost(self) -> dict[str, float]:
        ca = self.compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):     # jax < 0.5: per-computation list
            ca = ca[0] if ca else {}
        return {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "bytes_total": sum(float(v) for k, v in ca.items()
                               if k.startswith("bytes accessed")),
            "transcendentals": float(ca.get("transcendentals", 0.0)),
        }

    def memory(self) -> dict[str, int]:
        ma = self.compiled.memory_analysis()
        out = {}
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            if hasattr(ma, k):
                out[k] = int(getattr(ma, k))
        return out

    def host_program(self) -> str:
        """Render the generated host code as an XRT-style listing.

        This is the *static* single-shot launch plan.  The dynamic
        counterpart — command queue, backpressure, micro-batching,
        telemetry — is the serving runtime: see
        :class:`repro.runtime.engine.StreamEngine`, which turns this
        app into a long-lived service.
        """
        lines = [
            "// ---- generated host program (XRT-style rendering) ----",
            "auto device = xcl::get_devices()[0];",
            'auto bin = xcl::read_binary_file("%s.xclbin");' % self.graph.name,
            "auto q = cl::CommandQueue(context, device, 0);",
        ]
        for b in self.buffers:
            flag = "CL_MEM_READ_ONLY" if b.direction == "in" else "CL_MEM_WRITE_ONLY"
            lines.append(
                f"cl::Buffer {b.name}(context, {flag}, /*bytes=*/"
                f"{int(np.prod(b.shape))* np.dtype(b.dtype).itemsize}); "
                f"// bundle=mem{b.bundle}"
                + (" donated" if b.donated else ""))
        for b in self.buffers:
            if b.direction == "in":
                lines.append(f"q.enqueueWriteBuffer({b.name}, ...);  // H2D")
        for gi, g in enumerate(self.schedule.groups):
            names = ",".join(s.name for s in g.stages)
            vec = (f" tile={g.tile} vector_factor={g.vector_factor}"
                   if g.tile is not None else "")
            lines.append(f"launch kernel[{gi}]  "
                         f"// dataflow tasks: {names}{vec}")
        for b in self.buffers:
            if b.direction == "out":
                lines.append(f"q.enqueueReadBuffer({b.name}, ...);   // D2H")
        return "\n".join(lines)


def build_host_app(sched: Schedule, run: Callable,
                   *, backend="pallas", mesh: Mesh | None = None,
                   data_axis: str | Sequence[str] = "data",
                   donate: Sequence[str] = (),
                   jit: bool = True) -> CompiledApp:
    """Generate the host launcher around an already-lowered graph.

    ``run`` is the whole-graph function produced by
    :func:`repro.core.fusion.lower_graph`; the graph is taken from the
    schedule (post-canonicalization) so launcher and kernels can never
    disagree about the I/O signature.  When ``mesh`` is given, every
    2-D plane is row-sharded over ``data_axis`` (a TPU "memory bundle"
    at the cluster scale: parallel DAG paths live in different
    per-device HBM shards and transfer concurrently).  Donation lets
    an output reuse an input's HBM.
    """
    from repro.backends import resolve
    backend = resolve(backend)
    graph = sched.graph
    input_names = [c.name for c in graph.graph_inputs]
    output_names = [c.name for c in graph.graph_outputs]

    def step(*args):
        outs = run(dict(zip(input_names, args)))
        return tuple(outs[n] for n in output_names)

    in_avals = [jax.ShapeDtypeStruct(c.shape, c.dtype)
                for c in graph.graph_inputs]

    donate_argnums = tuple(i for i, n in enumerate(input_names)
                           if n in donate)
    jit_kwargs: dict[str, Any] = dict(donate_argnums=donate_argnums)
    if mesh is not None:
        def shard(c):
            spec_dims = [None] * len(c.shape)
            if len(c.shape) >= 1 and c.shape[0] % mesh.shape[_first(data_axis)] == 0:
                spec_dims[0] = data_axis
            return NamedSharding(mesh, P(*spec_dims))
        jit_kwargs["in_shardings"] = tuple(shard(c) for c in graph.graph_inputs)
        jit_kwargs["out_shardings"] = tuple(shard(c) for c in graph.graph_outputs)

    fn = jax.jit(step, **jit_kwargs) if jit else step
    lowered = fn.lower(*in_avals) if jit else None
    compiled = lowered.compile() if jit else None

    buffers = [BufferDecl(c.name, c.shape, str(np.dtype(c.dtype)), "in",
                          c.bundle, c.name in donate)
               for c in graph.graph_inputs]
    buffers += [BufferDecl(c.name, c.shape, str(np.dtype(c.dtype)), "out",
                           c.bundle, False)
                for c in graph.graph_outputs]

    return CompiledApp(graph, sched, backend, fn, lowered, compiled,
                       buffers, input_names, output_names, mesh)


def _first(axis: str | Sequence[str]) -> str:
    return axis if isinstance(axis, str) else axis[0]
