"""Pipeline parallelism: GPipe-style microbatched execution over a
``stage`` mesh axis via shard_map + ppermute.

This is FLOWER's dataflow pipeline at the *device* scale: stages are
devices, the FIFO channel is the ICI link between neighbours, the
items are microbatches.  The same latency law applies (and is asserted
in tests): total steps = n_micro + n_stages - 1, versus
n_micro * n_stages for sequential execution.

Off by default in the 40-cell table (the production mesh spends its
axes on DP×TP); enable by building a mesh with a ``stage`` axis and
wrapping the per-layer body with :func:`pipeline_apply`.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.parallel._compat import shard_map

__all__ = ["pipeline_apply"]


def pipeline_apply(stage_fn: Callable, params_stacked, x: jnp.ndarray,
                   mesh: Mesh, n_micro: int, axis: str = "stage"
                   ) -> jnp.ndarray:
    """Run ``x`` through ``n_stages`` sequential stages, pipelined.

    stage_fn(params_stage, x_micro) -> x_micro  (same shape)
    params_stacked: pytree with leading dim n_stages (sharded over
    ``axis``); x: (batch, ...) with batch % n_micro == 0.

    GPipe schedule: microbatch m enters stage s at step m + s; each
    device runs its stage every step on whatever the ring delivered,
    for n_micro + n_stages - 1 steps total (the Fig.-1 law).
    """
    n_stages = mesh.shape[axis]
    B = x.shape[0]
    assert B % n_micro == 0, (B, n_micro)
    mb = B // n_micro

    def body(params_local, xs):
        # params_local: stage's own params (leading dim 1); xs: the
        # full local copy of the batch (replicated over `axis`).
        sid = jax.lax.axis_index(axis)
        p = jax.tree.map(lambda a: a[0], params_local)
        perm = [(j, (j + 1) % n_stages) for j in range(n_stages)]
        n_steps = n_micro + n_stages - 1

        micro = xs.reshape(n_micro, mb, *xs.shape[1:])
        out = jnp.zeros_like(micro)
        # `hold` is the activation each device currently owns
        hold = jnp.zeros((mb,) + xs.shape[1:], xs.dtype)

        def step(t, carry):
            hold, out = carry
            # stage 0 injects microbatch t (if any remain)
            inject = micro[jnp.clip(t, 0, n_micro - 1)]
            hold = jnp.where(sid == 0,
                             jnp.where(t < n_micro, inject,
                                       jnp.zeros_like(inject)), hold)
            y = stage_fn(p, hold)
            # last stage retires microbatch t - (n_stages - 1)
            mi = t - (n_stages - 1)
            out = jnp.where(
                (sid == n_stages - 1) & (mi >= 0) & (mi < n_micro),
                jax.lax.dynamic_update_slice(
                    out, y[None], (jnp.clip(mi, 0, n_micro - 1), 0)
                    + (0,) * (y.ndim - 1)),
                out)
            # FIFO hand-off to the next stage
            y = jax.lax.ppermute(y, axis, perm)
            return y, out

        hold, out = jax.lax.fori_loop(0, n_steps, step, (hold, out))
        # only the last stage holds real outputs; broadcast them back
        out = jax.lax.psum(
            jnp.where(sid == n_stages - 1, out, jnp.zeros_like(out)), axis)
        return out.reshape(B, *xs.shape[1:])

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axis), P()),
                   out_specs=P(), check_vma=False)
    return fn(params_stacked, x)
