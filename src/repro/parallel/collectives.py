"""Communication/compute-overlap collectives (shard_map building blocks).

The FLOWER idea at cluster scale: a collective + matmul chain is a
2-stage dataflow pipeline, so it should *stream* — each ring step's
ppermute overlaps the previous chunk's matmul, instead of a barrier
all-gather followed by one big matmul.  On TPU the ring maps directly
onto ICI neighbours.

Property-tested against the barrier (einsum) versions in
tests/test_distribution.py (8 host devices, subprocess).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.parallel._compat import shard_map

__all__ = ["ring_allgather_matmul", "ring_matmul_reducescatter",
           "psum_scatter_grads", "halo_exchange_rows"]


def halo_exchange_rows(x: jnp.ndarray, hy: int, n_shards: int,
                       axis: str = "replica") -> jnp.ndarray:
    """Row-halo exchange for a row-partitioned 2-D plane (shard_map body).

    Each shard holds ``(H/k, W)`` rows; stencils near the cut need
    ``hy`` rows from the neighbouring shards.  The top shard's upper
    halo and the bottom shard's lower halo have no neighbour —
    ``ppermute`` leaves zeros there, which is exactly the compiler's
    zero-padding boundary semantics, so the replicated app reproduces
    the single-device app bit-for-bit.  With one shard both perms are
    empty and the whole halo is zeros: the single-device fallback runs
    the same code path CI exercises on CPU.
    """
    if hy == 0:
        return x
    # my bottom rows become the next shard's upper halo, and vice versa
    from_above = jax.lax.ppermute(
        x[-hy:], axis, [(j, j + 1) for j in range(n_shards - 1)])
    from_below = jax.lax.ppermute(
        x[:hy], axis, [(j + 1, j) for j in range(n_shards - 1)])
    return jnp.concatenate([from_above, x, from_below], axis=0)


def ring_allgather_matmul(x: jnp.ndarray, w: jnp.ndarray, mesh: Mesh,
                          axis: str = "model") -> jnp.ndarray:
    """Column-parallel matmul with streamed input all-gather.

    x: (m, k) row-sharded over ``axis`` (sequence-parallel residual);
    w: (k, n) col-sharded.  Returns (m, n) col-sharded.

    Instead of ``all_gather(x) @ w_local`` (a barrier), x's row blocks
    travel the ring; each arriving block is contracted immediately —
    P-1 ppermutes of an (m/P, k) tile hide behind P matmuls.
    """
    n_shards = mesh.shape[axis]

    def body(xs: jnp.ndarray, ws: jnp.ndarray) -> jnp.ndarray:
        idx = jax.lax.axis_index(axis)
        mb = xs.shape[0]                      # m/P local rows
        n_loc = ws.shape[1]
        out = jnp.zeros((mb * n_shards, n_loc), jnp.float32)
        perm = [(j, (j + 1) % n_shards) for j in range(n_shards)]

        def step(i, carry):
            out, blk = carry
            owner = (idx - i) % n_shards      # who produced blk
            part = jnp.dot(blk.astype(jnp.float32),
                           ws.astype(jnp.float32))
            out = jax.lax.dynamic_update_slice(out, part, (owner * mb, 0))
            blk = jax.lax.ppermute(blk, axis, perm)
            return out, blk

        out, _ = jax.lax.fori_loop(0, n_shards, step, (out, xs))
        return out.astype(x.dtype)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(axis, None), P(None, axis)),
                   out_specs=P(None, axis), check_vma=False)
    return fn(x, w)


def ring_matmul_reducescatter(x: jnp.ndarray, w: jnp.ndarray, mesh: Mesh,
                              axis: str = "model") -> jnp.ndarray:
    """Row-parallel matmul with streamed output reduce-scatter.

    x: (m, k) col-sharded over ``axis``; w: (k, n) row-sharded.
    partial_p = x_p @ w_p needs a sum over shards; the output comes
    back row-sharded (sequence-parallel) — the reduce-scatter rides
    the ring, one (m/P, n) tile per step, overlapping the reduction
    adds with the neighbouring shards' sends.
    """
    n_shards = mesh.shape[axis]

    def body(xs, ws):
        idx = jax.lax.axis_index(axis)
        part = jnp.dot(xs.astype(jnp.float32), ws.astype(jnp.float32))
        m = part.shape[0]
        mb = m // n_shards
        perm = [(j, (j + 1) % n_shards) for j in range(n_shards)]

        def blk(i):
            # the acc held here at step i has P-1-i hops left; it ends
            # at shard idx-1-i, so add that destination's row block.
            owner = (idx - 1 - i) % n_shards
            return jax.lax.dynamic_slice_in_dim(part, owner * mb, mb, 0)

        acc = blk(0)

        def step(i, acc):
            acc = jax.lax.ppermute(acc, axis, perm)
            return acc + blk(i)

        acc = jax.lax.fori_loop(1, n_shards, step, acc)
        return acc.astype(x.dtype)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(None, axis), P(axis, None)),
                   out_specs=P(axis, None), check_vma=False)
    return fn(x, w)


def psum_scatter_grads(grads, axis: str = "data"):
    """Leaf-wise reduce-scatter gradient sync (half the bytes of
    all-reduce) for use inside shard_map FSDP steps: each shard ends
    with the fully-reduced slice it owns and updates only that slice."""

    def one(g):
        return jax.lax.psum_scatter(g, axis, scatter_dimension=0,
                                    tiled=True)

    return jax.tree.map(one, grads)
