"""Spatial replication of compiled dataflow apps (FLOWER "replication").

The paper's hardware-parallelism taxonomy (after de Fine Licht et al.)
has two axes: *vectorization* widens one processing element's datapath
(:mod:`repro.core.vectorize`), *replication* instantiates the whole
pipeline k times and feeds each copy a slice of the plane.  On an FPGA
the copies are duplicated dataflow regions; here they are devices on a
1-D ``replica`` mesh, and the plane is row-partitioned with
``shard_map``.

Stencil stages need rows owned by the neighbouring shard: the
replicator computes the graph-wide cumulative halo (the same backward
DP the scheduler runs per fusion group, extended over the whole stage
DAG), recompiles the app once for the halo-extended local plane, and
exchanges halo rows over the ring before every launch
(:func:`repro.parallel.collectives.halo_exchange_rows`).  Missing
neighbours at the global top/bottom contribute zeros — identical to
the compiler's zero-padding boundary — so a replicated app is
bit-exact against the single-device app.  On one device the exchange
degenerates to pure zero padding and the identical code path runs:
CI on CPU exercises replication without a multi-chip host.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.backends import resolve, resolve_calibrated
from repro.core.fusion import lower_graph
from repro.core.graph import Channel, DataflowGraph, GraphError
from repro.core.host import CompiledApp, LaunchHandle
from repro.core.schedule import Schedule, build_schedule
from repro.parallel._compat import shard_map
from repro.parallel.collectives import halo_exchange_rows
from repro.parallel.sharding import replica_mesh

__all__ = ["ReplicatedApp", "replicate_app", "graph_input_halo",
           "replication_kwarg_routing", "UNROUTED_COMPILE_KWARGS"]

#: ``compile_graph`` knobs replication deliberately does NOT forward:
#: the shard_map launcher replaces the generated host launcher (mesh /
#: data_axis / donate / jit), and tracing is engine-level plumbing.
#: Everything else in ``compile_graph``'s signature must route into the
#: scheduler or the lowering — ``replication_kwarg_routing`` derives
#: that split from the live signatures, and a regression test asserts
#: full coverage so a NEW compile kwarg cannot be silently dropped.
UNROUTED_COMPILE_KWARGS = frozenset(
    {"mesh", "data_axis", "donate", "jit", "trace"})

#: kwargs consumed by the tuning/calibration resolution steps
#: themselves (not by the scheduler/lowering signatures)
_TUNE_KWARGS = frozenset({"tune", "tune_cache", "calibrate"})


def replication_kwarg_routing() -> tuple[frozenset, frozenset, frozenset]:
    """Derive ``(known, sched, lower)`` kwarg sets from live signatures.

    ``known`` is every ``compile_graph`` keyword ``replicate_app``
    accepts; ``sched``/``lower`` are the subsets forwarded to
    :func:`~repro.core.schedule.build_schedule` and
    :func:`~repro.core.fusion.lower_graph`.  Derived — not
    hand-maintained — so the three callables cannot drift apart; the
    companion invariant (``known | UNROUTED_COMPILE_KWARGS`` covers
    ``compile_graph``'s whole signature) is enforced by
    ``tests/test_backends.py``.
    """
    from repro.core.compiler import compile_graph
    all_kwargs = frozenset(
        inspect.signature(compile_graph).parameters) - {"graph", "backend"}
    routable = all_kwargs - UNROUTED_COMPILE_KWARGS - _TUNE_KWARGS
    sched = routable & frozenset(
        inspect.signature(build_schedule).parameters)
    lower = routable & frozenset(
        inspect.signature(lower_graph).parameters)
    return sched | lower | _TUNE_KWARGS, sched, lower


def graph_input_halo(graph: DataflowGraph) -> dict[Channel, tuple[int, int]]:
    """Cumulative (hy, hx) halo each *graph input* must carry.

    Backward DP over the whole stage DAG — the line-buffer analysis of
    :func:`repro.core.schedule._halo_analysis` without the fusion-group
    boundary: intermediate planes that round-trip through HBM still
    shrink the valid region of a row-partitioned shard, so replication
    must provision for the end-to-end stencil radius, not the
    per-kernel one.
    """
    halo: dict[Channel, tuple[int, int]] = {}
    for st in reversed(graph.toposort()):
        out_halos = [halo.get(ch, (0, 0)) for ch in st.outputs]
        oh = (max(h[0] for h in out_halos), max(h[1] for h in out_halos))
        ih = (oh[0] + st.halo[0], oh[1] + st.halo[1])
        for ch in st.inputs:
            prev = halo.get(ch, (0, 0))
            halo[ch] = (max(prev[0], ih[0]), max(prev[1], ih[1]))
    return {ch: halo.get(ch, (0, 0)) for ch in graph.graph_inputs}


def _clone_with_height(graph: DataflowGraph, new_h: int) -> DataflowGraph:
    """Rebuild ``graph`` with every plane's height replaced by ``new_h``.

    Stage bodies are shape-polymorphic (they stream tiles), so the
    clone is pure metadata surgery; topology, names, windows and
    timing survive unchanged.
    """
    g2 = DataflowGraph(graph.name)
    cmap: dict[Channel, Channel] = {}
    for ch in graph.channels:
        c2 = g2.channel((new_h, ch.shape[1]), ch.dtype, name=ch.name)
        c2.is_graph_input = ch.is_graph_input
        c2.is_graph_output = ch.is_graph_output
        c2.depth = ch.depth
        cmap[ch] = c2
    for st in graph.stages:
        g2.task(st.name, st.kind, st.fn,
                [cmap[c] for c in st.inputs], [cmap[c] for c in st.outputs],
                window=st.window, ii=st.ii, fill=st.fill, meta=dict(st.meta))
    return g2


@dataclasses.dataclass
class ReplicatedApp:
    """A dataflow app replicated across a 1-D device mesh.

    Call it exactly like the :class:`~repro.core.host.CompiledApp` it
    wraps — same input/output names, global plane shapes — and the
    row shards execute in parallel, one pipeline replica per device.
    """

    schedule: Schedule                  # for the local extended plane
    mesh: Mesh
    n_replicas: int
    halo_rows: int
    plane: tuple[int, int]              # global (H, W)
    fn: Callable                        # jitted sharded step
    input_names: list[str]
    output_names: list[str]

    def __call__(self, **inputs: Any) -> dict[str, Any]:
        args = [inputs[n] for n in self.input_names]
        outs = self.fn(*args)
        return dict(zip(self.output_names, outs))

    def launch(self, **inputs: Any) -> LaunchHandle:
        """Async dispatch across all replicas (XRT ``enqueueTask`` x k)."""
        args = [inputs[n] for n in self.input_names]
        outs = self.fn(*args)
        return LaunchHandle(dict(zip(self.output_names, outs)))

    def describe(self) -> str:
        lines = [f"replicated app {self.schedule.graph.name!r}: "
                 f"{self.n_replicas} replicas over mesh axis "
                 f"{self.mesh.axis_names[0]!r}",
                 f"  global plane {self.plane} -> local "
                 f"({self.plane[0] // self.n_replicas}"
                 f"+2*{self.halo_rows} halo rows, {self.plane[1]})"]
        lines.append(self.schedule.describe())
        return "\n".join(lines)


def replicate_app(source: DataflowGraph | CompiledApp,
                  n_replicas: int | None = None, *,
                  backend=None, axis: str = "replica",
                  devices: list | None = None,
                  **compile_kwargs: Any) -> ReplicatedApp:
    """Replicate a dataflow app across devices by row-partitioning.

    ``source`` is a graph or an already-compiled app (its
    post-canonicalization graph is reused).  ``n_replicas`` defaults to
    every visible device; 1 replica is the supported CI fallback — the
    same shard_map + halo-exchange path on a single-device mesh.

    Requirements: every channel in the graph is a 2-D plane of one
    shape (the streaming-pipeline apps of Table I) and the plane
    height divides evenly by the replica count.

    ``tune="auto"`` (with optional ``tune_cache=``) tunes the *local
    extended* plane each replica runs — the schedule is measured (or
    loaded from the persistent TuningCache) for the shard shape, so a
    replicated deployment also warm-starts at its measured operating
    point; the provenance shows up in ``rapp.describe()``.
    """
    if isinstance(source, CompiledApp):
        graph = source.schedule.graph
        backend = resolve(backend or source.backend)
    else:
        graph = source
        backend = resolve(backend or "pallas")
    backend.require("replication")
    # calibration resolves once, up front: the tuner's prior, the
    # scheduler's budgets and every replica's lowering must all see
    # the same (possibly fitted) constants
    backend = resolve_calibrated(backend, compile_kwargs.get("calibrate"))

    shapes = {ch.shape for ch in graph.channels}
    if len(shapes) != 1 or len(next(iter(shapes))) != 2:
        raise GraphError(
            f"replication row-partitions one 2-D plane; graph "
            f"{graph.name!r} has channel shapes {sorted(shapes)}")
    nonlocal_stages = [s.name for s in graph.stages
                       if s.kind in ("custom", "reduce")]
    if nonlocal_stages:
        raise GraphError(
            f"replication needs local (point/stencil/split) operators "
            f"with a known halo; stages {nonlocal_stages} are opaque "
            f"and could read across the row cut")
    H, W = next(iter(shapes))

    devs = list(devices if devices is not None else jax.devices())
    k = n_replicas if n_replicas is not None else len(devs)
    if k >= 1 and H % k != 0:
        raise GraphError(
            f"plane height {H} does not divide over {k} replicas; "
            f"pick a replica count dividing H or pad the plane")
    mesh = replica_mesh(k, axis=axis, devices=devs)
    h_local = H // k

    halos = graph_input_halo(graph)
    hy = max((h[0] for h in halos.values()), default=0)
    if hy >= h_local:
        raise GraphError(
            f"cumulative stencil halo ({hy} rows) does not fit a "
            f"{h_local}-row shard; use fewer replicas")

    known, sched_names, lower_names = replication_kwarg_routing()
    unknown = set(compile_kwargs) - known
    if unknown:
        raise TypeError(f"replicate_app got unsupported compile kwargs "
                        f"{sorted(unknown)}; supported: {sorted(known)}")
    sched_kwargs = {kw: v for kw, v in compile_kwargs.items()
                    if kw in sched_names}
    lower_kwargs = {kw: v for kw, v in compile_kwargs.items()
                    if kw in lower_names}

    he = h_local + 2 * hy
    clone = _clone_with_height(graph, he)
    tune = compile_kwargs.get("tune")
    notes: list[str] = []
    if tune is not None:
        # tune the *local extended* plane: that is the graph each
        # replica actually runs, and its TuningCache entry is keyed by
        # the extended shape — a k-replica deployment warm-starts from
        # the same persistent cache as its previous runs
        if compile_kwargs.get("vector_factor") is not None:
            raise TypeError("tune= and vector_factor= are mutually "
                            "exclusive in replicate_app")
        if compile_kwargs.get("max_tile") is not None:
            raise TypeError("tune= and max_tile= are mutually exclusive "
                            "in replicate_app: the tile cap is one of "
                            "the tuner's search axes")
        from repro.tune.search import resolve_tuning, tuned_schedule_kwargs
        spec = compile_kwargs.get("spec") or backend.spec
        tuned = resolve_tuning(
            clone, backend, tune=tune, spec=spec,
            cache=compile_kwargs.get("tune_cache"),
            interpret=backend.resolve_interpret(
                compile_kwargs.get("interpret")),
            strict=compile_kwargs.get("strict", False),
            canonicalize=compile_kwargs.get("canonicalize", True),
            passes=compile_kwargs.get("passes"))
        if tuned is not None:
            config, source, notes = tuned
            sched_kwargs.update(tuned_schedule_kwargs(config, source, spec))
    sched = build_schedule(clone, backend=backend, **sched_kwargs)
    sched.diagnostics.extend(notes)
    input_names = [c.name for c in sched.graph.graph_inputs]
    output_names = [c.name for c in sched.graph.graph_outputs]

    def variant(valid_rows: tuple[int, int]) -> Callable:
        # per-stage zero masking must follow the *global* image edges: a
        # shard at the top/bottom owns halo rows that lie outside the
        # image, and intermediates there are zero in the single-device
        # semantics.  One lowering per edge kind, same schedule/tiles.
        run, _ = lower_graph(sched.graph, backend, schedule=sched,
                             valid_rows=valid_rows, **lower_kwargs)

        def step(*xs):
            outs = run(dict(zip(input_names, xs)))
            return tuple(outs[n] for n in output_names)

        return step

    if k == 1:
        runs = [variant((hy, hy + h_local))]
    elif k == 2:
        runs = [variant((hy, he)), variant((0, hy + h_local))]
    else:
        runs = [variant((hy, he)), variant((0, he)),
                variant((0, hy + h_local))]

    def body(*xs):
        exts = [halo_exchange_rows(x, hy, k, axis) for x in xs]
        if k == 1:
            outs = runs[0](*exts)
        else:
            j = jax.lax.axis_index(axis)
            last = len(runs) - 1
            branch = jnp.where(j == 0, 0,
                               jnp.where(j == k - 1, last, 1))
            outs = jax.lax.switch(branch, runs, *exts)
        return tuple(o[hy:hy + h_local] for o in outs)

    sharded = shard_map(
        body, mesh=mesh,
        in_specs=tuple(P(axis, None) for _ in graph.graph_inputs),
        out_specs=tuple(P(axis, None) for _ in graph.graph_outputs),
        check_vma=False)
    fn = jax.jit(sharded)

    return ReplicatedApp(schedule=sched, mesh=mesh, n_replicas=k,
                         halo_rows=hy, plane=(H, W), fn=fn,
                         input_names=[c.name for c in graph.graph_inputs],
                         output_names=[c.name for c in graph.graph_outputs])
