"""Logical-axis sharding rules: DP / FSDP / TP / SP / EP on one mesh.

Models carry *logical* axis names (declared next to every parameter in
``ParamDef.axes`` and at activation constraint points).  This module
maps them onto the physical mesh — the cluster-scale version of
FLOWER's memory-bundle assignment: independent dataflow paths land on
different physical resources, from one declarative source.

Divisibility-aware: a logical axis only binds to a mesh axis when the
dimension divides evenly (or the mesh axis is explicitly marked
``uneven_ok``); otherwise it is left unsharded and the decision is
recorded so the dry-run can report it (e.g. qwen1.5's 40 heads on a
16-way model axis).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "TRAIN_RULES", "SERVE_RULES",
           "make_param_shardings", "make_activation_fn", "mesh_axis_size",
           "spec_for_axes", "replica_mesh"]


def replica_mesh(n_replicas: int | None = None, axis: str = "replica",
                 devices: list | None = None) -> Mesh:
    """A 1-D mesh of ``n_replicas`` devices for data-parallel farms.

    Used by :mod:`repro.parallel.replicate` (spatial plane replication)
    and the serving runtime's replicated micro-batcher.  Defaults to
    every visible device; asks for more than exist -> clear error.
    """
    devs = list(devices if devices is not None else jax.devices())
    k = n_replicas if n_replicas is not None else len(devs)
    if k < 1:
        raise ValueError(f"n_replicas must be >= 1, got {k}")
    if k > len(devs):
        raise ValueError(
            f"asked for {k} replicas but only {len(devs)} devices are "
            f"visible (set --xla_force_host_platform_device_count for "
            f"CPU testing)")
    return Mesh(np.asarray(devs[:k]), (axis,))

AxisBinding = Any  # str | tuple[str, ...] | None


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis -> mesh axis (or tuple of mesh axes, or None)."""

    rules: tuple[tuple[str, AxisBinding], ...]
    #: logical axes allowed to shard unevenly (GSPMD pads); attention
    #: heads are worth sharding even at 40/16.
    uneven_ok: frozenset[str] = frozenset()

    def binding(self, logical: str | None) -> AxisBinding:
        if logical is None:
            return None
        for k, v in self.rules:
            if k == logical:
                return v
        return None

    def replace(self, **kw: AxisBinding) -> "ShardingRules":
        rules = tuple((k, kw.pop(k)) if k in kw else (k, v)
                      for k, v in self.rules)
        rules += tuple(kw.items())
        return dataclasses.replace(self, rules=rules)


#: training: DP over (pod, data); FSDP (weight sharding) over data;
#: TP over model; experts over model when divisible.
TRAIN_RULES = ShardingRules(rules=(
    ("batch", ("pod", "data")),
    ("seq", None),
    ("embed", "data"),           # FSDP: weights' d_model dim over data
    ("vocab", "model"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("ff", "model"),
    ("experts", "model"),
    ("expert_ff", None),         # used when experts don't divide
    ("ssm_inner", "model"),
    ("layers", None),
), uneven_ok=frozenset({"heads", "kv_heads"}))

#: serving: no FSDP (weights resident), TP over model, batch over data.
SERVE_RULES = TRAIN_RULES.replace(embed=None)


def mesh_axis_size(mesh: Mesh, binding: AxisBinding) -> int:
    if binding is None:
        return 1
    if isinstance(binding, str):
        return mesh.shape[binding] if binding in mesh.shape else 1
    return int(np.prod([mesh.shape.get(a, 1) for a in binding]))


def spec_for_axes(mesh: Mesh, rules: ShardingRules,
                  axes: tuple[str | None, ...],
                  shape: tuple[int, ...] | None = None,
                  notes: list[str] | None = None,
                  allow_uneven: bool = False) -> P:
    """PartitionSpec for one array given its logical axes (and shape,
    for divisibility checks).

    ``allow_uneven`` is only legal for intermediate values
    (with_sharding_constraint; GSPMD pads) — pjit *arguments* must
    shard evenly, so it defaults off.
    """
    used: set[str] = set()
    dims: list[AxisBinding] = []
    for i, lg in enumerate(axes):
        b = rules.binding(lg)
        if b is None:
            dims.append(None)
            continue
        names = (b,) if isinstance(b, str) else tuple(b)
        names = tuple(n for n in names if n in mesh.shape and n not in used)
        if not names:
            dims.append(None)
            continue
        size = int(np.prod([mesh.shape[n] for n in names]))
        if shape is not None and shape[i] % size != 0:
            if allow_uneven and lg in rules.uneven_ok and shape[i] >= size:
                pass                       # GSPMD pads; accept
            else:
                if notes is not None:
                    notes.append(
                        f"axis {lg!r} dim {shape[i]} !% {size} -> unsharded")
                dims.append(None)
                continue
        used.update(names)
        dims.append(names[0] if len(names) == 1 else names)
    return P(*dims)


def make_param_shardings(mesh: Mesh, axes: Any, rules: ShardingRules,
                         shapes: Any = None, notes: list[str] | None = None
                         ) -> Any:
    """Tree of NamedSharding matching an axes_tree (and optional shape
    tree from jax.eval_shape for divisibility checks)."""
    is_axes = lambda x: (isinstance(x, tuple)
                         and all(isinstance(a, (str, type(None)))
                                 for a in x))
    if shapes is None:
        return jax.tree.map(
            lambda ax: NamedSharding(mesh, spec_for_axes(mesh, rules, ax,
                                                         None, notes)),
            axes, is_leaf=is_axes)
    return jax.tree.map(
        lambda ax, sh: NamedSharding(
            mesh, spec_for_axes(mesh, rules, ax, tuple(sh.shape), notes)),
        axes, shapes, is_leaf=is_axes)


def make_activation_fn(mesh: Mesh, rules: ShardingRules):
    """fn(x, logical_axes) -> with_sharding_constraint(x, spec)."""

    def constrain(x: jnp.ndarray, axes: tuple[str | None, ...]):
        if len(axes) != x.ndim:
            return x
        spec = spec_for_axes(mesh, rules, axes, tuple(x.shape),
                             allow_uneven=True)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain
