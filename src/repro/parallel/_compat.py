"""JAX version-compatibility shims for the parallel substrate.

``shard_map`` moved from ``jax.experimental.shard_map`` to ``jax``
around 0.5, and its replication-check kwarg was renamed
``check_rep`` -> ``check_vma``.  Code in this package writes the new
spelling; this shim translates for older installs.
"""
from __future__ import annotations

import inspect

try:
    from jax import shard_map as _shard_map
except ImportError:                      # jax < 0.5
    from jax.experimental.shard_map import shard_map as _shard_map

# the promotion to jax.shard_map and the check_rep -> check_vma rename
# happened in different releases, so detect the kwarg by signature
_CHECK_KW = ("check_vma"
             if "check_vma" in inspect.signature(_shard_map).parameters
             else "check_rep")

__all__ = ["shard_map"]


def shard_map(f, /, **kw):
    if "check_vma" in kw and _CHECK_KW != "check_vma":
        kw[_CHECK_KW] = kw.pop("check_vma")
    return _shard_map(f, **kw)
