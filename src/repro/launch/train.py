"""Production training launcher.

``python -m repro.launch.train --arch granite_3_2b --steps 100``

Wires together everything the framework generates: mesh construction,
sharding rules, the jitted+donated train step, deterministic data,
async checkpoints, preemption & straggler handling.  On this CPU
container use ``--smoke`` (reduced config, 1 device); on a real fleet
drop the flag and pass ``--mesh-data/--mesh-model``.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCHS, get_config, get_smoke
from repro.data.pipeline import SyntheticLM
from repro.optim.adamw import AdamWConfig
from repro.runtime.steps import train_state_shardings
from repro.runtime.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_2b",
                    help=f"one of {ARCHS}")
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="use the reduced same-family config (CPU)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh-data", type=int, default=0,
                    help="data-axis size (0 = no mesh / single device)")
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    print(f"{cfg.name}: {cfg.n_params()/1e6:.1f}M params, "
          f"{jax.device_count()} devices")

    mesh = state_sh = None
    if args.mesh_data:
        mesh = jax.make_mesh((args.mesh_data, args.mesh_model),
                             ("data", "model"))
        state_sh = train_state_shardings(cfg, mesh)

    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=args.seq,
                       global_batch=args.global_batch)
    opt = AdamWConfig(lr_peak=args.lr, warmup_steps=max(args.steps // 10, 1),
                      decay_steps=args.steps)
    tcfg = TrainerConfig(total_steps=args.steps,
                         ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir, log_every=10,
                         compress_grads=args.compress_grads)
    trainer = Trainer(cfg, opt, tcfg, data, mesh=mesh,
                      state_shardings=state_sh)
    hist = trainer.run()
    if hist:
        print(f"done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
