import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the *real* step function (train / prefill /
decode) with full sharding and donation, lowers it against
ShapeDtypeStruct stand-ins (no allocation), compiles it for the
production mesh, and records:

- ``compiled.memory_analysis()``  (fits-per-device proof)
- ``compiled.cost_analysis()``    (FLOPs / bytes for the roofline)
- collective bytes parsed from the optimized HLO
- the three roofline terms + dominant bottleneck

Usage:
  python -m repro.launch.dryrun --arch granite_3_2b --shape train_4k \
      --mesh pod                      # one cell (subprocess-friendly)
  python -m repro.launch.dryrun --sweep --mesh both --jobs 3
                                      # all cells via subprocesses
Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.analysis.roofline import analyze
from repro.configs import ARCHS, SHAPES, get_config
from repro.launch.mesh import make_production_mesh
from repro.models.config import ModelConfig, ShapeConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime import steps as S

OUT_DIR = "experiments/dryrun"


# ----------------------------------------------------------------------
# per-shape runtime knobs (NOT architecture: execution strategy)
# ----------------------------------------------------------------------
def runtime_cfg(cfg: ModelConfig, shape: ShapeConfig,
                overrides: dict | None = None) -> ModelConfig:
    kw: dict = {}
    if shape.seq_len > 2048 and cfg.family not in ("ssm",):
        kw["attn_chunk"] = 2048 if shape.seq_len >= 32768 else 1024
    if shape.kind == "train":
        kw["remat"] = "dots"
        kw["microbatches"] = 8      # fits 16 GB/chip (see EXPERIMENTS.md)
    kw.update(overrides or {})
    global EP_OVER_DATA
    EP_OVER_DATA = bool(kw.pop("ep_over_data", False))
    return dataclasses.replace(cfg, **kw)


EP_OVER_DATA = False   # set by --overrides {"ep_over_data": true}


def arch_rules(cfg: ModelConfig, mesh, rules):
    """Per-arch fallbacks and EP placement.

    - experts %% model axis != 0 (granite-moe 40/16): fall back to
      tensor parallelism *inside* each expert (d_ff sharded).
    - ep_over_data (perf knob, §Perf cell 1): shard experts over the
      *data* axis instead of FSDP'ing their weights — expert weights
      stop being all-gathered every microbatch; the token all-to-all
      rides the data axis instead.
    """
    msize = mesh.shape.get("model", 1)
    dsize = mesh.shape.get("data", 1)
    if cfg.n_experts and EP_OVER_DATA and cfg.n_experts % dsize == 0:
        return rules.replace(experts="data", expert_ff="model")
    if cfg.n_experts and cfg.n_experts % msize != 0:
        rules = rules.replace(experts=None, expert_ff="model")
    return rules


def calib_layers(cfg: ModelConfig) -> tuple[int, int]:
    if cfg.family == "hybrid":
        return cfg.attn_every, 2 * cfg.attn_every
    return 1, 2


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str | None:
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return ("long_500k needs sub-quadratic context state; "
                f"{cfg.name} is pure full-attention (assignment rule: skip)")
    return None


# ----------------------------------------------------------------------
# one cell
# ----------------------------------------------------------------------
def _lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, notes):
    """Build + lower the real step function for one cell."""
    from repro.models import model as M
    from repro.parallel.sharding import (SERVE_RULES, TRAIN_RULES,
                                         make_param_shardings)
    if shape.kind == "train":
        rules = arch_rules(cfg, mesh, TRAIN_RULES)
        state_av = S.abstract_train_state(cfg)
        state_sh = S.train_state_shardings(cfg, mesh, rules=rules,
                                           notes=notes)
        batch_av = S.batch_specs(cfg, shape)
        batch_sh = S.batch_shardings(cfg, shape, mesh, rules)
        step = S.make_train_step(cfg, AdamWConfig(), mesh=mesh,
                                 rules=rules)
        jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
        return jitted.lower(state_av, batch_av)
    rules = arch_rules(cfg, mesh, SERVE_RULES)
    params_av = jax.eval_shape(lambda: M.init(cfg, jax.random.PRNGKey(0)))
    params_sh = make_param_shardings(mesh, M.param_axes(cfg), rules,
                                     params_av, notes)
    cache_av = S.abstract_cache(cfg, shape)
    cache_sh = S.cache_shardings(cfg, shape, mesh, rules)
    batch_av = S.batch_specs(cfg, shape)
    batch_sh = S.batch_shardings(cfg, shape, mesh, rules)
    if shape.kind == "prefill":
        step = S.make_prefill_step(cfg, mesh=mesh, rules=rules)
    else:
        step = S.make_decode_step(cfg, mesh=mesh, rules=rules)
    jitted = jax.jit(step, in_shardings=(params_sh, batch_sh, cache_sh),
                     out_shardings=(None, cache_sh), donate_argnums=(2,))
    return jitted.lower(params_av, batch_av, cache_av)


def _cell_costs(compiled) -> dict:
    from repro.analysis.hlo import collective_bytes
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "coll": coll["total"],
            "coll_breakdown": {k: v for k, v in coll.items()
                               if k not in ("total", "ops")}}


def calibrate(cfg: ModelConfig, shape: ShapeConfig, mesh, notes
              ) -> dict:
    """Exact per-layer costs from unrolled L1/L2 compiles.

    XLA cost analysis counts while-loop bodies ONCE, so the production
    (scan-over-layers) module undercounts by the trip count.  The
    unrolled modules contain no layer loop and no attention-chunk loop
    (attn_chunk=0 -> naive attention: identical matmul FLOPs), so
    body = cost(L2) - cost(L1) and rest = cost(L1) - L1*body are
    exact; total(L) = L*body + rest.  All per-device (SPMD module).
    """
    L1, L2 = calib_layers(cfg)
    enc_scale = cfg.n_enc_layers // cfg.n_layers if cfg.n_enc_layers else 0
    out = []
    for Lc in (L1, L2):
        kw = dict(scan_layers=False, attn_unroll=True, microbatches=1,
                  n_layers=Lc, remat=cfg.remat)
        if cfg.n_enc_layers:
            kw["n_enc_layers"] = Lc * max(enc_scale, 1)
        cfg_c = dataclasses.replace(cfg, **kw)
        lowered = _lower_cell(cfg_c, shape, mesh, notes)
        out.append(_cell_costs(lowered.compile()))
    c1, c2 = out
    dL = L2 - L1
    body = {k: (c2[k] - c1[k]) / dL for k in ("flops", "bytes", "coll")}
    rest = {k: c1[k] - L1 * body[k] for k in ("flops", "bytes", "coll")}
    L = cfg.n_layers
    total = {k: max(L * body[k] + rest[k], 0.0)
             for k in ("flops", "bytes", "coll")}
    return {"body": body, "rest": rest, "total": total,
            "coll_breakdown_L1": c1["coll_breakdown"]}


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides: dict | None = None) -> dict:
    cfg0 = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "multipod" if multi_pod else "pod"
    reason = skip_reason(cfg0, shape)
    if reason:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skip", "reason": reason}
    cfg = runtime_cfg(cfg0, shape, overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(mesh.devices.size)
    t0 = time.time()
    notes: list[str] = []

    # 1) the production module: scan-over-layers, chunked attention.
    #    This is the compile/memory PROOF for the cell.
    lowered = _lower_cell(cfg, shape, mesh, notes)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    ma = compiled.memory_analysis()
    mem = {k: int(getattr(ma, k)) for k in
           ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes")
           if hasattr(ma, k)}
    raw = _cell_costs(compiled)

    if multi_pod:
        # multi-pod pass proves the "pod" axis shards + memory; the
        # roofline table is single-pod only (assignment spec).
        row = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "ok", "chips": chips,
               "lower_s": round(t_lower, 1),
               "compile_s": round(t_compile, 1),
               "bytes_per_chip": mem, "raw_uncalibrated": raw,
               "note": "compile+memory proof; roofline from pod mesh"}
        return row

    # 2) calibration: exact per-layer costs (see docstring).
    cal = calibrate(cfg, shape, mesh, notes)
    cost = {"flops": cal["total"]["flops"] * chips,
            "bytes accessed": cal["total"]["bytes"] * chips}
    coll_text_stub = ""   # collectives taken from calibration directly

    report = analyze(arch, shape, mesh_name, chips, cost, coll_text_stub,
                     mem, cfg, note="; ".join(sorted(set(notes))))
    # patch in calibrated collective bytes (analyze parsed empty text)
    from repro.analysis.roofline import V5E_HW
    report.coll_bytes = cal["total"]["coll"] * chips
    report.t_collective = cal["total"]["coll"] / V5E_HW.link_bw
    report.coll_breakdown = cal["coll_breakdown_L1"]
    terms = {"compute": report.t_compute, "memory": report.t_memory,
             "collective": report.t_collective}
    report.dominant = max(terms, key=terms.get)

    row = report.row()
    row.update({"status": "ok", "lower_s": round(t_lower, 1),
                "compile_s": round(t_compile, 1), "n_chips": chips,
                "raw_uncalibrated": raw,
                "calibration": cal})
    return row


# ----------------------------------------------------------------------
# sweep orchestration (subprocess per cell for isolation/parallelism)
# ----------------------------------------------------------------------
def cell_path(arch: str, shape: str, mesh: str) -> str:
    return os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh}.json")


def sweep(mesh_opt: str, jobs: int, force: bool = False,
          archs: list[str] | None = None) -> None:
    os.makedirs(OUT_DIR, exist_ok=True)
    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[mesh_opt]
    cells = [(a, s, mp) for a in (archs or ARCHS) for s in SHAPES
             for mp in meshes]
    todo = [(a, s, mp) for a, s, mp in cells
            if force or not os.path.exists(
                cell_path(a, s, "multipod" if mp else "pod"))]
    print(f"{len(todo)}/{len(cells)} cells to run, {jobs} parallel jobs")
    procs: list[tuple, subprocess.Popen] = []

    def launch(cell):
        a, s, mp = cell
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", a,
               "--shape", s, "--mesh", "multipod" if mp else "pod"]
        return subprocess.Popen(cmd, stdout=subprocess.DEVNULL,
                                stderr=subprocess.PIPE)

    queue = list(todo)
    running: list[tuple] = []
    while queue or running:
        while queue and len(running) < jobs:
            cell = queue.pop(0)
            running.append((cell, launch(cell), time.time()))
            print(f"  start {cell}")
        time.sleep(2)
        for item in list(running):
            cell, proc, t0 = item
            rc = proc.poll()
            if rc is None:
                continue
            running.remove(item)
            dt = time.time() - t0
            if rc == 0:
                print(f"  done  {cell} ({dt:.0f}s)")
            else:
                err = proc.stderr.read().decode()[-4000:]
                print(f"  FAIL  {cell} rc={rc} ({dt:.0f}s)\n{err[-800:]}")
                a, s, mp = cell
                path = cell_path(a, s, "multipod" if mp else "pod")
                if not os.path.exists(path):  # never clobber a good row
                    with open(path, "w") as f:
                        json.dump({"arch": a, "shape": s,
                                   "mesh": "multipod" if mp else "pod",
                                   "status": "fail", "rc": rc,
                                   "error": err}, f)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--overrides", default=None,
                    help="JSON dict of ModelConfig overrides (perf knobs)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if args.sweep:
        sweep(args.mesh, args.jobs, args.force,
              [args.arch] if args.arch else None)
        return

    assert args.arch and args.shape, "--arch and --shape required"
    overrides = json.loads(args.overrides) if args.overrides else None
    for mp in ({"pod": [False], "multipod": [True],
                "both": [False, True]}[args.mesh]):
        mesh_name = "multipod" if mp else "pod"
        try:
            row = run_cell(args.arch, args.shape, mp, overrides)
        except Exception:
            row = {"arch": args.arch, "shape": args.shape,
                   "mesh": mesh_name, "status": "fail",
                   "error": traceback.format_exc()[-4000:]}
        os.makedirs(OUT_DIR, exist_ok=True)
        path = args.out or cell_path(args.arch, args.shape, mesh_name)
        with open(path, "w") as f:
            json.dump(row, f, indent=1, default=str)
        status = row["status"]
        print(f"{args.arch} {args.shape} {mesh_name}: {status}")
        if status == "ok" and "t_compute" in row:
            print(f"  Tc={row['t_compute']*1e3:.3f}ms "
                  f"Tm={row['t_memory']*1e3:.3f}ms "
                  f"Tx={row['t_collective']*1e3:.3f}ms "
                  f"dom={row['dominant']} useful={row['useful_ratio']:.3f}")
            print(f"  mem/device: {row['bytes_per_chip']}")
        elif status == "fail":
            print(row["error"][-1500:])
            sys.exit(1)


if __name__ == "__main__":
    main()
