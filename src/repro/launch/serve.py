"""Production serving launcher: batched prefill + decode.

``python -m repro.launch.serve --arch mamba2_2p7b --batch 8``

The serving twin of launch/train.py: builds the cache, jits the
prefill/decode steps (with mesh shardings when requested) and runs a
greedy generation loop with per-phase throughput stats.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, get_smoke
from repro.models import model as M
from repro.runtime.steps import make_decode_step, make_prefill_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_3_2b",
                    help=f"one of {ARCHS}")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--mesh-data", type=int, default=0)
    ap.add_argument("--mesh-model", type=int, default=1)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    mesh = None
    if args.mesh_data:
        mesh = jax.make_mesh((args.mesh_data, args.mesh_model),
                             ("data", "model"))
    params = M.init(cfg, jax.random.PRNGKey(0))
    B = args.batch
    max_len = args.prompt_len + args.gen_len + 8
    cache = M.init_cache(cfg, B, max_len,
                         dtype=jnp.dtype(cfg.dtype))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (B, args.prompt_len), 0, cfg.vocab_size)
    batch = {"tokens": prompt}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jnp.zeros(
            (B, cfg.n_frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        batch["extra_embeds"] = jnp.zeros(
            (B, cfg.n_frontend_tokens, cfg.d_model), jnp.dtype(cfg.dtype))

    prefill = jax.jit(make_prefill_step(cfg, mesh=mesh))
    decode = jax.jit(make_decode_step(cfg, mesh=mesh),
                     donate_argnums=(2,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch, cache)
    logits.block_until_ready()
    tp = time.perf_counter() - t0

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    outs = [np.asarray(tok)]
    t0 = time.perf_counter()
    for _ in range(args.gen_len - 1):
        logits, cache = decode(params, {"token": tok}, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        outs.append(np.asarray(tok))
    jax.block_until_ready(logits)
    td = time.perf_counter() - t0

    print(f"{cfg.name}: prefill {tp*1e3:.1f} ms "
          f"({B*args.prompt_len/tp:.0f} tok/s), decode {td*1e3:.1f} ms "
          f"({B*(args.gen_len-1)/td:.0f} tok/s)")
    gen = np.stack(outs, 1)
    assert np.isfinite(gen).all()
    print("first row:", gen[0][:12], "... OK")


if __name__ == "__main__":
    main()
