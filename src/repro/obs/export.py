"""Chrome trace-event export: turn a :class:`Tracer` ring into JSON.

The output follows the `Trace Event Format
<https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU>`_
JSON-object flavor (``{"traceEvents": [...]}``) that both
``chrome://tracing`` and `Perfetto <https://ui.perfetto.dev>`_ load
directly.  Timestamps are emitted in **microseconds relative to the
first event**, so traces are readable regardless of the host's
``perf_counter`` epoch.

Because the recorder is a bounded ring that evicts oldest-first, the
snapshot can open mid-span: an ``E`` whose ``B`` was evicted, or a
``B`` whose ``E`` is still pending at export time.  ``to_chrome_events``
*sanitizes* the stream — orphan ``E`` events are dropped and dangling
``B`` events are closed at the trace's end — so the export always
passes :func:`validate_chrome_trace` (which is also what the CI smoke
step runs against a traced ``bench_serving``).
"""
from __future__ import annotations

import json
from typing import Any

from repro.obs.tracer import Event, Tracer

__all__ = ["to_chrome_events", "export_chrome_trace",
           "validate_chrome_trace", "load_chrome_trace"]

#: single-process traces: one pid for everything
_PID = 1


def _us(ts: float, t0: float) -> float:
    """perf_counter seconds -> microseconds relative to trace start."""
    return round((ts - t0) * 1e6, 3)


def to_chrome_events(tracer: Tracer) -> list[dict[str, Any]]:
    """Render the tracer's ring as a list of Chrome trace events.

    Events are ordered by ``(ts, seq)`` — the ring appends under a
    lock, but retroactive emissions (async request timelines, cross-
    thread ``X`` spans) carry captured timestamps older than their
    insertion order, and viewers require per-thread monotonic time.
    Sanitization then repairs ring-eviction damage (orphan ``E``,
    dangling ``B``) before anything is serialized.
    """
    events = sorted(tracer.events(), key=lambda e: (e.ts, e.seq))
    out: list[dict[str, Any]] = []
    for tid, name in sorted(tracer.thread_names().items()):
        out.append({"ph": "M", "name": "thread_name", "pid": _PID,
                    "tid": tid, "args": {"name": name}})
    if not events:
        return out
    t0 = events[0].ts
    t_end = max(e.ts + (e.dur or 0.0) for e in events)
    # depth of open B spans per tid, for eviction repair
    open_stacks: dict[int, list[Event]] = {}
    skipped_e: list[Event] = []
    for e in events:
        if e.ph == "B":
            open_stacks.setdefault(e.tid, []).append(e)
        elif e.ph == "E":
            stack = open_stacks.get(e.tid)
            if not stack:
                # its B was evicted from the ring: drop the orphan E
                skipped_e.append(e)
                continue
            stack.pop()
        rec: dict[str, Any] = {"ph": e.ph, "name": e.name, "cat": e.cat,
                               "ts": _us(e.ts, t0), "pid": _PID,
                               "tid": e.tid}
        if e.ph == "X":
            rec["dur"] = round((e.dur or 0.0) * 1e6, 3)
        if e.aid is not None:
            rec["id"] = str(e.aid)
        if e.ph == "i":
            rec["s"] = "t"
        if e.ph == "C":
            rec["args"] = dict(e.args or {"value": 0})
        elif e.args:
            rec["args"] = dict(e.args)
        out.append(rec)
    # close spans still open at snapshot time (or whose E was evicted)
    for tid, stack in open_stacks.items():
        for e in reversed(stack):
            out.append({"ph": "E", "name": e.name, "cat": e.cat,
                        "ts": _us(t_end, t0), "pid": _PID, "tid": tid})
    return out


def export_chrome_trace(tracer: Tracer, path: str) -> dict[str, Any]:
    """Write the tracer's ring to ``path`` as a Chrome trace JSON.

    Returns the payload that was written (handy for tests).  The
    payload carries ``displayTimeUnit: "ms"`` and a small metadata
    block recording how many events the ring dropped.
    """
    payload = {
        "traceEvents": to_chrome_events(tracer),
        "displayTimeUnit": "ms",
        "otherData": {"recorder": "repro.obs", "dropped": tracer.dropped},
    }
    with open(path, "w") as f:
        json.dump(payload, f)
    return payload


def load_chrome_trace(path: str) -> dict[str, Any]:
    """Load a trace JSON written by :func:`export_chrome_trace`."""
    with open(path) as f:
        return json.load(f)


def validate_chrome_trace(payload: dict[str, Any]) -> dict[str, Any]:
    """Check a trace payload against the trace-event schema rules.

    Raises ``ValueError`` on the first violation; returns a summary
    dict (event/span/async counts) on success.  Checked invariants —
    the ones Perfetto's importer actually relies on:

    - payload is an object with a ``traceEvents`` list of objects,
      each with string ``ph``/``name`` and numeric ``ts`` (except
      ``M`` metadata, which has no timestamp requirement);
    - per ``(pid, tid)``, timestamps are monotonically non-decreasing;
    - per ``(pid, tid)``, ``B``/``E`` events match like parentheses
      (same name on pop, nothing left open);
    - async ``b``/``e`` events balance per ``(cat, id, name)`` key;
    - ``X`` events carry a non-negative ``dur``.
    """
    if not isinstance(payload, dict):
        raise ValueError(f"trace payload must be an object, got "
                         f"{type(payload).__name__}")
    events = payload.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("trace payload has no traceEvents list")
    last_ts: dict[tuple, float] = {}
    stacks: dict[tuple, list[str]] = {}
    async_open: dict[tuple, int] = {}
    n_spans = n_async = n_x = 0
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            raise ValueError(f"traceEvents[{i}] is not an object")
        ph = e.get("ph")
        name = e.get("name")
        if not isinstance(ph, str) or not isinstance(name, str):
            raise ValueError(f"traceEvents[{i}] missing ph/name strings")
        if ph == "M":
            continue
        ts = e.get("ts")
        if not isinstance(ts, (int, float)):
            raise ValueError(f"traceEvents[{i}] ({ph} {name!r}) has no "
                             f"numeric ts")
        key = (e.get("pid"), e.get("tid"))
        prev = last_ts.get(key)
        if prev is not None and ts < prev:
            raise ValueError(
                f"traceEvents[{i}] ({ph} {name!r}): ts {ts} goes "
                f"backwards on tid {key[1]} (prev {prev})")
        last_ts[key] = ts
        if ph == "B":
            stacks.setdefault(key, []).append(name)
            n_spans += 1
        elif ph == "E":
            stack = stacks.get(key)
            if not stack:
                raise ValueError(
                    f"traceEvents[{i}]: E {name!r} on tid {key[1]} "
                    f"with no open B")
            top = stack.pop()
            if top != name:
                raise ValueError(
                    f"traceEvents[{i}]: E {name!r} closes B {top!r} "
                    f"on tid {key[1]} (mismatched pair)")
        elif ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(
                    f"traceEvents[{i}]: X {name!r} needs dur >= 0, "
                    f"got {dur!r}")
            n_x += 1
        elif ph == "b":
            akey = (e.get("cat"), e.get("id"), name)
            async_open[akey] = async_open.get(akey, 0) + 1
            n_async += 1
        elif ph == "e":
            akey = (e.get("cat"), e.get("id"), name)
            if async_open.get(akey, 0) <= 0:
                raise ValueError(
                    f"traceEvents[{i}]: async e {name!r} id="
                    f"{e.get('id')!r} with no open b")
            async_open[akey] -= 1
    for key, stack in stacks.items():
        if stack:
            raise ValueError(
                f"unclosed B span(s) {stack!r} on tid {key[1]}")
    dangling = {k: v for k, v in async_open.items() if v}
    if dangling:
        raise ValueError(f"unbalanced async spans: {dangling!r}")
    return {"events": len(events), "spans": n_spans,
            "async_spans": n_async, "complete": n_x,
            "threads": len(last_ts)}
