"""Unified metrics registry: counters, gauges, reservoir histograms.

One registry per serving engine (or per process) holds every metric
the runtime publishes — :class:`~repro.runtime.telemetry.Telemetry`
stores its samples *here* instead of keeping private lists, so an
operator (or an exporter grown later) can enumerate everything a
component measures through one interface.

:class:`Histogram` keeps a **uniform reservoir** (Vitter's Algorithm
R) rather than the first-N samples: a long serving run's p99 tracks
the *whole* run, not the warm-up era.  The reservoir RNG is seeded
from the metric name, so two runs observing the same stream keep the
same samples — deterministic tests, reproducible reports.

>>> reg = MetricsRegistry()
>>> reg.counter("served").inc(3)
>>> h = reg.histogram("latency_s", capacity=4)
>>> for x in range(100):
...     h.observe(float(x))
>>> h.count, len(h.samples())
(100, 4)
>>> reg.as_dict()["served"]
3
"""
from __future__ import annotations

import random
import threading
from typing import Any, Iterator

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


class Counter:
    """A monotonically increasing count (thread-safe)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0

    def as_value(self) -> int:
        return self._value


class Gauge:
    """A point-in-time value (thread-safe set/read)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value: float | None = None
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = value

    @property
    def value(self) -> float | None:
        return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = None

    def as_value(self) -> float | None:
        return self._value


class Histogram:
    """Uniform reservoir of observations (Algorithm R, seeded).

    Every observation is *counted*; at most ``capacity`` samples are
    *kept*, each surviving with probability ``capacity / count`` — so
    percentiles reflect the full stream uniformly instead of freezing
    on the first ``capacity`` observations.  The RNG is seeded from
    ``(name, seed)`` (string-seeded ``random.Random``: stable across
    processes and runs), and :meth:`reset` re-seeds it, so a reset
    measurement window replays deterministically.
    """

    __slots__ = ("name", "capacity", "_seed", "_samples", "_count",
                 "_sum", "_rand", "_lock")

    def __init__(self, name: str, capacity: int = 4096, seed: int = 0):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.name = name
        self.capacity = capacity
        self._seed = seed
        self._samples: list[float] = []
        self._count = 0
        self._sum = 0.0
        self._rand = random.Random(f"{name}:{seed}")
        self._lock = threading.Lock()

    def observe(self, x: float) -> None:
        with self._lock:
            self._observe(x)

    def extend(self, xs) -> None:
        with self._lock:
            for x in xs:
                self._observe(x)

    def _observe(self, x: float) -> None:
        x = float(x)
        self._count += 1
        if np.isfinite(x):          # a NaN/inf sample must not poison sum
            self._sum += x
        if len(self._samples) < self.capacity:
            self._samples.append(x)
            return
        j = self._rand.randrange(self._count)
        if j < self.capacity:
            self._samples[j] = x

    @property
    def count(self) -> int:
        """Total observations (not just the retained samples)."""
        return self._count

    @property
    def sum(self) -> float:
        """Running sum of every *finite* observation (whole stream)."""
        return self._sum

    def samples(self) -> list[float]:
        with self._lock:
            return list(self._samples)

    def finite_samples(self) -> list[float]:
        """Retained samples with NaN/inf filtered out.

        A hung launch or wrapped clock can observe a non-finite value;
        keeping it in the reservoir is honest accounting, but every
        percentile/mean consumer (and any exported JSON) wants only
        the digestible part.
        """
        with self._lock:
            return [x for x in self._samples if np.isfinite(x)]

    def percentile(self, q: float) -> float:
        xs = self.finite_samples()
        if not xs:
            return 0.0
        return float(np.percentile(np.asarray(xs), q))

    def mean(self) -> float:
        xs = self.finite_samples()
        return float(np.mean(xs)) if xs else 0.0

    def max(self) -> float:
        xs = self.finite_samples()
        return max(xs) if xs else 0.0

    def summary(self) -> dict[str, Any]:
        """JSON-ready stats: ``None`` (never NaN) when nothing usable.

        ``count`` is the whole observation stream, ``samples`` the
        finite retained reservoir the percentiles come from — a
        ``samples == 0`` summary carries ``None`` percentiles so an
        empty reservoir can never masquerade as a 0-latency one.
        """
        xs = np.asarray(self.finite_samples())
        if not len(xs):
            return {"count": self._count, "samples": 0, "sum": self._sum,
                    "mean": None, "p50": None, "p99": None, "max": None}
        return {"count": self._count, "samples": int(len(xs)),
                "sum": self._sum, "mean": float(np.mean(xs)),
                "p50": float(np.percentile(xs, 50)),
                "p99": float(np.percentile(xs, 99)),
                "max": float(xs.max())}

    def reset(self) -> None:
        with self._lock:
            self._samples.clear()
            self._count = 0
            self._sum = 0.0
            self._rand = random.Random(f"{self.name}:{self._seed}")

    def as_value(self) -> dict[str, float]:
        return self.summary()


class MetricsRegistry:
    """Name -> metric table with get-or-create accessors.

    Accessors are idempotent: ``counter("x")`` twice returns the same
    object; asking for an existing name as a *different* metric type
    raises.  ``as_dict()`` renders every metric for a report and
    ``reset()`` zeroes them all (a measurement-window boundary).
    """

    def __init__(self) -> None:
        self._metrics: dict[str, Any] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, name: str, cls, *args) -> Any:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, *args)
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge)

    def histogram(self, name: str, capacity: int = 4096,
                  seed: int = 0) -> Histogram:
        return self._get_or_create(name, Histogram, capacity, seed)

    def get(self, name: str) -> Any | None:
        with self._lock:
            return self._metrics.get(name)

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._metrics)

    def __iter__(self) -> Iterator[Any]:
        with self._lock:
            items = list(self._metrics.values())
        return iter(items)

    def __len__(self) -> int:
        return len(self._metrics)

    def as_dict(self) -> dict[str, Any]:
        with self._lock:
            items = sorted(self._metrics.items())
        return {name: m.as_value() for name, m in items}

    def reset(self) -> None:
        with self._lock:
            items = list(self._metrics.values())
        for m in items:
            m.reset()
