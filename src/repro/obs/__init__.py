"""Observability: tracing, metrics, export, health, drift, refit.

The feedback channel FLOWER gets from the HLS toolchain's analyzers,
rebuilt for the reproduction — and grown (PR 10) from a recorder into
a telemetry *plane*: :mod:`~repro.obs.tracer` records spans into a
bounded ring, :mod:`~repro.obs.export` renders the ring as a
Perfetto-loadable Chrome trace, :mod:`~repro.obs.metrics` is the
unified counter/gauge/histogram registry that runtime telemetry
publishes into, :mod:`~repro.obs.exporter` renders that registry as
an OpenMetrics/Prometheus exposition (with an optional stdlib scrape
endpoint), :mod:`~repro.obs.health` evaluates rolling-window SLOs
with hysteresis, :mod:`~repro.obs.drift` persists the
(modeled, measured) pairs that calibrate the cost model, and
:mod:`~repro.obs.sentinel` watches those pairs and triggers
recalibration when the fitted constants go stale.

This package imports only the standard library and numpy at module
load — every repro layer can depend on it without cycles (the
sentinel pulls in :mod:`repro.tune` lazily, at use).
"""
from repro.obs.drift import (DRIFT_ENV, DriftLog, DriftRow,
                             default_drift_path, drift_report,
                             predict_features, resolve_drift, spearman)
from repro.obs.export import (export_chrome_trace, load_chrome_trace,
                              to_chrome_events, validate_chrome_trace)
from repro.obs.exporter import (MetricFamily, MetricsHTTPServer, Sample,
                                export_metrics_at_exit, flatten_report,
                                parse_openmetrics, registry_families,
                                render_openmetrics, validate_openmetrics,
                                write_openmetrics)
from repro.obs.health import SLO, STATES, HealthMonitor
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.sentinel import DriftSentinel, SentinelPolicy
from repro.obs.tracer import (TRACE_ENV, Event, Tracer, get_tracer,
                              install, maybe_span, resolve_tracer,
                              uninstall)

__all__ = [
    "Event", "Tracer", "install", "uninstall", "get_tracer",
    "resolve_tracer", "maybe_span", "TRACE_ENV",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "to_chrome_events", "export_chrome_trace", "load_chrome_trace",
    "validate_chrome_trace",
    "DriftLog", "DriftRow", "default_drift_path", "drift_report",
    "predict_features", "resolve_drift", "spearman", "DRIFT_ENV",
    "Sample", "MetricFamily", "registry_families", "render_openmetrics",
    "parse_openmetrics", "validate_openmetrics", "MetricsHTTPServer",
    "write_openmetrics", "export_metrics_at_exit", "flatten_report",
    "SLO", "STATES", "HealthMonitor",
    "DriftSentinel", "SentinelPolicy",
]
