"""Observability: flight-recorder tracing, metrics, and drift capture.

The feedback channel FLOWER gets from the HLS toolchain's analyzers,
rebuilt for the reproduction: :mod:`~repro.obs.tracer` records spans
into a bounded ring, :mod:`~repro.obs.export` renders the ring as a
Perfetto-loadable Chrome trace, :mod:`~repro.obs.metrics` is the
unified counter/gauge/histogram registry that runtime telemetry
publishes into, and :mod:`~repro.obs.drift` persists the
(modeled, measured) pairs that will calibrate the cost model.

This package imports only the standard library and numpy at module
load — every repro layer can depend on it without cycles.
"""
from repro.obs.drift import (DRIFT_ENV, DriftLog, DriftRow,
                             default_drift_path, drift_report,
                             predict_features, resolve_drift, spearman)
from repro.obs.export import (export_chrome_trace, load_chrome_trace,
                              to_chrome_events, validate_chrome_trace)
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.tracer import (TRACE_ENV, Event, Tracer, get_tracer,
                              install, maybe_span, resolve_tracer,
                              uninstall)

__all__ = [
    "Event", "Tracer", "install", "uninstall", "get_tracer",
    "resolve_tracer", "maybe_span", "TRACE_ENV",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "to_chrome_events", "export_chrome_trace", "load_chrome_trace",
    "validate_chrome_trace",
    "DriftLog", "DriftRow", "default_drift_path", "drift_report",
    "predict_features", "resolve_drift", "spearman", "DRIFT_ENV",
]
