"""Modeled-vs-measured drift capture: the cost model's report card.

The analytic cost model (:func:`repro.core.vectorize.modeled_schedule_time`)
drives schedule selection and the tuner's search order, but
benchmarks show it is ~15x off in absolute terms and sometimes
*misorders* candidates (ROADMAP item 3).  Calibrating it needs data:
a persistent stream of (modeled, measured) pairs from real runs.

:class:`DriftLog` is that stream — an append-only JSONL file living
beside the :class:`~repro.tune.store.TuningCache` (same root, so one
directory holds everything learned about this machine).  Rows are
appended by:

- the serving engine, for **every batched launch** (kind ``launch``)
  and for the **first launch of each (signature, width)** bucket
  (kind ``compile``, where measured time includes jit compilation);
- the autotuner, for **every timed trial** (kind ``trial``).

:func:`drift_report` turns the accumulated rows into the calibration
input: per-group and overall **Spearman rank correlation** (does the
model at least order configurations correctly?) and **bias** (the
median measured/modeled ratio — the constant the model is off by).
Spearman is computed manually (tie-averaged ranks + Pearson on the
ranks) because scipy is not a dependency of this repo.

Rows may additionally carry **features** (``attrs["features"]``, see
:func:`repro.core.vectorize.schedule_features`): the spec-independent
terms (grid, bytes/step, per-stage-kind compute steps) behind the
modeled seconds.  :func:`predict_features` reconstitutes the modeled
time from those features under *any* spec — which is what lets the
calibration fit (:mod:`repro.tune.calibrate`) re-score history under
candidate constants, and lets ``drift_report(rows, spec=fitted)``
show a before/after-fit comparison without re-running anything.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Iterable

import numpy as np

__all__ = ["DriftLog", "DriftRow", "default_drift_path", "resolve_drift",
           "spearman", "drift_report", "predict_features", "DRIFT_ENV"]

#: environment variable overriding the on-disk drift log location
DRIFT_ENV = "REPRO_DRIFT_LOG"

#: rows buffered in memory before an automatic flush to disk
_FLUSH_EVERY = 64


def default_drift_path() -> str:
    """``drift.jsonl`` beside the tuning cache (``$REPRO_DRIFT_LOG``
    overrides)."""
    env = os.environ.get(DRIFT_ENV, "").strip()
    if env:
        return env
    # lazy import: obs must stay importable without pulling in the
    # tune -> core import chain at module load
    from repro.tune.store import default_cache_root
    return os.path.join(default_cache_root(), "drift.jsonl")


class DriftRow:
    """One (modeled, measured) observation.

    ``modeled_s`` / ``measured_s`` are wall-clock seconds for the same
    unit of work; ``kind`` says where the pair came from (``launch``,
    ``compile``, ``trial``); ``signature`` + ``shapes`` + ``backend``
    identify the workload so reports can group rows that the model
    should at least rank consistently.
    """

    __slots__ = ("kind", "signature", "shapes", "backend", "modeled_s",
                 "measured_s", "attrs")

    def __init__(self, kind: str, signature: str, shapes: Any,
                 backend: str, modeled_s: float, measured_s: float,
                 attrs: dict[str, Any] | None = None):
        self.kind = kind
        self.signature = signature
        self.shapes = shapes
        self.backend = backend
        self.modeled_s = float(modeled_s)
        self.measured_s = float(measured_s)
        self.attrs = attrs or {}

    def as_dict(self) -> dict[str, Any]:
        d = {"kind": self.kind, "signature": self.signature,
             "shapes": self.shapes, "backend": self.backend,
             "modeled_s": self.modeled_s, "measured_s": self.measured_s}
        if self.attrs:
            d["attrs"] = self.attrs
        return d

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "DriftRow":
        return cls(d.get("kind", "launch"), d.get("signature", ""),
                   d.get("shapes"), d.get("backend", ""),
                   d.get("modeled_s", 0.0), d.get("measured_s", 0.0),
                   d.get("attrs"))

    @property
    def features(self) -> dict[str, Any] | None:
        """Cost-model features behind ``modeled_s`` (or None for rows
        written before PR 9 / by writers that don't model)."""
        f = self.attrs.get("features")
        return f if isinstance(f, dict) else None


class DriftLog:
    """Append-only JSONL log of drift rows (thread-safe, buffered).

    ``record`` costs a dict build and a list append; rows hit disk
    every ``_FLUSH_EVERY`` records, on :meth:`flush`, and at
    interpreter exit — the serving hot path never waits on a write.
    A missing parent directory is created on first flush.

    ``max_rows`` bounds on-disk growth under long-running serving:
    when a flush pushes the live file past the cap it **rotates** —
    the live file replaces ``<path>.1`` (whose previous contents
    disappear from visibility and are counted in
    :attr:`rotated_rows`) and a fresh live file starts.
    :meth:`rows`, :func:`drift_report` and the sentinel's windows read
    ``<path>.1`` *then* the live file, so at most ``2 * max_rows``
    recent rows stay visible and rotation never yanks history out
    from under a rolling window mid-scan.  ``max_rows=None`` (the
    default) keeps the pre-rotation unbounded behaviour.
    """

    def __init__(self, path: str | None = None, *,
                 max_rows: int | None = None):
        if max_rows is not None and max_rows < 1:
            raise ValueError(f"max_rows must be >= 1, got {max_rows}")
        self.path = path if path is not None else default_drift_path()
        self.max_rows = max_rows
        #: rows retired from visibility by rotation (process lifetime)
        self.rotated_rows = 0
        self._disk_rows: int | None = None    # live-file rows, lazy count
        self._buf: list[dict[str, Any]] = []
        self._lock = threading.Lock()
        import atexit
        atexit.register(self.flush)

    @property
    def rotated_path(self) -> str:
        """Where the previous generation lives after a rotation."""
        return self.path + ".1"

    def record(self, kind: str, signature: str, shapes: Any,
               backend: str, modeled_s: float, measured_s: float,
               **attrs: Any) -> None:
        row = DriftRow(kind, signature, shapes, backend, modeled_s,
                       measured_s, attrs or None)
        with self._lock:
            self._buf.append(row.as_dict())
            need_flush = len(self._buf) >= _FLUSH_EVERY
        if need_flush:
            self.flush()

    @staticmethod
    def _count_lines(path: str) -> int:
        try:
            with open(path) as f:
                return sum(1 for line in f if line.strip())
        except OSError:
            return 0

    def flush(self) -> None:
        with self._lock:
            if not self._buf:
                return
            rows, self._buf = self._buf, []
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)
        with self._lock:
            if self._disk_rows is None:
                self._disk_rows = self._count_lines(self.path)
            with open(self.path, "a") as f:
                for row in rows:
                    f.write(json.dumps(row) + "\n")
            self._disk_rows += len(rows)
            if (self.max_rows is not None
                    and self._disk_rows > self.max_rows):
                retiring = self._count_lines(self.rotated_path)
                try:
                    os.replace(self.path, self.rotated_path)
                except OSError:
                    return             # rotation is best-effort
                self.rotated_rows += retiring
                self._disk_rows = 0

    @staticmethod
    def _read_rows(path: str, out: list[DriftRow]) -> None:
        if not os.path.exists(path):
            return
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    out.append(DriftRow.from_dict(json.loads(line)))
                except (json.JSONDecodeError, TypeError):
                    continue           # torn write: skip, keep reading

    def rows(self) -> list[DriftRow]:
        """All visible rows, oldest first: the rotated generation (if
        any), then the live file, then the unflushed buffer."""
        out: list[DriftRow] = []
        self._read_rows(self.rotated_path, out)
        self._read_rows(self.path, out)
        with self._lock:
            out.extend(DriftRow.from_dict(d) for d in self._buf)
        return out

    def __len__(self) -> int:
        n = self._count_lines(self.rotated_path) + self._count_lines(self.path)
        with self._lock:
            return n + len(self._buf)

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
            self._disk_rows = 0
        for path in (self.path, self.rotated_path):
            if os.path.exists(path):
                os.remove(path)


def resolve_drift(drift: Any) -> DriftLog | None:
    """Normalize a user-facing ``drift=`` argument into a log.

    ``None`` enables drift capture only when ``$REPRO_DRIFT_LOG`` is
    set (off-by-default: no disk writes unless asked); ``True`` logs
    to :func:`default_drift_path`; a path string logs there; ``False``
    opts out even under the env var; a :class:`DriftLog` passes
    through.
    """
    if drift is None:
        if not os.environ.get(DRIFT_ENV, "").strip():
            return None
        return DriftLog()
    if drift is True:
        return DriftLog()
    if drift is False:
        return None
    if isinstance(drift, str):
        return DriftLog(drift)
    if not isinstance(drift, DriftLog):
        raise TypeError(f"drift must be a DriftLog, path, True/False or "
                        f"None; got {type(drift).__name__}")
    return drift


def _ranks(xs: np.ndarray) -> np.ndarray:
    """Tie-averaged ranks (1-based, fractional on ties)."""
    order = np.argsort(xs, kind="stable")
    ranks = np.empty(len(xs), dtype=np.float64)
    i = 0
    while i < len(xs):
        j = i
        while j + 1 < len(xs) and xs[order[j + 1]] == xs[order[i]]:
            j += 1
        ranks[order[i:j + 1]] = (i + j) / 2.0 + 1.0
        i = j + 1
    return ranks


def spearman(xs: Iterable[float], ys: Iterable[float]) -> float:
    """Spearman rank correlation of two sequences (nan if degenerate).

    >>> round(spearman([1, 2, 3, 4], [10, 20, 30, 40]), 3)
    1.0
    >>> round(spearman([1, 2, 3, 4], [40, 30, 20, 10]), 3)
    -1.0
    """
    x = np.asarray(list(xs), dtype=np.float64)
    y = np.asarray(list(ys), dtype=np.float64)
    if len(x) != len(y):
        raise ValueError(f"length mismatch: {len(x)} vs {len(y)}")
    if len(x) < 2:
        return float("nan")
    rx, ry = _ranks(x), _ranks(y)
    sx, sy = rx.std(), ry.std()
    if sx == 0 or sy == 0:
        return float("nan")
    return float(((rx - rx.mean()) * (ry - ry.mean())).mean() / (sx * sy))


def predict_features(features: dict[str, Any], spec: Any) -> float:
    """Modeled seconds reconstituted from drift-row features.

    ``features`` is the dict produced by
    :func:`repro.core.vectorize.schedule_features` (or
    :func:`~repro.core.vectorize.plane_features` wrapped in a
    single-group list): per fusion group the DMA-issue ``grid``, HBM
    ``bytes_step``, and per-stage-kind compute ``steps`` (issue
    intervals x tile area).  The prediction is, per group,

    ``grid * (step_overhead_s + max(bytes_step / hbm_bw,
    sum_kind(steps[kind] * ii_scale[kind]) / clock_hz))``

    summed over groups and multiplied by ``items`` — **bit-identical**
    to :func:`repro.core.vectorize.modeled_schedule_time` for an
    unscaled spec, so re-scoring history under a candidate spec is
    exactly what the compiler would have modeled.  ``spec`` is duck
    typed (only ``clock_hz``/``hbm_bw``/``step_overhead_s`` and an
    optional ``ii_scale`` are read), keeping :mod:`repro.obs` free of
    the core import chain.

    >>> class S:
    ...     clock_hz, hbm_bw, step_overhead_s = 1e9, 1e9, 1e-6
    >>> feats = {"groups": [{"grid": 4, "bytes_step": 1000,
    ...                      "steps": {"point": 2000.0}}]}
    >>> round(predict_features(feats, S()) * 1e6, 3)  # 4*(1us + 2us)
    12.0
    """
    scale = dict(getattr(spec, "ii_scale", ()) or ())
    total = 0.0
    for g in features.get("groups", ()):
        dma_s = g["bytes_step"] / spec.hbm_bw
        steps = 0.0
        for kind, cycles in g.get("steps", {}).items():
            steps += cycles * scale.get(kind, 1.0)
        compute_s = steps / spec.clock_hz
        total += g["grid"] * (spec.step_overhead_s + max(dma_s, compute_s))
    return total * features.get("items", 1)


def _usable(modeled: float, measured: float) -> bool:
    """A (modeled, measured) pair the stats can digest: finite and
    positive on both sides.  NaN/inf measurements (a hung launch, a
    clock that wrapped) and unmodeled rows are skipped — and counted,
    so a report can't silently hide a sick log."""
    return (np.isfinite(modeled) and np.isfinite(measured)
            and modeled > 0 and measured > 0)


def _summary(modeled: np.ndarray, measured: np.ndarray) -> dict[str, Any]:
    ratio = measured / modeled
    q75, q25 = np.percentile(np.log10(ratio), [75, 25])
    return {
        "n": int(len(modeled)),
        "spearman": spearman(modeled, measured),
        "bias": float(np.median(ratio)),
        "log10_bias": float(np.median(np.log10(ratio))),
        "log10_spread": float(q75 - q25),
    }


def drift_report(rows: Iterable[DriftRow] | DriftLog | None = None,
                 *, min_group: int = 2, spec: Any = None) -> dict[str, Any]:
    """Summarize accumulated drift rows into the calibration inputs.

    Returns::

        {"n": ..., "skipped": ...,         # usable rows / dropped rows
         "spearman": ...,                  # overall rank correlation
         "bias": ...,                      # median measured/modeled
         "log10_bias": ..., "log10_spread": ...,
         "groups": {sig: {"n", "spearman", "bias"}, ...},
         "by_kind": {kind: n, ...},
         "with_spec": {...}}               # only when ``spec=`` given

    ``spearman`` near 1 means the model orders workloads correctly
    even if its absolute scale is off (then ``bias`` is the single
    constant to fold in); near 0 or negative reproduces the
    misordering that makes tuning-by-model unreliable (ROADMAP item
    3).  Rows whose modeled or measured seconds are NaN, infinite or
    nonpositive (a hung launch, an unmodeled path) are skipped and
    counted in ``skipped`` rather than poisoning every statistic.
    Groups smaller than ``min_group`` are skipped for per-group
    correlation but still count toward the overall stats.

    ``spec=`` turns on the before/after-fit comparison: every usable
    row carrying features is re-scored with :func:`predict_features`
    under the given (typically calibrated) spec, and the same summary
    statistics over those re-predictions land under ``with_spec`` —
    plus ``without_features``, the count of rows that predate feature
    capture and so cannot be re-scored.  Comparing the top-level
    ``spearman``/``bias`` (as logged, under the spec that produced the
    rows) against ``with_spec`` is the calibration exit criterion.
    """
    if rows is None:
        rows = DriftLog()
    if isinstance(rows, DriftLog):
        rows = rows.rows()
    rows = list(rows)
    usable = [r for r in rows if _usable(r.modeled_s, r.measured_s)]
    skipped = len(rows) - len(usable)
    if not usable:
        out: dict[str, Any] = {
            "n": 0, "skipped": skipped, "spearman": float("nan"),
            "bias": float("nan"), "log10_bias": float("nan"),
            "log10_spread": float("nan"), "groups": {}, "by_kind": {}}
        if spec is not None:
            out["with_spec"] = {
                "n": 0, "without_features": 0, "spearman": float("nan"),
                "bias": float("nan"), "log10_bias": float("nan"),
                "log10_spread": float("nan")}
        return out
    modeled = np.asarray([r.modeled_s for r in usable])
    measured = np.asarray([r.measured_s for r in usable])
    by_kind: dict[str, int] = {}
    groups: dict[str, list[DriftRow]] = {}
    for r in usable:
        by_kind[r.kind] = by_kind.get(r.kind, 0) + 1
        groups.setdefault(r.signature, []).append(r)
    group_stats: dict[str, dict[str, Any]] = {}
    for sig, rs in sorted(groups.items()):
        if len(rs) < min_group:
            continue
        g_mod = [r.modeled_s for r in rs]
        g_meas = [r.measured_s for r in rs]
        group_stats[sig] = {
            "n": len(rs),
            "spearman": spearman(g_mod, g_meas),
            "bias": float(np.median(np.asarray(g_meas)
                                    / np.asarray(g_mod))),
        }
    out = _summary(modeled, measured)
    out["skipped"] = skipped
    out["groups"] = group_stats
    out["by_kind"] = by_kind
    if spec is not None:
        re_mod: list[float] = []
        re_meas: list[float] = []
        no_feats = 0
        for r in usable:
            feats = r.features
            pred = (predict_features(feats, spec)
                    if feats is not None else float("nan"))
            if _usable(pred, r.measured_s):
                re_mod.append(pred)
                re_meas.append(r.measured_s)
            else:
                no_feats += 1
        if re_mod:
            with_spec = _summary(np.asarray(re_mod), np.asarray(re_meas))
        else:
            with_spec = {"n": 0, "spearman": float("nan"),
                         "bias": float("nan"), "log10_bias": float("nan"),
                         "log10_spread": float("nan")}
        with_spec["without_features"] = no_feats
        out["with_spec"] = with_spec
    return out
