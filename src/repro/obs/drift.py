"""Modeled-vs-measured drift capture: the cost model's report card.

The analytic cost model (:func:`repro.core.vectorize.modeled_schedule_time`)
drives schedule selection and the tuner's search order, but
benchmarks show it is ~15x off in absolute terms and sometimes
*misorders* candidates (ROADMAP item 3).  Calibrating it needs data:
a persistent stream of (modeled, measured) pairs from real runs.

:class:`DriftLog` is that stream — an append-only JSONL file living
beside the :class:`~repro.tune.store.TuningCache` (same root, so one
directory holds everything learned about this machine).  Rows are
appended by:

- the serving engine, for **every batched launch** (kind ``launch``)
  and for the **first launch of each (signature, width)** bucket
  (kind ``compile``, where measured time includes jit compilation);
- the autotuner, for **every timed trial** (kind ``trial``).

:func:`drift_report` turns the accumulated rows into the calibration
input: per-group and overall **Spearman rank correlation** (does the
model at least order configurations correctly?) and **bias** (the
median measured/modeled ratio — the constant the model is off by).
Spearman is computed manually (tie-averaged ranks + Pearson on the
ranks) because scipy is not a dependency of this repo.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Iterable

import numpy as np

__all__ = ["DriftLog", "DriftRow", "default_drift_path", "resolve_drift",
           "spearman", "drift_report", "DRIFT_ENV"]

#: environment variable overriding the on-disk drift log location
DRIFT_ENV = "REPRO_DRIFT_LOG"

#: rows buffered in memory before an automatic flush to disk
_FLUSH_EVERY = 64


def default_drift_path() -> str:
    """``drift.jsonl`` beside the tuning cache (``$REPRO_DRIFT_LOG``
    overrides)."""
    env = os.environ.get(DRIFT_ENV, "").strip()
    if env:
        return env
    # lazy import: obs must stay importable without pulling in the
    # tune -> core import chain at module load
    from repro.tune.store import default_cache_root
    return os.path.join(default_cache_root(), "drift.jsonl")


class DriftRow:
    """One (modeled, measured) observation.

    ``modeled_s`` / ``measured_s`` are wall-clock seconds for the same
    unit of work; ``kind`` says where the pair came from (``launch``,
    ``compile``, ``trial``); ``signature`` + ``shapes`` + ``backend``
    identify the workload so reports can group rows that the model
    should at least rank consistently.
    """

    __slots__ = ("kind", "signature", "shapes", "backend", "modeled_s",
                 "measured_s", "attrs")

    def __init__(self, kind: str, signature: str, shapes: Any,
                 backend: str, modeled_s: float, measured_s: float,
                 attrs: dict[str, Any] | None = None):
        self.kind = kind
        self.signature = signature
        self.shapes = shapes
        self.backend = backend
        self.modeled_s = float(modeled_s)
        self.measured_s = float(measured_s)
        self.attrs = attrs or {}

    def as_dict(self) -> dict[str, Any]:
        d = {"kind": self.kind, "signature": self.signature,
             "shapes": self.shapes, "backend": self.backend,
             "modeled_s": self.modeled_s, "measured_s": self.measured_s}
        if self.attrs:
            d["attrs"] = self.attrs
        return d

    @classmethod
    def from_dict(cls, d: dict[str, Any]) -> "DriftRow":
        return cls(d.get("kind", "launch"), d.get("signature", ""),
                   d.get("shapes"), d.get("backend", ""),
                   d.get("modeled_s", 0.0), d.get("measured_s", 0.0),
                   d.get("attrs"))


class DriftLog:
    """Append-only JSONL log of drift rows (thread-safe, buffered).

    ``record`` costs a dict build and a list append; rows hit disk
    every ``_FLUSH_EVERY`` records, on :meth:`flush`, and at
    interpreter exit — the serving hot path never waits on a write.
    A missing parent directory is created on first flush.
    """

    def __init__(self, path: str | None = None):
        self.path = path if path is not None else default_drift_path()
        self._buf: list[dict[str, Any]] = []
        self._lock = threading.Lock()
        import atexit
        atexit.register(self.flush)

    def record(self, kind: str, signature: str, shapes: Any,
               backend: str, modeled_s: float, measured_s: float,
               **attrs: Any) -> None:
        row = DriftRow(kind, signature, shapes, backend, modeled_s,
                       measured_s, attrs or None)
        with self._lock:
            self._buf.append(row.as_dict())
            need_flush = len(self._buf) >= _FLUSH_EVERY
        if need_flush:
            self.flush()

    def flush(self) -> None:
        with self._lock:
            if not self._buf:
                return
            rows, self._buf = self._buf, []
        parent = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(parent, exist_ok=True)
        with open(self.path, "a") as f:
            for row in rows:
                f.write(json.dumps(row) + "\n")

    def rows(self) -> list[DriftRow]:
        """All rows: what's on disk plus the unflushed buffer."""
        out: list[DriftRow] = []
        if os.path.exists(self.path):
            with open(self.path) as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        out.append(DriftRow.from_dict(json.loads(line)))
                    except (json.JSONDecodeError, TypeError):
                        continue       # torn write: skip, keep reading
        with self._lock:
            out.extend(DriftRow.from_dict(d) for d in self._buf)
        return out

    def __len__(self) -> int:
        n = 0
        if os.path.exists(self.path):
            with open(self.path) as f:
                n = sum(1 for line in f if line.strip())
        with self._lock:
            return n + len(self._buf)

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()
        if os.path.exists(self.path):
            os.remove(self.path)


def resolve_drift(drift: Any) -> DriftLog | None:
    """Normalize a user-facing ``drift=`` argument into a log.

    ``None`` enables drift capture only when ``$REPRO_DRIFT_LOG`` is
    set (off-by-default: no disk writes unless asked); ``True`` logs
    to :func:`default_drift_path`; a path string logs there; ``False``
    opts out even under the env var; a :class:`DriftLog` passes
    through.
    """
    if drift is None:
        if not os.environ.get(DRIFT_ENV, "").strip():
            return None
        return DriftLog()
    if drift is True:
        return DriftLog()
    if drift is False:
        return None
    if isinstance(drift, str):
        return DriftLog(drift)
    if not isinstance(drift, DriftLog):
        raise TypeError(f"drift must be a DriftLog, path, True/False or "
                        f"None; got {type(drift).__name__}")
    return drift


def _ranks(xs: np.ndarray) -> np.ndarray:
    """Tie-averaged ranks (1-based, fractional on ties)."""
    order = np.argsort(xs, kind="stable")
    ranks = np.empty(len(xs), dtype=np.float64)
    i = 0
    while i < len(xs):
        j = i
        while j + 1 < len(xs) and xs[order[j + 1]] == xs[order[i]]:
            j += 1
        ranks[order[i:j + 1]] = (i + j) / 2.0 + 1.0
        i = j + 1
    return ranks


def spearman(xs: Iterable[float], ys: Iterable[float]) -> float:
    """Spearman rank correlation of two sequences (nan if degenerate).

    >>> round(spearman([1, 2, 3, 4], [10, 20, 30, 40]), 3)
    1.0
    >>> round(spearman([1, 2, 3, 4], [40, 30, 20, 10]), 3)
    -1.0
    """
    x = np.asarray(list(xs), dtype=np.float64)
    y = np.asarray(list(ys), dtype=np.float64)
    if len(x) != len(y):
        raise ValueError(f"length mismatch: {len(x)} vs {len(y)}")
    if len(x) < 2:
        return float("nan")
    rx, ry = _ranks(x), _ranks(y)
    sx, sy = rx.std(), ry.std()
    if sx == 0 or sy == 0:
        return float("nan")
    return float(((rx - rx.mean()) * (ry - ry.mean())).mean() / (sx * sy))


def drift_report(rows: Iterable[DriftRow] | DriftLog | None = None,
                 *, min_group: int = 2) -> dict[str, Any]:
    """Summarize accumulated drift rows into the calibration inputs.

    Returns::

        {"n": ..., "spearman": ...,        # overall rank correlation
         "bias": ...,                      # median measured/modeled
         "log10_spread": ...,              # IQR of log10(ratio)
         "groups": {sig: {"n", "spearman", "bias"}, ...},
         "by_kind": {kind: n, ...}}

    ``spearman`` near 1 means the model orders workloads correctly
    even if its absolute scale is off (then ``bias`` is the single
    constant to fold in); near 0 or negative reproduces the
    misordering that makes tuning-by-model unreliable (ROADMAP item
    3).  Groups smaller than ``min_group`` are skipped for per-group
    correlation but still count toward the overall stats.
    """
    if rows is None:
        rows = DriftLog()
    if isinstance(rows, DriftLog):
        rows = rows.rows()
    rows = [r for r in rows if r.modeled_s > 0 and r.measured_s > 0]
    if not rows:
        return {"n": 0, "spearman": float("nan"), "bias": float("nan"),
                "log10_spread": float("nan"), "groups": {},
                "by_kind": {}}
    modeled = np.asarray([r.modeled_s for r in rows])
    measured = np.asarray([r.measured_s for r in rows])
    ratio = measured / modeled
    by_kind: dict[str, int] = {}
    groups: dict[str, list[DriftRow]] = {}
    for r in rows:
        by_kind[r.kind] = by_kind.get(r.kind, 0) + 1
        groups.setdefault(r.signature, []).append(r)
    group_stats: dict[str, dict[str, Any]] = {}
    for sig, rs in sorted(groups.items()):
        if len(rs) < min_group:
            continue
        g_mod = [r.modeled_s for r in rs]
        g_meas = [r.measured_s for r in rs]
        group_stats[sig] = {
            "n": len(rs),
            "spearman": spearman(g_mod, g_meas),
            "bias": float(np.median(np.asarray(g_meas)
                                    / np.asarray(g_mod))),
        }
    q75, q25 = np.percentile(np.log10(ratio), [75, 25])
    return {
        "n": len(rows),
        "spearman": spearman(modeled, measured),
        "bias": float(np.median(ratio)),
        "log10_spread": float(q75 - q25),
        "groups": group_stats,
        "by_kind": by_kind,
    }
