"""SLO health monitor: rolling-window objectives with hysteresis.

A serving engine publishes a stream of measurements; an *operator*
needs one word — is this engine OK?  :class:`HealthMonitor` turns the
stream into that word.  It tracks a small set of service-level
objectives (:class:`SLO`) over rolling windows:

- **latency** — p99 of the most recent ``window`` request latencies
  against the latency budget,
- **shed rate** — requests rejected by admission control as a
  fraction of requests submitted *since the last evaluation* (a rate
  over the evaluation interval, not the whole run — an engine that
  shed during a spike an hour ago is not unhealthy now),
- **queue depth** — instantaneous total backlog,
- **cache hit rate** — the compile cache's per-event hit rate.

Each evaluation yields the set of violated objectives and feeds a
three-state machine with **hysteresis**:

``healthy -> degraded`` on the first violating evaluation (an early
warning, immediately visible), ``-> breach`` only after
``breach_after`` *consecutive* violating evaluations, and back to
``healthy`` only after ``recover_after`` consecutive clean ones (a
recovering breach passes through ``degraded``).  A metric oscillating
exactly at its threshold therefore parks the monitor in ``degraded``
— it can never flap ``healthy <-> breach``, which is the property the
white-box sequence test in ``tests/test_telemetry_plane.py`` pins.

State transitions are emitted as instant events into the
:class:`~repro.obs.tracer.Tracer` (``health.transition``, cat
``health``) and counted in the :class:`~repro.obs.metrics.MetricsRegistry`
(``health_transitions``, ``health_violation_<objective>``, and the
``health_state`` gauge: 0 healthy / 1 degraded / 2 breach), so the
OpenMetrics exporter (:mod:`repro.obs.exporter`) publishes health
exactly like every other metric.

The monitor is engine-agnostic: the
:class:`~repro.runtime.engine.StreamEngine` owns one (``engine.health()``)
and feeds it latencies at batch retirement, but anything with
counters can evaluate against it.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any

import numpy as np

__all__ = ["SLO", "HealthMonitor", "STATES"]

#: health states in increasing severity; the gauge exports the index
STATES = ("healthy", "degraded", "breach")


@dataclasses.dataclass(frozen=True)
class SLO:
    """Service-level objectives; ``None`` disables an objective.

    >>> SLO(latency_p99_s=0.05).latency_p99_s
    0.05
    """

    #: p99 of the rolling latency window must stay below this (seconds)
    latency_p99_s: float | None = None
    #: shed/submitted over the evaluation interval must stay below this
    max_shed_rate: float | None = 0.05
    #: instantaneous queued requests must stay below this
    max_queue_depth: int | None = None
    #: compile-cache per-event hit rate must stay above this
    min_cache_hit_rate: float | None = None

    def objectives(self) -> dict[str, float]:
        """The enabled objectives and their limits."""
        out: dict[str, float] = {}
        if self.latency_p99_s is not None:
            out["latency_p99"] = self.latency_p99_s
        if self.max_shed_rate is not None:
            out["shed_rate"] = self.max_shed_rate
        if self.max_queue_depth is not None:
            out["queue_depth"] = float(self.max_queue_depth)
        if self.min_cache_hit_rate is not None:
            out["cache_hit_rate"] = self.min_cache_hit_rate
        return out


class HealthMonitor:
    """Rolling-window SLO evaluation with hysteresis (thread-safe).

    ``window`` bounds the latency deque; ``breach_after`` /
    ``recover_after`` are the hysteresis widths in *evaluations*;
    ``min_interval_s`` rate-limits :meth:`maybe_evaluate` so the
    engine worker can call it every loop iteration for free.
    ``min_latency_samples`` keeps the latency objective quiet until
    the window holds enough requests for a p99 to mean anything.
    """

    def __init__(self, slo: SLO | None = None, *, window: int = 512,
                 breach_after: int = 3, recover_after: int = 3,
                 min_interval_s: float = 1.0,
                 min_latency_samples: int = 20,
                 registry: Any = None, tracer: Any = None):
        if breach_after < 1 or recover_after < 1:
            raise ValueError("breach_after and recover_after must be >= 1")
        self.slo = slo if slo is not None else SLO()
        self.window = window
        self.breach_after = breach_after
        self.recover_after = recover_after
        self.min_interval_s = min_interval_s
        self.min_latency_samples = min_latency_samples
        self.registry = registry
        self.tracer = tracer
        self.state = "healthy"
        self.evaluations = 0
        #: ``(t, from_state, to_state, violated)`` audit trail
        self.transitions: list[tuple[float, str, str, tuple[str, ...]]] = []
        self._lat: deque[float] = deque(maxlen=window)
        self._fail_streak = 0
        self._ok_streak = 0
        self._last_submitted = 0
        self._last_shed = 0
        self._last_eval_t: float | None = None
        self._lock = threading.Lock()
        if registry is not None:
            registry.gauge("health_state").set(0.0)

    # -- feeding the windows (hot-path cheap) --------------------------
    def observe_latencies(self, latencies_s) -> None:
        """Append completed-request latencies to the rolling window."""
        with self._lock:
            self._lat.extend(latencies_s)

    # -- evaluation ----------------------------------------------------
    def _measurements(self, submitted: int, shed: int, queue_depth: int,
                      cache_hit_rate: float | None) -> dict[str, Any]:
        lat = [x for x in self._lat if np.isfinite(x)]
        p99 = (float(np.percentile(np.asarray(lat), 99))
               if len(lat) >= self.min_latency_samples else None)
        d_sub = submitted - self._last_submitted
        d_shed = shed - self._last_shed
        self._last_submitted, self._last_shed = submitted, shed
        offered = d_sub + d_shed       # sheds never reach `submitted`
        shed_rate = (d_shed / offered) if offered > 0 else None
        return {"latency_p99": p99, "shed_rate": shed_rate,
                "queue_depth": float(queue_depth),
                "cache_hit_rate": cache_hit_rate,
                "latency_window": len(lat)}

    def _violations(self, meas: dict[str, Any]) -> list[str]:
        out = []
        slo = self.slo
        if (slo.latency_p99_s is not None
                and meas["latency_p99"] is not None
                and meas["latency_p99"] > slo.latency_p99_s):
            out.append("latency_p99")
        if (slo.max_shed_rate is not None
                and meas["shed_rate"] is not None
                and meas["shed_rate"] > slo.max_shed_rate):
            out.append("shed_rate")
        if (slo.max_queue_depth is not None
                and meas["queue_depth"] > slo.max_queue_depth):
            out.append("queue_depth")
        if (slo.min_cache_hit_rate is not None
                and meas["cache_hit_rate"] is not None
                and meas["cache_hit_rate"] < slo.min_cache_hit_rate):
            out.append("cache_hit_rate")
        return out

    def _advance(self, violated: list[str]) -> str:
        """The hysteresis core: one evaluation moves the state machine.

        Consecutive-evaluation counting is what prevents flapping: a
        single excursion (or a metric sitting exactly on its
        threshold, alternating pass/fail) can reach ``degraded`` but
        never ``breach``, and a breach needs ``recover_after`` clean
        evaluations in a row before the monitor calls the engine
        healthy again.
        """
        prev = self.state
        if violated:
            self._fail_streak += 1
            self._ok_streak = 0
            if self._fail_streak >= self.breach_after:
                self.state = "breach"
            elif self.state == "healthy":
                self.state = "degraded"
        else:
            self._ok_streak += 1
            self._fail_streak = 0
            if self._ok_streak >= self.recover_after:
                self.state = "healthy"
            elif self.state == "breach":
                self.state = "degraded"
        return prev

    def evaluate(self, *, submitted: int = 0, shed: int = 0,
                 queue_depth: int = 0,
                 cache_hit_rate: float | None = None,
                 now: float | None = None) -> dict[str, Any]:
        """Evaluate every objective; advance the state machine once.

        Returns ``{"state", "violated", "objectives", "evaluations",
        "transitioned"}`` where ``objectives`` maps each enabled
        objective to its measured value, limit and pass/fail (value
        ``None`` = not enough data yet, which never violates).
        """
        t = now if now is not None else time.perf_counter()
        with self._lock:
            self.evaluations += 1
            self._last_eval_t = t
            meas = self._measurements(submitted, shed, queue_depth,
                                      cache_hit_rate)
            violated = self._violations(meas)
            prev = self._advance(violated)
            state = self.state
            if state != prev:
                self.transitions.append((t, prev, state, tuple(violated)))
        transitioned = state != prev
        reg = self.registry
        if reg is not None:
            reg.counter("health_evaluations").inc()
            reg.gauge("health_state").set(float(STATES.index(state)))
            for obj in violated:
                reg.counter(f"health_violation_{obj}").inc()
            if transitioned:
                reg.counter("health_transitions").inc()
        if transitioned and self.tracer is not None:
            self.tracer.instant("health.transition", cat="health",
                                ts=t, frm=prev, to=state,
                                violated=",".join(violated))
        limits = self.slo.objectives()
        objectives = {
            name: {"value": meas.get(name), "limit": limit,
                   "ok": name not in violated}
            for name, limit in limits.items()}
        return {"state": state, "violated": violated,
                "objectives": objectives,
                "latency_window": meas["latency_window"],
                "evaluations": self.evaluations,
                "transitioned": transitioned}

    def maybe_evaluate(self, **kwargs: Any) -> dict[str, Any] | None:
        """Rate-limited :meth:`evaluate` for a worker loop.

        Returns ``None`` (and does nothing) when the last evaluation
        was under ``min_interval_s`` ago — callers can invoke it every
        iteration without turning health checking into load.
        """
        now = kwargs.get("now")
        t = now if now is not None else time.perf_counter()
        with self._lock:
            last = self._last_eval_t
            if last is not None and (t - last) < self.min_interval_s:
                return None
        kwargs.setdefault("now", t)
        return self.evaluate(**kwargs)
