"""OpenMetrics/Prometheus text exposition for the metrics registry.

The registry (:mod:`repro.obs.metrics`) already holds everything a
serving engine measures; this module renders it in the one format
every scraper on earth understands — the `OpenMetrics text format
<https://prometheus.io/docs/specs/om/open_metrics_spec/>`_ — so a
live :class:`~repro.runtime.engine.StreamEngine` can be watched by a
stock Prometheus without the repo growing a client dependency.

Three layers, each usable alone:

- **families** — :class:`MetricFamily` / :class:`Sample` are the
  typed intermediate: a family has a metric ``kind`` (``counter`` |
  ``gauge`` | ``summary``) and label-carrying samples.
  :func:`registry_families` lifts a
  :class:`~repro.obs.metrics.MetricsRegistry` into families, with
  ``labels=`` stamped on every sample (the stable identity labels:
  ``app``, backend ``cache_key()``, device kind, ...) and ``rules=``
  mapping raw metric names into labelled families (the engine folds
  its ``phase_<p>_s`` histograms into ONE ``phase_seconds`` family
  with a ``phase`` label this way).
- **rendering** — :func:`render_openmetrics` produces the exposition
  text (``# TYPE`` lines, escaped label values, counters with the
  mandatory ``_total`` suffix, ``# EOF`` terminator);
  :func:`parse_openmetrics` is the matching strict reader used by
  tests and the CI gate — it validates metric-name / label-name
  grammar, escaping, type lines, and the EOF sentinel, so a format
  regression fails loudly instead of silently confusing a scraper.
- **serving** — :class:`MetricsHTTPServer` is an optional scrape
  endpoint on the stdlib ``http.server`` (a daemon thread; no new
  dependency), and :func:`write_openmetrics` /
  :func:`export_metrics_at_exit` cover headless runs that want the
  final exposition dropped to a file instead.

Values that are ``None`` or non-finite are **skipped at render time**
(OpenMetrics has no null): an empty reservoir exports its ``_count``
of 0 and no quantile samples, never a fake ``0.0`` percentile.
"""
from __future__ import annotations

import math
import os
import re
import tempfile
import threading
from typing import Any, Callable, Iterable, Mapping

from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry

__all__ = ["Sample", "MetricFamily", "registry_families",
           "render_openmetrics", "parse_openmetrics",
           "validate_openmetrics", "MetricsHTTPServer",
           "write_openmetrics", "export_metrics_at_exit",
           "flatten_report", "QUANTILES"]

#: quantiles exported for every reservoir histogram
QUANTILES = (0.5, 0.9, 0.99)

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
_KINDS = ("counter", "gauge", "summary")


def sanitize_name(name: str) -> str:
    """Coerce an arbitrary registry name into a legal metric name."""
    out = re.sub(r"[^a-zA-Z0-9_:]", "_", name)
    if not out or not _NAME_RE.match(out):
        out = "_" + out
    return out


def escape_label_value(v: Any) -> str:
    """Escape a label value per the exposition grammar."""
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


class Sample:
    """One exposition line: ``name{labels} value`` (+ optional suffix).

    ``suffix`` distinguishes the summary sub-series (``_count``,
    ``_sum``) and the counter ``_total``; plain gauges leave it empty.
    """

    __slots__ = ("labels", "value", "suffix")

    def __init__(self, value: float | int | None,
                 labels: Mapping[str, Any] | None = None,
                 suffix: str = ""):
        self.labels = dict(labels or {})
        self.value = value
        self.suffix = suffix


class MetricFamily:
    """A named metric of one ``kind`` with label-carrying samples.

    >>> fam = MetricFamily("served", "counter", "requests served")
    >>> fam.add(3, {"app": "blur"})
    >>> len(fam.samples)
    1
    """

    __slots__ = ("name", "kind", "help", "samples")

    def __init__(self, name: str, kind: str, help: str = ""):
        if kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}, got {kind!r}")
        self.name = sanitize_name(name)
        self.kind = kind
        self.help = help
        self.samples: list[Sample] = []

    def add(self, value: float | int | None,
            labels: Mapping[str, Any] | None = None,
            suffix: str = "") -> None:
        self.samples.append(Sample(value, labels, suffix))


def _histogram_samples(h: Histogram, labels: Mapping[str, Any],
                       extra: Mapping[str, Any] | None = None
                       ) -> list[Sample]:
    """Summary-family samples for one reservoir histogram.

    ``_count``/``_sum`` cover the whole observation stream; quantiles
    come from the (finite) reservoir and are omitted entirely when the
    reservoir holds no finite sample — never rendered as a fake 0.
    """
    base = dict(labels)
    if extra:
        base.update(extra)
    out = [Sample(h.count, base, "_count"), Sample(h.sum, base, "_sum")]
    xs = [x for x in h.samples() if math.isfinite(x)]
    if xs:
        xs.sort()
        for q in QUANTILES:
            idx = min(len(xs) - 1, max(0, round(q * (len(xs) - 1))))
            out.append(Sample(xs[idx], dict(base, quantile=f"{q:g}")))
    return out


def registry_families(registry: MetricsRegistry, *,
                      labels: Mapping[str, Any] | None = None,
                      namespace: str = "repro",
                      rules: Mapping[str, tuple[str, Mapping[str, Any]]]
                      | None = None) -> dict[str, MetricFamily]:
    """Lift every metric of ``registry`` into exposition families.

    ``labels`` are stamped on every sample (identity labels: ``app``,
    backend ``cache_key()``, device kind...).  ``rules`` maps a raw
    registry metric name to ``(family_name, extra_labels)`` so several
    registry metrics can fold into one labelled family — e.g. every
    ``phase_<p>_s`` histogram into ``phase_seconds{phase="<p>"}``.
    Returns ``{family_name: MetricFamily}`` (insertion-ordered by
    sorted registry name).

    >>> reg = MetricsRegistry()
    >>> reg.counter("served").inc(2)
    >>> fams = registry_families(reg, labels={"app": "blur"})
    >>> fams["repro_served"].kind
    'counter'
    """
    base = dict(labels or {})
    rules = dict(rules or {})
    fams: dict[str, MetricFamily] = {}
    for name in registry.names():
        m = registry.get(name)
        if m is None:            # racing unregister; nothing to render
            continue
        fam_name, extra = rules.get(name, (name, {}))
        fam_name = sanitize_name(f"{namespace}_{fam_name}"
                                 if namespace else fam_name)
        if isinstance(m, Counter):
            fam = fams.setdefault(fam_name,
                                  MetricFamily(fam_name, "counter"))
            fam.add(m.value, dict(base, **extra), "_total")
        elif isinstance(m, Gauge):
            fam = fams.setdefault(fam_name, MetricFamily(fam_name, "gauge"))
            fam.add(m.value, dict(base, **extra))
        elif isinstance(m, Histogram):
            fam = fams.setdefault(fam_name,
                                  MetricFamily(fam_name, "summary"))
            fam.samples.extend(_histogram_samples(m, base, extra))
    return fams


def _render_value(v: float | int) -> str:
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    return repr(float(v))


def render_openmetrics(families: Iterable[MetricFamily] |
                       Mapping[str, MetricFamily]) -> str:
    """Render families as OpenMetrics exposition text.

    Families render in the given order; samples whose value is
    ``None`` or non-finite are skipped (the format has no null — a
    missing series is the honest encoding of "no data").  The payload
    always ends with the ``# EOF`` sentinel scrapers use to detect
    truncated responses.
    """
    if isinstance(families, Mapping):
        families = families.values()
    lines: list[str] = []
    seen: set[str] = set()
    for fam in families:
        if fam.name in seen:
            raise ValueError(f"duplicate metric family {fam.name!r}")
        seen.add(fam.name)
        if fam.help:
            lines.append(f"# HELP {fam.name} "
                         + fam.help.replace("\\", "\\\\")
                         .replace("\n", "\\n"))
        lines.append(f"# TYPE {fam.name} {fam.kind}")
        for s in fam.samples:
            v = s.value
            if v is None or (isinstance(v, float) and not math.isfinite(v)):
                continue
            suffix = s.suffix
            if fam.kind == "counter" and suffix == "":
                suffix = "_total"
            label_str = ""
            if s.labels:
                inner = ",".join(
                    f'{sanitize_name(str(k))}="{escape_label_value(val)}"'
                    for k, val in sorted(s.labels.items()))
                label_str = "{" + inner + "}"
            lines.append(f"{fam.name}{suffix}{label_str} "
                         f"{_render_value(v)}")
    lines.append("# EOF")
    return "\n".join(lines) + "\n"


# ----------------------------------------------------------------------
# strict reader / validator (tests + CI gate)
# ----------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>.*)\})?"
    r" (?P<value>[^ ]+)(?: (?P<ts>[0-9.eE+-]+))?$")
_LABEL_PAIR_RE = re.compile(
    r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')
_SUFFIXES = ("_total", "_count", "_sum")


def _unescape(v: str) -> str:
    return (v.replace("\\n", "\n").replace('\\"', '"')
            .replace("\\\\", "\\"))


def parse_openmetrics(text: str) -> dict[str, dict[str, Any]]:
    """Parse (and strictly validate) an OpenMetrics exposition.

    Returns ``{family: {"type": kind, "samples": [(suffix, labels,
    value), ...]}}``.  Raises :class:`ValueError` on every grammar
    violation the renderer could regress into: a missing ``# EOF``,
    samples before their ``# TYPE`` line, malformed metric or label
    names, unparseable label blocks or values, counter samples without
    ``_total``.

    >>> fams = parse_openmetrics(render_openmetrics(
    ...     [MetricFamily("x", "gauge")]))
    >>> fams["x"]["type"]
    'gauge'
    """
    if not text.endswith("# EOF\n") and text.rstrip("\n") != "# EOF":
        raise ValueError("exposition does not end with '# EOF'")
    fams: dict[str, dict[str, Any]] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        if not line:
            raise ValueError(f"line {lineno}: blank line in exposition")
        if line == "# EOF":
            continue
        if line.startswith("# TYPE "):
            try:
                _, _, name, kind = line.split(" ", 3)
            except ValueError:
                raise ValueError(f"line {lineno}: malformed TYPE line")
            if kind not in _KINDS:
                raise ValueError(f"line {lineno}: unknown type {kind!r}")
            if not _NAME_RE.match(name):
                raise ValueError(f"line {lineno}: bad metric name {name!r}")
            if name in fams:
                raise ValueError(f"line {lineno}: duplicate family "
                                 f"{name!r}")
            fams[name] = {"type": kind, "samples": []}
            continue
        if line.startswith("# HELP "):
            continue
        if line.startswith("#"):
            raise ValueError(f"line {lineno}: unknown comment {line!r}")
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {lineno}: unparseable sample {line!r}")
        raw = m.group("name")
        fam_name, suffix = raw, ""
        for suf in _SUFFIXES:
            if raw.endswith(suf) and raw[:-len(suf)] in fams:
                fam_name, suffix = raw[:-len(suf)], suf
                break
        if fam_name not in fams:
            raise ValueError(f"line {lineno}: sample {raw!r} precedes its "
                             f"TYPE line")
        fam = fams[fam_name]
        if fam["type"] == "counter" and suffix != "_total":
            raise ValueError(f"line {lineno}: counter sample {raw!r} "
                             f"missing _total suffix")
        labels: dict[str, str] = {}
        block = m.group("labels")
        if block:
            pos = 0
            while pos < len(block):
                pair = _LABEL_PAIR_RE.match(block, pos)
                if pair is None:
                    raise ValueError(f"line {lineno}: unparseable label "
                                     f"block {block!r} at offset {pos}")
                k, v = pair.group(1), _unescape(pair.group(2))
                if k in labels:
                    raise ValueError(f"line {lineno}: duplicate label "
                                     f"{k!r}")
                labels[k] = v
                pos = pair.end()
                if pos < len(block):
                    if block[pos] != ",":
                        raise ValueError(f"line {lineno}: expected ',' in "
                                         f"label block {block!r}")
                    pos += 1
        try:
            value = float(m.group("value"))
        except ValueError:
            raise ValueError(f"line {lineno}: bad value "
                             f"{m.group('value')!r}")
        fam["samples"].append((suffix, labels, value))
    return fams


def validate_openmetrics(text: str) -> dict[str, int]:
    """Parse ``text`` strictly; return summary stats for assertions."""
    fams = parse_openmetrics(text)
    return {"families": len(fams),
            "samples": sum(len(f["samples"]) for f in fams.values()),
            "counters": sum(f["type"] == "counter" for f in fams.values()),
            "summaries": sum(f["type"] == "summary"
                             for f in fams.values())}


def flatten_report(d: Mapping[str, Any], *, sep: str = ".",
                   prefix: str = "") -> dict[str, Any]:
    """Flatten a nested report dict into one level of dotted keys.

    The headless-export companion to the exposition format: a nested
    ``Telemetry.report()`` becomes a flat scalar dict that lands in
    JSON/CSV without structure-aware consumers.

    >>> flatten_report({"a": {"b": 1}, "c": 2})
    {'a.b': 1, 'c': 2}
    """
    out: dict[str, Any] = {}
    for k, v in d.items():
        key = f"{prefix}{sep}{k}" if prefix else str(k)
        if isinstance(v, Mapping):
            out.update(flatten_report(v, sep=sep, prefix=key))
        else:
            out[key] = v
    return out


# ----------------------------------------------------------------------
# serving the exposition
# ----------------------------------------------------------------------

#: scrape responses carry the version the format mandates
CONTENT_TYPE = ("application/openmetrics-text; version=1.0.0; "
                "charset=utf-8")


class MetricsHTTPServer:
    """A stdlib scrape endpoint: ``GET /metrics`` renders live text.

    No dependency beyond ``http.server``; the server thread is a
    daemon, so a crashed engine never hangs on its exporter.  Pass
    ``port=0`` to bind an ephemeral port (tests, multi-engine hosts)
    and read it back from :attr:`port` / :attr:`url`.

    ``render`` is any zero-arg callable returning exposition text —
    typically ``engine.openmetrics`` — and is called per scrape, so
    scrapers always see current values.
    """

    def __init__(self, render: Callable[[], str], *,
                 host: str = "127.0.0.1", port: int = 0):
        import http.server

        outer = self

        class _Handler(http.server.BaseHTTPRequestHandler):
            def do_GET(self) -> None:      # noqa: N802 (stdlib casing)
                if self.path.rstrip("/") not in ("", "/metrics"):
                    self.send_error(404)
                    return
                try:
                    payload = outer.render().encode("utf-8")
                except Exception as e:      # render must not kill serving
                    self.send_error(500, explain=str(e))
                    return
                self.send_response(200)
                self.send_header("Content-Type", CONTENT_TYPE)
                self.send_header("Content-Length", str(len(payload)))
                self.end_headers()
                self.wfile.write(payload)
                outer.scrapes += 1

            def log_message(self, *args: Any) -> None:
                pass                        # scrapes are not stderr news

        self.render = render
        self.scrapes = 0
        self._server = http.server.ThreadingHTTPServer((host, port),
                                                       _Handler)
        self._server.daemon_threads = True
        self.host, self.port = self._server.server_address[:2]
        self._thread = threading.Thread(target=self._server.serve_forever,
                                        name="metrics-exporter",
                                        daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def close(self) -> None:
        self._server.shutdown()
        self._server.server_close()

    def __enter__(self) -> "MetricsHTTPServer":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


def write_openmetrics(path: str, text_or_render: str | Callable[[], str]
                      ) -> str:
    """Atomically write an exposition to ``path``; returns the path."""
    text = (text_or_render() if callable(text_or_render)
            else text_or_render)
    parent = os.path.dirname(os.path.abspath(path)) or "."
    os.makedirs(parent, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path


def export_metrics_at_exit(path: str, render: Callable[[], str]) -> None:
    """Register an atexit hook dumping the final exposition to ``path``.

    The headless-run answer to a scrape endpoint: a batch job or CI
    step gets its last metric state on disk without running a server.
    Failures are swallowed — an exporter must never turn a clean exit
    into a traceback.
    """
    import atexit

    def _dump() -> None:
        try:
            write_openmetrics(path, render)
        except Exception:
            pass

    atexit.register(_dump)
