"""The flight-recorder span tracer: a bounded, thread-safe event ring.

FLOWER's users lean on the HLS toolchain's analyzers (Vitis timelines,
latency reports) to see *where* a design spends its time; this module
is that feedback channel for the reproduction.  A :class:`Tracer`
records timestamped span events into a bounded ring buffer — when the
ring is full the **oldest events are dropped** (a flight recorder
keeps the most recent history; it never blocks or grows without
bound) — and the exporter (:mod:`repro.obs.export`) turns the ring
into a Chrome trace-event JSON that Perfetto loads directly.

Three recording idioms, matching how the stack is instrumented:

- ``with tracer.span("compile.lower", backend="pallas"):`` — a
  thread-scoped duration span (Chrome ``B``/``E`` pair).  Spans on one
  thread nest LIFO, so the pairs always match.  ``span(...)`` returns
  a context object whose :meth:`~_SpanCtx.set` adds result attributes
  that are recorded on exit (e.g. the tile a sweep chose).
- ``tok = tracer.begin("execute"); ...; tracer.end(tok)`` — an
  explicit begin/end pair for spans that *cross threads* (begun on a
  submitter, ended on the worker).  Recorded as one Chrome complete
  (``X``) event at ``end`` time, so it can never produce an unmatched
  ``B``/``E``.
- ``tracer.async_event("queue_wait", ph="b", aid=trace_id, ts=t0)`` —
  retroactive per-request phase spans keyed by a trace id (Chrome
  async ``b``/``e``).  The serving engine emits each request's whole
  submit→complete timeline at retirement, from timestamps captured on
  the hot path — the recording itself never sits on that path.

**Cost discipline.**  A disabled tracer (``enabled=False``) returns a
shared no-op context from ``span`` and early-outs of every record
method — a couple of attribute loads, no allocation, no lock.  Code on
hot paths guards with ``if tracer is not None`` so the off-by-default
engine pays literally nothing (asserted by tests/test_obs.py).

The module also owns the process-global tracer used by the ``--trace``
benchmark flags and the ``REPRO_TRACE`` environment variable:
:func:`install` / :func:`get_tracer` / :func:`resolve_tracer`.  When
``REPRO_TRACE`` is set to a path, the global tracer auto-exports there
at interpreter exit.

This module imports nothing from the rest of the repo — any layer
(core, runtime, tune) can depend on it without cycles.
"""
from __future__ import annotations

import os
import threading
import time
from collections import deque
from typing import Any

__all__ = ["Event", "Tracer", "install", "uninstall", "get_tracer",
           "resolve_tracer", "maybe_span", "TRACE_ENV"]

#: environment variable that enables the process-global tracer; set it
#: to ``1`` to record, or to a ``.json`` path to also auto-export a
#: Chrome trace at interpreter exit
TRACE_ENV = "REPRO_TRACE"

#: default ring capacity (events, not spans; a B/E span is two events)
DEFAULT_CAPACITY = 1 << 16


class Event:
    """One recorded trace event (a slot of the ring buffer).

    ``ph`` is the Chrome trace-event phase: ``B``/``E`` thread-scoped
    span begin/end, ``X`` complete (with ``dur``), ``b``/``e`` async
    span keyed by ``aid``, ``i`` instant, ``C`` counter sample.
    Timestamps are ``time.perf_counter()`` seconds.
    """

    __slots__ = ("ph", "name", "cat", "ts", "dur", "tid", "aid", "args",
                 "seq")

    def __init__(self, ph: str, name: str, cat: str, ts: float,
                 dur: float | None, tid: int, aid: int | None,
                 args: dict[str, Any] | None, seq: int):
        self.ph = ph
        self.name = name
        self.cat = cat
        self.ts = ts
        self.dur = dur
        self.tid = tid
        self.aid = aid
        self.args = args
        self.seq = seq

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Event({self.ph!r}, {self.name!r}, ts={self.ts:.6f}, "
                f"tid={self.tid}, aid={self.aid})")


class _NoopSpan:
    """Shared do-nothing context manager for disabled tracers."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc: Any) -> None:
        return None

    def set(self, **attrs: Any) -> "_NoopSpan":
        return self


_NOOP = _NoopSpan()


class _SpanCtx:
    """Context manager for one thread-scoped B/E span."""

    __slots__ = ("_tracer", "_name", "_cat", "_attrs", "_exit_attrs")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 attrs: dict[str, Any] | None):
        self._tracer = tracer
        self._name = name
        self._cat = cat
        self._attrs = attrs
        self._exit_attrs: dict[str, Any] | None = None

    def set(self, **attrs: Any) -> "_SpanCtx":
        """Attach result attributes, recorded on the span's E event."""
        if self._exit_attrs is None:
            self._exit_attrs = attrs
        else:
            self._exit_attrs.update(attrs)
        return self

    def __enter__(self) -> "_SpanCtx":
        self._tracer._emit("B", self._name, self._cat,
                           time.perf_counter(), None,
                           threading.get_ident(), None, self._attrs)
        return self

    def __exit__(self, *exc: Any) -> None:
        self._tracer._emit("E", self._name, self._cat,
                           time.perf_counter(), None,
                           threading.get_ident(), None, self._exit_attrs)


class _Token:
    """Handle for an explicit cross-thread begin/end span."""

    __slots__ = ("name", "cat", "ts", "tid", "attrs")

    def __init__(self, name: str, cat: str, ts: float, tid: int,
                 attrs: dict[str, Any] | None):
        self.name = name
        self.cat = cat
        self.ts = ts
        self.tid = tid
        self.attrs = attrs


class Tracer:
    """Thread-safe bounded-ring span recorder (the flight recorder).

    ``capacity`` bounds the event ring: when full, the **oldest**
    events are evicted (``dropped`` counts them) and recording never
    blocks.  ``enabled=False`` makes every recording method a cheap
    no-op — the object can stay wired into an engine at zero cost and
    be flipped on later.

    >>> tr = Tracer(capacity=128)
    >>> with tr.span("work", cat="demo", n=3) as sp:
    ...     _ = sp.set(result="ok")
    >>> [e.ph for e in tr.events()]
    ['B', 'E']
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 enabled: bool = True):
        if capacity < 2:
            raise ValueError(f"capacity must be >= 2, got {capacity}")
        self.capacity = capacity
        self.enabled = enabled
        self.dropped = 0
        self._events: deque[Event] = deque(maxlen=capacity)
        self._threads: dict[int, str] = {}
        self._lock = threading.Lock()
        self._seq = 0
        self._next_id = 0
        self._t0 = time.perf_counter()

    # ------------------------------------------------------------------
    # recording
    # ------------------------------------------------------------------
    def _emit(self, ph: str, name: str, cat: str, ts: float,
              dur: float | None, tid: int, aid: int | None,
              args: dict[str, Any] | None) -> None:
        with self._lock:
            if tid not in self._threads:
                self._threads[tid] = threading.current_thread().name
            if len(self._events) == self.capacity:
                self.dropped += 1
            self._events.append(Event(ph, name, cat, ts, dur, tid, aid,
                                      args, self._seq))
            self._seq += 1

    def span(self, name: str, cat: str = "span", **attrs: Any):
        """Thread-scoped duration span as a ``with`` context."""
        if not self.enabled:
            return _NOOP
        return _SpanCtx(self, name, cat, attrs or None)

    def begin(self, name: str, cat: str = "span",
              **attrs: Any) -> _Token | None:
        """Open an explicit span; :meth:`end` may run on ANY thread.

        Returns an opaque token (``None`` when disabled — ``end``
        accepts it).  The span is recorded as a single complete event
        at ``end`` time, attributed to the *beginning* thread.
        """
        if not self.enabled:
            return None
        return _Token(name, cat, time.perf_counter(),
                      threading.get_ident(), attrs or None)

    def end(self, token: _Token | None, **attrs: Any) -> None:
        """Close an explicit span opened by :meth:`begin`."""
        if token is None or not self.enabled:
            return
        if attrs:
            merged = dict(token.attrs or {})
            merged.update(attrs)
        else:
            merged = token.attrs
        now = time.perf_counter()
        self._emit("X", token.name, token.cat, token.ts,
                   max(0.0, now - token.ts), token.tid, None, merged)

    def complete(self, name: str, ts: float, dur: float,
                 cat: str = "span", tid: int | None = None,
                 **attrs: Any) -> None:
        """Record a retroactive complete (``X``) span from timestamps."""
        if not self.enabled:
            return
        self._emit("X", name, cat, ts, max(0.0, dur),
                   tid if tid is not None else threading.get_ident(),
                   None, attrs or None)

    def async_event(self, name: str, ph: str, aid: int,
                    ts: float | None = None, cat: str = "async",
                    **attrs: Any) -> None:
        """Record one async (``b``/``e``) event keyed by ``aid``.

        Async spans tie events on different threads (or emitted
        retroactively) into one timeline track — the engine uses the
        request's trace id as ``aid`` so every phase of one request
        lands on one Perfetto row.
        """
        if not self.enabled:
            return
        if ph not in ("b", "e"):
            raise ValueError(f"async phase must be 'b' or 'e', got {ph!r}")
        self._emit(ph, name, cat,
                   ts if ts is not None else time.perf_counter(),
                   None, threading.get_ident(), aid, attrs or None)

    def async_span(self, name: str, aid: int, t0: float, t1: float,
                   cat: str = "async", **attrs: Any) -> None:
        """Record a retroactive async span ``[t0, t1]`` in one call."""
        if not self.enabled:
            return
        tid = threading.get_ident()
        self._emit("b", name, cat, t0, None, tid, aid, attrs or None)
        self._emit("e", name, cat, max(t0, t1), None, tid, aid, None)

    def instant(self, name: str, cat: str = "span", **attrs: Any) -> None:
        """Record a zero-duration instant event."""
        if not self.enabled:
            return
        self._emit("i", name, cat, time.perf_counter(), None,
                   threading.get_ident(), None, attrs or None)

    def counter(self, name: str, value: float, cat: str = "metric") -> None:
        """Record a counter sample (rendered as a track by Perfetto)."""
        if not self.enabled:
            return
        self._emit("C", name, cat, time.perf_counter(), None,
                   threading.get_ident(), None, {"value": value})

    def new_id(self) -> int:
        """Allocate a fresh trace id (per-request correlation key)."""
        with self._lock:
            self._next_id += 1
            return self._next_id

    # ------------------------------------------------------------------
    # inspection
    # ------------------------------------------------------------------
    def events(self) -> list[Event]:
        """Snapshot of the ring, oldest first."""
        with self._lock:
            return list(self._events)

    def thread_names(self) -> dict[int, str]:
        with self._lock:
            return dict(self._threads)

    def __len__(self) -> int:
        return len(self._events)

    def __bool__(self) -> bool:
        # without this, __len__ makes an *empty* tracer falsy, so
        # `tracer or default` silently discards a live recorder
        return True

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self.dropped = 0


# ----------------------------------------------------------------------
# the process-global tracer (``--trace`` flags, $REPRO_TRACE)
# ----------------------------------------------------------------------
_GLOBAL: Tracer | None = None
_ENV_CHECKED = False


def install(tracer: Tracer | None = None) -> Tracer:
    """Install (and return) the process-global tracer.

    Components that resolve their ``trace`` argument through
    :func:`resolve_tracer` (the serving engine, ``compile_graph``)
    pick it up automatically — this is how ``benchmarks/run.py
    --trace out.json`` traces every layer without threading a tracer
    through each call site.
    """
    global _GLOBAL
    _GLOBAL = tracer if tracer is not None else Tracer()
    return _GLOBAL


def uninstall() -> None:
    global _GLOBAL, _ENV_CHECKED
    _GLOBAL = None
    _ENV_CHECKED = True          # do not resurrect from the env var


def get_tracer() -> Tracer | None:
    """The installed global tracer, creating one if ``$REPRO_TRACE`` asks.

    When ``REPRO_TRACE`` names a ``.json`` path, the trace is exported
    there automatically at interpreter exit (flight-recorder dump).
    """
    global _GLOBAL, _ENV_CHECKED
    if _GLOBAL is not None:
        return _GLOBAL
    if _ENV_CHECKED:
        return None
    _ENV_CHECKED = True
    val = os.environ.get(TRACE_ENV, "").strip()
    if not val or val.lower() in ("0", "false", "off"):
        return None
    _GLOBAL = Tracer()
    if val.lower() not in ("1", "true", "on", "yes"):
        import atexit

        def _dump(path: str = val, tracer: Tracer = _GLOBAL) -> None:
            from repro.obs.export import export_chrome_trace
            try:
                export_chrome_trace(tracer, path)
            except OSError:  # pragma: no cover - exit-time best effort
                pass

        atexit.register(_dump)
    return _GLOBAL


def resolve_tracer(trace: Any) -> Tracer | None:
    """Normalize a user-facing ``trace=`` argument into a tracer.

    ``None`` consults the process-global tracer (``install`` /
    ``$REPRO_TRACE``) so tracing can be switched on for a whole run
    without touching call sites; ``False`` opts a component out even
    then; ``True`` builds a private enabled tracer; a :class:`Tracer`
    passes through (disabled tracers resolve to ``None`` so guarded
    hot paths skip even the no-op calls).
    """
    if trace is None:
        trace = get_tracer()
    elif trace is True:
        trace = Tracer()
    elif trace is False:
        return None
    if trace is None:
        return None
    if not isinstance(trace, Tracer):
        raise TypeError(f"trace must be a Tracer, True/False or None; "
                        f"got {type(trace).__name__}")
    return trace if trace.enabled else None


def maybe_span(tracer: Tracer | None, name: str, cat: str = "span",
               **attrs: Any):
    """``tracer.span(...)`` or a shared no-op when ``tracer`` is None."""
    if tracer is None:
        return _NOOP
    return tracer.span(name, cat, **attrs)
