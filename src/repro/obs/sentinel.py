"""DriftSentinel: the staleness policy that closes the refit loop.

PR 9 taught the repo to *fit* the cost model from drift logs
(:mod:`repro.tune.calibrate`); what it left open — the explicit
remainder of ROADMAP item 3 — is **when**: nothing watched the
accumulating rows and decided that the :class:`CalibratedSpec`
serving ``compile_graph(calibrate="auto")`` no longer predicts this
machine.  :class:`DriftSentinel` is that watcher.

It consumes a rolling window of :class:`~repro.obs.drift.DriftLog`
rows belonging to one backend digest (rows carry a ``backend_key``
attr since this PR; older rows match by backend name) and one device
kind, re-scores them under the **active** fit via the existing
:func:`~repro.obs.drift.drift_report` machinery, and flags the fit
stale when any of:

- **correlation decay** — Spearman of re-scored-vs-measured drops
  below ``min_spearman`` (the model misorders workloads again),
- **bias drift** — ``|log10(median measured/modeled)|`` exceeds
  ``max_abs_log10_bias`` (the machine got systematically faster or
  slower: thermal state, contention, interpreter-vs-jit),
- **accumulation** — at least ``refit_rows`` new rows arrived since
  the sentinel's last fit (fresh evidence deserves a fresh fit),
- **no usable fit** — the store holds nothing non-stale for this
  (backend, device kind), which is also how a *device-kind change*
  presents: the store is keyed by device kind, so moving the same
  drift log to a different host makes the active fit vanish rather
  than silently mispredict.

On staleness it marks the superseded record stale in the *versioned*
:class:`~repro.tune.calibrate.CalibrationStore` (kept, not deleted),
runs :func:`~repro.tune.calibrate.calibrate` on the window, and
persists the new fit as the next version — after which
``compile_graph(calibrate="auto")`` resolves the refreshed spec with
no manual step.  :meth:`poll` is the rate-limited entry point the
:class:`~repro.runtime.engine.StreamEngine` calls from its worker
loop; checks and refits are counted in the metrics registry and
emitted as Tracer instants, so the whole loop is visible in the same
telemetry plane it feeds.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any

import numpy as np

from repro.obs.drift import DriftLog, DriftRow, drift_report, resolve_drift

__all__ = ["DriftSentinel", "SentinelPolicy"]


@dataclasses.dataclass(frozen=True)
class SentinelPolicy:
    """Staleness thresholds; ``None`` disables a trigger.

    >>> SentinelPolicy(refit_rows=32).refit_rows
    32
    """

    #: re-scored Spearman below this flags correlation decay
    min_spearman: float | None = 0.8
    #: ``|log10 bias|`` of re-scored predictions above this flags drift
    max_abs_log10_bias: float | None = 0.15
    #: this many new rows since the sentinel's last fit forces a refit
    refit_rows: int | None = 64
    #: rolling window: only the newest N matching rows are scored
    window: int = 256
    #: below this many windowed rows the sentinel stays quiet
    min_rows: int = 8
    #: :meth:`DriftSentinel.poll` rate limit (seconds)
    min_interval_s: float = 5.0


class DriftSentinel:
    """Watch one backend's drift window; refit when the fit goes stale.

    ``drift`` follows the :func:`~repro.obs.drift.resolve_drift`
    protocol (log / path / True); ``backend`` anything
    :func:`repro.backends.resolve` accepts.  ``store`` defaults to the
    process-wide :class:`~repro.tune.calibrate.CalibrationStore`, and
    ``device_kind`` pins the store key (default: detected, and
    re-detected on every check so a device-kind change is noticed).
    """

    def __init__(self, drift: Any, backend: Any = "pallas", *,
                 store: Any = None, device_kind: str | None = None,
                 policy: SentinelPolicy | None = None,
                 exclude_kinds: tuple[str, ...] = ("compile",),
                 registry: Any = None, tracer: Any = None):
        from repro.backends import resolve
        from repro.tune.calibrate import CalibrationStore
        log = resolve_drift(drift)
        if log is None:
            raise ValueError("DriftSentinel needs a drift log "
                             "(got drift=None/False)")
        self.drift: DriftLog = log
        self.backend = resolve(backend)
        self.backend_key = self.backend.cache_key()
        self.store = store if store is not None else CalibrationStore()
        self._pinned_kind = device_kind
        self.device_kind = (device_kind if device_kind is not None
                            else self._detect_kind())
        self.policy = policy if policy is not None else SentinelPolicy()
        self.exclude_kinds = tuple(exclude_kinds)
        self.registry = registry
        self.tracer = tracer
        self.checks = 0
        self.refits = 0
        #: row count of the window at the sentinel's last successful fit
        self._rows_at_fit = 0
        self._last_poll_t: float | None = None
        self.last_check: dict[str, Any] | None = None
        self.last_refit: Any = None
        self._lock = threading.Lock()

    @staticmethod
    def _detect_kind() -> str:
        from repro.tune.store import detect_device_kind
        return detect_device_kind()

    # -- the window ----------------------------------------------------
    def _matches(self, r: DriftRow) -> bool:
        key = r.attrs.get("backend_key")
        if key is not None:
            return key == self.backend_key
        return r.backend == self.backend.name   # pre-PR-10 rows

    def window_rows(self) -> list[DriftRow]:
        """The newest ``policy.window`` usable rows for this backend."""
        rows = [r for r in self.drift.rows()
                if self._matches(r) and r.kind not in self.exclude_kinds
                and np.isfinite(r.measured_s) and r.measured_s > 0]
        return rows[-self.policy.window:]

    # -- staleness check -----------------------------------------------
    def check(self, now: float | None = None) -> dict[str, Any]:
        """Score the window against the active fit; list stale reasons.

        Returns ``{"stale", "reasons", "n_rows", "n_new", "active_seq",
        "spearman", "log10_bias", "device_kind", "report"}``.  A short
        window (< ``policy.min_rows``) is never stale — the sentinel
        refuses to act on noise.
        """
        t = now if now is not None else time.time()
        pol = self.policy
        with self._lock:
            self.checks += 1
            if self._pinned_kind is None:
                kind = self._detect_kind()
                if kind != self.device_kind:
                    self.device_kind = kind
            rows = self.window_rows()
            n = len(rows)
            n_new = n - self._rows_at_fit
            active_raw = self.store.latest(self.backend_key,
                                           self.device_kind)
            active = self.store.get(self.backend_key, self.device_kind)
            reasons: list[str] = []
            spear = bias = None
            report: dict[str, Any] = {}
            if n >= pol.min_rows:
                report = drift_report(rows, spec=active)
                stats = report["with_spec"] if active is not None else report
                spear = stats.get("spearman")
                bias = stats.get("log10_bias")
                if active is None:
                    reasons.append("uncalibrated")
                else:
                    if (pol.min_spearman is not None and spear is not None
                            and np.isfinite(spear)
                            and spear < pol.min_spearman):
                        reasons.append("spearman")
                    if (pol.max_abs_log10_bias is not None
                            and bias is not None and np.isfinite(bias)
                            and abs(bias) > pol.max_abs_log10_bias):
                        reasons.append("bias")
                    if (pol.refit_rows is not None
                            and n_new >= pol.refit_rows):
                        reasons.append("new_rows")
            out = {
                "stale": bool(reasons), "reasons": reasons,
                "n_rows": n, "n_new": n_new,
                "active_seq": (active_raw or {}).get("seq"),
                "spearman": spear, "log10_bias": bias,
                "device_kind": self.device_kind,
                "report": report,
            }
            self.last_check = out
        reg = self.registry
        if reg is not None:
            reg.counter("sentinel_checks").inc()
            if reasons:
                reg.counter("sentinel_stale").inc()
            reg.gauge("sentinel_rows").set(float(n))
            if spear is not None and np.isfinite(spear):
                reg.gauge("sentinel_spearman").set(float(spear))
            if bias is not None and np.isfinite(bias):
                reg.gauge("sentinel_log10_bias").set(float(bias))
        if reasons and self.tracer is not None:
            self.tracer.instant("sentinel.stale", cat="sentinel", ts=t,
                                reasons=",".join(reasons), rows=n)
        return out

    # -- refit ---------------------------------------------------------
    def refit(self, reasons: tuple[str, ...] = ()) -> Any:
        """Mark the decayed fit stale, fit the window, persist a new
        version.  Returns the :class:`CalibrationResult` (``fitted``
        False means the window could not identify the constants — the
        stale mark still protects ``calibrate="auto"`` from the bad
        fit)."""
        from repro.tune.calibrate import calibrate
        with self._lock:
            rows = self.window_rows()
            if {"spearman", "bias"} & set(reasons):
                # the active fit demonstrably mispredicts: retire it
                # even if the refit below falls back
                self.store.mark_stale(self.backend_key, self.device_kind)
            result = calibrate(rows, spec=self.backend.spec,
                               min_rows=self.policy.min_rows,
                               exclude_kinds=self.exclude_kinds)
            if result.fitted:
                self.store.put(self.backend_key, self.device_kind,
                               result.spec, result=result)
                self._rows_at_fit = len(rows)
                self.refits += 1
            self.last_refit = result
        reg = self.registry
        if reg is not None:
            reg.counter("sentinel_refits" if result.fitted
                        else "sentinel_refit_failures").inc()
        if self.tracer is not None:
            self.tracer.instant("sentinel.refit", cat="sentinel",
                                fitted=result.fitted,
                                rows=result.n_rows,
                                reasons=",".join(reasons))
        return result

    def poll(self, now: float | None = None) -> dict[str, Any] | None:
        """Rate-limited check-and-refit for a worker loop.

        Returns the check dict (with ``refit`` attached when one ran),
        or ``None`` when called again inside ``min_interval_s``.
        """
        t = now if now is not None else time.time()
        with self._lock:
            last = self._last_poll_t
            if last is not None and (t - last) < self.policy.min_interval_s:
                return None
            self._last_poll_t = t
        out = self.check(now=t)
        if out["stale"]:
            result = self.refit(tuple(out["reasons"]))
            out["refit"] = {"fitted": result.fitted,
                            "n_rows": result.n_rows,
                            "warning": result.warning}
        return out
