"""Int8 gradient compression with error feedback.

Large-scale DP sync trick: quantize gradients to int8 (per-tensor
scale) before the cross-pod reduction, keep the quantization error in
a local buffer and add it back next step (error feedback), so the
optimizer sees an unbiased long-run gradient.  4x fewer bytes on the
slowest (pod-level DCN) axis.

Pure functions so the train step stays jit-able; the error buffers are
part of the optimizer state tree (same sharding as grads).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["ef_init", "compress", "decompress", "ef_roundtrip"]


def ef_init(grads_like: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_like)


def compress(g: jnp.ndarray, err: jnp.ndarray
             ) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """g + err -> (int8 q, scale, new_err)."""
    corrected = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
    new_err = corrected - q.astype(jnp.float32) * scale
    return q, scale, new_err


def decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_roundtrip(grads: Any, err_state: Any) -> tuple[Any, Any]:
    """Compress+decompress every leaf (the collective runs on the int8
    payload in the real pipeline; on the dry-run mesh XLA sees the int8
    all-reduce via the cast placement).  Returns (grads', new_err)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    outs = []
    errs = []
    for g, e in zip(flat_g, flat_e):
        q, s, ne = compress(g, e)
        outs.append(decompress(q, s).astype(g.dtype))
        errs.append(ne)
    return (jax.tree.unflatten(treedef, outs),
            jax.tree.unflatten(treedef, errs))
