"""AdamW with fp32 master weights, global-norm clipping, LR schedules.

Functional (optax-style but dependency-free): ``init`` builds the
state tree (master, m, v — all the same structure as params, so the
FSDP sharding rules apply verbatim), ``apply`` returns updated
(params, state).  bf16 params are re-cast from the fp32 master every
step, the standard large-scale recipe.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_apply", "lr_schedule",
           "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup then cosine decay to lr_min."""
    step = step.astype(jnp.float32)
    warm = cfg.lr_peak * step / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) \
        * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Any, max_norm: float
                        ) -> tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_init(params: Any) -> dict:
    # copy=True: when params are already f32, astype would alias the
    # buffer and donation of (params, master) would double-donate.
    f32 = lambda t: jax.tree.map(
        lambda x: jnp.array(x, jnp.float32, copy=True), t)
    zeros = lambda t: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return {"master": f32(params), "m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_apply(cfg: AdamWConfig, params: Any, grads: Any, state: dict
                ) -> tuple[Any, dict, dict]:
    """Returns (new_params (model dtype), new_state, metrics)."""
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, mst, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        new = mst - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * mst)
        return new, m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_mst = jax.tree.leaves(state["master"])
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(g, mst, m, v)
           for g, mst, m, v in zip(flat_g, flat_mst, flat_m, flat_v)]
    new_master = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_params = jax.tree.map(
        lambda new, old: new.astype(old.dtype), new_master, params)
    new_state = {"master": new_master, "m": new_m, "v": new_v,
                 "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
