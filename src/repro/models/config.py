"""Model / run configuration dataclasses.

One :class:`ModelConfig` per assigned architecture lives in
``repro/configs/<id>.py``; :class:`ShapeConfig` describes the four
assigned input shapes.  Configs are plain frozen dataclasses so they
hash into jit static args cleanly.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01

    # --- MLA (minicpm3) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 32            # decoupled RoPE dims for MLA

    # --- SSM (mamba2 / hybrid) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_chunk: int = 128
    conv_width: int = 4

    # --- hybrid (zamba2): shared attention block every k layers ---
    attn_every: int = 0

    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0

    # --- modality frontend stubs ---
    frontend: str | None = None        # "audio" | "vision"
    n_frontend_tokens: int = 0         # frames / patches provided by stub

    # --- misc architecture ---
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # --- runtime knobs (not architecture) ---
    dtype: str = "bfloat16"
    remat: str = "dots"                # "none" | "dots" | "full"
    attn_impl: str = "auto"            # ops.py impl selector
    attn_chunk: int = 0                # 0 = unchunked reference attention
    attn_unroll: bool = False          # unroll the KV-chunk scan (calibration)
    microbatches: int = 1              # gradient-accumulation factor
    scan_layers: bool = True
    # --- perf knobs (EXPERIMENTS.md §Perf) ---
    kv_repeat_to: int = 0              # replicate KV heads up to the TP
                                       # width so the cache arg shards
                                       # evenly (kills decode gathers)
    moe_groups: int = 0                # dispatch groups (0 = per batch
                                       # row; 1 = one global group —
                                       # right for decode)
    mla_absorb: str = "decode"         # "decode" | "always": absorbed
                                       # MLA only where it wins

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:          # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch run the long_500k shape? (assignment rule)"""
        return self.family in ("ssm", "hybrid")

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, ff, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        hd, Hq, Hkv = self.hd, self.n_heads, self.n_kv_heads
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_attn = d * Hq * hd + 2 * d * Hkv * hd + Hq * hd * d
        if self.use_mla:
            r, kr = self.kv_lora_rank, self.rope_head_dim
            qr = self.q_lora_rank or d
            per_attn = (d * qr + qr * Hq * (hd + kr)      # q down/up
                        + d * (r + kr)                     # kv down + rope k
                        + r * Hq * 2 * hd                  # kv up (k_nope, v)
                        + Hq * hd * d)                     # o
        per_mlp = 3 * d * ff
        if self.n_experts:
            per_mlp = per_mlp * self.n_experts + d * self.n_experts
        per_norms = 2 * d
        per_layer = per_attn + per_mlp + per_norms
        if self.family in ("ssm", "hybrid"):
            di, n, g = self.d_inner, self.ssm_state, self.ssm_groups
            H = self.ssm_heads
            per_mamba = (d * (2 * di + 2 * g * n + H)      # in_proj
                         + self.conv_width * (di + 2 * g * n)
                         + di * d + di + 2 * H + d)        # out_proj, norms, A, D
            if self.family == "ssm":
                per_layer = per_mamba
            else:
                shared_attn = per_attn + per_mlp + per_norms
                n_sites = L // self.attn_every if self.attn_every else 0
                return emb + L * per_mamba + shared_attn + d + n_sites * 0
        total = emb + L * per_layer + d
        if self.n_enc_layers:
            total += self.n_enc_layers * per_layer
        return total

    def n_active_params(self) -> int:
        """Active (per-token) parameter count — differs for MoE."""
        if not self.n_experts:
            return self.n_params()
        d, ff = self.d_model, self.d_ff
        dense_mlp = 3 * d * ff
        moe_mlp = dense_mlp * self.n_experts
        active_mlp = dense_mlp * self.experts_per_token
        return self.n_params() - self.n_layers * (moe_mlp - active_mlp)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
