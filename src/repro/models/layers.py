"""Model building blocks: declarative params, attention (GQA/MQA/MLA),
SwiGLU MLP, MoE, Mamba2 — all functional, all shardable.

Parameters are declared with :class:`ParamDef` (shape + logical axes +
init law); ``init_tree``/``axes_tree`` derive the value tree and the
logical-sharding tree from the *same* declaration, so parameter and
sharding structure cannot drift apart.  Logical axes are mapped to mesh
axes by :mod:`repro.parallel.sharding`.

Activation sharding uses :func:`shard_act`, which consults a context
set by the launcher (no-op outside a mesh) — the model code itself
stays mesh-agnostic, the FLOWER "single source" rule at cluster scale.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.backends import use_pallas_kernels
from repro.kernels import ops
from repro.models.config import ModelConfig

__all__ = [
    "ParamDef", "init_tree", "axes_tree", "shard_act", "activation_rules",
    "rmsnorm", "rope", "embed_tokens", "unembed", "attention_block",
    "mlp_block", "moe_block", "mamba2_block", "attention_xla",
    "decode_attn_cache", "mamba2_decode_step", "softmax_cross_entropy",
]

# ----------------------------------------------------------------------
# declarative parameters
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"        # normal | zeros | ones | ssm_a | dt_bias
    scale: float | None = None  # stddev override (default: 1/sqrt(fan_in))

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def init_tree(defs: Any, rng: jax.Array, dtype: Any) -> Any:
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(rng, len(leaves))
    vals = [_init_one(d, k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def axes_tree(defs: Any) -> Any:
    return jax.tree.map(lambda d: d.axes, defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))


def _init_one(d: ParamDef, key: jax.Array, dtype: Any) -> jnp.ndarray:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "ssm_a":     # A = -uniform[1, 16)  (mamba2 init)
        u = jax.random.uniform(key, d.shape, jnp.float32, 1.0, 16.0)
        return (-u).astype(jnp.float32)           # A kept in f32
    if d.init == "dt_bias":   # softplus^-1(uniform[1e-3, 1e-1])
        u = jax.random.uniform(key, d.shape, jnp.float32, 1e-3, 1e-1)
        return jnp.log(jnp.expm1(u)).astype(jnp.float32)
    fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
    std = d.scale if d.scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(dtype)


# ----------------------------------------------------------------------
# activation sharding hook
# ----------------------------------------------------------------------
_ACT_RULES = threading.local()


@contextlib.contextmanager
def activation_rules(rules: Callable[[jnp.ndarray, tuple], jnp.ndarray]):
    """Launcher installs a fn(x, logical_axes) -> x (sharding constraint)."""
    prev = getattr(_ACT_RULES, "fn", None)
    _ACT_RULES.fn = rules
    try:
        yield
    finally:
        _ACT_RULES.fn = prev


def shard_act(x: jnp.ndarray, axes: tuple[str | None, ...]) -> jnp.ndarray:
    fn = getattr(_ACT_RULES, "fn", None)
    return fn(x, axes) if fn is not None else x


# ----------------------------------------------------------------------
# primitives
# ----------------------------------------------------------------------
def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float) -> jnp.ndarray:
    return ops.rmsnorm(x, w, eps)


def rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D) or (..., H, D) with matching pos (..., S)/scalar."""
    D = x.shape[-1]
    half = D // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[..., None].astype(jnp.float32) * freqs      # (..., S, half)
    ang = ang[..., None, :]                               # broadcast heads
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def embed_tokens(emb: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    return shard_act(emb[tokens], ("batch", "seq", None))


def unembed(x: jnp.ndarray, head: jnp.ndarray) -> jnp.ndarray:
    logits = jnp.einsum("...d,dv->...v", x.astype(jnp.float32),
                        head.astype(jnp.float32))
    return shard_act(logits, ("batch", "seq", "vocab"))


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray
                          ) -> jnp.ndarray:
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return lse - gold


# ----------------------------------------------------------------------
# attention (XLA streaming form == the dataflow transformation in HLO)
# ----------------------------------------------------------------------
def attention_xla(q, k, v, bias=None, causal=True, chunk: int = 0,
                  impl: str = "auto", scale: float | None = None,
                  unroll: bool = False):
    """Dispatch: Pallas flash kernel, chunked-scan XLA (same dataflow,
    lowerable on any backend), or naive reference."""
    Sk = k.shape[2]
    # auto_native=False: only an EXPLICIT pallas request takes the
    # kernel path here — "auto" prefers the chunked XLA scan below,
    # which is portable and structurally the same dataflow
    if use_pallas_kernels(impl, auto_native=False):
        return ops.attention(q, k, v, bias=bias, causal=causal,
                             impl=impl, scale=scale)
    if chunk and Sk > chunk:
        return _chunked_attention(q, k, v, bias, causal, chunk, scale,
                                  unroll)
    from repro.kernels.ref import flash_attention_ref
    return flash_attention_ref(q, k, v, bias=bias, causal=causal,
                               scale=scale)


def _chunked_attention(q, k, v, bias, causal, chunk, scale=None,
                       unroll=False):
    """Online-softmax scan over KV blocks — the flash dataflow in pure
    lax (one KV block in "VMEM" per step; (Sq,Sk) never materializes)."""
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    Dv = v.shape[-1]
    G = Hq // Hkv
    offs = Sk - Sq  # query positions sit at the end of the kv stream
    pad = (-Sk) % chunk
    if pad:         # ragged KV (e.g. whisper's 1500 frames): mask pads
        if bias is None:
            bias = jnp.zeros((B, Sk), jnp.float32)
        bias = jnp.pad(bias.astype(jnp.float32), ((0, 0), (0, pad)),
                       constant_values=-1e30)
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        Sk += pad
    nk = Sk // chunk
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    qf = q.astype(jnp.float32) * scale

    kc = jnp.moveaxis(k.reshape(B, Hkv, nk, chunk, D), 2, 0)
    vc = jnp.moveaxis(v.reshape(B, Hkv, nk, chunk, Dv), 2, 0)
    bc = (jnp.moveaxis(bias.reshape(B, nk, chunk), 1, 0)
          if bias is not None else jnp.zeros((nk, B, chunk), jnp.float32))

    def step(carry, inp):
        m, l, acc = carry
        kb, vb, bb, ki = inp
        kb = jnp.repeat(kb, G, axis=1) if G > 1 else kb
        vb = jnp.repeat(vb, G, axis=1) if G > 1 else vb
        logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kb.astype(jnp.float32))
        logits += bb[:, None, None, :].astype(jnp.float32)
        if causal:
            qpos = jnp.arange(Sq)[:, None] + offs
            kpos = ki * chunk + jnp.arange(chunk)[None, :]
            logits = jnp.where(kpos <= qpos, logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        p = jnp.exp(logits - m_new[..., None])
        p = jnp.where(m_new[..., None] > -5e29, p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hq, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hq, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hq, Sq, Dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (kc, vc, bc, jnp.arange(nk)),
        unroll=nk if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


# ----------------------------------------------------------------------
# attention block (GQA / MQA; bias optional; KV cache aware)
# ----------------------------------------------------------------------
def attn_defs(cfg: ModelConfig, d_in: int | None = None) -> dict:
    d = d_in or cfg.d_model
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    defs = {
        "ln": ParamDef((d,), ("embed",), "ones"),
        "wq": ParamDef((d, Hq * hd), ("embed", "heads")),
        "wk": ParamDef((d, Hkv * hd), ("embed", "kv_heads")),
        "wv": ParamDef((d, Hkv * hd), ("embed", "kv_heads")),
        "wo": ParamDef((Hq * hd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((Hq * hd,), ("heads",), "zeros")
        defs["bk"] = ParamDef((Hkv * hd,), ("kv_heads",), "zeros")
        defs["bv"] = ParamDef((Hkv * hd,), ("kv_heads",), "zeros")
    return defs


def mla_defs(cfg: ModelConfig) -> dict:
    d, hd, Hq = cfg.d_model, cfg.hd, cfg.n_heads
    r, qr, kr = cfg.kv_lora_rank, cfg.q_lora_rank or cfg.d_model, cfg.rope_head_dim
    return {
        "ln": ParamDef((d,), ("embed",), "ones"),
        "wdq": ParamDef((d, qr), ("embed", None)),
        "q_ln": ParamDef((qr,), (None,), "ones"),
        "wuq": ParamDef((qr, Hq * (hd + kr)), (None, "heads")),
        "wdkv": ParamDef((d, r + kr), ("embed", None)),
        "kv_ln": ParamDef((r,), (None,), "ones"),
        "wuk": ParamDef((r, Hq * hd), (None, "heads")),
        "wuv": ParamDef((r, Hq * hd), (None, "heads")),
        "wo": ParamDef((Hq * hd, d), ("heads", "embed")),
    }


def attention_block(p: dict, cfg: ModelConfig, x: jnp.ndarray,
                    pos: jnp.ndarray, cache: dict | None = None,
                    cache_index: jnp.ndarray | None = None,
                    cross_kv: tuple | None = None,
                    causal: bool = True) -> tuple[jnp.ndarray, dict | None]:
    """Pre-norm attention with residual.  x: (B, S, d).

    cache: {"k","v"} (B, Hkv, S_max, D) — updated at ``cache_index``
    when decoding (S == 1) or filled at prefill.
    cross_kv: (k, v) from the encoder (whisper cross-attention).
    Returns (x + attn_out, new_cache).
    """
    B, S, d = x.shape
    hd, Hq, Hkv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    h = rmsnorm(x, p["ln"], cfg.norm_eps)

    q = h @ p["wq"]
    if "bq" in p:
        q = q + p["bq"]
    q = q.reshape(B, S, Hq, hd)
    q = rope(q, pos, cfg.rope_theta)
    q = shard_act(q, ("batch", "seq", "heads", None))

    if cross_kv is not None:
        k, v = cross_kv                       # (B, Hkv, Senc, D) pre-computed
    else:
        k = h @ p["wk"]
        v = h @ p["wv"]
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
        k = rope(k.reshape(B, S, Hkv, hd), pos, cfg.rope_theta)
        v = v.reshape(B, S, Hkv, hd)
        k = jnp.moveaxis(k, 1, 2)             # (B, Hkv, S, D)
        v = jnp.moveaxis(v, 1, 2)
        if cfg.kv_repeat_to > Hkv and cache is not None:
            # replicate KV heads up to the TP width: the cache argument
            # then shards evenly on its head dim and decode-time cache
            # updates stay local (GQA math is unchanged — each copy
            # serves Hq/kv_repeat_to query heads).
            rep = cfg.kv_repeat_to // Hkv
            k = jnp.repeat(k, rep, axis=1)
            v = jnp.repeat(v, rep, axis=1)
            Hkv = cfg.kv_repeat_to
        k = shard_act(k, ("batch", "kv_heads", "seq", None))
        v = shard_act(v, ("batch", "kv_heads", "seq", None))

    new_cache = cache
    if cache is not None and cross_kv is None:
        ck, cv = cache["k"], cache["v"]
        if getattr(cache_index, "ndim", 0) == 1:
            # per-slot positions (continuous batching): each sequence
            # writes its new KV at its own length.
            upd = jax.vmap(lambda c, x, i: jax.lax.dynamic_update_slice(
                c, x.astype(c.dtype), (0, i, 0)))
            ck = upd(ck, k, cache_index)
            cv = upd(cv, v, cache_index)
        else:
            ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                              (0, 0, cache_index, 0))
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                              (0, 0, cache_index, 0))
        new_cache = {"k": ck, "v": cv}
        if S == 1:                 # decode attends against the cache;
            k, v = ck, cv          # prefill attends against the fresh
                                   # projections (queries start at 0)

    qh = jnp.moveaxis(q, 1, 2)                # (B, Hq, S, D)
    if S == 1:
        Smax = k.shape[2]
        if cross_kv is not None:              # decode x encoder output:
            bias = None                       # every slot is valid
        else:
            idxb = (cache_index[:, None]
                    if getattr(cache_index, "ndim", 0) == 1
                    else cache_index)
            bias = jnp.where(jnp.arange(Smax)[None, :] <= idxb, 0.0,
                             -1e30).astype(jnp.float32)
            bias = jnp.broadcast_to(bias, (B, Smax))
        out = ops.decode_attention(qh[:, :, 0], k, v, bias=bias,
                                   impl=cfg.attn_impl)      # (B, Hq, D)
        out = out.reshape(B, 1, Hq * hd)
    else:
        out = attention_xla(qh, k, v, bias=None, causal=causal,
                            chunk=cfg.attn_chunk, impl=cfg.attn_impl,
                            unroll=cfg.attn_unroll)
        out = jnp.moveaxis(out, 1, 2).reshape(B, S, Hq * hd)
    out = shard_act(out, ("batch", "seq", "heads"))
    return x + (out @ p["wo"]).astype(x.dtype), new_cache


def mla_attention_block(p: dict, cfg: ModelConfig, x: jnp.ndarray,
                        pos: jnp.ndarray, cache: dict | None = None,
                        cache_index: jnp.ndarray | None = None
                        ) -> tuple[jnp.ndarray, dict | None]:
    """Multi-head Latent Attention (MiniCPM3 / DeepSeek-style), absorbed.

    The absorbed form turns MLA into **MQA over the latent cache**: per
    head, q_eff = [W_uk^T q_nope ; q_rope]  (dim r + kr) attends against
    the single shared k_eff = [c_kv ; k_rope], and the per-head value is
    the latent c_kv itself (dim r), up-projected once after attention.
    The KV cache stores r + kr floats per token instead of 2*Hq*hd —
    FLOWER's burst/bundle insight applied to cache traffic — and the
    streaming attention path (chunked scan / flash kernel) applies
    unchanged with Hkv=1, Dk=r+kr, Dv=r.
    """
    B, S, d = x.shape
    hd, Hq = cfg.hd, cfg.n_heads
    r, kr = cfg.kv_lora_rank, cfg.rope_head_dim
    h = rmsnorm(x, p["ln"], cfg.norm_eps)

    cq = rmsnorm(h @ p["wdq"], p["q_ln"], cfg.norm_eps)
    q = (cq @ p["wuq"]).reshape(B, S, Hq, hd + kr)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = rope(q_rope, pos, cfg.rope_theta)

    dkv = h @ p["wdkv"]                        # (B, S, r + kr)
    c_kv = rmsnorm(dkv[..., :r], p["kv_ln"], cfg.norm_eps)
    k_rope = rope(dkv[..., None, r:], pos, cfg.rope_theta)[:, :, 0]

    new_cache = cache
    if cache is not None:
        cc, cr = cache["c_kv"], cache["k_rope"]
        if getattr(cache_index, "ndim", 0) == 1:
            upd = jax.vmap(lambda c, x, i: jax.lax.dynamic_update_slice(
                c, x.astype(c.dtype), (i, 0)))
            cc = upd(cc, c_kv, cache_index)
            cr = upd(cr, k_rope, cache_index)
        else:
            cc = jax.lax.dynamic_update_slice(cc, c_kv.astype(cc.dtype),
                                              (0, cache_index, 0))
            cr = jax.lax.dynamic_update_slice(cr, k_rope.astype(cr.dtype),
                                              (0, cache_index, 0))
        new_cache = {"c_kv": cc, "k_rope": cr}
        if S == 1:
            c_kv, k_rope = cc, cr

    wuk = p["wuk"].reshape(r, Hq, hd)
    wuv = p["wuv"].reshape(r, Hq, hd)
    scale = 1.0 / np.sqrt(hd + kr)
    absorb = cfg.mla_absorb == "always" or S == 1

    if absorb:
        # absorbed: q projected into latent space; MQA over the latent
        # cache.  Optimal at decode (no K/V up-projection per step) but
        # inflates prefill logits flops (contraction over r+kr=288
        # instead of hd+kr=96) — see §Perf.
        q_lat = jnp.einsum("bshd,rhd->bshr", q_nope.astype(jnp.float32),
                           wuk.astype(jnp.float32)).astype(x.dtype)
        q_eff = jnp.concatenate([q_lat, q_rope], -1)       # (B,S,Hq,r+kr)
        q_eff = jnp.moveaxis(q_eff, 1, 2)
        k_eff = jnp.concatenate([c_kv, k_rope], -1)[:, None]
        v_eff = c_kv[:, None]                              # (B,1,Sk,r)
        if S == 1:
            Sk = k_eff.shape[2]
            idxb = (cache_index[:, None]
                    if getattr(cache_index, "ndim", 0) == 1
                    else cache_index)
            bias = jnp.where(jnp.arange(Sk)[None, :] <= idxb, 0.0,
                             -1e30).astype(jnp.float32)
            bias = jnp.broadcast_to(bias, (B, Sk))
            ctx = ops.decode_attention(q_eff[:, :, 0], k_eff, v_eff,
                                       bias=bias, scale=scale,
                                       impl=cfg.attn_impl)
            ctx = ctx[:, None]                             # (B,1,Hq,r)
        else:
            ctx = attention_xla(q_eff, k_eff, v_eff, causal=True,
                                chunk=cfg.attn_chunk, impl=cfg.attn_impl,
                                scale=scale, unroll=cfg.attn_unroll)
            ctx = jnp.moveaxis(ctx, 1, 2)                  # (B,S,Hq,r)
        out = jnp.einsum("bshr,rhd->bshd", ctx.astype(jnp.float32),
                         wuv.astype(jnp.float32))
    else:
        # non-absorbed (train/prefill): up-project K/V once, then
        # standard GQA-style attention with per-head dim hd+kr — 3.4x
        # fewer logits flops than the absorbed form at minicpm3 ranks.
        k_nope = jnp.einsum("btr,rhd->bthd", c_kv.astype(jnp.float32),
                            wuk.astype(jnp.float32)).astype(x.dtype)
        v = jnp.einsum("btr,rhd->bthd", c_kv.astype(jnp.float32),
                       wuv.astype(jnp.float32)).astype(x.dtype)
        Sk = c_kv.shape[1]
        k_rope_h = jnp.broadcast_to(k_rope[:, :, None],
                                    (B, Sk, Hq, kr)).astype(x.dtype)
        k_full = jnp.concatenate([k_nope, k_rope_h], -1)   # (B,Sk,Hq,hd+kr)
        q_full = jnp.concatenate([q_nope.astype(x.dtype), q_rope], -1)
        q_full = jnp.moveaxis(q_full, 1, 2)
        k_full = jnp.moveaxis(k_full, 1, 2)
        v = jnp.moveaxis(v, 1, 2)                          # (B,Hq,Sk,hd)
        ctx = attention_xla(q_full, k_full, v, causal=True,
                            chunk=cfg.attn_chunk, impl=cfg.attn_impl,
                            scale=scale, unroll=cfg.attn_unroll)
        ctx = jnp.moveaxis(ctx, 1, 2)                      # (B,S,Hq,hd)
        out = ctx.astype(jnp.float32)

    out = out.reshape(B, S, Hq * hd).astype(x.dtype)
    out = shard_act(out, ("batch", "seq", "heads"))
    return x + out @ p["wo"], new_cache


# ----------------------------------------------------------------------
# MLP / MoE
# ----------------------------------------------------------------------
def mlp_defs(cfg: ModelConfig, d: int | None = None, ff: int | None = None
             ) -> dict:
    d = d or cfg.d_model
    ff = ff or cfg.d_ff
    return {
        "ln": ParamDef((d,), ("embed",), "ones"),
        "wg": ParamDef((d, ff), ("embed", "ff")),
        "wu": ParamDef((d, ff), ("embed", "ff")),
        "wd": ParamDef((ff, d), ("ff", "embed")),
    }


def mlp_block(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    lead = x.shape
    y = ops.mlp(x, p["ln"], p["wg"], p["wu"], p["wd"], eps=cfg.norm_eps,
                impl=cfg.attn_impl)
    return x + shard_act(y.reshape(lead), ("batch", "seq", None))


def moe_defs(cfg: ModelConfig) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "ln": ParamDef((d,), ("embed",), "ones"),
        "router": ParamDef((d, E), ("embed", None), scale=0.02),
        "wg": ParamDef((E, d, ff), ("experts", "embed", "expert_ff")),
        "wu": ParamDef((E, d, ff), ("experts", "embed", "expert_ff")),
        "wd": ParamDef((E, ff, d), ("experts", "expert_ff", "embed")),
    }


def moe_block(p: dict, cfg: ModelConfig, x: jnp.ndarray
              ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k MoE with batch-grouped capacity dispatch.

    Dispatch is scatter/gather (no (T,E,C) one-hot einsum) and is
    *grouped by batch row*: capacity and ranks are computed per
    sequence, so the dispatch bookkeeping (one-hot cumsum) never
    crosses data shards — tokens stay data-local until the expert
    contraction, whose (group, expert, cap, d) operand is sharded
    batch-over-data x experts-over-model; the expert exchange is the
    only cross-shard hop (XLA lowers it to the MoE all-to-all).
    Overflow beyond capacity is dropped (combine weight zero; the
    residual carries the token) — standard Switch/GShard semantics.

    Returns (x + moe_out, aux_loss).
    """
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    # dispatch groups: per batch row by default (tokens stay
    # data-local); a single global group when S is tiny (decode), so
    # capacity padding doesn't dwarf the active tokens.
    G = cfg.moe_groups or B
    T = (B * S) // G                                     # tokens / group
    cap = int(np.ceil(T * K / E * cfg.capacity_factor))
    cap = max(cap, K)

    h = rmsnorm(x, p["ln"], cfg.norm_eps)                # (B, S, d)
    gates = jax.nn.softmax(
        jnp.einsum("bsd,de->bse", h.astype(jnp.float32),
                   p["router"].astype(jnp.float32)), axis=-1)
    topw, tope = jax.lax.top_k(gates, K)                 # (B, S, K)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)
    h = h.reshape(G, T, d)
    tope_g = tope.reshape(G, T, K)

    def dispatch_row(h_row, e_row, w_row):
        """h_row: (T, d); e_row/w_row: (T, K).

        Scatter only int32 slot->token INDICES (E*cap*4 bytes — tiny),
        then move the d-wide vectors with a gather; the reverse path
        scatter-adds expert outputs into a token-ordered buffer.  Under
        experts-over-model sharding this lowers to the bandwidth-
        optimal MoE all-to-all of (T, d) activations instead of an
        all-reduce of the whole (E, cap, d) capacity buffer (21 GB vs
        0.5 GB per layer per device on qwen3 — see §Perf cell 1).
        """
        flat_e = e_row.reshape(-1)                       # (T*K,)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos_in_e = jnp.cumsum(onehot, axis=0) - onehot   # exclusive rank
        pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], 1)[:, 0]
        keep = pos < cap
        slot = jnp.where(keep, pos, cap - 1)
        tok = jnp.arange(T * K, dtype=jnp.int32) // K
        # (E, cap) int32 map: which token feeds each expert slot (T = none)
        idx = jnp.full((E, cap), T, jnp.int32)
        idx = idx.at[flat_e, slot].set(jnp.where(keep, tok, T))
        wslot = jnp.zeros((E, cap), jnp.float32)
        wslot = wslot.at[flat_e, slot].add(
            jnp.where(keep, w_row.reshape(-1), 0.0))
        h_pad = jnp.concatenate([h_row, jnp.zeros((1, d), h_row.dtype)], 0)
        buf = h_pad[idx]                                  # (E, cap, d)
        return buf, idx, wslot

    buf, idx, wslot = jax.vmap(dispatch_row)(
        h, tope_g, topw.astype(jnp.float32).reshape(G, T, K))
    buf = shard_act(buf, ("batch", "experts", None, None))

    # expert FFN (batched over E; experts sharded over the model axis).
    # MXU semantics: bf16 operands, f32 accumulation — halves the
    # weight/activation read traffic vs f32-upcast einsums.
    g = jnp.einsum("becd,edf->becf", buf, p["wg"],
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("becd,edf->becf", buf, p["wu"],
                   preferred_element_type=jnp.float32)
    a = (jax.nn.silu(g) * u).astype(x.dtype)
    y = jnp.einsum("becf,efd->becd", a, p["wd"],
                   preferred_element_type=jnp.float32)
    y = shard_act(y.astype(x.dtype), ("batch", "experts", None, None))

    def combine_row(y_row, idx, wslot):
        y_scaled = y_row * wslot[..., None].astype(y_row.dtype)
        out = jnp.zeros((T + 1, d), y_row.dtype)
        out = out.at[idx.reshape(-1)].add(y_scaled.reshape(E * cap, d))
        return out[:T]

    out = jax.vmap(combine_row)(y, idx, wslot)
    out = out.reshape(B, S, d)

    # load-balance auxiliary loss (Switch):  E * sum_e f_e * P_e
    me = gates.mean((0, 1))                              # (E,)
    ce = jax.nn.one_hot(tope[..., 0], E, dtype=jnp.float32).mean((0, 1))
    aux = E * jnp.sum(me * ce)
    return x + out, aux


# ----------------------------------------------------------------------
# Mamba2 (SSD) block
# ----------------------------------------------------------------------
def mamba2_defs(cfg: ModelConfig) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    g, n, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_ch = di + 2 * g * n
    return {
        "ln": ParamDef((d,), ("embed",), "ones"),
        "in_proj": ParamDef((d, 2 * di + 2 * g * n + H), ("embed", "ssm_inner")),
        "conv_w": ParamDef((cfg.conv_width, conv_ch), (None, "ssm_inner"),
                           scale=0.5),
        "conv_b": ParamDef((conv_ch,), ("ssm_inner",), "zeros"),
        "A": ParamDef((H,), (None,), "ssm_a"),
        "D": ParamDef((H,), (None,), "ones"),
        "dt_bias": ParamDef((H,), (None,), "dt_bias"),
        "out_ln": ParamDef((di,), ("ssm_inner",), "ones"),
        "out_proj": ParamDef((di, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray | None = None
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv via shifted adds.  x: (B, S, C); w: (W, C).

    state: (B, W-1, C) trailing context from the previous segment.
    Returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)             # (B, S+W-1, C)
    S = x.shape[1]
    y = b
    for i in range(W):
        y = y + xp[:, i:i + S] * w[i]
    new_state = xp[:, -(W - 1):] if W > 1 else state
    return y, new_state


def mamba2_block(p: dict, cfg: ModelConfig, x: jnp.ndarray,
                 conv_state: jnp.ndarray | None = None,
                 ssm_state: jnp.ndarray | None = None,
                 return_state: bool = False):
    """Mamba2 block (SSD).  x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    di, g, n, H, P = (cfg.d_inner, cfg.ssm_groups, cfg.ssm_state,
                      cfg.ssm_heads, cfg.ssm_head_dim)
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    zxbcdt = h @ p["in_proj"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * g * n]
    dt_raw = zxbcdt[..., -H:]

    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :di].reshape(B, S, H, P)
    Bm = xbc[..., di:di + g * n].reshape(B, S, g, n)
    Cm = xbc[..., di + g * n:].reshape(B, S, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))

    y, final_state = ops.ssd(xs, dt, p["A"], Bm, Cm, chunk=cfg.ssm_chunk,
                             impl=cfg.attn_impl,
                             init_state=ssm_state)
    y = y + xs * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = rmsnorm(y * jax.nn.silu(z), p["out_ln"], cfg.norm_eps)
    out = x + y @ p["out_proj"]
    if return_state:
        return out, new_conv, final_state
    return out


def mamba2_decode_step(p: dict, cfg: ModelConfig, x: jnp.ndarray,
                       conv_state: jnp.ndarray, ssm_state: jnp.ndarray):
    """Single-token recurrent step.  x: (B, 1, d); states carried.

    conv_state: (B, W-1, conv_ch); ssm_state: (B, H, P, N) f32."""
    B, _, d = x.shape
    di, g, n, H, P = (cfg.d_inner, cfg.ssm_groups, cfg.ssm_state,
                      cfg.ssm_heads, cfg.ssm_head_dim)
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    zxbcdt = h @ p["in_proj"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * g * n]
    dt_raw = zxbcdt[..., -H:]

    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xbc = jax.nn.silu(xbc)
    xs = xbc[..., :di].reshape(B, H, P)
    Bm = xbc[..., di:di + g * n].reshape(B, g, n)
    Cm = xbc[..., di + g * n:].reshape(B, g, n)
    rep = H // g
    Bm = jnp.repeat(Bm, rep, axis=1)                     # (B, H, n)
    Cm = jnp.repeat(Cm, rep, axis=1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))[:, 0]  # (B, H)

    dec = jnp.exp(dt * p["A"].astype(jnp.float32))       # (B, H)
    upd = jnp.einsum("bhn,bhp,bh->bhpn", Bm.astype(jnp.float32),
                     xs.astype(jnp.float32), dt)
    new_state = ssm_state * dec[..., None, None] + upd
    y = jnp.einsum("bhn,bhpn->bhp", Cm.astype(jnp.float32), new_state)
    y = y.astype(x.dtype) + xs * p["D"].astype(x.dtype)[None, :, None]
    y = y.reshape(B, 1, di)
    y = rmsnorm(y * jax.nn.silu(z), p["out_ln"], cfg.norm_eps)
    return x + y @ p["out_proj"], new_conv, new_state


def decode_attn_cache(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> dict:
    """Empty per-layer KV cache aval (stacked over layers elsewhere)."""
    if cfg.use_mla:
        return {
            "c_kv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype),
        }
    hkv = max(cfg.n_kv_heads, cfg.kv_repeat_to)
    return {
        "k": jnp.zeros((batch, hkv, max_len, cfg.hd), dtype),
        "v": jnp.zeros((batch, hkv, max_len, cfg.hd), dtype),
    }
