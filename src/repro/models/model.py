"""Unified multi-family LM: dense / MoE / MLA / SSM / hybrid / enc-dec / VLM.

One functional model, config-dispatched — the FLOWER "single source"
rule: the same code lowers to the train step, the prefill step and the
decode step, on one chip or on the multi-pod mesh.

Layer iteration goes through :func:`_scan_or_loop`: ``scan_layers=True``
(production) lowers to one ``lax.scan`` over stacked params;
``scan_layers=False`` unrolls in Python.  The unrolled form exists for
the dry-run *calibration* compiles — XLA's cost analysis counts a
while-loop body once, so exact per-layer FLOP/byte/collective costs
are extracted from unrolled L=1 vs L=2 modules (see launch/dryrun.py).

Public API (all pure functions over pytrees):
  param_defs(cfg)         declarative parameter tree (ParamDef leaves)
  init(cfg, rng)          parameter values
  param_axes(cfg)         logical-sharding tree (same structure)
  forward(params, cfg, tokens, extra=...)   logits, aux
  loss_fn(params, cfg, batch)              scalar + metrics
  init_cache(cfg, batch, max_len)          decode cache pytree
  prefill(params, cfg, tokens, cache)      fill cache, last-pos logits
  decode_step(params, cfg, token, cache)   one-token step
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig

__all__ = ["param_defs", "init", "param_axes", "forward", "loss_fn",
           "init_cache", "prefill", "decode_step"]


# ----------------------------------------------------------------------
# parameter declaration
# ----------------------------------------------------------------------
def _stack(defs: Any, n: int) -> Any:
    """Prepend a stacked 'layers' dim to every ParamDef in a tree."""
    return jax.tree.map(
        lambda d: L.ParamDef((n,) + d.shape, ("layers",) + d.axes, d.init,
                             d.scale),
        defs, is_leaf=lambda x: isinstance(x, L.ParamDef))


def _block_defs(cfg: ModelConfig) -> dict:
    if cfg.family in ("ssm", "hybrid"):
        return {"mamba": L.mamba2_defs(cfg)}
    attn = L.mla_defs(cfg) if cfg.use_mla else L.attn_defs(cfg)
    mlp = L.moe_defs(cfg) if cfg.n_experts else L.mlp_defs(cfg)
    return {"attn": attn, "mlp": mlp}


def param_defs(cfg: ModelConfig) -> dict:
    d, V = cfg.d_model, cfg.vocab_size
    defs: dict[str, Any] = {
        "embed": L.ParamDef((V, d), ("vocab", "embed"), scale=0.02),
        "final_ln": L.ParamDef((d,), ("embed",), "ones"),
        "blocks": _stack(_block_defs(cfg), cfg.n_layers),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = L.ParamDef((d, V), ("embed", "vocab"))
    if cfg.family == "hybrid":
        defs["shared_attn"] = L.attn_defs(cfg)
        defs["shared_mlp"] = L.mlp_defs(cfg)
    if cfg.family == "encdec":
        enc = {"attn": L.attn_defs(cfg), "mlp": L.mlp_defs(cfg)}
        defs["enc_blocks"] = _stack(enc, cfg.n_enc_layers)
        defs["enc_final_ln"] = L.ParamDef((d,), ("embed",), "ones")
        defs["cross_blocks"] = _stack(L.attn_defs(cfg), cfg.n_layers)
    return defs


def init(cfg: ModelConfig, rng: jax.Array) -> dict:
    return L.init_tree(param_defs(cfg), rng, jnp.dtype(cfg.dtype))


def param_axes(cfg: ModelConfig) -> dict:
    return L.axes_tree(param_defs(cfg))


# ----------------------------------------------------------------------
# scan-or-unroll layer driver
# ----------------------------------------------------------------------
def _scan_or_loop(cfg: ModelConfig, body: Callable, carry: Any,
                  xs: Any, length: int):
    """body(carry, x_slice, idx) -> (carry, out).  In scan mode idx is
    a traced scalar; unrolled it is a Python int (so family dispatch
    like the hybrid's shared-attention sites becomes static)."""
    fn = _remat(body, cfg)
    if cfg.scan_layers:
        def b(c, inp):
            x, i = inp
            return fn(c, x, i)

        return jax.lax.scan(b, carry, (xs, jnp.arange(length)))
    outs = []
    for i in range(length):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, out = fn(carry, x_i, i)
        outs.append(out)
    if outs and outs[0] is not None:
        outs = jax.tree.map(lambda *x: jnp.stack(x), *outs)
    else:
        outs = None
    return carry, outs


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint(fn, policy=policy)


def _is_site(cfg: ModelConfig, idx) -> Any:
    """Shared-attention site predicate (hybrid); static when unrolled."""
    k = cfg.attn_every
    if isinstance(idx, int):
        return (idx % k) == (k - 1)
    return (idx % k) == (k - 1)


def _maybe_shared_attn(cfg, params, x, pos, idx, attn_cache=None,
                       cache_index=None):
    """Apply the hybrid's shared attention block at site layers."""
    shared_a, shared_m = params["shared_attn"], params["shared_mlp"]
    k = cfg.attn_every

    def with_attn(op):
        x, ac = op
        if ac is None:
            x2, _ = L.attention_block(shared_a, cfg, x, pos)
            return L.mlp_block(shared_m, cfg, x2), None
        site = idx // k
        cache_l = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, site, 0, False), ac)
        x2, new_l = L.attention_block(shared_a, cfg, x, pos, cache_l,
                                      cache_index)
        x2 = L.mlp_block(shared_m, cfg, x2)
        ac = jax.tree.map(
            lambda c, n: jax.lax.dynamic_update_index_in_dim(
                c, n.astype(c.dtype), site, 0), ac, new_l)
        return x2, ac

    if isinstance(idx, int):                    # unrolled: static branch
        if (idx % k) == (k - 1):
            return with_attn((x, attn_cache))
        return x, attn_cache
    return jax.lax.cond(_is_site(cfg, idx), with_attn, lambda op: op,
                        (x, attn_cache))


# ----------------------------------------------------------------------
# forward (training / scoring; no cache)
# ----------------------------------------------------------------------
def forward(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
            extra_embeds: jnp.ndarray | None = None,
            enc_embeds: jnp.ndarray | None = None
            ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: (B, S_text).  extra_embeds: (B, S_vis, d) vision/audio
    prefix (VLM).  enc_embeds: (B, S_enc, d) encoder frames (whisper).
    Returns (logits (B, S_total, V), aux_loss)."""
    x = L.embed_tokens(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    pos = jnp.arange(S)

    enc_out = None
    if cfg.family == "encdec":
        assert enc_embeds is not None
        enc_out = _encode(params, cfg, enc_embeds)

    x, aux = _run_blocks(params, cfg, x, pos, enc_out=enc_out)
    x = L.rmsnorm(x, params["final_ln"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return L.unembed(x, head), aux


def _encode(params, cfg, enc_embeds):
    x = enc_embeds.astype(jnp.dtype(cfg.dtype))
    pos = jnp.arange(x.shape[1])

    def body(x, p, i):
        x, _, _ = _dense_block(p, cfg, x, pos, causal=False)
        return x, None

    x, _ = _scan_or_loop(cfg, body, x, params["enc_blocks"],
                         cfg.n_enc_layers)
    return L.rmsnorm(x, params["enc_final_ln"], cfg.norm_eps)


def _dense_block(p, cfg, x, pos, cache=None, idx=None, causal=True):
    aux = jnp.zeros((), jnp.float32)
    if cfg.use_mla:
        x, new_cache = L.mla_attention_block(p["attn"], cfg, x, pos,
                                             cache, idx)
    else:
        x, new_cache = L.attention_block(p["attn"], cfg, x, pos, cache,
                                         idx, causal=causal)
    if cfg.n_experts:
        x, aux = L.moe_block(p["mlp"], cfg, x)
    else:
        x = L.mlp_block(p["mlp"], cfg, x)
    return x, new_cache, aux


def _run_blocks(params, cfg, x, pos, enc_out=None):
    aux0 = jnp.zeros((), jnp.float32)
    Ldec = cfg.n_layers

    if cfg.family == "ssm":
        def body(x, p, i):
            return L.mamba2_block(p["mamba"], cfg, x), None

        x, _ = _scan_or_loop(cfg, body, x, params["blocks"], Ldec)
        return x, aux0

    if cfg.family == "hybrid":
        def body(x, p, i):
            x = L.mamba2_block(p["mamba"], cfg, x)
            x, _ = _maybe_shared_attn(cfg, params, x, pos, i)
            return x, None

        x, _ = _scan_or_loop(cfg, body, x, params["blocks"], Ldec)
        return x, aux0

    if cfg.family == "encdec":
        def body(x, p, i):
            blk, cross = p
            x, _, aux = _dense_block(blk, cfg, x, pos, causal=True)
            x, _ = L.attention_block(cross, cfg, x, pos,
                                     cross_kv=L_cross_kv(cross, cfg,
                                                         enc_out),
                                     causal=False)
            return x, aux

        x, auxs = _scan_or_loop(cfg, body, x,
                                (params["blocks"],
                                 params["cross_blocks"]), Ldec)
        return x, (auxs.mean() if auxs is not None else aux0)

    def body(x, p, i):
        x, _, aux = _dense_block(p, cfg, x, pos)
        return x, aux

    x, auxs = _scan_or_loop(cfg, body, x, params["blocks"], Ldec)
    return x, (auxs.mean() if auxs is not None else aux0)


def L_cross_kv(p: dict, cfg: ModelConfig, enc_out: jnp.ndarray):
    """Project encoder output to K/V for one cross-attention block."""
    B, Se, d = enc_out.shape
    Hkv, hd = cfg.n_kv_heads, cfg.hd
    k = (enc_out @ p["wk"]).reshape(B, Se, Hkv, hd)
    v = (enc_out @ p["wv"]).reshape(B, Se, Hkv, hd)
    return jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2)


# ----------------------------------------------------------------------
# loss
# ----------------------------------------------------------------------
def loss_fn(params: dict, cfg: ModelConfig, batch: dict
            ) -> tuple[jnp.ndarray, dict]:
    """batch: tokens (B,S) int32, labels (B,S) int32 (-1 = ignore),
    optionally extra_embeds / enc_embeds."""
    logits, aux = forward(params, cfg, batch["tokens"],
                          extra_embeds=batch.get("extra_embeds"),
                          enc_embeds=batch.get("enc_embeds"))
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:     # VLM: drop vision prefix
        logits = logits[:, logits.shape[1] - labels.shape[1]:]
    mask = (labels >= 0).astype(jnp.float32)
    ce = L.softmax_cross_entropy(logits, jnp.maximum(labels, 0))
    loss = (ce * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    total = loss + cfg.router_aux_loss * aux
    return total, {"loss": loss, "aux": aux, "tokens": mask.sum()}


# ----------------------------------------------------------------------
# serving: cache init / prefill / decode
# ----------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    Ldec = cfg.n_layers
    cache: dict[str, Any] = {"index": jnp.zeros((), jnp.int32)}
    if cfg.family in ("ssm", "hybrid"):
        conv_ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        cache["conv"] = jnp.zeros((Ldec, batch, cfg.conv_width - 1,
                                   conv_ch), dtype)
        cache["ssm"] = jnp.zeros(
            (Ldec, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32)
        if cfg.family == "hybrid":
            n_sites = cfg.n_layers // cfg.attn_every
            cache["attn"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n_sites,) + x.shape).copy(),
                L.decode_attn_cache(cfg, batch, max_len, dtype))
        return cache
    per_layer = L.decode_attn_cache(cfg, batch, max_len, dtype)
    cache["attn"] = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (Ldec,) + x.shape).copy(), per_layer)
    if cfg.family == "encdec":
        cache["enc_out"] = jnp.zeros((batch, cfg.n_frontend_tokens or 1500,
                                      cfg.d_model), dtype)
    return cache


def prefill(params: dict, cfg: ModelConfig, tokens: jnp.ndarray,
            cache: dict, enc_embeds: jnp.ndarray | None = None,
            extra_embeds: jnp.ndarray | None = None
            ) -> tuple[jnp.ndarray, dict]:
    """Run the prompt through the model, filling the cache.
    Returns (last-position logits (B, V), cache)."""
    x = L.embed_tokens(params["embed"], tokens).astype(jnp.dtype(cfg.dtype))
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    pos = jnp.arange(S)
    idx0 = jnp.zeros((), jnp.int32)

    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(params, cfg, enc_embeds)
        cache = dict(cache)
        cache["enc_out"] = enc_out.astype(cache["enc_out"].dtype)

    if cfg.family in ("ssm", "hybrid"):
        x, cache = _iterate_ssm(params, cfg, x, pos, cache, idx0,
                                decode=False)
    else:
        def body(x, p, i):
            if cfg.family == "encdec":
                blk, cross, cache_l = p
            else:
                (blk, cache_l), cross = p, None
            x, new_c, _ = _dense_block(blk, cfg, x, pos, cache_l, idx0)
            if cross is not None:
                x, _ = L.attention_block(cross, cfg, x, pos,
                                         cross_kv=L_cross_kv(cross, cfg,
                                                             enc_out),
                                         causal=False)
            return x, new_c

        xs = ((params["blocks"], params["cross_blocks"], cache["attn"])
              if cfg.family == "encdec"
              else (params["blocks"], cache["attn"]))
        x, new_attn = _scan_or_loop(cfg, body, x, xs, cfg.n_layers)
        cache = {**cache, "attn": new_attn}

    cache["index"] = jnp.asarray(S, jnp.int32)
    x = L.rmsnorm(x[:, -1:], params["final_ln"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return L.unembed(x, head)[:, 0], cache


def decode_step(params: dict, cfg: ModelConfig, token: jnp.ndarray,
                cache: dict) -> tuple[jnp.ndarray, dict]:
    """One token for every sequence.  token: (B,) int32.
    Returns (logits (B, V), updated cache)."""
    idx = cache["index"]
    x = L.embed_tokens(params["embed"], token[:, None]
                       ).astype(jnp.dtype(cfg.dtype))
    # idx may be a scalar (lock-step serving) or a (B,) vector of
    # per-slot lengths (continuous batching).
    pos = idx[None] if idx.ndim == 0 else idx[:, None]

    if cfg.family in ("ssm", "hybrid"):
        x, cache = _iterate_ssm(params, cfg, x, pos, cache, idx,
                                decode=True)
    else:
        enc_out = cache.get("enc_out")

        def body(x, p, i):
            if cfg.family == "encdec":
                blk, cross, cache_l = p
            else:
                (blk, cache_l), cross = p, None
            x, new_c, _ = _dense_block(blk, cfg, x, pos, cache_l, idx)
            if cross is not None:
                x, _ = L.attention_block(cross, cfg, x, pos,
                                         cross_kv=L_cross_kv(cross, cfg,
                                                             enc_out),
                                         causal=False)
            return x, new_c

        xs = ((params["blocks"], params["cross_blocks"], cache["attn"])
              if cfg.family == "encdec"
              else (params["blocks"], cache["attn"]))
        x, new_attn = _scan_or_loop(cfg, body, x, xs, cfg.n_layers)
        cache = {**cache, "attn": new_attn}

    cache["index"] = idx + 1
    x = L.rmsnorm(x, params["final_ln"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return L.unembed(x, head)[:, 0], cache


def _iterate_ssm(params, cfg, x, pos, cache, cache_index, decode: bool):
    """Layer loop for ssm/hybrid in both prefill and decode modes.

    The hybrid's shared-attention cache is threaded through the carry
    (its site indexing is dynamic under scan, static when unrolled).
    """
    has_attn = cfg.family == "hybrid"

    def body(carry, p, i):
        x, attn_cache = carry
        blk, conv_l, ssm_l = p
        if decode:
            x, nc, ns = L.mamba2_decode_step(blk["mamba"], cfg, x,
                                             conv_l, ssm_l)
        else:
            x, nc, ns = L.mamba2_block(blk["mamba"], cfg, x,
                                       return_state=True)
        if has_attn:
            x, attn_cache = _maybe_shared_attn(
                cfg, params, x, pos, i, attn_cache, cache_index)
        return (x, attn_cache), (nc.astype(conv_l.dtype), ns)

    carry = (x, cache.get("attn"))
    xs = (params["blocks"], cache["conv"], cache["ssm"])
    (x, attn_cache), stacked = _scan_or_loop(cfg, body, carry, xs,
                                             cfg.n_layers)
    nconv, nssm = stacked
    new_cache = {**cache, "conv": nconv, "ssm": nssm}
    if has_attn:
        new_cache["attn"] = attn_cache
    return x, new_cache
