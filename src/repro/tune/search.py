"""Profile-guided schedule search: model ranks, measurements pick.

PR 3 selected tiles purely analytically
(:func:`repro.core.vectorize.modeled_plane_time`).  The HLS literature
is unambiguous that this is only half the loop: de Fine Licht et al.
and the FLOWER evaluation both validate transformation parameters
against the target before committing.  This module closes the loop:

1. **prior** — the analytic sweep ranks candidates per fusion group
   (top-k by modeled time) so the measured search starts at the
   model's pick and never wastes a trial on a config the model can
   already rule out;
2. **measure** — each surviving candidate is *lowered and timed on
   the live backend* (:func:`default_measure`), the only judge that
   knows about padding pathologies, DMA issue limits and everything
   else the closed form misses;
3. **pick** — greedy coordinate descent over the per-group vector
   factors (plus the ``max_tile`` and fusion-budget axes), capped at
   ``max_trials`` measurements.  The analytic pick is always measured
   first, so the winner is **never slower than the analytic
   schedule** by construction;
4. **persist** — the winner goes into the on-disk
   :class:`~repro.tune.store.TuningCache`; the next
   ``compile_graph(..., tune="auto")`` of the same app on the same
   device kind does **zero** measurements.

Doctest (fake measurements, so it runs anywhere — real use omits
``measure``):

    >>> import tempfile
    >>> from repro.core.graph import DataflowGraph
    >>> from repro.tune.store import TuningCache
    >>> g = DataflowGraph("doc")
    >>> x = g.input("img", (64, 256))
    >>> _ = g.output(g.point(x, lambda v: v * 2.0), "out")
    >>> cache = TuningCache(tempfile.mkdtemp())
    >>> res = tune_graph(g, "xla", cache=cache,
    ...                  measure=lambda cfg: 1.0 / cfg.group_vf[0])
    >>> res.source, res.config.group_vf         # widest factor is fastest
    ('measured', (2,))
    >>> again = tune_graph(g, "xla", cache=cache,
    ...                    measure=lambda cfg: 1.0 / cfg.group_vf[0])
    >>> again.source, again.n_measurements      # served from disk
    ('cache', 0)
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Sequence

import numpy as np

from repro.core.schedule import Schedule, build_schedule
from repro.core.vectorize import (DEFAULT_MAX_TILE, TPUSpec, V5E,
                                  modeled_schedule_time, scale_spec,
                                  schedule_features, sweep_vector_factor)
from repro.obs.drift import DriftLog, resolve_drift
from repro.obs.tracer import maybe_span, resolve_tracer
from repro.tune.store import (ScheduleConfig, TuningCache, TuningKey,
                              TuningRecord, detect_device_kind)

__all__ = ["Trial", "TuningResult", "tune_graph", "resolve_tuning",
           "default_measure", "tuned_schedule_kwargs"]


def tuned_schedule_kwargs(config: ScheduleConfig, source: str,
                          spec: TPUSpec = V5E) -> dict:
    """:func:`~repro.core.schedule.build_schedule` kwargs for a config.

    The one mapping from a tuned :class:`ScheduleConfig` onto the
    scheduler's knobs, shared by ``compile_graph`` and
    ``replicate_app`` so the two can never drift apart.
    """
    return dict(spec=scale_spec(spec, config.vmem_fraction),
                group_vector_factors=config.group_vf,
                max_tile=config.max_tile, tile_source=source)


@dataclasses.dataclass
class Trial:
    """One measured candidate of the search."""

    label: str
    config: ScheduleConfig
    modeled_s: float
    measured_s: float


@dataclasses.dataclass
class TuningResult:
    """Outcome of :func:`tune_graph` for one ``(graph, backend, device)``."""

    key: TuningKey
    config: ScheduleConfig
    #: "measured" (fresh search) or "cache" (loaded, zero measurements)
    source: str
    trials: list[Trial]
    n_measurements: int
    record: TuningRecord
    #: candidates skipped on the calibrated prior without measuring
    n_pruned: int = 0

    def notes(self) -> list[str]:
        """Provenance lines for ``Schedule.diagnostics``."""
        lines = [f"[tune] source={self.source} backend={self.key.backend} "
                 f"device={self.key.device_kind} {self.config.describe()}"]
        if self.source == "cache":
            lines.append(f"[tune] loaded from TuningCache "
                         f"({self.n_measurements} measurements)")
        else:
            best = self.record.best_measured_s
            base = self.record.analytic_measured_s
            if best is not None and base is not None:
                lines.append(
                    f"[tune] measured {self.n_measurements} candidates: "
                    f"best={best * 1e6:.1f}us analytic={base * 1e6:.1f}us "
                    f"({base / best:.2f}x)" if best else
                    f"[tune] measured {self.n_measurements} candidates")
            if self.n_pruned:
                lines.append(f"[tune] calibrated prior pruned "
                             f"{self.n_pruned} candidates unmeasured")
        return lines


def _tuning_context(spec: TPUSpec, strict: bool, canonicalize: bool,
                    passes) -> str:
    """Digest of everything besides graph/backend/device that changes
    what a measurement means: the spec's hardware constants and the
    canonicalization regime (strict/point-fusion change the partition
    a config's ``group_vf`` refers to)."""
    import hashlib
    import json
    blob = json.dumps([sorted((f, repr(getattr(spec, f)))
                              for f in spec.__dataclass_fields__),
                       bool(strict), bool(canonicalize),
                       [type(p).__name__ for p in passes]
                       if passes is not None else None])
    return hashlib.sha256(blob.encode()).hexdigest()[:12]


def default_measure(graph, backend, config: ScheduleConfig, *,
                    spec: TPUSpec | None = None, reps: int = 3,
                    interpret: bool = True,
                    seed: int = 0, strict: bool = False,
                    canonicalize: bool = True, passes=None) -> float:
    """Lower ``graph`` under ``config`` and time it on the live backend.

    Compiles through :func:`repro.core.compiler.compile_graph` with the
    explicit config (no recursion into the tuner), synthesizes random
    inputs of the declared shapes, does one warmup call (JIT compile)
    and returns the best-of-``reps`` seconds per call.  Best-of is the
    standard autotuning estimator: min is robust to scheduler noise
    where mean is not.
    """
    from repro.backends import resolve
    from repro.core.compiler import compile_graph
    be = resolve(backend)
    app = compile_graph(graph, be, tune=config, spec=spec or be.spec,
                        interpret=interpret, strict=strict,
                        canonicalize=canonicalize, passes=passes)
    rng = np.random.default_rng(seed)
    inputs = {c.name: rng.normal(size=c.shape).astype(np.dtype(c.dtype))
              for c in app.graph.graph_inputs}
    names = app.output_names

    def call() -> None:
        out = app(**inputs)
        for n in names:
            np.asarray(out[n])          # force to host: include D2H

    call()                              # warmup (compiles)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        call()
        best = min(best, time.perf_counter() - t0)
    return best


def _model_config(graph, spec: TPUSpec, max_tile: tuple[int, int],
                  vmem_fraction: float,
                  build_kwargs: dict) -> tuple[ScheduleConfig, Schedule]:
    """The analytic pick under one (max_tile, budget) point, as a config."""
    sched = build_schedule(graph, spec=scale_spec(spec, vmem_fraction),
                           max_tile=max_tile, **build_kwargs)
    vfs = tuple(None if g.is_trivial else g.vector_factor
                for g in sched.groups)
    return (ScheduleConfig(group_vf=vfs, max_tile=max_tile,
                           vmem_fraction=vmem_fraction), sched)


def _modeled_for(graph, cfg: ScheduleConfig, spec: TPUSpec,
                 build_kwargs: dict) -> tuple[float, dict]:
    """Whole-app modeled seconds + drift features for one candidate."""
    sched = build_schedule(graph, spec=scale_spec(spec, cfg.vmem_fraction),
                           group_vector_factors=cfg.group_vf,
                           max_tile=cfg.max_tile, **build_kwargs)
    return modeled_schedule_time(sched, spec), schedule_features(sched)


def tune_graph(graph, backend="pallas", *,
               spec: TPUSpec | None = None,
               cache: TuningCache | None = None,
               device_kind: str | None = None, top_k: int = 3,
               max_trials: int = 12, reps: int = 3,
               measure: Callable[[ScheduleConfig], float] | None = None,
               interpret: bool = True, seed: int = 0,
               strict: bool = False, canonicalize: bool = True,
               passes=None,
               max_tile_candidates: Sequence[tuple[int, int]] = (
                   DEFAULT_MAX_TILE, (128, 1024)),
               vmem_fractions: Sequence[float] = (1.0,),
               force: bool = False, trace: Any = None,
               drift: Any = None, calibrate: Any = None,
               prior_ratio: float = 1.3) -> TuningResult:
    """Search the schedule space for ``graph`` by measuring candidates.

    The search space is the per-group vector factor (top-``top_k`` by
    the analytic model), the ``max_tile`` height cap and the fusion
    budget (``vmem_fractions`` of the spec's VMEM).  ``measure`` maps a
    :class:`ScheduleConfig` to seconds per call; the default lowers and
    times on the live backend — tests inject deterministic fakes.  At
    most ``max_trials`` measurements run; the analytic pick is always
    one of them, so the returned winner is never slower than it (as
    measured).  Results persist in ``cache`` keyed by graph signature,
    backend, device kind and input shapes; a hit returns immediately
    with ``n_measurements == 0``.

    Observability: ``trace`` wraps every measurement in a
    ``tune.trial`` span (label, modeled and measured seconds) for the
    flight recorder; each trial also appends a ``kind="trial"``
    (modeled, measured) row to the drift log living beside the tuning
    cache (``drift.jsonl`` under ``cache.root``), the data ROADMAP
    item 3's calibration pass consumes.  ``drift=False`` disables the
    rows, ``drift=`` a :class:`~repro.obs.drift.DriftLog`/path
    redirects them.  Every trial row carries the candidate schedule's
    cost-model **features** so it can feed the calibration fit.

    ``calibrate`` (same protocol as ``compile_graph``) swaps in the
    fitted :class:`~repro.tune.calibrate.CalibratedSpec` for this
    backend + device kind before the search starts.  Under a
    calibrated spec the model is trusted further: a candidate whose
    modeled time exceeds ``prior_ratio`` times the best modeled time
    seen so far is **pruned without measuring** (counted in
    ``n_pruned``), so a calibrated search reaches the same winner in
    strictly fewer measurements than an uncalibrated one whenever the
    fitted model ranks the pruned candidates correctly.  An
    *uncalibrated* spec never prunes — the seed model has not earned
    that trust (ROADMAP item 3).
    """
    from repro.backends import resolve_calibrated
    be = resolve_calibrated(backend, calibrate)
    be.require("tuning")
    spec = spec or be.spec
    # pruning is gated on evidence: only a spec that went through the
    # calibration fit (carries fitted per-kind ii multipliers) may veto
    # measurements on modeled time alone
    prune = bool(getattr(spec, "ii_scale", ())) and prior_ratio is not None
    # NOT `cache or ...`: an empty TuningCache is falsy (__len__ == 0)
    # and must still be used, not silently swapped for the default root
    cache = cache if cache is not None else TuningCache()
    device_kind = device_kind or detect_device_kind()
    tracer = resolve_tracer(trace)
    # trial rows land beside the tuning cache by default: one directory
    # holds everything learned about this machine
    drift_log = (DriftLog(os.path.join(cache.root, "drift.jsonl"))
                 if drift is None else resolve_drift(drift))
    # the measured program must BE the compiled program: the compile
    # flags ride in both the search (below) and the cache key, so a
    # config tuned under one regime never serves another — and the
    # backend rides along so the scheduler budgets with ITS constants
    build_kwargs = dict(strict=strict, canonicalize=canonicalize,
                        passes=passes, backend=be)
    context = _tuning_context(spec, strict, canonicalize, passes)
    key_pre = TuningKey.for_graph(graph, be, device_kind,
                                  interpret=interpret, context=context)
    if not force:
        rec = cache.get(key_pre)
        if rec is not None:
            return TuningResult(key_pre, rec.config, "cache", [], 0, rec)

    counter = {"n": 0, "pruned": 0}
    if measure is None:
        # the backend's measurement hook is the harness; the seeds all
        # point it at default_measure (lower + time on the live device)
        hook = be.measure if be.measure is not None else default_measure

        def measure(cfg: ScheduleConfig, _g=graph) -> float:
            return hook(_g, be, cfg, spec=spec, reps=reps,
                        interpret=interpret, seed=seed,
                        strict=strict, canonicalize=canonicalize,
                        passes=passes)
    user_measure = measure

    def timed(cfg: ScheduleConfig) -> float:
        counter["n"] += 1
        return user_measure(cfg)

    trials: list[Trial] = []
    seen: set[ScheduleConfig] = set()
    best_modeled = [float("inf")]

    def try_config(label: str, cfg: ScheduleConfig, modeled_s: float,
                   features: dict | None = None) -> Trial | None:
        if cfg in seen or counter["n"] >= max_trials:
            return None
        seen.add(cfg)
        if modeled_s > 0:
            best_modeled[0] = min(best_modeled[0], modeled_s)
        if prune and modeled_s > prior_ratio * best_modeled[0]:
            counter["pruned"] += 1
            return None
        with maybe_span(tracer, "tune.trial", cat="tune",
                        graph=graph.name, label=label) as sp:
            measured_s = timed(cfg)
            sp.set(modeled_s=modeled_s, measured_s=measured_s)
        t = Trial(label, cfg, modeled_s, measured_s)
        trials.append(t)
        if drift_log is not None:
            # sig/shapes bind late: set post-canonicalization, below
            attrs = dict(label=label, device=device_kind)
            if features is not None:
                attrs["features"] = features
            drift_log.record("trial", drift_sig, drift_shapes, be.name,
                             modeled_s, measured_s, **attrs)
        return t

    # ---- analytic baseline: the model's pick, measured first --------
    baseline_cfg, baseline_sched = _model_config(
        graph, spec, tuple(max_tile_candidates[0]), 1.0, build_kwargs)
    # canonicalization may have rewritten the graph in place: alias the
    # post-canonicalization signature so either form hits later
    key_post = TuningKey.for_graph(baseline_sched.graph, be,
                                   device_kind, interpret=interpret,
                                   context=context)
    tunable = [i for i, g in enumerate(baseline_sched.groups)
               if not g.is_trivial]
    drift_sig = baseline_sched.graph.signature()
    drift_shapes = [list(c.shape)
                    for c in baseline_sched.graph.graph_inputs]

    if not tunable:                      # nothing to search: model wins
        rec = TuningRecord(config=baseline_cfg, source="measured",
                           modeled_s=0.0, n_trials=0)
        cache.put(key_post, rec, aliases=(key_pre,))
        return TuningResult(key_pre, baseline_cfg, "measured", [], 0, rec)

    analytic = try_config("analytic", baseline_cfg,
                          modeled_schedule_time(baseline_sched, spec),
                          schedule_features(baseline_sched))
    assert analytic is not None
    best = analytic

    # ---- axis 1: per-group vector factor (coordinate descent) ------
    for gi in tunable:
        group = baseline_sched.groups[gi]
        records = sweep_vector_factor(group, spec,
                                      max_tile=baseline_cfg.max_tile,
                                      backend=be)
        feasible = sorted((r for r in records if r["feasible"]),
                          key=lambda r: r["modeled_s"])
        for r in feasible[:top_k]:
            vfs = list(best.config.group_vf)
            vfs[gi] = r["vector_factor"]
            cand = dataclasses.replace(best.config, group_vf=tuple(vfs))
            mod_s, feats = _modeled_for(graph, cand, spec, build_kwargs)
            t = try_config(f"g{gi}:vf{r['vector_factor']}", cand,
                           mod_s, feats)
            if t is not None and t.measured_s < best.measured_s:
                best = t

    # ---- axis 2: tile-height cap ------------------------------------
    for mt in max_tile_candidates[1:]:
        cand = dataclasses.replace(best.config, max_tile=tuple(mt))
        mod_s, feats = _modeled_for(graph, cand, spec, build_kwargs)
        t = try_config(f"max_tile{tuple(mt)}", cand, mod_s, feats)
        if t is not None and t.measured_s < best.measured_s:
            best = t

    # ---- axis 3: fusion budget (changes the partition itself) -------
    for frac in vmem_fractions:
        if frac == 1.0:
            continue
        cfg_f, sched_f = _model_config(graph, spec, best.config.max_tile,
                                       frac, build_kwargs)
        t = try_config(f"vmem{frac:g}", cfg_f,
                       modeled_schedule_time(sched_f, spec),
                       schedule_features(sched_f))
        if t is not None and t.measured_s < best.measured_s:
            best = t

    rec = TuningRecord(config=best.config, source="measured",
                       best_measured_s=best.measured_s,
                       analytic_measured_s=analytic.measured_s,
                       modeled_s=best.modeled_s, n_trials=counter["n"],
                       n_pruned=counter["pruned"])
    cache.put(key_post, rec, aliases=(key_pre,))
    if drift_log is not None:
        drift_log.flush()       # trial rows persist with the record
    return TuningResult(key_pre, best.config, "measured", trials,
                        counter["n"], rec, n_pruned=counter["pruned"])


def resolve_tuning(graph, backend, *, tune: Any,
                   spec: TPUSpec | None = None,
                   cache: TuningCache | None = None,
                   interpret: bool = True,
                   **tune_kwargs: Any) -> tuple[ScheduleConfig, str,
                                                list[str]] | None:
    """Normalize a ``tune=`` argument into ``(config, source, notes)``.

    Shared by :func:`repro.core.compiler.compile_graph` and
    :func:`repro.parallel.replicate.replicate_app`:

    - ``None`` / ``"model"`` — no tuning (analytic sweep); returns None,
    - a :class:`ScheduleConfig` — apply verbatim (source ``"config"``),
    - ``"auto"`` — consult the :class:`TuningCache`, searching with
      :func:`tune_graph` on a miss (source ``"measured"`` or
      ``"cache"``).
    """
    if tune is None or tune == "model":
        return None
    if isinstance(tune, ScheduleConfig):
        return (tune, "config",
                [f"[tune] source=config {tune.describe()}"])
    if tune == "auto":
        result = tune_graph(graph, backend, spec=spec, cache=cache,
                            interpret=interpret, **tune_kwargs)
        return result.config, result.source, result.notes()
    raise ValueError(
        f"tune must be None, 'model', 'auto' or a ScheduleConfig; "
        f"got {tune!r}")
