"""Persistent tuning cache: measured schedule configs, on disk.

FLOWER amortizes its most expensive step by shipping the synthesized
bitstream: place-and-route runs once, every later execution loads the
artifact.  The software analogue for a *measured* autotuner is this
store — profiling lowered candidates on the live backend costs real
wall-clock, so the winning :class:`ScheduleConfig` is persisted under a
:class:`TuningKey` of ``(DataflowGraph.signature(), backend,
device_kind, input shapes)`` and every later
``compile_graph(..., tune="auto")`` of the same app on the same
hardware loads it with **zero** re-measurement.

Layout: one JSON file per key under the cache root (``root`` argument,
else ``$REPRO_TUNE_CACHE``, else ``~/.cache/repro/tune``).  Writes are
atomic (temp file + ``os.replace``) so concurrent tuners never expose
a torn record; records are versioned so a future format change
invalidates old entries instead of misreading them.

    >>> import tempfile
    >>> cache = TuningCache(tempfile.mkdtemp())
    >>> key = TuningKey("sig0123", "pallas", "cpu", (("img", (8, 128), "float32"),))
    >>> cfg = ScheduleConfig(group_vf=(2,))
    >>> cache.put(key, TuningRecord(config=cfg, source="measured"))
    >>> cache.get(key).config.group_vf
    (2,)
    >>> len(TuningCache(cache.root))      # a fresh handle re-reads disk
    1
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import threading
import time
from typing import Any, Iterator

__all__ = ["ScheduleConfig", "TuningKey", "TuningRecord", "TuningCache",
           "default_cache_root"]

#: bump when the record format changes; readers skip other versions
RECORD_VERSION = 1


@dataclasses.dataclass(frozen=True)
class ScheduleConfig:
    """One point of the schedule search space, ready to re-apply.

    The three knobs the tuner searches (see ``docs/tuning.md``):

    - ``group_vf`` — per-fusion-group vector factor, aligned with
      ``Schedule.groups`` order (``None`` for trivial custom/reduce
      groups, which have no tile),
    - ``max_tile`` — the tile-shape cap handed to
      :func:`repro.core.vectorize.choose_tile` (the height axis of the
      search; the width axis is ``group_vf``),
    - ``vmem_fraction`` — the fusion budget: the fraction of
      ``TPUSpec.vmem_bytes`` the partitioner may spend, which changes
      *which stages fuse*, not just how they tile.
    """

    group_vf: tuple[int | None, ...]
    max_tile: tuple[int, int] = (256, 1024)
    vmem_fraction: float = 1.0

    def to_json(self) -> dict[str, Any]:
        return {"group_vf": list(self.group_vf),
                "max_tile": list(self.max_tile),
                "vmem_fraction": self.vmem_fraction}

    @classmethod
    def from_json(cls, d: dict[str, Any]) -> "ScheduleConfig":
        return cls(group_vf=tuple(d["group_vf"]),
                   max_tile=tuple(d["max_tile"]),
                   vmem_fraction=float(d["vmem_fraction"]))

    def describe(self) -> str:
        vfs = ",".join("-" if v is None else str(v) for v in self.group_vf)
        return (f"vf=[{vfs}] max_tile={self.max_tile} "
                f"vmem_fraction={self.vmem_fraction:g}")


@dataclasses.dataclass(frozen=True)
class TuningKey:
    """Identity of a tuning result: graph x backend x hardware x shapes.

    ``signature`` is :meth:`repro.core.graph.DataflowGraph.signature`
    (structural: topology, shapes, dtypes, stage bodies); ``shapes``
    repeats the graph-input shapes explicitly so a record survives a
    signature-algorithm change detectably rather than silently.
    ``mode`` separates Pallas interpreter-mode timings from compiled
    ones — they have unrelated performance profiles, so a winner
    measured under one must never be served for the other.
    ``context`` digests everything else that changes what a
    measurement means (the TPUSpec's constants, strict/canonicalize
    compile flags): configs tuned under one context are invisible to
    compiles running under another.

    ``backend`` is the resolved record's
    :meth:`~repro.backends.Backend.cache_key` — ``name@digest`` over
    its capabilities and constants — so a re-registered backend with
    different lane/VMEM constants invalidates old winners instead of
    silently serving schedules measured under other budgets.
    """

    signature: str
    backend: str
    device_kind: str
    shapes: tuple[tuple[str, tuple[int, ...], str], ...]
    mode: str = "interpret"
    context: str = ""

    @classmethod
    def for_graph(cls, graph, backend,
                  device_kind: str | None = None, *,
                  interpret: bool = True,
                  context: str = "") -> "TuningKey":
        from repro.backends import resolve
        backend_key = resolve(backend).cache_key()
        if device_kind is None:
            device_kind = detect_device_kind()
        import numpy as np
        shapes = tuple((c.name, tuple(c.shape), np.dtype(c.dtype).name)
                       for c in graph.graph_inputs)
        return cls(graph.signature(), backend_key, device_kind, shapes,
                   "interpret" if interpret else "compiled", context)

    def digest(self) -> str:
        blob = json.dumps([self.signature, self.backend, self.device_kind,
                           [list(map(str, s)) for s in self.shapes],
                           self.mode, self.context])
        return hashlib.sha256(blob.encode()).hexdigest()[:24]


@dataclasses.dataclass
class TuningRecord:
    """A stored tuning result plus enough context to audit it."""

    config: ScheduleConfig
    #: how the config was obtained ("measured"); a *loaded* record is
    #: reported as source="cache" by the search layer
    source: str = "measured"
    best_measured_s: float | None = None
    analytic_measured_s: float | None = None
    modeled_s: float | None = None
    n_trials: int = 0
    #: candidates the calibrated prior skipped without measuring
    #: (0 for uncalibrated searches and pre-calibration records)
    n_pruned: int = 0
    created_at: float = 0.0
    version: int = RECORD_VERSION

    def to_json(self, key: TuningKey) -> dict[str, Any]:
        return {"version": self.version,
                "key": {"signature": key.signature, "backend": key.backend,
                        "device_kind": key.device_kind, "mode": key.mode,
                        "context": key.context,
                        "shapes": [[n, list(s), d] for n, s, d in key.shapes]},
                "config": self.config.to_json(), "source": self.source,
                "best_measured_s": self.best_measured_s,
                "analytic_measured_s": self.analytic_measured_s,
                "modeled_s": self.modeled_s, "n_trials": self.n_trials,
                "n_pruned": self.n_pruned,
                "created_at": self.created_at}

    @classmethod
    def from_json(cls, d: dict[str, Any]) -> "TuningRecord":
        return cls(config=ScheduleConfig.from_json(d["config"]),
                   source=d.get("source", "measured"),
                   best_measured_s=d.get("best_measured_s"),
                   analytic_measured_s=d.get("analytic_measured_s"),
                   modeled_s=d.get("modeled_s"),
                   n_trials=int(d.get("n_trials", 0)),
                   n_pruned=int(d.get("n_pruned", 0)),
                   created_at=float(d.get("created_at", 0.0)),
                   version=int(d.get("version", 0)))


def default_cache_root() -> str:
    """Resolve the on-disk root: ``$REPRO_TUNE_CACHE`` else XDG cache."""
    env = os.environ.get("REPRO_TUNE_CACHE")
    if env:
        return env
    xdg = os.environ.get("XDG_CACHE_HOME",
                         os.path.join(os.path.expanduser("~"), ".cache"))
    return os.path.join(xdg, "repro", "tune")


def detect_device_kind() -> str:
    """Best-effort hardware identity for the tuning key.

    A schedule measured on one device kind must not be served on
    another — the whole point of measuring — so the key carries
    ``jax.devices()[0].device_kind`` (falling back to the platform
    name, then ``"unknown"`` when JAX is unavailable).
    """
    try:
        import jax
        dev = jax.devices()[0]
        return getattr(dev, "device_kind", None) or dev.platform
    except Exception:  # pragma: no cover - no backend at all
        return "unknown"


class TuningCache:
    """On-disk store of measured :class:`ScheduleConfig` winners.

    ``get``/``put`` are keyed by :class:`TuningKey`; a process-local
    memo sits in front of the filesystem so the serving engine's many
    per-request ``compile_graph(tune="auto")`` calls do not re-read
    JSON.  ``put`` accepts ``aliases`` — extra keys mapping to the same
    record — because canonicalization can legitimately change a graph's
    signature once (see :class:`repro.runtime.cache.CompileCache`):
    both the pre- and post-canonicalization forms must hit.
    """

    def __init__(self, root: str | None = None):
        self.root = root or default_cache_root()
        self._memo: dict[str, TuningRecord | None] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def _path(self, key: TuningKey) -> str:
        return os.path.join(self.root, key.digest() + ".json")

    def get(self, key: TuningKey) -> TuningRecord | None:
        """Load the record for ``key`` (memoized), or ``None`` on miss."""
        digest = key.digest()
        with self._lock:
            if digest in self._memo:
                return self._memo[digest]
        rec: TuningRecord | None = None
        try:
            with open(self._path(key)) as f:
                raw = json.load(f)
            if raw.get("version") == RECORD_VERSION:
                rec = TuningRecord.from_json(raw)
        except (OSError, ValueError, KeyError):
            rec = None
        with self._lock:
            self._memo[digest] = rec
        return rec

    def put(self, key: TuningKey, record: TuningRecord,
            aliases: tuple[TuningKey, ...] = ()) -> None:
        """Persist ``record`` under ``key`` (and ``aliases``) atomically."""
        if not record.created_at:
            record.created_at = time.time()
        os.makedirs(self.root, exist_ok=True)
        for k in (key, *aliases):
            payload = json.dumps(record.to_json(k), indent=1)
            fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
            try:
                with os.fdopen(fd, "w") as f:
                    f.write(payload)
                os.replace(tmp, self._path(k))
            except BaseException:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass
                raise
            with self._lock:
                self._memo[k.digest()] = record

    def invalidate(self, key: TuningKey) -> None:
        with self._lock:
            self._memo.pop(key.digest(), None)
        try:
            os.unlink(self._path(key))
        except OSError:
            pass

    def clear(self) -> None:
        with self._lock:
            self._memo.clear()
        try:
            names = os.listdir(self.root)
        except OSError:
            return
        for n in names:
            if n.endswith(".json"):
                try:
                    os.unlink(os.path.join(self.root, n))
                except OSError:
                    pass

    def entries(self) -> Iterator[TuningRecord]:
        """Yield every readable current-version record on disk.

        Alias files (the pre/post-canonicalization forms of one
        tuning result) are deduplicated — one tuned app counts once.
        """
        try:
            names = sorted(os.listdir(self.root))
        except OSError:
            return
        seen: list[TuningRecord] = []
        for n in names:
            if not n.endswith(".json"):
                continue
            try:
                with open(os.path.join(self.root, n)) as f:
                    raw = json.load(f)
                if raw.get("version") != RECORD_VERSION:
                    continue
                rec = TuningRecord.from_json(raw)
            except (OSError, ValueError, KeyError):
                continue
            if rec in seen:                 # an alias of a yielded record
                continue
            seen.append(rec)
            yield rec

    def __len__(self) -> int:
        return sum(1 for _ in self.entries())
