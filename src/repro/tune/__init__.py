"""Profile-guided schedule autotuning with a persistent cache.

The analytic cost model (:mod:`repro.core.vectorize`) *ranks* schedule
candidates; this package *measures* the short-list on the live backend
and persists the winner, so ``compile_graph(..., tune="auto")`` pays
for profiling once per ``(graph, backend, device kind, shapes)`` and
then always compiles straight to the measured operating point — the
software analogue of FLOWER shipping a synthesized bitstream.

  store.py     — :class:`ScheduleConfig` (a reapplyable point of the
                 search space) and :class:`TuningCache` (atomic on-disk
                 JSON records keyed by :class:`TuningKey`)
  search.py    — :func:`tune_graph` (model-pruned measured search) and
                 :func:`resolve_tuning` (the ``tune=`` argument protocol)
  calibrate.py — :func:`calibrate` (fit the cost model's constants from
                 drift logs), :class:`CalibratedSpec` and its
                 :class:`CalibrationStore` persistence

See ``docs/tuning.md`` for every knob and a worked trace.
"""
from repro.tune.calibrate import (CalibratedSpec, CalibrationResult,
                                  CalibrationStore, calibrate,
                                  calibrate_backend, load_calibration,
                                  resolve_calibration)
from repro.tune.search import (Trial, TuningResult, default_measure,
                               resolve_tuning, tune_graph)
from repro.tune.store import (ScheduleConfig, TuningCache, TuningKey,
                              TuningRecord, default_cache_root)

__all__ = [
    "ScheduleConfig", "TuningCache", "TuningKey", "TuningRecord",
    "default_cache_root", "Trial", "TuningResult", "default_measure",
    "resolve_tuning", "tune_graph",
    "CalibratedSpec", "CalibrationResult", "CalibrationStore",
    "calibrate", "calibrate_backend", "load_calibration",
    "resolve_calibration",
]
