"""Cost-model calibration: fit the spec's constants from drift logs.

The analytic model (:func:`repro.core.vectorize.modeled_plane_time`)
prices a fusion group as

``t = grid * (step_overhead_s + max(bytes_step / hbm_bw,
sum_kind(steps[kind] * ii_scale[kind]) / clock_hz))``

with constants declared by :class:`~repro.core.vectorize.TPUSpec`.
Those constants are datasheet numbers — on the machine actually
serving requests (often a CPU host running Pallas in interpreter
mode) they are ~15x off and *misordered* (ROADMAP item 3, observed
in ``BENCH_parallel.json``).  This module closes the loop the way the
de Fine Licht HLS-transformations work calibrates its resource model
from synthesis reports: every drift row (PR 7) now carries the
spec-independent **features** behind its modeled time (grid,
bytes/step, per-stage-kind compute steps — see
:func:`repro.core.vectorize.schedule_features`), which makes the
model **linear in the constants' reciprocals** once each group's
``max(dma, compute)`` branch is decided.  :func:`calibrate` solves
that with an alternating active-set, relative-error-weighted least
squares:

1. canonicalize rows (drop unusable, dedupe exact duplicates, sort) —
   the fit is invariant to row order and duplication;
2. under the current constants, mark each group DMA- or
   compute-bound; the model is now linear in
   ``theta = [step_overhead_s, 1/hbm_bw, alpha_kind...]`` where
   ``alpha_kind = ii_scale[kind] / clock_hz``;
3. solve the weighted normal problem (rows scaled by ``1/measured``
   so every row contributes *relative* error — a 4 ms blur and a
   40 us copy weigh the same), drop all-zero columns (their constants
   keep seed values), clamp nonphysical negatives;
4. repeat until the branch assignment stops changing.

Too few rows or a rank-deficient design **falls back to the seed
spec with a warning — never NaN constants**; engine ``compile`` rows
(whose measured time includes jit compilation, PR 7) are excluded by
default so they cannot bias the fit.

The result is a :class:`CalibratedSpec` — a frozen
:class:`~repro.core.vectorize.TPUSpec` subclass carrying the fitted
constants plus a per-stage-kind ``ii_scale`` — persisted beside the
:class:`~repro.tune.store.TuningCache` (atomic JSON, keyed by backend
``cache_key()`` + device kind, versioned) by :class:`CalibrationStore`
and resolved into compiles by
:func:`repro.backends.resolve_calibrated` /
``compile_graph(calibrate="auto")``.  Because
:meth:`~repro.backends.Backend.digest` covers every spec field,
calibrated runs get their own compile/tuning cache namespace
automatically while uncalibrated digests are untouched.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import threading
import time
import warnings
from typing import Any, Iterable

import numpy as np

from repro.core.vectorize import TPUSpec, V5E
from repro.obs.drift import DriftLog, DriftRow
from repro.tune.store import default_cache_root, detect_device_kind

__all__ = ["CalibratedSpec", "CalibrationResult", "CalibrationStore",
           "calibrate", "calibrate_backend", "load_calibration",
           "resolve_calibration", "spec_to_json", "spec_from_json",
           "CALIBRATION_VERSION", "MIN_ROWS"]

#: bump when the fit/record format changes; readers skip other versions
CALIBRATION_VERSION = 1

#: prior fits kept in a record's ``history`` chain (freshest first)
_HISTORY_KEEP = 8

#: below this many usable rows the fit refuses and keeps the seed spec
MIN_ROWS = 8

#: maximum alternating (branch-assign / solve) iterations
_MAX_ITER = 25


@dataclasses.dataclass(frozen=True)
class CalibratedSpec(TPUSpec):
    """A :class:`~repro.core.vectorize.TPUSpec` with fitted constants.

    Being a subclass is the whole trick: every consumer that threads a
    spec (vectorizer sweep, partitioner budget, tuner prior, backend
    digest) picks up the calibrated constants with no new plumbing.
    ``ii_scale`` is a tuple of ``(stage_kind, multiplier)`` pairs
    (tuple, not dict, to stay hashable for the frozen dataclass);
    :func:`repro.core.vectorize.modeled_plane_time` multiplies each
    stage's declared issue interval by its kind's multiplier, so the
    fit can express "stencil steps cost 3x what the seed ii claims"
    without touching graph declarations.

    >>> s = CalibratedSpec(ii_scale=(("stencil", 2.0),), n_rows=12)
    >>> dict(s.ii_scale)["stencil"]
    2.0
    >>> isinstance(s, TPUSpec)
    True
    """

    #: per-stage-kind issue-interval multipliers, sorted by kind
    ii_scale: tuple = ()
    #: drift rows the fit consumed (provenance, not behaviour)
    n_rows: int = 0
    #: fit/record format version
    calibration_version: int = CALIBRATION_VERSION

    def scale_for(self, kind: str) -> float:
        return dict(self.ii_scale).get(kind, 1.0)


def spec_to_json(spec: TPUSpec) -> dict[str, Any]:
    """JSON-ready dict of every dataclass field (ii_scale as lists)."""
    out: dict[str, Any] = {}
    for f in dataclasses.fields(spec):
        v = getattr(spec, f.name)
        if f.name == "ii_scale":
            v = [[k, s] for k, s in v]
        out[f.name] = v
    return out


def spec_from_json(d: dict[str, Any]) -> CalibratedSpec:
    """Inverse of :func:`spec_to_json` (unknown keys are ignored).

    >>> s = CalibratedSpec(clock_hz=2e9, ii_scale=(("point", 1.5),))
    >>> spec_from_json(spec_to_json(s)) == s
    True
    """
    fields = {f.name for f in dataclasses.fields(CalibratedSpec)}
    kw = {k: v for k, v in d.items() if k in fields}
    if "ii_scale" in kw:
        kw["ii_scale"] = tuple((str(k), float(s)) for k, s in kw["ii_scale"])
    return CalibratedSpec(**kw)


@dataclasses.dataclass
class CalibrationResult:
    """Outcome of one fit: the spec to use plus an audit trail.

    ``fitted`` False means the fallback path ran (``spec`` is the seed
    spec, ``warning`` says why); either way ``spec`` is usable and
    finite — callers never need to re-check for NaN.
    """

    spec: TPUSpec
    fitted: bool
    n_rows: int = 0               #: usable rows the fit consumed
    n_excluded: int = 0           #: rows dropped by kind (jit-polluted)
    n_unusable: int = 0           #: rows without features / nonfinite
    n_duplicates: int = 0         #: exact duplicates collapsed
    iterations: int = 0
    warning: str | None = None
    #: fitted reciprocal-space parameters, for introspection/tests
    params: dict[str, float] = dataclasses.field(default_factory=dict)

    def describe(self) -> str:
        if not self.fitted:
            return f"calibration fallback ({self.warning})"
        s = self.spec
        scales = ",".join(f"{k}={v:.3g}" for k, v in
                          getattr(s, "ii_scale", ()))
        return (f"calibrated from {self.n_rows} rows: "
                f"clock={s.clock_hz:.3g}Hz hbm_bw={s.hbm_bw:.3g}B/s "
                f"overhead={s.step_overhead_s:.3g}s ii_scale[{scales}]")


# ----------------------------------------------------------------------
# row canonicalization
# ----------------------------------------------------------------------

def _canon_rows(rows: Iterable[DriftRow],
                exclude_kinds: tuple[str, ...]) -> tuple[list, int, int, int]:
    """Filter, dedupe and sort rows into fit inputs.

    Returns ``(fit_rows, n_excluded, n_unusable, n_duplicates)`` where
    each fit row is ``(measured_s, items, groups)`` with ``groups`` a
    list of ``(grid, bytes_step, {kind: steps})``.  Exact duplicates
    collapse to one and the survivors are sorted by their canonical
    JSON encoding, so the design matrix — and therefore the solution,
    bit for bit — is independent of input order and duplication.
    """
    n_excluded = n_unusable = 0
    keyed: dict[str, tuple] = {}
    n_seen = 0
    for r in rows:
        if r.kind in exclude_kinds:
            n_excluded += 1
            continue
        feats = r.features
        if (feats is None or not feats.get("groups")
                or not np.isfinite(r.measured_s) or r.measured_s <= 0):
            n_unusable += 1
            continue
        try:
            groups = [(int(g["grid"]), float(g["bytes_step"]),
                       {str(k): float(v)
                        for k, v in sorted(g.get("steps", {}).items())})
                      for g in feats["groups"]]
        except (KeyError, TypeError, ValueError):
            n_unusable += 1
            continue
        if any(g[0] <= 0 for g in groups):
            n_unusable += 1
            continue
        items = int(feats.get("items", 1))
        row = (float(r.measured_s), items, groups)
        key = json.dumps(row, sort_keys=True)
        n_seen += 1
        keyed[key] = row
    n_duplicates = n_seen - len(keyed)
    fit_rows = [keyed[k] for k in sorted(keyed)]
    return fit_rows, n_excluded, n_unusable, n_duplicates


# ----------------------------------------------------------------------
# the fit
# ----------------------------------------------------------------------

def _assign_branches(fit_rows: list, theta_o: float, theta_b: float,
                     alpha: dict[str, float]) -> list[list[bool]]:
    """Per-row, per-group: True when DMA-bound under current theta."""
    out = []
    for _, _, groups in fit_rows:
        out.append([bytes_step * theta_b
                    >= sum(steps[k] * alpha.get(k, 0.0) for k in steps)
                    for _, bytes_step, steps in groups])
    return out


def calibrate(rows: Iterable[DriftRow] | DriftLog,
              spec: TPUSpec | None = None, *,
              min_rows: int = MIN_ROWS,
              exclude_kinds: tuple[str, ...] = ("compile",),
              huber_delta: float | None = None,
              max_iter: int = _MAX_ITER) -> CalibrationResult:
    """Fit a :class:`CalibratedSpec` from drift rows.

    ``rows`` is a :class:`~repro.obs.drift.DriftLog` or an iterable of
    :class:`~repro.obs.drift.DriftRow`; only rows carrying features
    and a finite positive ``measured_s`` participate.  ``spec`` seeds
    the iteration and supplies every constant the data cannot identify
    (default :data:`~repro.core.vectorize.V5E`).

    ``exclude_kinds`` drops rows whose measured time is not a clean
    launch measurement — by default the engine's ``compile`` rows,
    whose ``measured_s`` includes jit compilation (PR 7) and would
    drag every constant toward "first launches are slow".  Pass ``()``
    to fit on everything.

    ``huber_delta`` (in units of relative residual, e.g. ``3.0``)
    switches the final solve to Huber IRLS so a few wild outliers
    (preempted measurements) cannot dominate; ``None`` keeps plain
    least squares, which is exactly recoverable in tests.

    Never raises on bad data and never returns NaN constants: with
    fewer than ``min_rows`` usable rows, or a design matrix that
    cannot identify the remaining constants (rank-deficient), the
    seed ``spec`` comes back with ``fitted=False`` and a warning.
    """
    seed = spec if spec is not None else V5E
    if isinstance(rows, DriftLog):
        rows = rows.rows()
    fit_rows, n_excl, n_bad, n_dup = _canon_rows(tuple(rows),
                                                 tuple(exclude_kinds))

    def fallback(why: str) -> CalibrationResult:
        warnings.warn(f"calibration fell back to the seed spec: {why}",
                      RuntimeWarning, stacklevel=2)
        return CalibrationResult(spec=seed, fitted=False,
                                 n_rows=len(fit_rows), n_excluded=n_excl,
                                 n_unusable=n_bad, n_duplicates=n_dup,
                                 warning=why)

    if len(fit_rows) < min_rows:
        return fallback(f"{len(fit_rows)} usable rows < min_rows="
                        f"{min_rows} ({n_bad} without features/nonfinite, "
                        f"{n_excl} excluded by kind)")

    kinds = sorted({k for _, _, groups in fit_rows
                    for _, _, steps in groups for k in steps})
    if not kinds:
        return fallback("no compute steps in any row")

    # seed theta: overhead, 1/bw, and alpha_k = ii_scale_k / clock
    seed_scale = dict(getattr(seed, "ii_scale", ()) or ())
    theta_o = float(seed.step_overhead_s)
    theta_b = 1.0 / float(seed.hbm_bw)
    alpha = {k: seed_scale.get(k, 1.0) / float(seed.clock_hz)
             for k in kinds}

    branches = _assign_branches(fit_rows, theta_o, theta_b, alpha)
    iterations = 0
    for iterations in range(1, max_iter + 1):
        cols = ["overhead", "bw"] + kinds
        A = np.zeros((len(fit_rows), len(cols)))
        y = np.ones(len(fit_rows))
        for i, (measured, items, groups) in enumerate(fit_rows):
            w = items / measured          # relative-error weighting
            for (grid, bytes_step, steps), dma in zip(groups, branches[i]):
                A[i, 0] += w * grid
                if dma:
                    A[i, 1] += w * grid * bytes_step
                else:
                    for k, s in steps.items():
                        A[i, 2 + kinds.index(k)] += w * grid * s
        live = [j for j in range(len(cols)) if np.any(A[:, j] != 0.0)]
        if not live:
            return fallback("design matrix is all zeros")
        sol, _, rank, _ = np.linalg.lstsq(A[:, live], y, rcond=None)
        if rank < len(live):
            return fallback(
                f"rank-deficient design (rank {rank} < {len(live)} "
                f"identifiable constants); need more workload variety")
        if not np.all(np.isfinite(sol)):
            return fallback("solver returned non-finite constants")
        if huber_delta is not None:
            # IRLS: down-weight rows whose relative residual exceeds
            # delta, re-solve until weights settle (few steps suffice)
            wts = np.ones(len(fit_rows))
            for _ in range(10):
                res = A[:, live] @ sol - y
                new = np.where(np.abs(res) <= huber_delta, 1.0,
                               huber_delta / np.maximum(np.abs(res), 1e-30))
                if np.allclose(new, wts):
                    break
                wts = new
                sw = np.sqrt(wts)
                sol, _, rank, _ = np.linalg.lstsq(
                    A[:, live] * sw[:, None], y * sw, rcond=None)
                if rank < len(live) or not np.all(np.isfinite(sol)):
                    return fallback("robust re-solve degenerated")
        # scatter solution back; dead columns keep their current value
        new_o, new_b = theta_o, theta_b
        new_alpha = dict(alpha)
        for j, v in zip(live, sol):
            if cols[j] == "overhead":
                new_o = max(float(v), 0.0)       # can't owe time back
            elif cols[j] == "bw":
                new_b = float(v) if v > 0 else theta_b
            else:
                new_alpha[cols[j]] = float(v) if v > 0 else alpha[cols[j]]
        theta_o, theta_b, alpha = new_o, new_b, new_alpha
        new_branches = _assign_branches(fit_rows, theta_o, theta_b, alpha)
        if new_branches == branches:
            break
        branches = new_branches

    # translate reciprocal-space theta back into spec constants.  The
    # reference kind (largest total step mass) pins clock_hz; other
    # kinds become ii multipliers relative to it.
    mass = {k: 0.0 for k in kinds}
    for _, items, groups in fit_rows:
        for grid, _, steps in groups:
            for k, s in steps.items():
                mass[k] += items * grid * s
    ref = max(kinds, key=lambda k: (mass[k], k))
    clock = 1.0 / alpha[ref] if alpha[ref] > 0 else float(seed.clock_hz)
    ii_scale = tuple((k, 1.0 if k == ref else alpha[k] * clock)
                     for k in kinds)
    fitted = dataclasses.replace(
        CalibratedSpec(**{f.name: getattr(seed, f.name)
                          for f in dataclasses.fields(TPUSpec)}),
        clock_hz=clock, hbm_bw=1.0 / theta_b, step_overhead_s=theta_o,
        ii_scale=ii_scale, n_rows=len(fit_rows),
        calibration_version=CALIBRATION_VERSION)
    params = {"step_overhead_s": theta_o, "inv_hbm_bw": theta_b}
    params.update({f"alpha_{k}": alpha[k] for k in kinds})
    return CalibrationResult(spec=fitted, fitted=True,
                             n_rows=len(fit_rows), n_excluded=n_excl,
                             n_unusable=n_bad, n_duplicates=n_dup,
                             iterations=iterations, params=params)


# ----------------------------------------------------------------------
# persistence
# ----------------------------------------------------------------------

class CalibrationStore:
    """Atomic, *versioned* on-disk store of fitted specs.

    One JSON file per ``(backend cache_key, device_kind)`` under
    ``<root>/calibration/`` — same root as the
    :class:`~repro.tune.store.TuningCache`, so one directory holds
    everything learned about this machine.  Writes go through a temp
    file + ``os.replace`` (never a torn record); records carry
    :data:`CALIBRATION_VERSION` and readers skip other versions.

    Each record is a **version chain**: the current fit (monotone
    ``seq``, a ``stale`` flag) plus up to ``_HISTORY_KEEP`` prior fits
    under ``history`` (freshest first).  :meth:`put` supersedes the
    current fit, pushing it into history; :meth:`mark_stale` flags the
    current fit without deleting anything (the sentinel does this when
    drift statistics say the fit no longer predicts reality);
    :meth:`get` returns the **freshest non-stale** spec in the chain —
    so ``compile_graph(calibrate="auto")`` quietly falls back to an
    older good fit, or to a fresh fit from the drift log, rather than
    serving constants known to be wrong.  Records written before this
    scheme read as ``seq 0, not stale`` — both directions stay
    compatible without a :data:`CALIBRATION_VERSION` bump.
    """

    def __init__(self, root: str | None = None):
        self.root = os.path.join(root or default_cache_root(),
                                 "calibration")
        self._memo: dict[str, CalibratedSpec | None] = {}
        self._lock = threading.Lock()

    def _path(self, backend_key: str, device_kind: str) -> str:
        digest = hashlib.sha256(
            json.dumps([backend_key, device_kind]).encode()
        ).hexdigest()[:24]
        return os.path.join(self.root, digest + ".json")

    def _load(self, path: str) -> dict[str, Any] | None:
        """The raw record at ``path``, or None (missing/torn/foreign)."""
        try:
            with open(path) as f:
                raw = json.load(f)
            if raw.get("version") == CALIBRATION_VERSION:
                return raw
        except (OSError, ValueError, TypeError):
            pass
        return None

    def _write(self, path: str, record: dict[str, Any]) -> None:
        os.makedirs(self.root, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                f.write(json.dumps(record, indent=1))
            os.replace(tmp, path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    @staticmethod
    def _chain(raw: dict[str, Any]) -> list[dict[str, Any]]:
        """Version entries, freshest first: the record then history."""
        chain = [raw]
        hist = raw.get("history")
        if isinstance(hist, list):
            chain.extend(h for h in hist if isinstance(h, dict))
        return chain

    def latest(self, backend_key: str,
               device_kind: str) -> dict[str, Any] | None:
        """The raw current record (including ``seq``/``stale``/
        ``history``), or None."""
        return self._load(self._path(backend_key, device_kind))

    def versions(self, backend_key: str,
                 device_kind: str) -> list[dict[str, Any]]:
        """The whole version chain, freshest first (may be empty)."""
        raw = self.latest(backend_key, device_kind)
        return self._chain(raw) if raw is not None else []

    def get(self, backend_key: str,
            device_kind: str) -> CalibratedSpec | None:
        """The freshest **non-stale** fitted spec, or None."""
        path = self._path(backend_key, device_kind)
        with self._lock:
            if path in self._memo:
                return self._memo[path]
        spec: CalibratedSpec | None = None
        raw = self._load(path)
        if raw is not None:
            for entry in self._chain(raw):
                if entry.get("stale"):
                    continue
                try:
                    spec = spec_from_json(entry["spec"])
                except (KeyError, ValueError, TypeError):
                    continue
                break
        with self._lock:
            self._memo[path] = spec
        return spec

    def put(self, backend_key: str, device_kind: str,
            spec: CalibratedSpec, *,
            result: CalibrationResult | None = None) -> str:
        """Persist ``spec`` as the new current version; returns the
        record path.  The previous current version (if any) moves into
        ``history`` with its ``stale`` flag intact."""
        path = self._path(backend_key, device_kind)
        prev = self._load(path)
        seq = 1
        history: list[dict[str, Any]] = []
        if prev is not None:
            seq = int(prev.get("seq", 0)) + 1
            demoted = {k: prev[k] for k in
                       ("seq", "created_at", "spec", "stale", "fit")
                       if k in prev}
            demoted.setdefault("seq", 0)
            demoted.setdefault("stale", False)
            history = [demoted] + self._chain(prev)[1:]
            history = history[:_HISTORY_KEEP]
        record: dict[str, Any] = {
            "version": CALIBRATION_VERSION,
            "backend": backend_key,
            "device_kind": device_kind,
            "created_at": time.time(),
            "seq": seq,
            "stale": False,
            "spec": spec_to_json(spec),
        }
        if result is not None:
            record["fit"] = {"n_rows": result.n_rows,
                             "n_excluded": result.n_excluded,
                             "n_unusable": result.n_unusable,
                             "iterations": result.iterations,
                             "params": result.params}
        if history:
            record["history"] = history
        self._write(path, record)
        with self._lock:
            self._memo[path] = spec
        return path

    def mark_stale(self, backend_key: str, device_kind: str) -> bool:
        """Flag the current fit stale (kept on disk, skipped by
        :meth:`get`).  Returns True when a record was updated."""
        path = self._path(backend_key, device_kind)
        raw = self._load(path)
        if raw is None or raw.get("stale"):
            return raw is not None
        raw["stale"] = True
        raw["stale_at"] = time.time()
        self._write(path, raw)
        with self._lock:
            self._memo.pop(path, None)
        return True

    def invalidate(self, backend_key: str, device_kind: str) -> None:
        path = self._path(backend_key, device_kind)
        with self._lock:
            self._memo.pop(path, None)
        try:
            os.unlink(path)
        except OSError:
            pass

    def clear(self) -> None:
        with self._lock:
            self._memo.clear()
        try:
            names = os.listdir(self.root)
        except OSError:
            return
        for n in names:
            if n.endswith(".json"):
                try:
                    os.unlink(os.path.join(self.root, n))
                except OSError:
                    pass


# ----------------------------------------------------------------------
# backend-facing entry points
# ----------------------------------------------------------------------

def calibrate_backend(backend, drift=None, *,
                      store: CalibrationStore | None = None,
                      device_kind: str | None = None,
                      persist: bool = True,
                      **fit_kw) -> CalibrationResult:
    """Fit (and by default persist) a calibrated spec for ``backend``.

    ``drift`` follows the :func:`repro.obs.drift.resolve_drift`
    protocol (``None`` -> the default drift log, a path, a
    :class:`~repro.obs.drift.DriftLog`) or may be a plain iterable of
    rows.  On a successful fit the spec lands in ``store`` under the
    backend's :meth:`~repro.backends.Backend.cache_key` and the
    detected device kind, where ``compile_graph(calibrate="auto")``
    finds it.
    """
    from repro.backends import resolve
    be = resolve(backend)
    if drift is None or isinstance(drift, (bool, str, DriftLog)):
        from repro.obs.drift import resolve_drift
        log = resolve_drift(True if drift is None else drift)
        rows: Iterable[DriftRow] = log.rows() if log is not None else ()
    else:
        rows = drift
    result = calibrate(rows, spec=be.spec, **fit_kw)
    if result.fitted and persist:
        if device_kind is None:
            device_kind = detect_device_kind()
        (store or CalibrationStore()).put(
            be.cache_key(), device_kind, result.spec, result=result)
    return result


def load_calibration(backend, *, store: CalibrationStore | None = None,
                     device_kind: str | None = None) -> CalibratedSpec | None:
    """The persisted calibrated spec for ``backend`` here, or None."""
    from repro.backends import resolve
    be = resolve(backend)
    if device_kind is None:
        device_kind = detect_device_kind()
    return (store or CalibrationStore()).get(be.cache_key(), device_kind)


def resolve_calibration(backend, calibrate: Any = "auto", *,
                        store: CalibrationStore | None = None,
                        device_kind: str | None = None,
                        drift=None) -> TPUSpec | None:
    """Normalize a user-facing ``calibrate=`` argument into a spec.

    ``None``/``False`` opt out (returns None — the caller keeps the
    seed spec and, crucially, its digest); a
    :class:`~repro.core.vectorize.TPUSpec` instance passes through;
    ``"auto"``/``True`` loads the persisted spec for this backend +
    device kind, fitting one from the drift log first when the store
    is empty but enough rows have accumulated.  An unusable value
    raises :class:`TypeError` — silently ignoring a typo'd
    ``calibrate="atuo"`` would quietly serve uncalibrated priors.
    """
    if calibrate is None or calibrate is False:
        return None
    if isinstance(calibrate, TPUSpec):
        return calibrate
    if calibrate is True:
        calibrate = "auto"
    if calibrate != "auto":
        raise TypeError(f"calibrate must be 'auto', True/False/None or a "
                        f"TPUSpec; got {calibrate!r}")
    spec = load_calibration(backend, store=store, device_kind=device_kind)
    if spec is not None:
        return spec
    from repro.obs.drift import resolve_drift
    log = resolve_drift(drift)
    if log is None:
        return None
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        result = calibrate_backend(backend, log, store=store,
                                   device_kind=device_kind)
    return result.spec if result.fitted else None
