"""Generic N-stage streaming pipeline kernel (the paper artifact).

The *generated* fused top-level kernel lives in
:func:`repro.core.fusion.lower_group_pallas` — it is synthesized from a
dataflow graph.  This module provides the standalone building block for
microbenchmarks and kernel tests: fuse a chain of pointwise stage
functions over a 2-D plane into a single ``pallas_call`` whose grid
streams hardware-aligned tiles through all stages in VMEM.

It demonstrates in isolation what the dataflow transformation buys:
one HBM read + one HBM write for the whole chain, versus one
read + write *per stage* in the staged (AnyHLS-like) execution.
"""
from __future__ import annotations

import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["stream_pipeline", "stream_pipeline_staged"]


def _kernel(x_ref, o_ref, *, fns: tuple[Callable, ...]):
    v = x_ref[...]
    for fn in fns:           # the task chain; FIFO hand-off is the VMEM value
        v = fn(v)
    o_ref[...] = v.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("fns", "tile", "interpret"))
def stream_pipeline(x: jnp.ndarray, fns: tuple[Callable, ...],
                    tile: tuple[int, int] = (256, 512),
                    interpret: bool = True) -> jnp.ndarray:
    """Fused execution of a pointwise stage chain over x: (H, W)."""
    H, W = x.shape
    th = min(tile[0], _round_up(H, 8))
    tw = min(tile[1], _round_up(W, 128))
    Hp, Wp = _round_up(H, th), _round_up(W, tw)
    xp = jnp.pad(x, ((0, Hp - H), (0, Wp - W)))
    out = pl.pallas_call(
        functools.partial(_kernel, fns=fns),
        grid=(Hp // th, Wp // tw),
        in_specs=[pl.BlockSpec((th, tw), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((th, tw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Hp, Wp), x.dtype),
        interpret=interpret,
    )(xp)
    return out[:H, :W]


def stream_pipeline_staged(x: jnp.ndarray, fns: Sequence[Callable]
                           ) -> jnp.ndarray:
    """The no-dataflow baseline: each stage materializes to HBM."""
    v = x
    for fn in fns:
        v = jax.lax.optimization_barrier(fn(v))
    return v


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
