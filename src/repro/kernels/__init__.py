"""Pallas TPU kernels for the compute hot-spots (+ ops.py wrappers, ref.py oracles).

- stream_pipeline.py — generic fused N-stage streaming pipeline
  (standalone form of the generated top-level kernel in core/fusion.py)
- flash_attention.py — streaming attention over KV blocks
- decode_attention.py — single-token attention vs KV cache
- fused_mlp.py — RMSNorm->SwiGLU with d_ff streamed through VMEM
- ssd_scan.py — Mamba2 SSD chunked scan with VMEM-carried state

All validated in interpret mode against ref.py; models use kernels
through ops.py only.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
