"""Single-token (decode) attention Pallas kernel.

Decode attention is the memory-bound end of the roofline: one query
token versus an S-long KV cache.  FLOWER's burst-transfer insight
applies directly — the KV cache is streamed through VMEM in long
contiguous blocks (one DMA burst per block) while the online-softmax
state rides in VMEM scratch; the cache is read from HBM exactly once.

GQA layout trick: the ``G = Hq/Hkv`` query heads that share one KV head
form the *rows* of the matmul tile, so the MXU sees a (G, D) x (D, bk)
problem instead of G rank-1 products (G is padded to the 8-row
sublane).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, bias_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale: float):
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                  # (G, D)
    k = k_ref[0].astype(jnp.float32)                  # (bk, D)
    v = v_ref[0].astype(jnp.float32)                  # (bk, D)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (G, bk)
    logits = logits + bias_ref[0].astype(jnp.float32)[None, :]

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, -1, keepdims=True))
    p = jnp.exp(logits - m_new)
    p = jnp.where(m_new > NEG_INF / 2, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "scale", "interpret"))
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     bias: jnp.ndarray | None = None,
                     block_k: int = 512, scale: float | None = None,
                     interpret: bool = True) -> jnp.ndarray:
    """q: (B, Hq, Dk); k: (B, Hkv, S, Dk); v: (B, Hkv, S, Dv);
    bias: (B, S) additive mask.  Returns (B, Hq, Dv).

    ``bias`` carries -inf for cache slots past the current length.
    Dv may differ from Dk (MLA latent cache).
    """
    B, Hq, D = q.shape
    _, Hkv, S, _ = k.shape
    Dv = v.shape[-1]
    G = Hq // Hkv
    Gp = _round_up(G, 8)                      # sublane alignment
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    bk = min(block_k, _round_up(S, 128))
    Sp = _round_up(S, bk)

    if bias is None:
        bias = jnp.zeros((B, S), jnp.float32)
    bias = jnp.pad(bias.astype(jnp.float32), ((0, 0), (0, Sp - S)),
                   constant_values=NEG_INF)
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))

    qg = q.reshape(B, Hkv, G, D)
    qg = jnp.pad(qg, ((0, 0), (0, 0), (0, Gp - G), (0, 0)))
    qf = qg.reshape(B * Hkv, Gp, D)
    kf = kp.reshape(B * Hkv, Sp, D)
    vf = vp.reshape(B * Hkv, Sp, Dv)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=(B * Hkv, Sp // bk),
        in_specs=[
            pl.BlockSpec((1, Gp, D), lambda bh, ki: (bh, 0, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, Dv), lambda bh, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk), lambda bh, ki, Hkv=Hkv: (bh // Hkv, ki)),
        ],
        out_specs=pl.BlockSpec((1, Gp, Dv), lambda bh, ki: (bh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, Gp, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Gp, 1), jnp.float32),
            pltpu.VMEM((Gp, 1), jnp.float32),
            pltpu.VMEM((Gp, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf, bias)
    return out.reshape(B, Hkv, Gp, Dv)[:, :, :G].reshape(B, Hq, Dv)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
