"""Fused RMSNorm -> SwiGLU MLP streaming Pallas kernel.

This is the LM-block instance of FLOWER's top-level-kernel generation:
the chain  norm -> (x@Wg, x@Wu) -> silu·mul -> @Wd  is a 4-stage
dataflow graph whose intermediates (the (T, d_ff) activations) normally
round-trip through HBM.  The fused kernel streams d_ff *blocks* through
VMEM — each grid step computes a (bt, bf) slice of the hidden
activation and immediately contracts it into the (bt, d) output
accumulator, so the d_ff-sized intermediate never exists in HBM.

HBM traffic: naive = 2·T·d + 3·T·f + weights; fused = 2·T·d + weights.
For f >> d (e.g. qwen1.5-32b: f = 27392 vs d = 5120) this removes the
dominant activation traffic term.

Grid: (T/bt, f/bf); f innermost ("arbitrary") carrying the output
accumulator; the normalized input tile is computed once per row block
(at f-block 0) and parked in VMEM scratch — the FIFO between the norm
task and the matmul tasks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["fused_mlp"]


def _kernel(x_ref, wn_ref, wg_ref, wu_ref, wd_ref, o_ref,
            xn_ref, acc_ref, *, eps: float):
    fi = pl.program_id(1)
    nf = pl.num_programs(1)

    @pl.when(fi == 0)
    def _norm():
        x = x_ref[...].astype(jnp.float32)            # (bt, d)
        var = jnp.mean(x * x, axis=-1, keepdims=True)
        xn_ref[...] = x * jax.lax.rsqrt(var + eps) \
            * wn_ref[...].astype(jnp.float32)[None, :]
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xn = xn_ref[...]                                   # (bt, d) f32
    g = jax.lax.dot_general(xn, wg_ref[...].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    u = jax.lax.dot_general(xn, wu_ref[...].astype(jnp.float32),
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    a = jax.nn.silu(g) * u                             # (bt, bf)
    acc_ref[...] += jax.lax.dot_general(
        a, wd_ref[...].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(fi == nf - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_f",
                                             "interpret", "eps"))
def fused_mlp(x: jnp.ndarray, w_norm: jnp.ndarray, w_gate: jnp.ndarray,
              w_up: jnp.ndarray, w_down: jnp.ndarray, eps: float = 1e-6,
              block_t: int = 256, block_f: int = 512,
              interpret: bool = True) -> jnp.ndarray:
    """x: (T, d); w_gate/w_up: (d, f); w_down: (f, d) -> (T, d)."""
    T, d = x.shape
    f = w_gate.shape[1]
    bt = min(block_t, _round_up(T, 8))
    bf = min(block_f, _round_up(f, 128))
    Tp, fp = _round_up(T, bt), _round_up(f, bf)

    xp = jnp.pad(x, ((0, Tp - T), (0, 0)))
    wg = jnp.pad(w_gate, ((0, 0), (0, fp - f)))
    wu = jnp.pad(w_up, ((0, 0), (0, fp - f)))
    wd = jnp.pad(w_down, ((0, fp - f), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(Tp // bt, fp // bf),
        in_specs=[
            pl.BlockSpec((bt, d), lambda t, fi: (t, 0)),
            pl.BlockSpec((d,), lambda t, fi: (0,)),
            pl.BlockSpec((d, bf), lambda t, fi: (0, fi)),
            pl.BlockSpec((d, bf), lambda t, fi: (0, fi)),
            pl.BlockSpec((bf, d), lambda t, fi: (fi, 0)),
        ],
        out_specs=pl.BlockSpec((bt, d), lambda t, fi: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((Tp, d), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bt, d), jnp.float32),
            pltpu.VMEM((bt, d), jnp.float32),
        ],
        interpret=interpret,
    )(xp, w_norm, wg, wu, wd)
    return out[:T]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
