"""Mamba2 SSD (state-space duality) chunked-scan Pallas kernel.

The SSD computation is itself a dataflow pipeline over *chunks*:

    read chunk -> within-chunk "attention" (quadratic in L, cheap)
               -> chunk-final state contribution
               -> cross-chunk recurrence  (the FIFO-carried state)
               -> state-to-output correction -> write chunk

The cross-chunk state (P, N per head) is exactly a FLOWER channel: it
lives in VMEM scratch and is carried across the sequential chunk grid
dimension, so the O(S·N·P) recurrent state never touches HBM.

Inputs are pre-scaled outside the kernel (xd = x*dt, dA = dt*A) so the
kernel body is pure matmul + decay algebra and stays free of captured
constants.

Grid: ``(B*H, S/L)`` with the chunk dimension sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan"]


def _kernel(xd_ref, da_ref, b_ref, c_ref, y_ref, fs_ref, state_ref,
            *, L: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xd = xd_ref[0].astype(jnp.float32)        # (L, P)  x*dt
    da = da_ref[0].astype(jnp.float32)        # (1, L)  dt*A  (row vector)
    B = b_ref[0].astype(jnp.float32)          # (L, N)
    C = c_ref[0].astype(jnp.float32)          # (L, N)

    cs = jnp.cumsum(da, axis=-1)              # (1, L)
    # segsum: sum_{k=j+1..i} da_k  = cs[i] - cs[j]; lower-triangular
    diff = cs.reshape(L, 1) - cs.reshape(1, L)
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    ldec = jnp.where(ii >= jj, jnp.exp(diff), 0.0)          # (L, L)

    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, L)
    y = jax.lax.dot_general(cb * ldec, xd, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (L, P)

    # contribution of the carried state: y += (C * exp(cs)) @ state^T
    decay_in = jnp.exp(cs).reshape(L, 1)                     # (L, 1)
    y = y + jax.lax.dot_general(
        C * decay_in, state_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                  # (L, P)
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: state = state * exp(cs[-1]) + (xd * decay_out)^T @ B
    total = jnp.exp(cs[0, L - 1])
    decay_out = jnp.exp(cs[0, L - 1] - cs).reshape(L, 1)     # (L, 1)
    contrib = jax.lax.dot_general(
        xd * decay_out, B, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                  # (P, N)
    state_ref[...] = state_ref[...] * total + contrib

    @pl.when(ci == nc - 1)
    def _done():
        fs_ref[0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             B: jnp.ndarray, C: jnp.ndarray, chunk: int = 64,
             interpret: bool = True) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Same contract as :func:`repro.kernels.ref.ssd_scan_ref`.

    x: (b, s, h, p); dt: (b, s, h); A: (h,); B, C: (b, s, g, n).
    Returns (y (b, s, h, p), final_state (b, h, p, n)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    assert s % chunk == 0, f"seq {s} % chunk {chunk} != 0"
    L = chunk

    # pre-scale outside the kernel (keeps the body constant-free)
    xd = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])
    da = dt.astype(jnp.float32) * A.astype(jnp.float32)      # (b, s, h)

    # flatten (b, h) -> rows; group-broadcast B/C via the index map
    xdf = jnp.moveaxis(xd, 2, 1).reshape(b * h, s, p)
    daf = jnp.moveaxis(da, 2, 1).reshape(b * h, 1, s)
    Bf = jnp.moveaxis(B, 2, 1).reshape(b * g, s, n)
    Cf = jnp.moveaxis(C, 2, 1).reshape(b * g, s, n)

    def bc_idx(bh, ci, *, h=h, g=g, rep=rep):
        return ((bh // h) * g + (bh % h) // rep, ci, 0)

    y, fs = pl.pallas_call(
        functools.partial(_kernel, L=L),
        grid=(b * h, s // L),
        in_specs=[
            pl.BlockSpec((1, L, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1, L), lambda bh, ci: (bh, 0, ci)),
            pl.BlockSpec((1, L, n), bc_idx),
            pl.BlockSpec((1, L, n), bc_idx),
        ],
        out_specs=[
            pl.BlockSpec((1, L, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, p, n), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, p), x.dtype),
            jax.ShapeDtypeStruct((b * h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xdf, daf, Bf, Cf)

    y = jnp.moveaxis(y.reshape(b, h, s, p), 1, 2)
    return y, fs.reshape(b, h, p, n)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
