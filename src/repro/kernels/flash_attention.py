"""Streaming (flash) attention Pallas kernel.

This is the FLOWER dataflow transformation applied to attention: the
naive kernel materializes the (Sq, Sk) logits to HBM (a multi-stage
chain with a global-memory round trip); the streaming kernel walks KV
*blocks* through VMEM like FIFO items, carrying the online-softmax
state (m, l, acc) in VMEM scratch — read task (DMA of Q/K/V tiles),
compute tasks (logits → rescale → accumulate), write task (normalized
output tile).  HBM traffic drops from O(Sq·Sk) to O(Sq·D + Sk·D).

Layout: the MXU wants the contracting dim minor — all matmuls here are
(bq, D)·(D, bk) and (bq, bk)·(bk, D) with D, bk multiples of 128.

Grid: ``(B*Hq, Sq/bq, Sk/bk)``; the KV dimension is innermost and
"arbitrary" (sequential) so the scratch carry is legal; B*Hq and the Q
dimension are parallel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention"]

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, bias_ref, o_ref,
            m_ref, l_ref, acc_ref, *, scale: float, causal: bool,
            bq: int, bk: int, seq_k: int):
    # note: Dv (v/o/acc minor dim) may differ from Dk (q/k minor dim),
    # e.g. MLA absorbed attention (MQA over the latent cache).
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)               # (bq, D)
    k = k_ref[0].astype(jnp.float32)               # (bk, D)
    v = v_ref[0].astype(jnp.float32)               # (bk, D)
    logits = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (bq, bk)
    logits = logits + bias_ref[0].astype(jnp.float32)[None, :]
    if causal:
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        logits = jnp.where(kpos <= qpos + (seq_k - pl.num_programs(1) * bq),
                           logits, NEG_INF)

    m_prev = m_ref[...]                            # (bq, 1)
    m_cur = jnp.max(logits, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_new)                    # (bq, bk)
    # fully-masked rows: m_new is still NEG_INF -> exp(0)=1 garbage.
    p = jnp.where(m_new > NEG_INF / 2, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)                # (bq, 1)
    l_new = alpha * l_ref[...] + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "scale", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    bias: jnp.ndarray | None = None, causal: bool = True,
                    block_q: int = 128, block_k: int = 128,
                    scale: float | None = None,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (B, Hq, Sq, Dk); k: (B, Hkv, Sk, Dk); v: (B, Hkv, Sk, Dv);
    bias: (B, Sk) additive.  Returns (B, Hq, Sq, Dv).

    Sq, Sk are padded to block multiples internally; GQA handled by the
    KV index map (no materialized repeat).  Dv may differ from Dk (MLA
    absorbed attention == MQA over the latent cache).
    """
    B, Hq, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    Dv = v.shape[-1]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    bq = min(block_q, _round_up(Sq, 8))
    bk = min(block_k, _round_up(Sk, 128))
    Sqp, Skp = _round_up(Sq, bq), _round_up(Sk, bk)

    if bias is None:
        bias = jnp.zeros((B, Sk), q.dtype)
    # fold pad-slot masking into the additive bias (the FLOWER trick of
    # folding boundary handling into the stream contents)
    bias = jnp.pad(bias.astype(jnp.float32), ((0, 0), (0, Skp - Sk)),
                   constant_values=NEG_INF)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, Sqp - Sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Skp - Sk), (0, 0)))

    qf = qp.reshape(B * Hq, Sqp, D)
    kf = kp.reshape(B * Hkv, Skp, D)
    vf = vp.reshape(B * Hkv, Skp, Dv)

    grid = (B * Hq, Sqp // bq, Skp // bk)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, bq=bq,
                          bk=bk, seq_k=Skp),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
            pl.BlockSpec((1, bk, Dv), lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
            pl.BlockSpec((1, bk), lambda bh, qi, ki, Hq=Hq: (bh // Hq, ki)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dv), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sqp, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf, bias)
    return out.reshape(B, Hq, Sqp, Dv)[:, :, :Sq]


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
