"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` function defines the semantics that the corresponding
kernel must reproduce (tests assert allclose across shape/dtype
sweeps).  They are also the XLA lowering used by the models when
``use_pallas=False`` (the dry-run path), so kernel and model semantics
can never diverge.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "rmsnorm_ref", "flash_attention_ref", "decode_attention_ref",
    "fused_mlp_ref", "ssd_scan_ref", "ssd_sequential_ref",
]


def rmsnorm_ref(x: jnp.ndarray, w: jnp.ndarray,
                eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
            ).astype(x.dtype)


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    b, h, s, d = k.shape
    return jnp.broadcast_to(k[:, :, None], (b, h, n_rep, s, d)
                            ).reshape(b, h * n_rep, s, d)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        bias: jnp.ndarray | None = None,
                        causal: bool = True,
                        scale: float | None = None) -> jnp.ndarray:
    """Naive attention oracle.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D); bias: (B, Sk) additive
    (used for padding masks).  GQA handled by repeating KV heads.
    """
    B, Hq, Sq, D = q.shape
    Hkv = k.shape[1]
    k = _repeat_kv(k, Hq // Hkv)
    v = _repeat_kv(v, Hq // Hkv)
    scale = scale if scale is not None else 1.0 / np.sqrt(D)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if bias is not None:
        logits = logits + bias[:, None, None, :].astype(jnp.float32)
    if causal:
        Sk = k.shape[2]
        qi = jnp.arange(Sq)[:, None] + (Sk - Sq)
        ki = jnp.arange(Sk)[None, :]
        logits = jnp.where(ki <= qi, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         bias: jnp.ndarray | None = None,
                         scale: float | None = None) -> jnp.ndarray:
    """Single-token attention oracle. q: (B, Hq, D); k/v: (B, Hkv, S, D).

    ``bias`` (B, S) carries the -inf padding mask for cache slots beyond
    the current length (decode is never causal-within-step).
    """
    out = flash_attention_ref(q[:, :, None], k, v, bias=bias, causal=False,
                              scale=scale)
    return out[:, :, 0]


def fused_mlp_ref(x: jnp.ndarray, w_norm: jnp.ndarray, w_gate: jnp.ndarray,
                  w_up: jnp.ndarray, w_down: jnp.ndarray,
                  eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm -> SwiGLU MLP oracle.  x: (T, d); w_gate/w_up: (d, f);
    w_down: (f, d).  Matmuls accumulate in f32."""
    h = rmsnorm_ref(x, w_norm, eps).astype(jnp.float32)
    g = h @ w_gate.astype(jnp.float32)
    u = h @ w_up.astype(jnp.float32)
    a = jax.nn.silu(g) * u
    return (a @ w_down.astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------------
# Mamba2 SSD (state-space duality) scan
# ----------------------------------------------------------------------
def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """segsum(x)[..., i, j] = sum_{k=j+1..i} x[..., k]  (−inf for j>i)."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    ii = jnp.arange(L)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                 B: jnp.ndarray, C: jnp.ndarray,
                 chunk: int = 64,
                 init_state: jnp.ndarray | None = None
                 ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan (Mamba2, arXiv:2405.21060 Listing 1).

    x:  (b, s, h, p)   inputs per head
    dt: (b, s, h)      positive step sizes (already softplus'ed)
    A:  (h,)           negative decay rates
    B:  (b, s, g, n)   input projections  (g groups broadcast to heads)
    C:  (b, s, g, n)   output projections
    Returns (y (b, s, h, p), final_state (b, h, p, n)).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0, f"seq {s} not divisible by chunk {chunk}"
    nc = s // chunk
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2) if rep > 1 else B      # (b,s,h,n)
    Ch = jnp.repeat(C, rep, axis=2) if rep > 1 else C

    f32 = jnp.float32
    xc = x.reshape(b, nc, chunk, h, p).astype(f32)
    dtc = dt.reshape(b, nc, chunk, h).astype(f32)
    Bc = Bh.reshape(b, nc, chunk, h, n).astype(f32)
    Cc = Ch.reshape(b, nc, chunk, h, n).astype(f32)
    dA = dtc * A.astype(f32)                                 # (b,c,l,h)
    dA = jnp.moveaxis(dA, -1, -2)                            # (b,c,h,l)
    dA_cum = jnp.cumsum(dA, axis=-1)                         # (b,c,h,l)

    # 1. within-chunk (the "quadratic attention-like" part)
    Ldec = jnp.exp(_segsum(dA))                              # (b,c,h,l,l)
    cb = jnp.einsum("bclhn,bcshn->bchls", Cc, Bc)
    dtx = dtc[..., None] * xc                                # (b,c,l,h,p)
    y_diag = jnp.einsum("bchls,bcshp->bclhp", cb * Ldec, dtx)

    # 2. chunk-final states
    decay_states = jnp.exp(dA_cum[..., -1:] - dA_cum)        # (b,c,h,l)
    states = jnp.einsum("bclhn,bchl,bclhp->bchpn", Bc, decay_states, dtx)

    # 3. cross-chunk recurrence (associative; lax.scan over chunks)
    chunk_decay = jnp.exp(dA_cum[..., -1])                   # (b,c,h)
    s0 = (jnp.zeros((b, h, p, n), f32) if init_state is None
          else init_state.astype(f32))

    def step(carry, inp):
        st_in, dec = inp                                      # (b,h,p,n),(b,h)
        new = carry * dec[..., None, None] + st_in
        return new, carry                                     # emit state *before* chunk

    final, prev_states = jax.lax.scan(
        step, s0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)             # (b,c,h,p,n)

    # 4. state -> output within chunk
    state_decay = jnp.exp(dA_cum)                             # (b,c,h,l)
    y_off = jnp.einsum("bclhn,bchpn,bchl->bclhp", Cc, prev_states,
                       state_decay)
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final


def ssd_sequential_ref(x, dt, A, B, C,
                       init_state=None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Token-by-token recurrence (the gold model the chunked scan must
    match): h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ; y_t = C_t h_t."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2) if rep > 1 else B
    Ch = jnp.repeat(C, rep, axis=2) if rep > 1 else C
    f32 = jnp.float32
    s0 = (jnp.zeros((b, h, p, n), f32) if init_state is None
          else init_state.astype(f32))

    def step(carry, inp):
        xt, dtt, Bt, Ct = inp
        dec = jnp.exp(dtt * A.astype(f32))                    # (b,h)
        upd = jnp.einsum("bhn,bhp,bh->bhpn", Bt.astype(f32),
                         xt.astype(f32), dtt.astype(f32))
        new = carry * dec[..., None, None] + upd
        yt = jnp.einsum("bhn,bhpn->bhp", Ct.astype(f32), new)
        return new, yt

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0))
    final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final
