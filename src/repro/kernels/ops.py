"""Jit'd public wrappers for the Pallas kernels.

Every op takes ``impl=`` with three values:

- ``"pallas"``     — the Pallas kernel (interpret=True on CPU; on a real
                     TPU backend set ``interpret=False`` via
                     ``repro.kernels.ops.INTERPRET``)
- ``"ref"``        — the pure-jnp oracle from :mod:`repro.kernels.ref`
- ``"auto"``       — pallas on TPU, ref elsewhere (the dry-run path:
                     the XLA lowering is structurally equivalent and
                     keeps compiled HLO analyzable on CPU)

Models call only these wrappers, so kernel selection is a config knob,
never a code change — the FLOWER single-source promise.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.backends import use_pallas_kernels as _use_pallas
from repro.kernels import ref as _ref
from repro.kernels.decode_attention import decode_attention as _decode_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.fused_mlp import fused_mlp as _mlp_pallas
from repro.kernels.ssd_scan import ssd_scan as _ssd_pallas

__all__ = ["attention", "decode_attention", "mlp", "ssd", "rmsnorm"]

#: flip to False when running on real TPU hardware
INTERPRET = True

# impl= resolution ("pallas" | "ref" | "auto") lives in the backend
# registry (repro.backends.use_pallas_kernels): "auto" asks whether the
# registered pallas backend is native on this platform — the same
# device probe the dataflow stack uses, instead of a local copy.


def rmsnorm(x, w, eps: float = 1e-6):
    return _ref.rmsnorm_ref(x, w, eps)


def attention(q, k, v, bias=None, causal=True, impl: str = "auto",
              block_q: int = 128, block_k: int = 128, scale=None):
    """q: (B, Hq, Sq, Dk); k: (B, Hkv, Sk, Dk); v: (B, Hkv, Sk, Dv)."""
    if _use_pallas(impl):
        return _flash_pallas(q, k, v, bias=bias, causal=causal,
                             block_q=block_q, block_k=block_k, scale=scale,
                             interpret=INTERPRET)
    return _ref.flash_attention_ref(q, k, v, bias=bias, causal=causal,
                                    scale=scale)


def decode_attention(q, k, v, bias=None, impl: str = "auto",
                     block_k: int = 512, scale=None):
    """q: (B, Hq, Dk); k: (B, Hkv, S, Dk); v: (B, Hkv, S, Dv)."""
    if _use_pallas(impl):
        return _decode_pallas(q, k, v, bias=bias, block_k=block_k,
                              scale=scale, interpret=INTERPRET)
    return _ref.decode_attention_ref(q, k, v, bias=bias, scale=scale)


def mlp(x, w_norm, w_gate, w_up, w_down, eps: float = 1e-6,
        impl: str = "auto", block_t: int = 256, block_f: int = 512):
    """Fused rmsnorm+SwiGLU.  x: (..., d) (leading dims flattened)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if _use_pallas(impl):
        y = _mlp_pallas(x2, w_norm, w_gate, w_up, w_down, eps=eps,
                        block_t=block_t, block_f=block_f,
                        interpret=INTERPRET)
    else:
        y = _ref.fused_mlp_ref(x2, w_norm, w_gate, w_up, w_down, eps=eps)
    return y.reshape(*lead, x.shape[-1])


def ssd(x, dt, A, B, C, chunk: int = 64, impl: str = "auto",
        init_state=None):
    """Mamba2 SSD scan; see ref.ssd_scan_ref for the contract.

    Sequences are padded up to a chunk multiple with dt=0 steps (decay
    exp(0)=1, zero input) — a no-op on both outputs and final state.
    """
    s = x.shape[1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    if _use_pallas(impl):
        if init_state is not None:  # kernel starts from zero state
            raise NotImplementedError(
                "pallas ssd_scan does not take init_state; use impl='ref' "
                "for continuation (decode prefill hand-off)")
        y, fs = _ssd_pallas(x, dt, A, B, C, chunk=chunk,
                            interpret=INTERPRET)
    else:
        y, fs = _ref.ssd_scan_ref(x, dt, A, B, C, chunk=chunk,
                                  init_state=init_state)
    return (y[:, :s] if pad else y), fs
