"""Async, atomic, mesh-agnostic checkpointing with elastic restore.

Layout: one ``.npy`` per leaf under ``<dir>/step_<n>.tmp-*`` renamed
atomically to ``step_<n>/`` on completion, plus ``manifest.json``
(tree structure, shapes, dtypes, crc32 per leaf, step, wall time).

- **async**: `save` snapshots to host numpy, then writes on a
  background thread; training continues.  `wait()` joins; a crashed
  write never leaves a ``step_<n>/`` directory behind (atomicity).
- **integrity**: crc32 per leaf, verified on restore.
- **elastic**: checkpoints carry no sharding; `restore` takes target
  shardings (any mesh shape) and `jax.device_put`s each leaf — resume
  on 2x fewer or more hosts works by construction.
- **retention**: keep the latest k checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time
import zlib
from typing import Any

import jax
import ml_dtypes  # noqa: F401  (registers bfloat16 etc. with numpy)
import numpy as np

__all__ = ["Checkpointer", "save_pytree", "restore_pytree", "latest_step"]

_SEP = "."


def _flatten(tree: Any) -> dict[str, Any]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = leaf
    return out


def save_pytree(tree: Any, directory: str, step: int) -> str:
    """Synchronous atomic save; returns the final directory."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    flat = _flatten(host)
    tmp = tempfile.mkdtemp(prefix=f"step_{step:08d}.tmp-", dir=directory)
    manifest = {"step": step, "time": time.time(), "leaves": {}}
    for key, arr in flat.items():
        fn = key.replace("/", "_") + ".npy"
        orig_dtype = str(arr.dtype)
        store = arr
        if arr.dtype == ml_dtypes.bfloat16 or str(arr.dtype) == "bfloat16":
            # .npy files don't round-trip ml_dtypes reliably; store the
            # raw uint16 bit pattern and re-view on restore.
            store = arr.view(np.uint16)
        np.save(os.path.join(tmp, fn), store)
        manifest["leaves"][key] = {
            "file": fn, "shape": list(arr.shape), "dtype": orig_dtype,
            "crc32": zlib.crc32(np.ascontiguousarray(store).tobytes()),
        }
    # tree structure (for unflattening on restore)
    treedef = jax.tree.structure(tree)
    manifest["treedef"] = str(treedef)
    manifest["keys"] = sorted(flat.keys())
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def restore_pytree(like: Any, directory: str, step: int | None = None,
                   shardings: Any = None, verify: bool = True) -> Any:
    """Restore into the structure of ``like`` (avals or arrays).

    ``shardings`` (same tree structure or a single sharding) reshard
    every leaf onto the *current* mesh — elastic restart.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like = _flatten(like)
    leaves = {}
    for key, meta in manifest["leaves"].items():
        arr = np.load(os.path.join(d, meta["file"]))
        if verify:
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != meta["crc32"]:
                raise IOError(f"checkpoint corruption in {key!r} "
                              f"(crc {crc} != {meta['crc32']})")
        if meta["dtype"] == "bfloat16" and arr.dtype == np.uint16:
            arr = arr.view(ml_dtypes.bfloat16)
        leaves[key] = arr
    missing = set(flat_like) - set(leaves)
    if missing:
        raise KeyError(f"checkpoint missing leaves: {sorted(missing)[:5]}")

    flat_shard = (_flatten(shardings)
                  if shardings is not None
                  and not hasattr(shardings, "device_set") else None)
    vals, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in vals:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        arr = leaves[key]
        dtype = getattr(leaf, "dtype", arr.dtype)
        if str(arr.dtype) != str(dtype):
            arr = arr.astype(np.dtype(dtype) if not hasattr(dtype, "name")
                             else dtype)
        if flat_shard is not None:
            arr = jax.device_put(arr, flat_shard[key])
        elif shardings is not None:
            arr = jax.device_put(arr, shardings)
        out.append(arr)
    return jax.tree.unflatten(jax.tree.structure(like), out)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(n.split("_")[1]) for n in os.listdir(directory)
             if n.startswith("step_") and ".tmp-" not in n]
    return max(steps) if steps else None


class Checkpointer:
    """Async wrapper with retention and preemption flushing."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, tree: Any, step: int, blocking: bool = False) -> None:
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_pytree(host, self.directory, step)
                self._retain()
            except BaseException as e:  # pragma: no cover
                self._error = e

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def restore(self, like: Any, step: int | None = None,
                shardings: Any = None) -> Any:
        return restore_pytree(like, self.directory, step, shardings)

    def latest_step(self) -> int | None:
        return latest_step(self.directory)

    def _retain(self) -> None:
        steps = sorted(int(n.split("_")[1])
                       for n in os.listdir(self.directory)
                       if n.startswith("step_") and ".tmp-" not in n)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
