"""Canonicalization pass pipeline (transform.py).

The paper's *automatic transformations*: programmers write the natural
program; the compiler rewrites it into canonical dataflow form.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (AutoSplitInsertion, ChannelContractError,
                        DataflowGraph, DeadChannelElimination, PassPipeline,
                        PointFusion, build_schedule, compile_graph,
                        default_pipeline)


def _multi_reader_graph(h=8, w=128):
    """x is read twice with no explicit split — non-canonical."""
    g = DataflowGraph("mr")
    x = g.input("x", (h, w))
    a = g.point(x, jnp.abs, name="A")
    b = g.point(x, jnp.exp, name="B")
    g.output(g.point2(a, b, jnp.add, name="C"), "y")
    return g


def test_auto_split_inserts_split_stage():
    g = _multi_reader_graph()
    with pytest.raises(ChannelContractError):
        g.validate()
    g, diags = AutoSplitInsertion().run(g)
    g.validate()  # canonical now
    splits = [s for s in g.stages if s.kind == "split"]
    assert len(splits) == 1 and len(splits[0].outputs) == 2
    assert any("read 2x" in d for d in diags)


def test_auto_split_same_stage_reading_channel_twice():
    g = DataflowGraph("dup")
    x = g.input("x", (8, 128))
    g.output(g.point2(x, x, jnp.add, name="dbl"), "y")
    g, _ = AutoSplitInsertion().run(g)
    g.validate()
    xv = np.random.default_rng(0).normal(size=(8, 128)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(g.reference_eval({"x": xv})["y"]), xv + xv)


def test_auto_split_reference_semantics_unchanged():
    rng = np.random.default_rng(1)
    xv = rng.normal(size=(8, 128)).astype(np.float32)
    expected = np.abs(xv) + np.exp(xv)
    g = _multi_reader_graph()
    g, _ = AutoSplitInsertion().run(g)
    np.testing.assert_allclose(
        np.asarray(g.reference_eval({"x": xv})["y"]), expected, atol=1e-6)


def test_dead_channel_elimination_prunes_stage_and_arm():
    g = DataflowGraph("dead")
    x = g.input("x", (8, 128))
    a, b = g.split(x, 2)
    g.output(g.point(a, jnp.abs, name="live"), "y")
    g.point(b, jnp.exp, name="deadstage")        # result never read
    with pytest.raises(ChannelContractError):
        g.validate()
    g, diags = DeadChannelElimination().run(g)
    g.validate()
    names = {s.name for s in g.stages}
    assert "deadstage" not in names
    # the split lost its dead arm and collapsed into a wire
    assert not any(s.kind == "split" for s in g.stages)
    assert any("collapsed" in d for d in diags)


def test_dead_channel_elimination_multi_output_stage():
    """A multi-output stage whose outputs are ALL dead is pruned whole
    (regression: the second dead output used to crash the sweep)."""
    g = DataflowGraph("dead2")
    x = g.input("x", (8, 128))
    a, b = g.split(x, 2)
    g.output(g.point(a, jnp.abs, name="live"), "y")
    g.custom([b], lambda v: (v, v), [(8, 128), (8, 128)], name="deadcustom")
    g, _ = DeadChannelElimination().run(g)
    g.validate()
    assert "deadcustom" not in {s.name for s in g.stages}


def test_dead_channel_elimination_drops_unread_input():
    g = DataflowGraph("unread-in")
    x = g.input("x", (8, 128))
    g.input("unused", (8, 128))
    g.output(g.point(x, jnp.abs), "y")
    g, diags = DeadChannelElimination().run(g)
    g.validate()
    assert [c.name for c in g.graph_inputs] == ["x"]
    assert any("unused" in d for d in diags)


def test_point_fusion_composes_stages():
    g = DataflowGraph("pf")
    x = g.input("x", (8, 128))
    a = g.point(x, lambda v: v * 2.0, name="dbl")
    b = g.point(a, lambda v: v + 1.0, name="inc")
    g.output(b, "y")
    g, diags = PointFusion().run(g)
    g.validate()
    assert len(g.stages) == 1
    assert g.stages[0].kind == "point"
    assert any("fused" in d for d in diags)
    xv = np.random.default_rng(0).normal(size=(8, 128)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(g.reference_eval({"x": xv})["y"]), xv * 2.0 + 1.0)


def test_point_fusion_into_pointn():
    g = DataflowGraph("pfn")
    x = g.input("x", (8, 128))
    z = g.input("z", (8, 128))
    a = g.point(x, lambda v: v * 0.5, name="half")
    g.output(g.point2(a, z, lambda u, v: u - v, name="sub"), "y")
    g, _ = PointFusion().run(g)
    g.validate()
    assert len(g.stages) == 1 and g.stages[0].kind == "pointN"
    rng = np.random.default_rng(0)
    xv = rng.normal(size=(8, 128)).astype(np.float32)
    zv = rng.normal(size=(8, 128)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(g.reference_eval({"x": xv, "z": zv})["y"]),
        xv * 0.5 - zv)


def test_point_fusion_respects_graph_outputs():
    """A channel that IS a graph output must materialize: no fusion."""
    g = DataflowGraph("keep")
    x = g.input("x", (8, 128))
    a = g.point(x, lambda v: v * 2.0, name="dbl")
    g.output(a, "mid")
    g.output(g.point(a, lambda v: v + 1.0, name="inc"), "y")
    g, _ = AutoSplitInsertion().run(g)   # 'mid' read by inc AND output
    g, diags = PointFusion().run(g)
    g.validate()
    assert "mid" in [c.name for c in g.graph_outputs]


def test_pipeline_runs_all_passes_with_tagged_diags():
    g = _multi_reader_graph()
    g, diags = default_pipeline().run(g)
    g.validate()
    tags = {d.split("]")[0].lstrip("[") for d in diags}
    assert "auto-split" in tags and "point-fusion" in tags


def test_multi_reader_compiles_via_pipeline_and_errors_strict():
    g = _multi_reader_graph()
    app = compile_graph(g, backend="xla")
    xv = np.random.default_rng(2).normal(size=(8, 128)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(app(x=xv)["y"]),
                               np.abs(xv) + np.exp(xv), atol=1e-6)
    # the same program is rejected when strict (the seed behaviour)
    with pytest.raises(ChannelContractError):
        compile_graph(_multi_reader_graph(), strict=True)


def test_schedule_describe_reports_pass_diagnostics():
    sched = build_schedule(_multi_reader_graph())
    text = sched.describe()
    assert "passes:" in text
    assert "[auto-split]" in text
    assert "[convex-fusion]" in text


def test_cycle_still_raises_through_pipeline():
    """Passes must not eat cycles: a 2-cycle survives canonicalization
    (no self-fusion) and validate() raises."""
    from repro.core import CycleError
    g = DataflowGraph("cyc")
    c1 = g.channel((8, 128))
    c2 = g.channel((8, 128))
    g.task("a", "point", jnp.abs, [c1], [c2])
    g.task("b", "point", jnp.abs, [c2], [c1])
    with pytest.raises((CycleError, ChannelContractError)):
        compile_graph(g)


def test_custom_pass_list():
    g = _multi_reader_graph()
    sched = build_schedule(g, passes=PassPipeline((AutoSplitInsertion(),)))
    # without PointFusion the three point stages stay distinct
    kinds = [s.kind for s in sched.graph.stages]
    assert kinds.count("point") == 2 and kinds.count("pointN") == 1
