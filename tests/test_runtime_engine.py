"""Streaming serving runtime: engine, cache, micro-batcher, telemetry.

The acceptance path: >=32 concurrent requests against a compiled
diamond graph are bit-exact vs ``reference_eval``, with the compile
cache reporting exactly 1 miss + N-1 hits for same-signature traffic.
"""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CycleError, DataflowGraph, compile_graph
from repro.core.apps import JACOBI3, LAPLACE3, _conv
from repro.runtime import (CompileCache, MicroBatcher, QueueFullError,
                           SlotPool, StreamEngine, Telemetry, modeled_latency)


def _diamond(h=48, w=256, name="diamond"):
    g = DataflowGraph(name)
    x = g.input("x", (h, w))
    s1 = g.stencil(x, (3, 3), _conv(LAPLACE3), name="lap")
    s2 = g.stencil(x, (3, 3), _conv(JACOBI3), name="jac")
    g.output(g.point2(s1, s2, lambda u, v: u - v, name="merge"), "y")
    return g


# ----------------------------------------------------------------------
# acceptance: the full engine path on the pallas backend
# ----------------------------------------------------------------------
def test_engine_e2e_32_requests_bit_exact_and_cached(rng):
    n = 32
    g = _diamond()
    frames = [rng.normal(size=(48, 256)).astype(np.float32)
              for _ in range(n)]
    with StreamEngine(backend="pallas", max_batch=8, max_queue=64) as eng:
        handles = []
        lock = threading.Lock()

        def submit(chunk):
            for f in chunk:
                h = eng.submit(g, {"x": f})
                with lock:
                    handles.append((f, h))

        threads = [threading.Thread(target=submit, args=(frames[i::4],))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        results = [(f, h.result(timeout=600)) for f, h in handles]
        report = eng.report()

    # bit-exact against the reference oracle (atol=0)
    ref_graph = eng.cache.get(g, backend="pallas").schedule.graph
    for f, r in results:
        ref = np.asarray(ref_graph.reference_eval({"x": f})["y"])
        np.testing.assert_array_equal(r["y"], ref)

    # same-signature traffic: exactly 1 compile event for N requests.
    # hits/misses are per COMPILE, not per request (resubmitting the
    # same graph object is a `requests` tick, not a phantom hit)
    assert report["cache"]["misses"] == 1
    assert report["cache"]["hits"] == 0
    assert report["cache"]["requests"] == n

    m = report["measured"]
    assert m["completed"] == n and m["submitted"] == n
    assert m["latency_p50_ms"] <= m["latency_p99_ms"]
    # the Fig. 1 model rides along with the live metrics
    mod = report["modeled"]["diamond"]
    assert mod["sequential"] > mod["dataflow"] > 0


# ----------------------------------------------------------------------
# compile cache
# ----------------------------------------------------------------------
def test_cache_structural_hit_across_fresh_graphs():
    """Two structurally identical graphs (different names) share one
    compile; a different topology misses."""
    cache = CompileCache()
    a1 = cache.get(_diamond(8, 128, name="g1"), backend="xla")
    a2 = cache.get(_diamond(8, 128, name="g2"), backend="xla")
    assert a1 is a2
    assert cache.stats.misses == 1 and cache.stats.hits == 1
    g3 = _diamond(16, 128, name="g3")        # different shape
    a3 = cache.get(g3, backend="xla")
    assert a3 is not a1 and cache.stats.misses == 2
    # backend is part of the identity
    cache.get(_diamond(8, 128), backend="xla_staged")
    assert cache.stats.misses == 3


def test_cache_alias_survives_in_place_canonicalization():
    """Passes rewrite graphs in place (auto-split inserts a stage), so
    the same OBJECT resubmitted after compiling must still hit."""
    cache = CompileCache()
    g = _diamond(8, 128)
    pre = g.signature()
    cache.get(g, backend="xla")
    assert g.signature() != pre              # canonicalized in place
    cache.get(g, backend="xla")              # same object: no new event
    assert cache.stats.misses == 1 and cache.stats.hits == 0
    assert cache.stats.requests == 2
    # and a fresh non-canonical twin hits through the structural key
    cache.get(_diamond(8, 128), backend="xla")
    assert cache.stats.misses == 1 and cache.stats.hits == 1


def test_cache_lru_eviction():
    cache = CompileCache(maxsize=2)
    cache.get(_diamond(8, 128), backend="xla")
    cache.get(_diamond(16, 128), backend="xla")
    cache.get(_diamond(24, 128), backend="xla")
    assert cache.stats.evictions > 0
    # maxsize bounds the entry table
    assert len(cache) <= 2


def test_signature_ignores_labels_but_not_bodies():
    s1 = _diamond(8, 128, name="a").signature()
    s2 = _diamond(8, 128, name="b").signature()
    assert s1 == s2
    g = _diamond(8, 128)
    g.stages[-1].fn = lambda u, v: u + v     # different merge body
    assert g.signature() != s1


def test_signature_sees_globals_defaults_and_io_names():
    """Stage bodies differing only in the global they call or a default
    value must not collide (they compute different things); graph I/O
    names are the app's calling convention so they count too."""
    def build(fn, inn="x", outn="y"):
        g = DataflowGraph("g")
        x = g.input(inn, (8, 128))
        g.output(g.point(x, fn), outn)
        return g

    assert build(lambda v: jnp.abs(v)).signature() \
        != build(lambda v: jnp.exp(v)).signature()
    assert build(lambda v, k=2.0: v * k).signature() \
        != build(lambda v, k=3.0: v * k).signature()
    assert build(jnp.abs).signature() == build(jnp.abs).signature()
    assert build(jnp.abs).signature() \
        != build(jnp.abs, inn="img", outn="z").signature()


# ----------------------------------------------------------------------
# backpressure (the simulator's finite FIFO, live)
# ----------------------------------------------------------------------
def test_bounded_queue_backpressure(rng):
    g = _diamond(8, 128)
    x = rng.normal(size=(8, 128)).astype(np.float32)
    eng = StreamEngine(backend="xla", max_queue=2, max_batch=2,
                       autostart=False)
    try:
        eng.submit(g, {"x": x}, block=False)
        eng.submit(g, {"x": x}, block=False)
        with pytest.raises(QueueFullError):
            eng.submit(g, {"x": x}, block=False)
        # draining the queue releases the backpressure
        eng.start()
        h = eng.submit(g, {"x": x}, timeout=60)
        assert h.result(timeout=60)["y"].shape == (8, 128)
    finally:
        eng.close()


def test_engine_rejects_after_close(rng):
    eng = StreamEngine(backend="xla", autostart=False)
    eng.close()
    with pytest.raises(RuntimeError):
        eng.submit(_diamond(8, 128), {"x": np.zeros((8, 128), np.float32)})


def test_engine_rejects_bad_input_at_submit(rng):
    """A malformed request fails its own submit instead of poisoning
    the micro-batch it would have joined."""
    g = _diamond(8, 128)
    with StreamEngine(backend="xla", max_batch=2) as eng:
        ok = eng.submit(g, {"x": rng.normal(size=(8, 128))
                            .astype(np.float32)})
        with pytest.raises(ValueError, match="expected shape"):
            eng.submit(g, {"x": np.zeros((4, 4), np.float32)})
        with pytest.raises(ValueError, match="missing graph input"):
            eng.submit(g, {"img": np.zeros((8, 128), np.float32)})
        assert ok.result(timeout=120)["y"].shape == (8, 128)


# ----------------------------------------------------------------------
# async launch handles and the micro-batcher
# ----------------------------------------------------------------------
def test_compiled_app_async_launch(rng):
    app = compile_graph(_diamond(8, 128), backend="xla")
    x = rng.normal(size=(8, 128)).astype(np.float32)
    h = app.launch(x=x)
    out = h.result()
    assert h.done()
    np.testing.assert_array_equal(np.asarray(out["y"]),
                                  np.asarray(app(x=x)["y"]))


def test_micro_batcher_pad_and_slice_bit_exact(rng):
    app = compile_graph(_diamond(8, 128), backend="xla")
    mb = MicroBatcher(max_batch=8)

    class R:
        def __init__(self, x):
            self.inputs = {"x": x}

    reqs = [R(rng.normal(size=(8, 128)).astype(np.float32))
            for _ in range(5)]
    outs = mb.launch(app, reqs, pad_to=8)    # ragged batch, padded
    y = np.asarray(outs["y"])
    assert y.shape == (8, 8, 128)            # padded width
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(
            y[i], np.asarray(app(x=r.inputs["x"])["y"]))
    with pytest.raises(ValueError):
        mb.launch(app, [R(np.zeros((8, 128), np.float32))] * 9)


# ----------------------------------------------------------------------
# shared slot machinery
# ----------------------------------------------------------------------
def test_slot_pool_fifo_admission_and_retirement():
    pool = SlotPool(2)
    for item in "abcd":
        pool.submit(item)
    assert [i for _, i in pool.admit()] == ["a", "b"]
    assert pool.active == 2 and not pool.free_slots()
    oldest = pool.oldest()
    assert pool.retire(oldest) == "a"
    assert pool.admit() == [(oldest, "c")]
    # retirement follows admission order, not slot index order
    assert pool.slots[pool.oldest()] == "b"
    pool.retire(pool.oldest())
    pool.retire(pool.oldest())
    assert pool.finished == ["a", "b", "c"]
    with pytest.raises(ValueError):
        pool.retire(0)                       # empty slot
    assert pool.busy                         # "d" still queued


def test_telemetry_report_shapes():
    t = Telemetry()
    t.observe_submit(0)
    t.observe_batch(4)
    for ms in (1.0, 2.0, 3.0):
        t.observe_completion(ms * 1e-3)
    snap = t.snapshot()
    assert snap["completed"] == 3
    assert snap["latency_p50_ms"] == pytest.approx(2.0)
    assert snap["latency_p50_ms"] <= snap["latency_p99_ms"]
    app = compile_graph(_diamond(8, 128), backend="xla")
    rep = t.report(modeled={"diamond": modeled_latency(app, 16)})
    assert set(rep) == {"measured", "modeled"}
    mod = rep["modeled"]["diamond"]
    assert mod["speedup"] > 1.0 and "dataflow_sim" in mod
