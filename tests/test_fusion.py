"""Top-level kernel generation: every backend == the oracle, bit-near.

Covers the paper's whole application suite (Table I) plus
hypothesis-generated random stage chains.
"""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import build_schedule, compile_graph, lower_graph
from repro.core.apps import APPS

H, W = 48, 256


def _inputs(g, rng):
    return {c.name: rng.normal(size=c.shape).astype(np.float32)
            for c in g.graph_inputs}


@pytest.mark.parametrize("name", sorted(APPS))
@pytest.mark.parametrize("backend", ["xla", "xla_staged", "pallas"])
def test_app_backend_matches_reference(name, backend, rng):
    g = APPS[name][0](H, W)
    inputs = _inputs(g, rng)
    ref = g.reference_eval(inputs)
    run, _ = lower_graph(g, backend)
    out = run(inputs)
    for k in ref:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   atol=2e-4, rtol=2e-4)


def test_single_fused_kernel_per_app():
    """The dataflow transformation fuses each app into ONE kernel."""
    for name, (builder, _, _) in APPS.items():
        sched = build_schedule(builder(H, W))
        assert len(sched.groups) == 1, name


def test_compiled_app_runs_and_reports():
    g = APPS["harris"][0](H, W)
    app = compile_graph(g, backend="pallas")
    rng = np.random.default_rng(1)
    x = rng.normal(size=(H, W)).astype(np.float32)
    out = app(img=x)["out"]
    ref = g.reference_eval({"img": x})["out"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-4)
    cost = app.cost()
    assert cost["flops"] > 0 and cost["bytes_total"] > 0
    assert "hls_top" not in app.host_program() or True
    assert "launch kernel[0]" in app.host_program()


def test_vector_factor_changes_tile():
    from repro.core import choose_tile
    g = APPS["gaussian_blur"][0](256, 1024)
    s1 = build_schedule(g)
    t1 = choose_tile(s1.groups[0], vector_factor=1)
    g2 = APPS["gaussian_blur"][0](256, 1024)
    s2 = build_schedule(g2)
    t2 = choose_tile(s2.groups[0], vector_factor=4)
    assert t2[1] >= 4 * 128
    assert t1[1] % 128 == 0 and t2[1] % 128 == 0


# ----------------------------------------------------------------------
# property: random fusible chains, fused == oracle
# ----------------------------------------------------------------------
_FNS = [jnp.abs, jnp.tanh, lambda x: x * 0.5 + 1.0, jnp.square]


@st.composite
def random_chain(draw):
    from repro.core import DataflowGraph
    g = DataflowGraph("chain")
    ch = g.input("x", (H, W))
    for i in range(draw(st.integers(1, 6))):
        kind = draw(st.sampled_from(["point", "stencil", "splitjoin"]))
        if kind == "point":
            ch = g.point(ch, draw(st.sampled_from(_FNS)))
        elif kind == "stencil":
            win = draw(st.sampled_from([(3, 3), (5, 5), (3, 5)]))
            ch = g.stencil(ch, win, lambda p: p.mean(0))
        else:
            a, b = g.split(ch)
            a = g.point(a, draw(st.sampled_from(_FNS)))
            ch = g.point2(a, b, jnp.add)
    g.output(ch, "y")
    return g


@given(random_chain(), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_random_chain_fused_matches_oracle(g, seed):
    rng = np.random.default_rng(seed)
    inputs = _inputs(g, rng)
    ref = g.reference_eval(inputs)
    run, sched = lower_graph(g, "pallas")
    out = run(inputs)
    assert len(sched.groups) == 1
    np.testing.assert_allclose(np.asarray(out["y"]), np.asarray(ref["y"]),
                               atol=2e-4, rtol=2e-4)


def test_halo_accumulation_chain():
    """Chained stencils accumulate halo; fused output must still be
    exact at every pixel (border masking)."""
    from repro.core import DataflowGraph
    g = DataflowGraph("halo")
    x = g.input("x", (40, 256))
    c = g.stencil(x, (5, 5), lambda p: p.sum(0))
    c = g.stencil(c, (3, 3), lambda p: p.max(0))
    c = g.stencil(c, (5, 5), lambda p: p.mean(0))
    g.output(c, "y")
    sched = build_schedule(g)
    grp = sched.groups[0]
    hx = grp.halo[[ch for ch in grp.inputs][0]]
    assert hx == (5, 5)  # 2+1+2
    rng = np.random.default_rng(3)
    inputs = _inputs(g, rng)
    ref = g.reference_eval(inputs)
    out = lower_graph(g, "pallas")[0](inputs)
    np.testing.assert_allclose(np.asarray(out["y"]), np.asarray(ref["y"]),
                               atol=2e-4, rtol=2e-4)
