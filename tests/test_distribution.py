"""Multi-device distribution tests (8 host devices via subprocess —
conftest keeps the main process at 1 device on purpose)."""
import subprocess
import sys

import pytest

PREAMBLE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
"""


def run_sub(code: str, timeout: int = 560):
    r = subprocess.run([sys.executable, "-c", PREAMBLE + code],
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_ring_collectives_match_barrier():
    run_sub("""
from repro.parallel.collectives import (ring_allgather_matmul,
                                        ring_matmul_reducescatter)
mesh = jax.make_mesh((8,), ("model",))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(64, 128)), jnp.float32)
w = jnp.asarray(rng.normal(size=(128, 96)), jnp.float32)
y1 = ring_allgather_matmul(x, w, mesh)
assert np.allclose(y1, x @ w, atol=1e-3), float(jnp.abs(y1 - x@w).max())
y2 = ring_matmul_reducescatter(x, w, mesh)
assert np.allclose(y2, x @ w, atol=1e-3), float(jnp.abs(y2 - x@w).max())
""")


def test_pipeline_parallel_matches_sequential():
    run_sub("""
from repro.parallel.pipeline import pipeline_apply
mesh = jax.make_mesh((8,), ("stage",))
rng = np.random.default_rng(0)
ws = jnp.asarray(rng.normal(size=(8, 32, 32)) * 0.3, jnp.float32)
xb = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
def stage(p, x): return jnp.tanh(x @ p)
yp = pipeline_apply(stage, ws, xb, mesh, n_micro=4, axis="stage")
yref = xb
for i in range(8): yref = jnp.tanh(yref @ ws[i])
assert np.allclose(yp, yref, atol=1e-4)
""")


def test_sharded_train_step_matches_single_device():
    """DPxTP sharded training step == unsharded step (same math)."""
    run_sub("""
import dataclasses
from repro.configs import get_smoke
from repro.optim.adamw import AdamWConfig
from repro.runtime import steps as S
from repro.models import model as M
from repro.data.pipeline import SyntheticLM

cfg = dataclasses.replace(get_smoke("granite_3_2b"), remat="none")
opt = AdamWConfig(lr_peak=1e-3, warmup_steps=1, decay_steps=10)
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8)
batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}

params = M.init(cfg, jax.random.PRNGKey(0))
from repro.optim.adamw import adamw_init
state = {"params": params, "opt": adamw_init(params)}

# single device
step1 = jax.jit(S.make_train_step(cfg, opt))
s1, m1 = step1(jax.tree.map(jnp.copy, state), batch)

# sharded 2x4
mesh = jax.make_mesh((2, 4), ("data", "model"))
sh = S.train_state_shardings(cfg, mesh)
from repro.models.config import ShapeConfig
shp = ShapeConfig("t", 16, 8, "train")
bsh = S.batch_shardings(cfg, shp, mesh, S.TRAIN_RULES)
step2 = jax.jit(S.make_train_step(cfg, opt, mesh=mesh),
                in_shardings=(sh, bsh), out_shardings=(sh, None))
s2, m2 = step2(jax.tree.map(jnp.copy, state), batch)

d = abs(float(m1["loss"]) - float(m2["loss"]))
assert d < 1e-4, f"loss mismatch {d}"
for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
    err = float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
    assert err < 1e-2, err
print("loss", float(m1["loss"]))
""")


def test_microbatched_step_matches_full_batch():
    run_sub("""
import dataclasses
from repro.configs import get_smoke
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.runtime import steps as S
from repro.models import model as M
from repro.data.pipeline import SyntheticLM

cfg = dataclasses.replace(get_smoke("granite_3_2b"), remat="none")
opt = AdamWConfig(lr_peak=1e-3, warmup_steps=1, decay_steps=10)
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8)
batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
params = M.init(cfg, jax.random.PRNGKey(0))
state = {"params": params, "opt": adamw_init(params)}
s1, m1 = jax.jit(S.make_train_step(cfg, opt))(jax.tree.map(jnp.copy, state), batch)
cfg4 = dataclasses.replace(cfg, microbatches=4)
s4, m4 = jax.jit(S.make_train_step(cfg4, opt))(jax.tree.map(jnp.copy, state), batch)
d = abs(float(m1["loss"]) - float(m4["loss"]))
assert d < 1e-4, d
for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s4["params"])):
    err = float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
    assert err < 1e-2, err
""")


def test_elastic_restore_across_mesh_shapes(tmp_path):
    """Checkpoint on a 2x4 mesh, restore on 4x2 and on 1 device."""
    run_sub(f"""
import dataclasses
from repro.configs import get_smoke
from repro.models import model as M
from repro.optim.adamw import adamw_init
from repro.runtime import steps as S
from repro.checkpoint.checkpointer import save_pytree, restore_pytree

cfg = get_smoke("granite_3_2b")
params = M.init(cfg, jax.random.PRNGKey(1))
state = {{"params": params, "opt": adamw_init(params)}}
mesh1 = jax.make_mesh((2, 4), ("data", "model"))
sh1 = S.train_state_shardings(cfg, mesh1)
state = jax.device_put(state, sh1)
save_pytree(state, r"{tmp_path}", 3)

mesh2 = jax.make_mesh((4, 2), ("data", "model"))
sh2 = S.train_state_shardings(cfg, mesh2)
like = jax.eval_shape(lambda: state)
restored = restore_pytree(like, r"{tmp_path}", 3, shardings=sh2)
for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
    assert np.allclose(np.asarray(jax.device_get(a), np.float32),
                       np.asarray(jax.device_get(b), np.float32)), "mismatch"
print("elastic ok")
""")


def test_grad_compression_in_sharded_step():
    run_sub("""
import dataclasses
from repro.configs import get_smoke
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.optim.compression import ef_init
from repro.runtime import steps as S
from repro.models import model as M
from repro.data.pipeline import SyntheticLM

cfg = dataclasses.replace(get_smoke("granite_3_2b"), remat="none")
opt = AdamWConfig(lr_peak=1e-3, warmup_steps=1, decay_steps=10)
data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16, global_batch=8)
batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
params = M.init(cfg, jax.random.PRNGKey(0))
state = {"params": params, "opt": adamw_init(params), "ef": ef_init(params)}
mesh = jax.make_mesh((2, 4), ("data", "model"))
step = jax.jit(S.make_train_step(cfg, opt, mesh=mesh, compress_grads=True))
s, m = step(state, batch)
assert np.isfinite(float(m["loss"]))
# error-feedback buffers are now non-zero (quantization residue)
nz = sum(float(jnp.abs(e).sum()) for e in jax.tree.leaves(s["ef"]))
assert nz > 0
print("compressed step ok", float(m["loss"]))
""")
