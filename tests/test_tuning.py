"""Profile-guided autotuner: store round-trips, deterministic search,
cache-hit-zero-measurement, and serving/replication integration.

The measured search is exercised with *injected fake measurements*
(deterministic functions of the candidate config), so these tests
check search logic and persistence, not wall-clock — except the two
integration tests at the bottom, which run the real measurer on tiny
planes with ``backend="xla"``.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from repro.core import DataflowGraph, build_schedule, compile_graph
from repro.core.apps import build_app
from repro.tune import (ScheduleConfig, TuningCache, TuningKey, TuningRecord,
                        tune_graph)
from repro.tune.search import resolve_tuning


def _stencil_graph(h=64, w=512):
    g = DataflowGraph("tunable")
    x = g.input("img", (h, w))
    b = g.stencil(x, (3, 3), lambda p: sum(p[i] for i in range(9)) / 9.0)
    g.output(g.point2(x, b, lambda a, c: 2.0 * a - c), "out")
    return g


def _prefers_vf(target: int):
    """Fake measurer: fastest exactly at vector factor ``target``."""

    def measure(cfg: ScheduleConfig) -> float:
        vf = next(v for v in cfg.group_vf if v is not None)
        return 1.0 + abs(vf - target) + 0.1 * (cfg.max_tile[0] != 256)

    return measure


# ----------------------------------------------------------------------
# TuningCache store
# ----------------------------------------------------------------------
def test_tuning_cache_round_trip(tmp_path):
    cache = TuningCache(str(tmp_path))
    key = TuningKey("sigdead", "pallas", "cpu",
                    (("img", (64, 512), "float32"),))
    cfg = ScheduleConfig(group_vf=(3, None), max_tile=(128, 1024),
                         vmem_fraction=0.5)
    cache.put(key, TuningRecord(config=cfg, source="measured",
                                best_measured_s=1e-3, n_trials=5))
    # a FRESH handle re-reads from disk: survives process restarts
    rec = TuningCache(str(tmp_path)).get(key)
    assert rec is not None
    assert rec.config == cfg
    assert rec.best_measured_s == 1e-3 and rec.n_trials == 5
    assert rec.created_at > 0


def test_tuning_cache_round_trip_identical_schedule(tmp_path):
    """save -> load -> recompile produces an identical Schedule."""
    cache = TuningCache(str(tmp_path))
    g = _stencil_graph()
    res = tune_graph(g, "xla", cache=cache, measure=_prefers_vf(2))
    first = compile_graph(_stencil_graph(), "xla", tune="auto",
                          tune_cache=cache)
    second = compile_graph(_stencil_graph(), "xla", tune="auto",
                           tune_cache=cache)
    tiles = [(gr.tile, gr.vector_factor) for gr in first.schedule.groups]
    assert tiles == [(gr.tile, gr.vector_factor)
                     for gr in second.schedule.groups]
    assert [v for v in res.config.group_vf if v is not None] == [2]
    assert all(gr.tile_source == "cache" for gr in second.schedule.groups
               if gr.tile is not None)


def test_tuning_cache_miss_on_different_key(tmp_path):
    cache = TuningCache(str(tmp_path))
    key = TuningKey("sig1", "pallas", "cpu", ())
    cache.put(key, TuningRecord(config=ScheduleConfig(group_vf=(1,))))
    assert cache.get(dataclasses.replace(key, backend="xla")) is None
    assert cache.get(dataclasses.replace(key, device_kind="TPU v5e")) is None
    assert cache.get(key) is not None


def test_tuning_cache_rejects_foreign_versions(tmp_path):
    cache = TuningCache(str(tmp_path))
    key = TuningKey("sigv", "pallas", "cpu", ())
    rec = TuningRecord(config=ScheduleConfig(group_vf=(1,)), version=999)
    cache.put(key, rec)
    assert TuningCache(str(tmp_path)).get(key) is None


def test_signature_stable_across_code_object_identity():
    """The persistent cache key must not depend on memory addresses.

    A restarted process rebuilds the same program with NEW code
    objects (new ``id()``s); the graph signature — and hence the
    TuningKey — must be identical anyway, including for stage fns
    with *nested* code objects (genexprs), whose default repr embeds
    an ``at 0x…`` address.
    """
    src = "lambda p: sum(p[i] for i in range(9)) / 9.0"

    def build():
        fn = eval(compile(src, "<probe>", "eval"))   # fresh code object
        g = DataflowGraph("sig")
        x = g.input("img", (32, 128))
        g.output(g.stencil(x, (3, 3), fn), "out")
        return g

    g1, g2 = build(), build()
    assert g1.stages[0].fn.__code__ is not g2.stages[0].fn.__code__
    assert g1.signature() == g2.signature()
    assert TuningKey.for_graph(g1, "pallas", "cpu") == \
        TuningKey.for_graph(g2, "pallas", "cpu")


# ----------------------------------------------------------------------
# the measured search
# ----------------------------------------------------------------------
def test_deterministic_winner_under_fake_measurements(tmp_path):
    """Same fake measurements -> same winner, twice over."""
    r1 = tune_graph(_stencil_graph(), "xla",
                    cache=TuningCache(str(tmp_path / "a")),
                    measure=_prefers_vf(2))
    r2 = tune_graph(_stencil_graph(), "xla",
                    cache=TuningCache(str(tmp_path / "b")),
                    measure=_prefers_vf(2))
    assert r1.source == r2.source == "measured"
    assert r1.config == r2.config
    assert 2 in r1.config.group_vf


def test_winner_never_slower_than_analytic_pick(tmp_path):
    """The analytic pick is always measured, so it bounds the winner."""
    for target in (1, 2, 3, 4):
        res = tune_graph(_stencil_graph(), "xla",
                         cache=TuningCache(str(tmp_path / str(target))),
                         measure=_prefers_vf(target))
        assert res.record.best_measured_s <= res.record.analytic_measured_s


def test_cache_hit_means_zero_measurements(tmp_path):
    """The regression the persistent cache exists for."""
    cache = TuningCache(str(tmp_path))
    calls = {"n": 0}

    def counting(cfg: ScheduleConfig) -> float:
        calls["n"] += 1
        return _prefers_vf(2)(cfg)

    first = tune_graph(_stencil_graph(), "xla", cache=cache,
                       measure=counting)
    assert first.source == "measured"
    assert calls["n"] == first.n_measurements > 0

    before = calls["n"]
    again = tune_graph(_stencil_graph(), "xla", cache=cache,
                       measure=counting)
    assert again.source == "cache"
    assert again.n_measurements == 0
    assert calls["n"] == before            # not a single new measurement
    assert again.config == first.config


def test_cache_hit_after_canonicalization_alias(tmp_path):
    """A graph canonicalized in place still hits its own record."""
    cache = TuningCache(str(tmp_path))
    g = _stencil_graph()                    # non-canonical (multi-reader)
    tune_graph(g, "xla", cache=cache, measure=_prefers_vf(2))
    # g was canonicalized in place during the search; its signature
    # changed, but the post-canonicalization alias must hit
    res = tune_graph(g, "xla", cache=cache, measure=_prefers_vf(2))
    assert res.source == "cache" and res.n_measurements == 0


def test_max_trials_caps_measurements(tmp_path):
    counting = {"n": 0}

    def measure(cfg):
        counting["n"] += 1
        return 1.0

    tune_graph(_stencil_graph(), "xla", cache=TuningCache(str(tmp_path)),
               measure=measure, max_trials=2)
    assert counting["n"] == 2


def test_resolve_tuning_protocol(tmp_path):
    g = _stencil_graph()
    assert resolve_tuning(g, "xla", tune=None) is None
    assert resolve_tuning(g, "xla", tune="model") is None
    cfg = ScheduleConfig(group_vf=(1,))
    out = resolve_tuning(g, "xla", tune=cfg)
    assert out is not None and out[0] is cfg and out[1] == "config"
    with pytest.raises(ValueError, match="tune must be"):
        resolve_tuning(g, "xla", tune="bogus")
    with pytest.raises(ValueError, match="mutually exclusive"):
        compile_graph(g, "xla", tune="auto", vector_factor=2)


def test_interpret_and_compiled_modes_tune_separately(tmp_path):
    """Interpreter-mode timings must never serve compiled-mode runs."""
    cache = TuningCache(str(tmp_path))
    r_interp = tune_graph(_stencil_graph(), "xla", cache=cache,
                          measure=_prefers_vf(2), interpret=True)
    r_comp = tune_graph(_stencil_graph(), "xla", cache=cache,
                        measure=_prefers_vf(2), interpret=False)
    assert r_interp.source == "measured"
    assert r_comp.source == "measured"      # NOT a hit on the interp entry
    assert r_interp.key.mode == "interpret"
    assert r_comp.key.mode == "compiled"
    # but each mode hits its own entry
    assert tune_graph(_stencil_graph(), "xla", cache=cache,
                      interpret=False).source == "cache"


def test_tune_rejects_max_tile_override():
    with pytest.raises(ValueError, match="mutually exclusive"):
        compile_graph(_stencil_graph(), "xla", tune="auto",
                      max_tile=(64, 256))
    from repro.parallel.replicate import replicate_app
    with pytest.raises(TypeError, match="mutually exclusive"):
        replicate_app(compile_graph(_stencil_graph(32, 128), "xla"),
                      tune="auto", max_tile=(64, 128))


def test_tune_model_is_the_analytic_default():
    """tune="model" names the no-tuning regime; it composes with the
    explicit knobs instead of tripping the mutual-exclusion guards."""
    app = compile_graph(_stencil_graph(), "xla", tune="model",
                        vector_factor=2)
    assert all(g.vector_factor == 2 for g in app.schedule.groups
               if g.tile is not None)
    assert "via forced" in app.schedule.describe()


def test_tuning_key_separates_spec_and_strictness(tmp_path):
    """Configs measured under one spec/compile regime must not serve
    another: the context digest keeps the cache entries apart."""
    import dataclasses as dc

    from repro.core import V5E

    cache = TuningCache(str(tmp_path))
    r1 = tune_graph(_stencil_graph(), "xla", cache=cache,
                    measure=_prefers_vf(2))
    small = dc.replace(V5E, vmem_bytes=V5E.vmem_bytes // 2)
    r2 = tune_graph(_stencil_graph(), "xla", cache=cache, spec=small,
                    measure=_prefers_vf(2))
    assert r2.source == "measured"         # NOT served from r1's entry
    assert r1.key.context != r2.key.context
    # each regime then hits its own entry
    assert tune_graph(_stencil_graph(), "xla", cache=cache,
                      spec=small).source == "cache"


def test_entries_deduplicates_canonicalization_aliases(tmp_path):
    """One tuned app == one record, even when stored under both the
    pre- and post-canonicalization signatures."""
    cache = TuningCache(str(tmp_path))
    tune_graph(_stencil_graph(), "xla", cache=cache,
               measure=_prefers_vf(2))     # non-canonical: writes an alias
    import os
    files = [n for n in os.listdir(str(tmp_path)) if n.endswith(".json")]
    assert len(files) == 2                 # pre + post forms on disk
    assert len(cache) == 1                 # but ONE tuning result


def test_stale_config_infeasible_factor_falls_back():
    """A cached factor the plane can no longer hold degrades gracefully."""
    sched = build_schedule(_stencil_graph(64, 256),   # cap is vf=2
                           group_vector_factors=[10])
    assert any("no longer feasible" in d for d in sched.diagnostics)
    g0 = sched.groups[0]
    assert g0.tile is not None and g0.tile_source == "model"
    # an EXPLICIT infeasible vector_factor= stays a hard error
    with pytest.raises(ValueError, match="vector_factor=10"):
        build_schedule(_stencil_graph(64, 256), vector_factor=10)


def test_stale_config_length_mismatch_falls_back():
    """A config sized for a different partition degrades gracefully."""
    sched = build_schedule(_stencil_graph(),
                           group_vector_factors=[1, 1, 1, 1, 1])
    assert any("falling back to the analytic sweep" in d
               for d in sched.diagnostics)
    g0 = sched.groups[0]
    assert g0.tile is not None and g0.tile_source == "model"


def test_describe_provenance_lines(tmp_path):
    cache = TuningCache(str(tmp_path))
    g = _stencil_graph()
    tune_graph(g, "xla", cache=cache, measure=_prefers_vf(1))
    fresh = compile_graph(_stencil_graph(), "xla", tune="auto",
                          tune_cache=cache)
    text = fresh.schedule.describe()
    assert "via cache" in text and "[tune] source=cache" in text
    default = compile_graph(_stencil_graph(), "xla")
    assert "via model" in default.schedule.describe()
    forced = compile_graph(_stencil_graph(), "xla", vector_factor=2)
    assert "via forced" in forced.schedule.describe()


# ----------------------------------------------------------------------
# integration: real measurements on tiny planes
# ----------------------------------------------------------------------
def test_tuned_app_is_bit_exact_and_correct(tmp_path):
    cache = TuningCache(str(tmp_path))
    g = build_app("gaussian_blur", 32, 256)
    app = compile_graph(g, "xla", tune="auto", tune_cache=cache)
    plain = compile_graph(build_app("gaussian_blur", 32, 256), "xla")
    rng = np.random.default_rng(0)
    x = rng.normal(size=(32, 256)).astype(np.float32)
    # tuning picks tiles, never semantics: bit-exact vs the untuned app
    np.testing.assert_array_equal(np.asarray(app(img=x)["out"]),
                                  np.asarray(plain(img=x)["out"]))
    ref = build_app("gaussian_blur", 32, 256).reference_eval({"img": x})
    np.testing.assert_allclose(np.asarray(app(img=x)["out"]),
                               np.asarray(ref["out"]), rtol=1e-5, atol=1e-6)
    assert all(gr.tile_source in ("measured", "cache")
               for gr in app.schedule.groups if gr.tile is not None)


def test_engine_serves_tuned_schedules_through_compile_cache(tmp_path):
    """StreamEngine(tune="auto") warm-starts at the tuned point."""
    from repro.runtime import StreamEngine

    cache = TuningCache(str(tmp_path))
    g = _stencil_graph(32, 256)
    res = tune_graph(g, "xla", cache=cache, measure=_prefers_vf(2))

    calls = {"n": 0}
    import repro.tune.search as search

    real = search.default_measure

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    search.default_measure = counting
    try:
        rng = np.random.default_rng(1)
        frames = [rng.normal(size=(32, 256)).astype(np.float32)
                  for _ in range(6)]
        with StreamEngine(backend="xla", max_batch=4, tune="auto",
                          tune_cache=cache) as eng:
            handles = [eng.submit(_stencil_graph(32, 256), {"img": f})
                       for f in frames]
            outs = [h.result() for h in handles]
            rep = eng.report()
    finally:
        search.default_measure = real
    assert calls["n"] == 0                 # zero measurements: cache-served
    plain = compile_graph(_stencil_graph(32, 256), "xla")
    np.testing.assert_allclose(outs[0]["out"],
                               np.asarray(plain(img=frames[0])["out"]),
                               rtol=1e-6, atol=1e-7)
    prov = [m["tile_provenance"] for m in rep["modeled"].values()]
    assert prov and all(p == ["cache"] for p in prov)
    assert 2 in res.config.group_vf


def test_replicate_app_picks_up_tuning(tmp_path):
    from repro.parallel.replicate import replicate_app

    cache = TuningCache(str(tmp_path))
    g = build_app("filter_chain", 32, 128)
    app = compile_graph(build_app("filter_chain", 32, 128), backend="xla")
    rapp = replicate_app(app, tune="auto", tune_cache=cache)
    x = np.random.default_rng(0).normal(size=(32, 128)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(rapp(img=x)["out"]),
                                  np.asarray(app(img=x)["out"]))
    assert len(cache) >= 1                 # the local extended plane's entry
    assert "via measured" in rapp.describe() or \
        "via cache" in rapp.describe()
    # second replication: served from the persistent cache
    calls = {"n": 0}
    import repro.tune.search as search
    real = search.default_measure

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    search.default_measure = counting
    try:
        rapp2 = replicate_app(app, tune="auto", tune_cache=cache)
    finally:
        search.default_measure = real
    assert calls["n"] == 0
    assert "via cache" in rapp2.describe()
