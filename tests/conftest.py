"""Shared test fixtures.

NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see
the real single CPU device.  Multi-device tests spawn subprocesses with
their own --xla_force_host_platform_device_count (see
tests/test_distribution.py).
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture
def rng():
    return np.random.default_rng(0)
