"""Hardware parallelism end-to-end: vector factor + replication.

Covers the vectorization knob (tile minor-dim widening through the
cost-model sweep), spatial replication (shard_map row partitioning
with halo exchange), the batch-parallel serving farm, and the
correctness fixes in the tile/sim/batching hot paths.

Bit-exactness note: the replication/vectorization equivalence tests
use apps whose stencil taps are powers of two (``filter_chain``,
``gaussian_blur``), so every product is exact and no backend's FMA
contraction can change a single bit — the same convention as
tests/test_compiler.py.
"""
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (DataflowGraph, TaskTiming, analytic_latency,
                        build_schedule, choose_tile, compile_graph,
                        simulate_pipeline, sweep_vector_factor)
from repro.core.apps import build_app
from repro.core.graph import GraphError
from repro.parallel.replicate import (graph_input_halo, replicate_app)
from repro.runtime import MicroBatcher

H, W = 96, 256


def _single_group(name="gaussian_blur", h=H, w=W):
    sched = build_schedule(build_app(name, h, w))
    assert len(sched.groups) == 1
    return sched.groups[0]


# ----------------------------------------------------------------------
# choose_tile clamping (satellite bugfix)
# ----------------------------------------------------------------------
def test_choose_tile_exact_minor_dim():
    g = _single_group()
    th, tw = choose_tile(g, vector_factor=2)
    assert tw == 2 * 128
    assert th % 8 == 0


def test_choose_tile_rejects_factor_beyond_plane():
    """The old code silently returned a tile wider than the plane."""
    g = _single_group(h=96, w=256)          # lane-rounded width: 256
    with pytest.raises(ValueError, match="widest feasible"):
        choose_tile(g, vector_factor=3)     # 384 lanes > 256


def test_choose_tile_rejects_factor_beyond_max_tile():
    g = _single_group(h=96, w=4096)
    with pytest.raises(ValueError, match="max_tile"):
        choose_tile(g, vector_factor=4, max_tile=(256, 256))


def test_choose_tile_never_exceeds_max_tile():
    g = _single_group(h=2048, w=4096)
    th, tw = choose_tile(g, vector_factor=2, max_tile=(64, 512))
    assert th <= 64 and tw == 256


# ----------------------------------------------------------------------
# cost-model sweep
# ----------------------------------------------------------------------
def test_sweep_feasibility_is_monotone():
    g = _single_group(h=96, w=640)
    records = sweep_vector_factor(g)
    feas = [r["feasible"] for r in records]
    # once infeasible, never feasible again (wider tiles only get worse)
    assert feas == sorted(feas, reverse=True)
    assert feas[0] is True and feas[-1] is False
    for r in records:
        if r["feasible"]:
            assert r["tile"][1] == 128 * r["vector_factor"]


def test_sweep_does_not_mutate_selected_tile():
    """The sweep only scores; a standalone sweep over a scheduled
    group must not replace the schedule's chosen tile."""
    sched = build_schedule(build_app("gaussian_blur", 96, 640))
    g = sched.groups[0]
    chosen = (g.tile, g.vector_factor)
    sweep_vector_factor(g)
    assert (g.tile, g.vector_factor) == chosen


def test_schedule_selects_tile_and_reports_it():
    sched = build_schedule(build_app("gaussian_blur", 96, 640))
    g = sched.groups[0]
    assert g.tile is not None and g.vector_factor is not None
    assert g.tile[1] == 128 * g.vector_factor
    # the sweep avoids padding waste: 640 = 5 * 128 divides exactly
    assert g.vector_factor == 5
    text = sched.describe()
    assert "[vectorize]" in text and "vector_factor=5" in text


def test_forced_vector_factor_in_diagnostics():
    sched = build_schedule(build_app("gaussian_blur", 96, 640),
                           vector_factor=2)
    assert sched.groups[0].tile[1] == 256
    assert any("forced vector_factor=2" in d for d in sched.diagnostics)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
def test_vectorized_bit_exact_vs_default(backend, rng):
    """vector_factor>1 tiles change the schedule, never the bits."""
    x = rng.normal(size=(H, W)).astype(np.float32)
    base = compile_graph(build_app("gaussian_blur", H, W), backend=backend)
    vec = compile_graph(build_app("gaussian_blur", H, W), backend=backend,
                        vector_factor=2)
    assert vec.schedule.groups[0].tile[1] == 256
    np.testing.assert_array_equal(np.asarray(base(img=x)["out"]),
                                  np.asarray(vec(img=x)["out"]))


# ----------------------------------------------------------------------
# simulate_pipeline steady_rate (satellite bugfix)
# ----------------------------------------------------------------------
def test_steady_rate_equals_max_ii_exactly():
    """Constant-ii pipeline completes one item every max(ii) cycles in
    steady state; the old fencepost error under-reported it by
    ~ii/(n/2)."""
    for iis in ([1.0, 2.0, 1.0], [3.0, 1.0], [2.5]):
        tasks = [TaskTiming(f"t{i}", ii=v, fill=8.0)
                 for i, v in enumerate(iis)]
        sim = simulate_pipeline(tasks, 64, depth=2)
        assert sim["steady_rate"] == pytest.approx(max(iis), abs=1e-9)


def test_analytic_latency_zero_items():
    tasks = [TaskTiming("a", ii=1.0, fill=4.0)]
    r = analytic_latency(tasks, 0)
    assert r["sequential"] == r["dataflow"] == 4.0
    assert r["speedup"] == 1.0
    assert analytic_latency([TaskTiming("z", ii=1.0, fill=0.0)],
                            0)["speedup"] == 1.0  # 0/0 guarded
    with pytest.raises(ValueError):
        simulate_pipeline(tasks, 0)


# ----------------------------------------------------------------------
# MicroBatcher validation (satellite bugfix)
# ----------------------------------------------------------------------
class _Req:
    def __init__(self, inputs):
        self.inputs = inputs


def test_microbatcher_rejects_empty_batch(rng):
    app = compile_graph(build_app("square", 16, 128), backend="xla")
    mb = MicroBatcher(max_batch=4)
    with pytest.raises(ValueError, match="empty request batch"):
        mb.stack(app, [])
    with pytest.raises(ValueError, match="empty request batch"):
        mb.launch(app, [])


def test_microbatcher_stacks_scalar_channels(rng):
    """0-d channel inputs stack to a (B,) staging buffer."""
    g = DataflowGraph("scalar_mix")
    x = g.input("x", (16, 128))
    s = g.input("s", ())
    y = g.custom([x, s], lambda xv, sv: xv * sv, [(16, 128)],
                 name="scale")[0]
    g.output(y, "y")
    app = compile_graph(g, backend="xla")
    mb = MicroBatcher(max_batch=4)
    reqs = [_Req({"x": rng.normal(size=(16, 128)).astype(np.float32),
                  "s": np.float32(i + 1)}) for i in range(3)]
    args = mb.stack(app, reqs, pad_to=4)
    assert args[0].shape == (4, 16, 128) and args[1].shape == (4,)
    out = mb.launch(app, reqs, pad_to=4)["y"]
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(
            np.asarray(out[i]), r.inputs["x"] * r.inputs["s"])


def test_microbatcher_names_bad_shape(rng):
    app = compile_graph(build_app("square", 16, 128), backend="xla")
    mb = MicroBatcher(max_batch=4)
    good = _Req({"img": rng.normal(size=(16, 128)).astype(np.float32)})
    bad = _Req({"img": rng.normal(size=(16, 64)).astype(np.float32)})
    with pytest.raises(ValueError, match=r"request\[1\] input 'img'"):
        mb.stack(app, [good, bad])


def test_microbatcher_replicas_must_divide():
    with pytest.raises(ValueError, match="divide evenly"):
        MicroBatcher(max_batch=6, replicas=4)


# ----------------------------------------------------------------------
# replication: halo analysis + single-device fallback (bit-exact)
# ----------------------------------------------------------------------
def test_graph_input_halo_accumulates_across_groups():
    g = build_app("filter_chain", H, W)      # three 3x3 stencils
    halos = graph_input_halo(g)
    assert list(halos.values()) == [(3, 3)]


def test_replicate_rejects_mixed_shapes():
    g = DataflowGraph("mixed")
    x = g.input("x", (32, 128))
    g.output(g.reduce(x, lambda v: v.sum(), out_shape=()), "total")
    with pytest.raises(GraphError, match="2-D plane"):
        replicate_app(g, 1, backend="xla")


def test_replicate_rejects_opaque_stages():
    """custom/reduce stages could read across the row cut; no halo
    provision or masking makes that correct, so reject loudly."""
    g = DataflowGraph("opaque")
    x = g.input("x", (32, 128))
    y = g.custom([x], lambda v: v + 1.0, [(32, 128)], name="addone")[0]
    g.output(g.stencil(y, (3, 3), lambda p: p.mean(0)), "out")
    with pytest.raises(GraphError, match="opaque"):
        replicate_app(g, 1, backend="xla")


def test_replicate_rejects_nondividing_height():
    with pytest.raises(GraphError, match="divide"):
        replicate_app(build_app("square", 30, 128), 4, backend="xla")


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("name", ["filter_chain", "gaussian_blur"])
def test_replicated_single_device_bit_exact(backend, name, rng):
    """1 replica == the CI fallback: same shard_map + halo-exchange
    code path, must reproduce the plain app bit-for-bit."""
    app = compile_graph(build_app(name, H, W), backend=backend)
    rep = replicate_app(app)
    assert rep.n_replicas == 1 and rep.halo_rows > 0
    x = rng.normal(size=(H, W)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(app(img=x)["out"]),
                                  np.asarray(rep(img=x)["out"]))


def test_replicated_app_launch_and_describe(rng):
    rep = replicate_app(build_app("filter_chain", H, W), backend="xla")
    x = rng.normal(size=(H, W)).astype(np.float32)
    h = rep.launch(img=x)
    out = h.result()["out"]
    assert out.shape == (H, W)
    text = rep.describe()
    assert "1 replicas" in text and "halo rows" in text


# ----------------------------------------------------------------------
# replication: true multi-device (subprocess, forced host devices)
# ----------------------------------------------------------------------
PREAMBLE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "src")
import numpy as np
from repro.core import compile_graph
from repro.core.apps import build_app
"""


def run_sub(code: str, timeout: int = 560):
    r = subprocess.run([sys.executable, "-c", PREAMBLE + code],
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_replicated_multi_device_bit_exact():
    run_sub("""
from repro.parallel.replicate import replicate_app
for backend in ("xla", "pallas"):
    app = compile_graph(build_app("filter_chain", 96, 256), backend=backend)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(96, 256)).astype(np.float32)
    ref = np.asarray(app(img=x)["out"])
    for k in (2, 4):
        rep = replicate_app(app, k)
        assert rep.n_replicas == k
        out = np.asarray(rep(img=x)["out"])
        assert np.array_equal(out, ref), (backend, k,
                                          float(np.abs(out - ref).max()))
print("ok")
""")


def test_engine_replicas_multi_device_bit_exact():
    run_sub("""
from repro.runtime import StreamEngine
g = build_app("filter_chain", 32, 128)
app = compile_graph(build_app("filter_chain", 32, 128), backend="xla")
rng = np.random.default_rng(0)
xs = [rng.normal(size=(32, 128)).astype(np.float32) for _ in range(12)]
ref = [np.asarray(app(img=x)["out"]) for x in xs]
with StreamEngine(backend="xla", max_batch=8, replicas=4) as eng:
    handles = [eng.submit(g, {"img": x}) for x in xs]
    outs = [h.result()["out"] for h in handles]
    rep = eng.report()
assert all(np.array_equal(a, b) for a, b in zip(outs, ref))
m = rep["measured"]
assert m["replicas"] == 4
assert m["throughput_per_replica_rps"] * 4 == m["throughput_rps"]
mod = next(iter(rep["modeled"].values()))
assert mod["replica_scaling_modeled"] > 1.0
print("ok")
""")
