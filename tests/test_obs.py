"""Flight-recorder tests: tracer, export, metrics, drift, engine wiring.

Covers the observability contracts the rest of the repo leans on:

- concurrent tracing — interleaved spans from many threads nest and
  attribute correctly, per-thread timelines stay monotonic;
- the ring buffer drops oldest and never blocks, and the Chrome
  exporter sanitizes the eviction damage into a valid trace;
- a disabled tracer is a cheap ``None`` guard on the hot path
  (overhead bound asserted);
- one traced engine request yields a single trace id whose phase
  spans tile submit→complete with no gaps;
- telemetry reservoirs (not first-N buffers): late-run latency shifts
  move p99;
- drift capture persists modeled-vs-measured rows on disk and
  ``drift_report`` reproduces a misordering as negative rank
  correlation.
"""
from __future__ import annotations

import json
import os
import threading
import time

import numpy as np
import pytest

from repro.obs import (DriftLog, Histogram, MetricsRegistry, Tracer,
                       drift_report, export_chrome_trace, load_chrome_trace,
                       resolve_drift, resolve_tracer, spearman,
                       validate_chrome_trace)


# ----------------------------------------------------------------------
# tracer core
# ----------------------------------------------------------------------
def test_span_nesting_and_exit_attrs():
    tr = Tracer()
    with tr.span("outer", cat="t", a=1) as sp:
        with tr.span("inner", cat="t"):
            pass
        sp.set(b=2)
    evs = tr.events()
    assert [(e.ph, e.name) for e in evs] == [
        ("B", "outer"), ("B", "inner"), ("E", "inner"), ("E", "outer")]
    assert evs[0].args == {"a": 1}
    assert evs[-1].args == {"b": 2}          # exit attrs ride on the E


def test_cross_thread_begin_end():
    tr = Tracer()
    tok = tr.begin("xfer", cat="t")
    out: list = []
    th = threading.Thread(target=lambda: out.append(tr.end(tok)))
    th.start()
    th.join()
    evs = tr.events()
    assert len(evs) == 1 and evs[0].ph == "X" and evs[0].name == "xfer"
    assert evs[0].dur >= 0.0
    # the X is attributed to the *beginning* thread's timeline
    assert evs[0].tid == threading.main_thread().ident


def test_concurrent_interleaved_spans_validate(tmp_path):
    tr = Tracer()
    barrier = threading.Barrier(4)

    def work(i: int):
        barrier.wait()
        for j in range(50):
            with tr.span(f"req{i}", cat="load", j=j):
                with tr.span("step", cat="load"):
                    pass

    threads = [threading.Thread(target=work, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    payload = export_chrome_trace(tr, str(tmp_path / "t.json"))
    stats = validate_chrome_trace(payload)      # raises on any violation
    assert stats["spans"] == 4 * 50 * 2
    assert stats["threads"] == 4
    # every thread's B events name only its own requests
    by_tid: dict = {}
    for e in tr.events():
        if e.ph == "B" and e.name.startswith("req"):
            by_tid.setdefault(e.tid, set()).add(e.name)
    assert all(len(names) == 1 for names in by_tid.values())


def test_ring_drops_oldest_never_blocks(tmp_path):
    tr = Tracer(capacity=64)
    for i in range(500):
        with tr.span(f"s{i}", cat="t"):
            pass
    assert len(tr) == 64
    assert tr.dropped == 2 * 500 - 64
    names = [e.name for e in tr.events()]
    assert "s0" not in names and "s499" in names      # oldest evicted
    # eviction orphans E events / leaves dangling Bs; export sanitizes
    payload = export_chrome_trace(tr, str(tmp_path / "ring.json"))
    validate_chrome_trace(payload)


def test_disabled_tracer_is_none_and_cheap():
    assert resolve_tracer(False) is None
    assert resolve_tracer(Tracer(enabled=False)) is None
    # the hot-path pattern is a None guard; bound its per-iteration
    # cost (generous: CI boxes are noisy, the guard is ~10ns)
    tracer = resolve_tracer(False)
    n = 200_000
    t0 = time.perf_counter()
    for _ in range(n):
        if tracer is not None:
            tracer.instant("never")
    dt = time.perf_counter() - t0
    assert dt / n < 2e-6, f"disabled-tracer guard cost {dt / n * 1e9:.0f}ns"


def test_tracer_is_always_truthy():
    # __len__ would make an empty tracer falsy and `tracer or x`
    # silently discard a live recorder (the engine->batcher bug)
    assert bool(Tracer())
    assert len(Tracer()) == 0


def test_resolve_tracer_semantics():
    tr = Tracer()
    assert resolve_tracer(tr) is tr
    assert isinstance(resolve_tracer(True), Tracer)
    assert resolve_tracer(False) is None
    with pytest.raises(TypeError):
        resolve_tracer("out.json")


def test_counter_and_instant_export(tmp_path):
    tr = Tracer()
    tr.instant("mark", cat="t")
    tr.counter("depth", 3)
    payload = export_chrome_trace(tr, str(tmp_path / "c.json"))
    phs = {e["ph"] for e in payload["traceEvents"]}
    assert "i" in phs and "C" in phs
    validate_chrome_trace(payload)


# ----------------------------------------------------------------------
# chrome export
# ----------------------------------------------------------------------
def test_export_roundtrip_and_schema(tmp_path):
    tr = Tracer()
    with tr.span("a", cat="t"):
        pass
    aid = tr.new_id()
    now = time.perf_counter()
    tr.async_span("phase", aid, now, now + 1e-3, cat="req")
    path = str(tmp_path / "out.json")
    export_chrome_trace(tr, path)
    payload = load_chrome_trace(path)
    assert payload["displayTimeUnit"] == "ms"
    stats = validate_chrome_trace(payload)
    assert stats["spans"] == 1 and stats["async_spans"] == 1
    # raw file is plain JSON (Perfetto/chrome://tracing loadable)
    with open(path) as f:
        assert isinstance(json.load(f)["traceEvents"], list)


def test_validate_rejects_unbalanced():
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [
            {"ph": "B", "name": "a", "pid": 1, "tid": 1, "ts": 0},
            {"ph": "E", "name": "b", "pid": 1, "tid": 1, "ts": 1},
        ]})


# ----------------------------------------------------------------------
# metrics: reservoir histograms
# ----------------------------------------------------------------------
def test_histogram_reservoir_sees_late_run():
    # first-N truncation would freeze the percentile on the early era;
    # a uniform reservoir keeps sampling the whole run
    h = Histogram("lat", capacity=500, seed=0)
    h.extend([1.0] * 5000)
    assert h.percentile(99) == 1.0
    h.extend([100.0] * 5000)
    assert h.count == 10_000
    assert h.percentile(99) == 100.0          # late shift visible
    assert 0.3 < np.mean(h.samples() == np.float64(100.0)) < 0.7


def test_histogram_deterministic_seed():
    a, b = Histogram("x", capacity=64, seed=7), Histogram("x", capacity=64,
                                                          seed=7)
    xs = list(range(10_000))
    a.extend(xs)
    b.extend(xs)
    assert a.samples() == b.samples()


def test_registry_type_conflict():
    reg = MetricsRegistry()
    reg.counter("n")
    with pytest.raises(ValueError):
        reg.histogram("n")
    assert sorted(reg.names()) == ["n"]


def test_telemetry_p99_tracks_late_latency_shift():
    from repro.runtime.telemetry import Telemetry
    tel = Telemetry(max_samples=1000, seed=0)
    now = time.perf_counter()
    tel.observe_batches([(now, 8, None, [0.001] * 100, None)
                         for _ in range(50)])
    assert tel.snapshot()["latency_p99_ms"] == pytest.approx(1.0)
    tel.observe_batches([(now, 8, None, [0.5] * 100, None)
                         for _ in range(50)])
    snap = tel.snapshot()
    assert snap["completed"] == 10_000
    # with first-5000 truncation this would still read 1.0ms
    assert snap["latency_p99_ms"] > 100.0


# ----------------------------------------------------------------------
# drift capture
# ----------------------------------------------------------------------
def test_drift_log_persists_and_reloads(tmp_path):
    path = str(tmp_path / "drift.jsonl")
    log = DriftLog(path)
    log.record("trial", "sigA", [[8, 128]], "xla", 1e-5, 2e-4, label="vf1")
    log.record("trial", "sigA", [[8, 128]], "xla", 2e-5, 1e-4, label="vf2")
    log.flush()
    rows = DriftLog(path).rows()               # fresh handle, from disk
    assert [r.attrs["label"] for r in rows] == ["vf1", "vf2"]
    assert rows[0].modeled_s == 1e-5 and rows[0].measured_s == 2e-4


def test_drift_report_reproduces_misordering(tmp_path):
    # the model ranks candidates one way, the hardware the other —
    # exactly the bench_parallel misordering; spearman must go negative
    log = DriftLog(str(tmp_path / "d.jsonl"))
    modeled = [1.0, 2.0, 3.0, 4.0]
    measured = [4.0, 3.0, 2.0, 1.0]
    for m, s in zip(modeled, measured):
        log.record("vf_sweep", "sig", [[96, 256]], "pallas", m * 1e-5,
                   s * 1e-5)
    log.flush()
    rep = drift_report(DriftLog(log.path))
    assert rep["n"] == 4
    assert rep["spearman"] == pytest.approx(-1.0)
    assert rep["groups"]["sig"]["spearman"] == pytest.approx(-1.0)
    assert os.path.exists(log.path)


def test_spearman_ties_and_degenerate():
    assert spearman([1, 2, 3], [1, 2, 3]) == pytest.approx(1.0)
    assert np.isnan(spearman([1.0], [2.0]))
    assert np.isnan(spearman([1, 1, 1], [1, 2, 3]))
    # partial ties average ranks instead of breaking arbitrarily
    assert spearman([1, 2, 2, 3], [1, 2, 2, 3]) == pytest.approx(1.0)
    with pytest.raises(ValueError):
        spearman([1, 2], [1, 2, 3])


def _row(modeled, measured, kind="launch", sig="sig", attrs=None):
    from repro.obs.drift import DriftRow
    return DriftRow(kind, sig, [[8, 128]], "xla", modeled, measured, attrs)


def test_drift_report_skips_and_counts_sick_rows():
    # NaN/inf/nonpositive on either side must be dropped AND counted —
    # not poison every statistic, not vanish silently
    clean = [_row(1e-5, 2e-5), _row(2e-5, 3e-5), _row(3e-5, 5e-5)]
    sick = [_row(float("nan"), 1e-5), _row(1e-5, float("inf")),
            _row(0.0, 1e-5), _row(1e-5, -1e-5)]
    rep = drift_report(clean + sick)
    assert rep["n"] == 3 and rep["skipped"] == 4
    assert rep["spearman"] == pytest.approx(1.0)
    assert np.isfinite(rep["bias"]) and np.isfinite(rep["log10_spread"])


def test_drift_report_all_sick_rows():
    rep = drift_report([_row(float("nan"), 1e-5), _row(1e-5, 0.0)])
    assert rep["n"] == 0 and rep["skipped"] == 2
    assert np.isnan(rep["spearman"]) and np.isnan(rep["bias"])
    assert rep["groups"] == {} and rep["by_kind"] == {}


def test_drift_report_all_tied_and_single_row():
    # all-tied modeled: rank correlation is undefined (nan), but the
    # bias is still a perfectly good constant to report
    tied = drift_report([_row(1e-5, 1e-4), _row(1e-5, 2e-4),
                         _row(1e-5, 3e-4)])
    assert np.isnan(tied["spearman"])
    assert tied["bias"] == pytest.approx(20.0)
    single = drift_report([_row(1e-5, 2e-5)])
    assert single["n"] == 1 and np.isnan(single["spearman"])
    assert single["bias"] == pytest.approx(2.0)


def test_drift_report_with_spec_rescoring():
    # rows carrying features are re-scored under the given spec; rows
    # without features are counted, not guessed at
    class Spec:
        clock_hz, hbm_bw, step_overhead_s = 1e9, 1e9, 1e-3

    feats = {"groups": [{"grid": 2, "bytes_step": 10.0,
                         "steps": {"point": 100.0}}]}
    with_f = [_row(1e-5, 2.1e-3, attrs={"features": dict(feats)}),
              _row(2e-5, 2.0e-3, attrs={"features": dict(feats)})]
    without = [_row(3e-5, 4e-5)]
    rep = drift_report(with_f + without, spec=Spec())
    ws = rep["with_spec"]
    assert ws["n"] == 2 and ws["without_features"] == 1
    # predicted 2*(1ms + 100ns) for both rows: bias ~1, spearman nan
    assert ws["bias"] == pytest.approx(1.0, rel=0.1)
    assert np.isnan(ws["spearman"])
    # without spec= the key is absent entirely
    assert "with_spec" not in drift_report(with_f)


def test_drift_row_features_roundtrip_disk(tmp_path):
    # features ride attrs through the JSONL file bit-for-bit, and the
    # accessor is None (not a crash) for rows that predate them
    from repro.obs.drift import DriftRow, predict_features
    log = DriftLog(str(tmp_path / "f.jsonl"))
    feats = {"groups": [{"grid": 4, "bytes_step": 1000.0,
                         "steps": {"stencil": 2000.0}}], "items": 2}
    log.record("launch", "sig", [[8, 128]], "xla", 1e-5, 2e-5,
               features=feats)
    log.record("launch", "sig", [[8, 128]], "xla", 1e-5, 2e-5)
    log.flush()
    rows = DriftLog(log.path).rows()
    assert rows[0].features == feats
    assert rows[1].features is None
    class Spec:
        clock_hz, hbm_bw, step_overhead_s = 1e9, 1e9, 1e-6
    # items multiplies through the reconstituted prediction
    assert predict_features(rows[0].features, Spec()) == pytest.approx(
        2 * 4 * (1e-6 + 2e-6), rel=1e-12)
    # malformed features (wrong type) read back as None, not a crash
    assert DriftRow("launch", "s", None, "xla", 1e-5, 2e-5,
                    {"features": "oops"}).features is None


def test_resolve_drift_semantics(tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_DRIFT_LOG", raising=False)
    assert resolve_drift(None) is None         # off by default
    assert resolve_drift(False) is None
    path = str(tmp_path / "d.jsonl")
    monkeypatch.setenv("REPRO_DRIFT_LOG", path)
    log = resolve_drift(None)                  # env switches it on
    assert isinstance(log, DriftLog) and log.path == path
    assert resolve_drift(path).path == path
    with pytest.raises(TypeError):
        resolve_drift(3.14)


# ----------------------------------------------------------------------
# engine + compile integration
# ----------------------------------------------------------------------
def _pointwise():
    from repro.core import DataflowGraph
    g = DataflowGraph("obs_pw")
    x = g.input("x", (8, 128))
    g.output(g.point(x, lambda v: v * 2.0, name="dbl"), "y")
    return g


def test_engine_trace_single_id_contiguous_phases(tmp_path):
    from repro.runtime import StreamEngine
    tr = Tracer()
    with StreamEngine(backend="xla", max_batch=4, trace=tr) as eng:
        h = eng.submit(_pointwise(), {"x": np.ones((8, 128), np.float32)})
        np.asarray(h.result(timeout=60)["y"])
    aids = {e.aid for e in tr.events() if e.cat == "request"
            if e.aid is not None}
    assert len(aids) == 1                      # one request, one trace id
    aid = aids.pop()
    phases = [e for e in tr.events()
              if e.cat == "request" and e.aid == aid and e.ph == "b"
              and e.name != "request"]
    phases.sort(key=lambda e: e.ts)
    assert [e.name for e in phases] == ["queue_wait", "form", "stack",
                                       "launch", "execute", "readback"]
    # phase spans tile submit→complete with no gaps: each 'b' at the
    # previous phase's 'e'
    evs = [e for e in tr.events() if e.cat == "request" and e.aid == aid]
    b_ts = {e.name: e.ts for e in evs if e.ph == "b"}
    e_ts = {e.name: e.ts for e in evs if e.ph == "e"}
    chain = ["queue_wait", "form", "stack", "launch", "execute",
             "readback"]
    assert b_ts["queue_wait"] == pytest.approx(b_ts["request"], abs=1e-9)
    for prev, nxt in zip(chain, chain[1:]):
        assert e_ts[prev] == pytest.approx(b_ts[nxt], abs=1e-9)
    assert e_ts["readback"] == pytest.approx(e_ts["request"], abs=1e-9)
    # the batcher's stack/launch X spans rode the same tracer
    assert {e.name for e in tr.events() if e.cat == "batcher"} == {
        "batch.stack", "batch.launch"}
    validate_chrome_trace(export_chrome_trace(tr, str(tmp_path / "e.json")))


def test_engine_drift_rows_compile_then_launch(tmp_path):
    from repro.runtime import StreamEngine
    path = str(tmp_path / "drift.jsonl")
    with StreamEngine(backend="xla", max_batch=2, drift=path) as eng:
        g = _pointwise()
        for i in range(3):
            eng.submit(g, {"x": np.full((8, 128), i, np.float32)}
                       ).result(timeout=60)
    rows = DriftLog(path).rows()
    assert len(rows) >= 3
    kinds = [r.kind for r in rows]
    assert kinds[0] == "compile"               # first launch includes jit
    assert "launch" in kinds[1:]
    rep = drift_report(DriftLog(path))
    assert rep["n"] == len(rows) and rep["bias"] > 0


def test_compile_trace_spans():
    from repro.core import compile_graph
    tr = Tracer()
    compile_graph(_pointwise(), backend="xla", trace=tr)
    names = {e.name for e in tr.events() if e.ph == "B"}
    assert {"compile", "compile.lower", "compile.host",
            "compile.partition", "compile.pass.auto-split",
            "compile.pass.dead-channel", "compile.pass.point-fusion",
            "compile.vectorize.sweep"} <= names


def test_untraced_engine_has_no_recorder_state():
    from repro.runtime import StreamEngine
    with StreamEngine(backend="xla", max_batch=2) as eng:
        assert eng.tracer is None and eng.drift is None
        assert eng._batcher.tracer is None
