"""Optimizer, data pipeline, checkpointing, fault machinery."""
import os
import shutil
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.checkpoint.checkpointer import (Checkpointer, latest_step,
                                           restore_pytree, save_pytree)
from repro.data.pipeline import SyntheticLM
from repro.optim.adamw import (AdamWConfig, adamw_apply, adamw_init,
                               clip_by_global_norm, lr_schedule)
from repro.optim.compression import compress, decompress, ef_init, \
    ef_roundtrip
from repro.runtime.fault import (HeartbeatRegistry, PreemptionGuard,
                                 StragglerMonitor)


# ----------------------------------------------------------------------
# optimizer
# ----------------------------------------------------------------------
def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr_peak=0.1, warmup_steps=5, decay_steps=200,
                      weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    target = jnp.array([1.0, 2.0])
    for _ in range(200):
        grads = {"w": (params["w"] - target)}
        params, state, _ = adamw_apply(cfg, params, grads, state)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.05)


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr_peak=1e-3, lr_min=1e-4, warmup_steps=10,
                      decay_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in range(120)]
    assert lrs[0] < lrs[9] <= 1e-3 + 1e-9
    assert abs(lrs[10] - 1e-3) < 1e-4
    assert lrs[115] <= lrs[50]
    assert lrs[-1] >= 1e-4 - 1e-9


def test_clipping():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 100
    n2 = float(jnp.linalg.norm(clipped["a"]))
    assert abs(n2 - 1.0) < 1e-4


# ----------------------------------------------------------------------
# gradient compression (error feedback)
# ----------------------------------------------------------------------
def test_compress_roundtrip_error_bounded(rng):
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    q, s, err = compress(g, jnp.zeros_like(g))
    rec = decompress(q, s)
    assert float(jnp.abs(rec - g).max()) <= float(s) * 0.5 + 1e-6


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_error_feedback_is_unbiased_over_time(seed):
    """Sum of transmitted values ~= sum of true gradients (EF property)."""
    rng = np.random.default_rng(seed)
    true = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    err = {"g": jnp.zeros((64,), jnp.float32)}
    sent = jnp.zeros((64,), jnp.float32)
    T = 50
    for _ in range(T):
        out, err = ef_roundtrip({"g": true}, err)
        sent = sent + out["g"]
    drift = float(jnp.abs(sent / T - true).max())
    assert drift < 5e-2       # residual error bounded by one quantum


# ----------------------------------------------------------------------
# data pipeline
# ----------------------------------------------------------------------
def test_data_deterministic_and_resumable():
    p1 = SyntheticLM(vocab_size=100, seq_len=16, global_batch=4, seed=7)
    p2 = SyntheticLM(vocab_size=100, seq_len=16, global_batch=4, seed=7)
    for step in (0, 5, 1000):
        b1, b2 = p1.batch(step), p2.batch(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(p1.batch(1)["tokens"], p1.batch(2)["tokens"])


def test_data_host_slicing_partitions_batch():
    full = SyntheticLM(vocab_size=100, seq_len=8, global_batch=8, seed=1)
    lo = SyntheticLM(vocab_size=100, seq_len=8, global_batch=8, seed=1,
                     host_lo=0, host_hi=4)
    assert lo.batch(3)["tokens"].shape[0] == 4
    assert full.batch(3)["tokens"].shape[0] == 8


def test_data_is_learnable_next_token():
    b = SyntheticLM(vocab_size=97, seq_len=32, global_batch=2,
                    seed=0).batch(0)
    # labels are tokens shifted by one (next-token prediction)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


# ----------------------------------------------------------------------
# checkpointing
# ----------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16),
                  "step": jnp.asarray(7, jnp.int32)}}
    save_pytree(tree, str(tmp_path), 7)
    like = jax.eval_shape(lambda: tree)
    out = restore_pytree(like, str(tmp_path), 7)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_detects_corruption(tmp_path):
    tree = {"w": jnp.arange(100, dtype=jnp.float32)}
    d = save_pytree(tree, str(tmp_path), 1)
    victim = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    arr = np.load(os.path.join(d, victim))
    arr[0] += 1
    np.save(os.path.join(d, victim), arr)
    with pytest.raises(IOError, match="corruption"):
        restore_pytree(jax.eval_shape(lambda: tree), str(tmp_path), 1)


def test_checkpoint_retention_and_async(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros((8,))}
    for s in (1, 2, 3, 4):
        ck.save(tree, s)
    ck.wait()
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [3, 4]
    assert ck.latest_step() == 4


def test_checkpoint_atomicity_no_partial_dirs(tmp_path):
    """A completed save never coexists with tmp litter."""
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save({"w": jnp.ones((4,))}, 5, blocking=True)
    assert latest_step(str(tmp_path)) == 5
    assert not [n for n in os.listdir(tmp_path) if ".tmp-" in n]


# ----------------------------------------------------------------------
# fault machinery
# ----------------------------------------------------------------------
def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(n_hosts=8, patience=3)
    flagged = []
    for t in range(10):
        times = np.ones(8)
        times[3] = 3.0          # host 3 is persistently 3x slower
        flagged = mon.observe(times)
    assert flagged == [3]


def test_straggler_monitor_ignores_transients():
    mon = StragglerMonitor(n_hosts=4, patience=3)
    for t in range(10):
        times = np.ones(4)
        if t == 4:
            times[1] = 5.0      # single spike
        assert mon.observe(times) == []


def test_heartbeats():
    clock = [0.0]
    reg = HeartbeatRegistry(n_hosts=3, deadline_s=10,
                            clock=lambda: clock[0])
    clock[0] = 5.0
    reg.beat(0)
    reg.beat(2)
    clock[0] = 12.0
    assert reg.dead_hosts() == [1]
    assert reg.survivors() == [0, 2]


def test_preemption_guard_catches_sigterm():
    with PreemptionGuard() as g:
        assert not g.preempted
        os.kill(os.getpid(), signal.SIGTERM)
        assert g.preempted
