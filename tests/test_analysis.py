"""HLO parsing + roofline arithmetic."""
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import collective_bytes, count_ops, shape_bytes
from repro.analysis.roofline import HW, analyze, model_flops
from repro.configs import get_config
from repro.models.config import SHAPES


def test_shape_bytes():
    assert shape_bytes("f32[16,128]") == 16 * 128 * 4
    assert shape_bytes("bf16[4,8]{1,0}") == 4 * 8 * 2
    assert shape_bytes("(bf16[2,2], u32[])") == 8 + 4
    assert shape_bytes("pred[]") == 1


HLO_FIXTURE = """
HloModule m
ENTRY e {
  %p = f32[64,64]{1,0} parameter(0)
  %ag = f32[64,512]{1,0} all-gather(%p), replica_groups=[8,8]<=[64], dimensions={1}
  %ar = f32[64,64]{1,0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[8,64]{1,0} reduce-scatter(%p), replica_groups=[8,8]<=[64], dimensions={0}, to_apply=%add
  %cp = f32[64,64]{1,0} collective-permute(%p), source_target_pairs={{0,1}}
  %aa = f32[64,64]{1,0} all-to-all(%p), replica_groups={{0,1}}
}
"""


def test_collective_bytes_fixture():
    out = collective_bytes(HLO_FIXTURE)
    f = 4
    assert out["all-gather"] == 64 * 512 * f
    assert out["all-reduce"] == 64 * 64 * f
    assert out["reduce-scatter"] == 8 * 64 * f * 8   # x group size
    assert out["collective-permute"] == 64 * 64 * f
    assert out["all-to-all"] == 64 * 64 * f
    assert out["ops"] == 5


def test_collective_bytes_real_module():
    """Parse a real sharded module compiled on host devices."""
    import subprocess
    import sys
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
import sys
sys.path.insert(0, "src")
from repro.analysis.hlo import collective_bytes
mesh = jax.make_mesh((8,), ("m",))
def f(x, w):
    y = x @ w
    return y.sum()
x = jax.ShapeDtypeStruct((64, 512), jnp.float32)
w = jax.ShapeDtypeStruct((512, 64), jnp.float32)
c = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, "m")),
                             NamedSharding(mesh, P("m", None))),
            out_shardings=NamedSharding(mesh, P())).lower(x, w).compile()
out = collective_bytes(c.as_text())
assert out["total"] > 0, out
print("TOTAL", out["total"])
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300)
    assert r.returncode == 0, r.stderr
    assert "TOTAL" in r.stdout


def test_count_ops():
    assert count_ops(HLO_FIXTURE)["while"] == 0


def test_roofline_terms_and_dominance():
    cfg = get_config("granite-3-2b")
    shape = SHAPES["train_4k"]
    cost = {"flops": 1e15, "bytes accessed": 1e12}
    rep = analyze("granite_3_2b", shape, "pod", 256, cost, HLO_FIXTURE,
                  {}, cfg)
    hw = HW()
    assert abs(rep.t_compute - 1e15 / (256 * hw.peak_flops)) < 1e-12
    assert abs(rep.t_memory - 1e12 / (256 * hw.hbm_bw)) < 1e-12
    assert rep.dominant in ("compute", "memory", "collective")
    assert rep.model_flops == model_flops(cfg, shape)
    # train model flops = 6 N D
    assert abs(rep.model_flops
               - 6.0 * cfg.n_active_params() * 256 * 4096) < 1e6


def test_model_flops_kinds():
    cfg = get_config("granite-3-2b")
    assert model_flops(cfg, SHAPES["decode_32k"]) == \
        2.0 * cfg.n_active_params() * 128
    assert model_flops(cfg, SHAPES["prefill_32k"]) == \
        2.0 * cfg.n_active_params() * 32 * 32768
