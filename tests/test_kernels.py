"""Per-kernel shape/dtype sweeps against the ref.py oracles
(interpret mode == the kernel body executed on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref as R
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_mlp import fused_mlp
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.stream_pipeline import (stream_pipeline,
                                           stream_pipeline_staged)


def _tol(dtype):
    return 3e-2 if dtype == jnp.bfloat16 else 2e-3


@pytest.mark.parametrize("B,Hq,Hkv,S,D,causal,dtype", [
    (2, 4, 2, 256, 128, True, jnp.float32),
    (1, 8, 1, 384, 128, True, jnp.float32),      # MQA
    (2, 4, 4, 200, 128, False, jnp.float32),     # ragged S
    (1, 4, 2, 256, 64, True, jnp.float32),       # small head dim
    (1, 4, 2, 256, 128, True, jnp.bfloat16),
])
def test_flash_attention(B, Hq, Hkv, S, D, causal, dtype, rng):
    q = jnp.asarray(rng.normal(size=(B, Hq, S, D)), dtype)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), dtype)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), dtype)
    o = flash_attention(q, k, v, causal=causal, interpret=True)
    r = R.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(o, np.float32), np.asarray(r, np.float32),
        atol=_tol(dtype), rtol=_tol(dtype))


def test_flash_attention_mla_shapes(rng):
    """Dv != Dk (MLA absorbed attention == MQA over latents)."""
    B, Hq, S, Dk, Dv = 2, 8, 256, 288, 256
    q = jnp.asarray(rng.normal(size=(B, Hq, S, Dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, 1, S, Dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, 1, S, Dv)), jnp.float32)
    o = flash_attention(q, k, v, causal=True, scale=0.1, interpret=True)
    r = R.flash_attention_ref(q, k, v, causal=True, scale=0.1)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-3)


def test_flash_attention_padding_bias(rng):
    B, H, S, D = 2, 4, 256, 128
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    lens = np.array([200, 128])
    bias = jnp.where(np.arange(S)[None] < lens[:, None], 0.0, -1e30
                     ).astype(jnp.float32)
    o = flash_attention(q, k, v, bias=bias, causal=False, interpret=True)
    r = R.flash_attention_ref(q, k, v, bias=bias, causal=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-3)


@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (2, 8, 2, 512, 128), (1, 8, 8, 300, 128), (4, 4, 1, 1024, 64)])
def test_decode_attention(B, Hq, Hkv, S, D, rng):
    q = jnp.asarray(rng.normal(size=(B, Hq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    lens = rng.integers(S // 2, S, size=(B,))
    bias = jnp.where(np.arange(S)[None] < lens[:, None], 0.0, -1e30
                     ).astype(jnp.float32)
    o = decode_attention(q, k, v, bias=bias, interpret=True)
    r = R.decode_attention_ref(q, k, v, bias=bias)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-3)


@pytest.mark.parametrize("T,d,f,dtype", [
    (128, 256, 512, jnp.float32), (200, 384, 1000, jnp.float32),
    (64, 256, 768, jnp.bfloat16)])
def test_fused_mlp(T, d, f, dtype, rng):
    x = jnp.asarray(rng.normal(size=(T, d)), dtype)
    wn = jnp.asarray(rng.normal(size=(d,)), dtype)
    wg = jnp.asarray(rng.normal(size=(d, f)) * 0.05, dtype)
    wu = jnp.asarray(rng.normal(size=(d, f)) * 0.05, dtype)
    wd = jnp.asarray(rng.normal(size=(f, d)) * 0.05, dtype)
    o = fused_mlp(x, wn, wg, wu, wd, block_t=64, block_f=256,
                  interpret=True)
    r = R.fused_mlp_ref(x, wn, wg, wu, wd)
    tol = 1e-1 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=tol)


@pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
    (2, 128, 4, 16, 2, 32, 32), (1, 256, 8, 64, 1, 128, 64),
    (2, 96, 4, 16, 4, 32, 32)])
def test_ssd_scan_kernel(b, s, h, p, g, n, chunk, rng):
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(b, s, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    y, fs = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    yr, fr = R.ssd_scan_ref(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=3e-4)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(fr), atol=3e-4)


@given(st.integers(8, 96), st.integers(2, 12), st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_ssd_chunked_equals_sequential(s, h, seed):
    """Property: the chunked SSD scan == token-by-token recurrence."""
    rng = np.random.default_rng(seed)
    b, p, g, n = 1, 8, 1, 16
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.3, size=(b, s, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, g, n)), jnp.float32)
    from repro.kernels.ops import ssd
    y1, f1 = ssd(x, dt, A, B, C, chunk=16, impl="ref")
    y2, f2 = R.ssd_sequential_ref(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=5e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), atol=5e-4)


def test_stream_pipeline_fused_vs_staged(rng):
    fns = (jnp.tanh, lambda x: x * 2.0, jnp.abs, jnp.sqrt)
    x = jnp.asarray(np.abs(rng.normal(size=(100, 300))), jnp.float32)
    fused = stream_pipeline(x, fns, tile=(32, 128), interpret=True)
    staged = stream_pipeline_staged(x, fns)
    ref = x
    for f in fns:
        ref = f(ref)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(staged), np.asarray(ref),
                               atol=1e-6)


def test_chunked_attention_xla_matches_ref(rng):
    """The XLA streaming form (used by the dry-run) == naive oracle."""
    from repro.models.layers import attention_xla
    B, Hq, Hkv, S, D = 2, 4, 2, 512, 64
    q = jnp.asarray(rng.normal(size=(B, Hq, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, S, D)), jnp.float32)
    o1 = attention_xla(q, k, v, causal=True, chunk=128)
    o2 = R.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-4)
    o3 = attention_xla(q, k, v, causal=True, chunk=128, unroll=True)
    np.testing.assert_allclose(np.asarray(o3), np.asarray(o2), atol=2e-4)
