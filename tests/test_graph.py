"""Dataflow-graph extraction & validation (paper Section IV-A)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (ChannelContractError, CycleError, DataflowGraph,
                        build_schedule, default_pipeline)


def test_builder_produces_valid_graph():
    g = DataflowGraph("t")
    x = g.input("x", (8, 128))
    a, b = g.split(x)
    y = g.point2(g.point(a, jnp.abs), g.point(b, jnp.exp), jnp.add)
    g.output(y, "y")
    g.validate()
    order = g.toposort()
    assert len(order) == 4
    # write-before-read: every input channel's producer precedes the stage
    seen = set()
    for st_ in order:
        for ch in st_.inputs:
            if ch.producer is not None:
                assert ch.producer in seen
        seen.add(st_)


def test_cycle_detected():
    g = DataflowGraph("cyc")
    c1 = g.channel((8, 128))
    c2 = g.channel((8, 128))
    g.task("a", "point", jnp.abs, [c1], [c2])
    g.task("b", "point", jnp.abs, [c2], [c1])
    with pytest.raises((CycleError, ChannelContractError)):
        g.validate()


def test_double_write_rejected():
    g = DataflowGraph("dw")
    x = g.input("x", (8, 128))
    c = g.channel((8, 128))
    g.task("a", "point", jnp.abs, [x], [c])
    with pytest.raises(ChannelContractError):
        g.task("b", "point", jnp.abs, [x], [c])


def test_double_read_rejected():
    """The paper: channels are read only once; fan-out needs split."""
    g = DataflowGraph("dr")
    x = g.input("x", (8, 128))
    g.output(g.point(x, jnp.abs), "y1")
    g.output(g.point(x, jnp.exp), "y2")   # second read of x
    with pytest.raises(ChannelContractError):
        g.validate()


def test_unread_channel_rejected():
    g = DataflowGraph("ur")
    x = g.input("x", (8, 128))
    g.point(x, jnp.abs)   # result never read, never output
    with pytest.raises(ChannelContractError):
        g.validate()


def test_missing_producer_rejected():
    g = DataflowGraph("mp")
    c = g.channel((8, 128))
    g.output(g.point(c, jnp.abs), "y")
    with pytest.raises(ChannelContractError):
        g.validate()


def test_isolated_stage_schedules():
    """Paper: isolated tasks still execute (in parallel with the rest)."""
    g = DataflowGraph("iso")
    x = g.input("x", (8, 128))
    g.output(g.point(x, jnp.abs), "y")
    z = g.input("z", (8, 128))
    g.output(g.point(z, jnp.exp), "w")
    g.validate()
    assert len(g.toposort()) == 2
    sched = build_schedule(g)
    assert sum(len(grp.stages) for grp in sched.groups) == 2


# ----------------------------------------------------------------------
# property: random layered DAGs always validate + schedule
# ----------------------------------------------------------------------
@st.composite
def layered_dag(draw):
    g = DataflowGraph("prop")
    shape = (8, 128)
    live = [g.input(f"in{i}", shape)
            for i in range(draw(st.integers(1, 3)))]
    n_stages = draw(st.integers(1, 12))
    for i in range(n_stages):
        kind = draw(st.sampled_from(["point", "split", "stencil", "point2"]))
        src = draw(st.integers(0, len(live) - 1))
        ch = live.pop(src)
        if kind == "point":
            live.append(g.point(ch, jnp.abs))
        elif kind == "stencil":
            live.append(g.stencil(ch, (3, 3), lambda p: p.sum(0)))
        elif kind == "split":
            live.extend(g.split(ch, 2))
        else:
            if not live:
                live.append(g.point(ch, jnp.abs))
                continue
            src2 = draw(st.integers(0, len(live) - 1))
            ch2 = live.pop(src2)
            live.append(g.point2(ch, ch2, jnp.add))
    for i, ch in enumerate(live):
        if ch.is_graph_input:          # an input cannot also be an output
            ch = g.point(ch, jnp.abs)
        g.output(ch, f"out{i}")
    return g


@given(layered_dag())
@settings(max_examples=25, deadline=None)
def test_canonicalization_pipeline_is_idempotent(g):
    """Running the pass pipeline on an already-canonical graph is a
    fixed point: same stage/channel counts, same signature, identical
    schedule description, and no further diagnostics."""
    g1, _ = default_pipeline().run(g)
    g1.validate()
    before = (len(g1.stages), len(g1.channels), g1.signature())
    describe_before = build_schedule(g1, canonicalize=False).describe()
    g2, diags2 = default_pipeline().run(g1)
    assert g2 is g1                   # passes rewrite in place
    assert diags2 == []               # nothing left to rewrite
    assert (len(g2.stages), len(g2.channels), g2.signature()) == before
    assert build_schedule(g2, canonicalize=False).describe() \
        == describe_before


@given(layered_dag())
@settings(max_examples=25, deadline=None)
def test_random_dag_validates_and_schedules(g):
    g.validate()
    order = g.toposort()
    assert len(order) == len(g.stages)
    sched = build_schedule(g)
    # every stage lands in exactly one group
    placed = [s for grp in sched.groups for s in grp.stages]
    assert sorted(id(s) for s in placed) == sorted(id(s) for s in g.stages)
    # bundle assignment covers all graph I/O
    for ch in g.graph_inputs + g.graph_outputs:
        assert ch.bundle is not None
