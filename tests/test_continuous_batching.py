"""Continuous-batching engine: formation, fairness, cancel, buckets.

The submit→dispatch→complete hot path rebuilt around continuous
batching (per-app admission, deficit-weighted round-robin formation,
power-of-two bucketed padding, staged zero-copy launch, cancel
without leaking queue slots).  Everything here runs on the ``xla``
backend at small plane sizes so the suite stays fast; bit-exactness
is always against ``reference_eval``.
"""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DataflowGraph, compile_graph
from repro.core.apps import JACOBI3, LAPLACE3, _conv
from repro.runtime import (PHASES, CancelledError, CompileCache,
                           MicroBatcher, QueueFullError, StreamEngine,
                           Telemetry)
from repro.runtime.engine import _BUDGET_MAX_S, _BUDGET_MIN_S


def _diamond(h=8, w=128, name="diamond"):
    g = DataflowGraph(name)
    x = g.input("x", (h, w))
    s1 = g.stencil(x, (3, 3), _conv(LAPLACE3), name="lap")
    s2 = g.stencil(x, (3, 3), _conv(JACOBI3), name="jac")
    g.output(g.point2(s1, s2, lambda u, v: u - v, name="merge"), "y")
    return g


def _pointwise(h=8, w=128, name="act"):
    """A second topology (different signature than the diamond)."""
    g = DataflowGraph(name)
    x = g.input("x", (h, w))
    g.output(g.point(x, lambda v: jnp.tanh(v) * 1.5, name="tanh"), "y")
    return g


class _Req:
    def __init__(self, x):
        self.inputs = {"x": x}


# ----------------------------------------------------------------------
# bucketed pad widths
# ----------------------------------------------------------------------
def test_bucket_is_next_pow2_capped_at_max_batch():
    mb = MicroBatcher(max_batch=8)
    assert [mb.bucket(n) for n in (1, 2, 3, 4, 5, 7, 8)] \
        == [1, 2, 4, 4, 8, 8, 8]
    with pytest.raises(ValueError):
        mb.bucket(0)
    # the cap wins over the power of two
    assert MicroBatcher(max_batch=6).bucket(5) == 6


def test_launch_pads_to_bucket_and_counts_it(rng):
    app = compile_graph(_diamond(), backend="xla")
    mb = MicroBatcher(max_batch=8)
    reqs = [_Req(rng.normal(size=(8, 128)).astype(np.float32))
            for _ in range(5)]
    y3 = np.asarray(mb.launch(app, reqs[:3])["y"])
    y5 = np.asarray(mb.launch(app, reqs)["y"])
    # a 3-request batch launches a 4-wide kernel, not max_batch-wide
    assert y3.shape[0] == 4 and y5.shape[0] == 8
    assert mb.bucket_launches == {4: 1, 8: 1}
    for i, r in enumerate(reqs):
        np.testing.assert_array_equal(
            y5[i], np.asarray(app(x=r.inputs["x"])["y"]))


def test_staging_buffers_are_reused_and_stay_bit_exact(rng):
    """Rows stage into pinned buffers rotated ``staging_depth`` deep:
    the same arrays come back every depth launches, and repeated
    rotation never corrupts results."""
    app = compile_graph(_diamond(), backend="xla")
    mb = MicroBatcher(max_batch=4, staging_depth=2)
    ids = []
    for _ in range(6):
        reqs = [_Req(rng.normal(size=(8, 128)).astype(np.float32))
                for _ in range(4)]
        y = np.asarray(mb.launch(app, reqs)["y"])
        for i, r in enumerate(reqs):
            np.testing.assert_array_equal(
                y[i], np.asarray(app(x=r.inputs["x"])["y"]))
        ids.append(id(mb._staging[(app.signature(), 4)][0][0]))
    # one allocation, not one per batch
    assert len(set(ids)) == 1
    assert mb.bucket_launches == {4: 6}


# ----------------------------------------------------------------------
# cancellation: a timed-out caller can abandon without leaking capacity
# ----------------------------------------------------------------------
def test_cancel_frees_queue_slot_immediately(rng):
    g = _diamond()
    x = rng.normal(size=(8, 128)).astype(np.float32)
    eng = StreamEngine(backend="xla", max_queue=2, max_batch=2,
                       autostart=False)
    try:
        h1 = eng.submit(g, {"x": x}, block=False)
        h2 = eng.submit(g, {"x": x}, block=False)
        with pytest.raises(QueueFullError):
            eng.submit(g, {"x": x}, block=False)
        assert h1.cancel() is True
        # the cancelled request's slot is free right now, no drain needed
        h3 = eng.submit(g, {"x": x}, block=False)
        assert h1.cancelled()
        with pytest.raises(CancelledError):
            h1.result()
        assert h1.cancel() is False          # already completed
        eng.start()
        np.testing.assert_array_equal(h2.result(timeout=120)["y"],
                                      h3.result(timeout=120)["y"])
        m = eng.report()["measured"]
        assert m["cancelled"] == 1 and m["completed"] == 2
    finally:
        eng.close()


def test_result_timeout_then_cancel(rng):
    g = _diamond()
    x = rng.normal(size=(8, 128)).astype(np.float32)
    eng = StreamEngine(backend="xla", autostart=False)   # never serves
    try:
        h = eng.submit(g, {"x": x})
        with pytest.raises(TimeoutError):
            h.result(timeout=0.05)
        assert not h.done()
        assert h.cancel() is True
        with pytest.raises(CancelledError):
            h.result(timeout=1)
        assert isinstance(h.exception(), CancelledError)
    finally:
        eng.close()


def test_cancel_of_inflight_request_discards_its_row(rng):
    """Cancelling after the batch was formed (white-box: drive the
    worker steps by hand): the computed row is discarded at
    retirement, the cancel wins, and neighbours are unaffected."""
    g = _diamond()
    frames = [rng.normal(size=(8, 128)).astype(np.float32)
              for _ in range(2)]
    eng = StreamEngine(backend="xla", max_batch=2, autostart=False)
    try:
        handles = [eng.submit(g, {"x": f}) for f in frames]
        batch = eng._form_batch()
        assert len(batch) == 2               # both taken into the batch
        assert handles[1].cancel() is True   # in flight, not yet retired
        eng._dispatch(batch)
        eng._retire(eng._pool.oldest())
        ref_graph = eng.cache.get(g, backend="xla").schedule.graph
        np.testing.assert_array_equal(
            handles[0].result(timeout=1)["y"],
            np.asarray(ref_graph.reference_eval({"x": frames[0]})["y"]))
        with pytest.raises(CancelledError):
            handles[1].result(timeout=1)
        m = eng.report()["measured"]
        assert m["completed"] == 1           # the discarded row never counts
        assert m["cancelled"] == 1
    finally:
        eng.close(wait=False)


# ----------------------------------------------------------------------
# per-app admission control
# ----------------------------------------------------------------------
def test_admission_sheds_per_app_not_globally(rng):
    """One hot app saturating its FIFO cannot reject the other app."""
    hot, cold = _diamond(name="hot"), _pointwise(name="cold")
    x = rng.normal(size=(8, 128)).astype(np.float32)
    eng = StreamEngine(backend="xla", max_queue=2, autostart=False)
    try:
        for _ in range(2):
            eng.submit(hot, {"x": x}, block=False)
        with pytest.raises(QueueFullError):
            eng.submit(hot, {"x": x}, block=False)
        with pytest.raises(QueueFullError):
            eng.submit(hot, {"x": x}, timeout=0.01)
        # the cold app still has its own headroom
        eng.submit(cold, {"x": x}, block=False)
        rep = eng.report()
        assert rep["apps"]["hot"]["shed"] == 2
        assert rep["apps"]["cold"]["shed"] == 0
        assert rep["measured"]["shed"] == 2
    finally:
        eng.close(wait=False)


def test_max_pending_bounds_total_across_apps(rng):
    hot, cold = _diamond(name="hot"), _pointwise(name="cold")
    x = rng.normal(size=(8, 128)).astype(np.float32)
    eng = StreamEngine(backend="xla", max_queue=8, max_pending=2,
                       autostart=False)
    try:
        eng.submit(hot, {"x": x}, block=False)
        eng.submit(cold, {"x": x}, block=False)
        with pytest.raises(QueueFullError):
            eng.submit(cold, {"x": x}, block=False)
    finally:
        eng.close(wait=False)


# ----------------------------------------------------------------------
# weighted fairness (white-box: drive _form_batch directly)
# ----------------------------------------------------------------------
def test_deficit_weighted_round_robin_formation(rng):
    hot, cold = _diamond(name="hot"), _pointwise(name="cold")
    x = rng.normal(size=(8, 128)).astype(np.float32)
    eng = StreamEngine(backend="xla", max_batch=2, max_queue=64,
                       app_weights={"hot": 2.0, "cold": 1.0},
                       autostart=False)
    try:
        for _ in range(12):
            eng.submit(hot, {"x": x})
        for _ in range(6):
            eng.submit(cold, {"x": x})
        formed = []
        for _ in range(9):
            batch = eng._form_batch()       # device idle: closes at once
            assert len(batch) == 2
            formed.append(batch[0].app.graph.name)
        # weight 2 : weight 1 == two hot batches per cold batch, and
        # the cold app is visited every replenish cycle (no starvation)
        assert formed.count("hot") == 6 and formed.count("cold") == 3
        assert "cold" in formed[:3]
        rep = eng.report()
        assert rep["apps"]["hot"]["batches"] == 6
        assert rep["apps"]["cold"]["batches"] == 3
        assert rep["apps"]["hot"]["served"] == 12
    finally:
        eng.close(wait=False)


def test_set_app_weight_applies_to_live_queue(rng):
    g = _diamond(name="hot")
    x = rng.normal(size=(8, 128)).astype(np.float32)
    eng = StreamEngine(backend="xla", autostart=False)
    try:
        eng.submit(g, {"x": x})
        eng.set_app_weight("hot", 3.0)
        assert eng.report()["apps"]["hot"]["weight"] == 3.0
    finally:
        eng.close(wait=False)


# ----------------------------------------------------------------------
# deadline-based formation budget
# ----------------------------------------------------------------------
def test_form_budget_adapts_and_clamps(rng):
    eng = StreamEngine(backend="xla", linger=0.002, autostart=False)
    try:
        assert eng._form_budget() == 0.002          # seeded by linger
        eng._service_ewma = 0.01                    # 10 ms batches
        assert eng._form_budget() == pytest.approx(0.005)
        eng._service_ewma = 10.0
        assert eng._form_budget() == _BUDGET_MAX_S  # clamped above
        eng._service_ewma = 1e-9
        assert eng._form_budget() == _BUDGET_MIN_S  # clamped below
    finally:
        eng.close(wait=False)
    eng = StreamEngine(backend="xla", latency_budget=0.5, autostart=False)
    try:
        eng._service_ewma = 1e-9
        assert eng._form_budget() == 0.5            # explicit budget wins
    finally:
        eng.close(wait=False)


def test_formation_is_work_conserving_when_idle(rng):
    """With the device idle, one queued request dispatches immediately
    instead of lingering for batch-mates."""
    g = _diamond()
    x = rng.normal(size=(8, 128)).astype(np.float32)
    eng = StreamEngine(backend="xla", max_batch=8, latency_budget=10.0,
                       autostart=False)
    try:
        eng.submit(g, {"x": x})
        t0 = time.perf_counter()
        batch = eng._form_batch()
        assert len(batch) == 1                       # closed, not held
        assert time.perf_counter() - t0 < 1.0        # and without waiting
    finally:
        eng.close(wait=False)


# ----------------------------------------------------------------------
# shutdown and mixed-signature streams
# ----------------------------------------------------------------------
def test_close_drains_inflight_without_drops(rng):
    """close() right after a burst: every request completes exactly
    once, bit-exact — nothing is dropped or double-finished."""
    n = 24
    g = _diamond()
    frames = [rng.normal(size=(8, 128)).astype(np.float32)
              for _ in range(n)]
    eng = StreamEngine(backend="xla", max_batch=4, max_queue=64)
    handles = [eng.submit(g, {"x": f}) for f in frames]
    eng.close(wait=True)                   # drains queued + in-flight
    results = [h.result(timeout=1) for h in handles]   # all already done
    ref_graph = eng.cache.get(g, backend="xla").schedule.graph
    for f, r in zip(frames, results):
        np.testing.assert_array_equal(
            r["y"], np.asarray(ref_graph.reference_eval({"x": f})["y"]))
    m = eng.report()["measured"]
    assert m["completed"] == n and m["submitted"] == n
    with pytest.raises(RuntimeError):
        eng.submit(g, {"x": frames[0]})


def test_mixed_signature_interleaved_bit_exact(rng):
    """Two topologies interleaved 1:1: batches stay same-signature
    (results are bit-exact per app) and both apps are served."""
    n = 16
    ga, gb = _diamond(name="a"), _pointwise(name="b")
    fa = [rng.normal(size=(8, 128)).astype(np.float32) for _ in range(n)]
    fb = [rng.normal(size=(8, 128)).astype(np.float32) for _ in range(n)]
    with StreamEngine(backend="xla", max_batch=4, max_queue=64) as eng:
        handles = []
        for xa, xb in zip(fa, fb):
            handles.append(("a", xa, eng.submit(ga, {"x": xa})))
            handles.append(("b", xb, eng.submit(gb, {"x": xb})))
        results = [(k, x, h.result(timeout=120)) for k, x, h in handles]
        rep = eng.report()
    refs = {"a": eng.cache.get(ga, backend="xla").schedule.graph,
            "b": eng.cache.get(gb, backend="xla").schedule.graph}
    for k, x, r in results:
        np.testing.assert_array_equal(
            r["y"], np.asarray(refs[k].reference_eval({"x": x})["y"]))
    assert rep["apps"]["a"]["served"] == n
    assert rep["apps"]["b"]["served"] == n
    assert rep["cache"]["misses"] == 2     # one compile per topology


# ----------------------------------------------------------------------
# cache hit accounting is per compile event
# ----------------------------------------------------------------------
def test_cache_hit_rate_counts_compile_events_not_requests():
    """N fresh structurally identical graphs: 1 miss + N-1 hits; then
    re-serving the SAME objects moves `requests` only, so a serving
    stream cannot inflate hit_rate."""
    cache = CompileCache()
    graphs = [_diamond(name=f"g{i}") for i in range(5)]
    apps = [cache.get(g, backend="xla") for g in graphs]
    assert all(a is apps[0] for a in apps)
    assert cache.stats.misses == 1 and cache.stats.hits == 4
    assert cache.stats.hit_rate == pytest.approx(4 / 5)
    for _ in range(3):                      # a 15-request serving stream
        for g in graphs:
            cache.get(g, backend="xla")
    assert cache.stats.requests == 20
    assert cache.stats.misses == 1 and cache.stats.hits == 4
    assert cache.stats.hit_rate == pytest.approx(4 / 5)   # unchanged
    d = cache.stats.as_dict()
    assert d["requests"] == 20 and d["hit_rate"] == pytest.approx(0.8)


# ----------------------------------------------------------------------
# telemetry: per-phase breakdown + bulk ingest + reset
# ----------------------------------------------------------------------
def test_report_breaks_down_hot_path_phases(rng):
    n = 16
    g = _diamond()
    frames = [rng.normal(size=(8, 128)).astype(np.float32)
              for _ in range(n)]
    with StreamEngine(backend="xla", max_batch=4, max_queue=64) as eng:
        handles = [eng.submit(g, {"x": f}) for f in frames]
        for h in handles:
            h.result(timeout=120)
        rep = eng.report()
    phases = rep["measured"]["phases"]
    assert set(PHASES) <= set(phases)
    assert phases["queue_wait"]["count"] == n    # one sample per request
    batches = phases["launch"]["count"]
    assert batches >= 1
    assert phases["readback"]["count"] == batches
    for p in PHASES:
        assert phases[p]["mean_ms"] >= 0.0
        assert phases[p]["p99_ms"] >= 0.0
    # every launch was bucket-padded within max_batch
    assert rep["buckets"] and all(1 <= w <= 4 for w in rep["buckets"])
    assert sum(rep["buckets"].values()) == batches


def test_telemetry_bulk_ingest_and_reset():
    t = Telemetry()
    t.replicas = 2
    now = time.perf_counter()
    t.observe_batches([
        (now, 4, {"launch": 1e-3, "queue_wait": [1e-4] * 4},
         [2e-3] * 4, 5e-3),
        (now + 0.1, 2, {"launch": 2e-3}, [3e-3] * 2, 4e-3),
    ])
    t.observe_submits(6, [0, 1, 2, 3, 4, 5])
    snap = t.snapshot()
    assert snap["completed"] == 6 and snap["submitted"] == 6
    assert snap["batch_size_mean"] == pytest.approx(3.0)
    assert snap["phases"]["launch"]["count"] == 2
    assert snap["phases"]["queue_wait"]["count"] == 4
    assert snap["service_ewma_ms"] > 0
    assert snap["throughput_rps"] > 0      # span from original stamps
    t.reset()
    snap = t.snapshot()
    assert snap["completed"] == 0 and snap["submitted"] == 0
    assert snap["phases"] == {} and snap["throughput_rps"] == 0.0
    assert snap["replicas"] == 2           # reset keeps the farm width
