"""Backend abstraction layer: one registry drives lowering, tuning,
serving, and replication.

Acceptance tests for ``src/repro/backends/``:

- registry anatomy: the seed trio plus the gated ``pallas_gpu`` stub
  register, resolve (by name or spec), and report stable digests;
- capability matrix: every registered backend x every Table-I app
  either compiles and matches the ``xla`` oracle bit-exactly, or
  raises a single typed :class:`UnsupportedBackendError` naming the
  missing capability — never a crash;
- policy resolution: interpret-vs-compiled, donation and staging
  decisions come from the resolved record and reproduce the
  pre-registry behaviour on CPU;
- the serving/tuning caches key on the backend digest, so constants
  changes invalidate instead of aliasing;
- replication's kwarg filter is DERIVED from ``compile_graph``'s live
  signature — the regression test here fails when a new compile kwarg
  appears without being routed or declared unrouted;
- lint-as-test: zero backend string-literal comparisons anywhere in
  ``src/`` outside ``src/repro/backends/``.
"""
import dataclasses
import pathlib
import re

import numpy as np
import pytest

from repro.backends import (Backend, PALLAS, PALLAS_GPU, SEED_BACKENDS,
                            STAGE_KINDS, UnsupportedBackendError, XLA,
                            backends, current_platform, get, names,
                            register, resolve, unregister,
                            use_pallas_kernels)
from repro.core.apps import APPS, build_app
from repro.core.compiler import compile_graph
from repro.core.graph import GraphError

H, W = 48, 256

SRC = pathlib.Path(__file__).resolve().parent.parent / "src"


# ----------------------------------------------------------------------
# registry anatomy
# ----------------------------------------------------------------------
def test_seed_backends_registered():
    assert set(SEED_BACKENDS) <= set(names())
    assert "pallas_gpu" in names()
    assert all(isinstance(b, Backend) for b in backends())


def test_resolve_name_and_spec_passthrough():
    assert resolve("pallas") is PALLAS
    assert resolve(PALLAS) is PALLAS
    adhoc = dataclasses.replace(XLA, name="adhoc")   # never registered
    assert resolve(adhoc) is adhoc                   # specs pass through
    assert get("does-not-exist") is None


def test_resolve_unknown_name_is_typed():
    with pytest.raises(UnsupportedBackendError) as ei:
        resolve("hexagon")
    assert ei.value.backend == "hexagon"
    assert "registered" in ei.value.missing
    assert isinstance(ei.value, GraphError)          # one error taxonomy
    with pytest.raises(UnsupportedBackendError):
        resolve(42)


def test_register_duplicate_name_rejected():
    clone = dataclasses.replace(XLA)
    with pytest.raises(ValueError, match="already registered"):
        register(clone)
    try:
        register(dataclasses.replace(XLA, name="scratch_backend"))
        assert resolve("scratch_backend").name == "scratch_backend"
    finally:
        unregister("scratch_backend")
    assert "scratch_backend" not in names()


def test_digest_is_stable_and_constants_sensitive():
    assert XLA.digest() == XLA.digest()
    assert XLA.cache_key() == f"xla@{XLA.digest()}"
    wider = dataclasses.replace(XLA, lane=256)
    assert wider.digest() != XLA.digest()
    fatter = dataclasses.replace(
        XLA, spec=dataclasses.replace(XLA.spec, vmem_bytes=1 << 20))
    assert fatter.digest() != XLA.digest()
    # capabilities are part of the identity too
    gated = dataclasses.replace(
        XLA, capabilities=frozenset({"point"}))
    assert gated.digest() != XLA.digest()


def test_capability_api():
    assert XLA.supports("stencil") and XLA.supports("tuning")
    assert not PALLAS_GPU.supports("stencil")
    assert PALLAS_GPU.missing("stencil", "point") == ("stencil",)
    XLA.require("point", "stencil")                  # no raise
    with pytest.raises(UnsupportedBackendError) as ei:
        PALLAS_GPU.require("stencil")
    assert ei.value.backend == "pallas_gpu"
    assert "stencil" in ei.value.missing


def test_backend_validates_capability_vocabulary():
    with pytest.raises(ValueError, match="unknown capabilit"):
        Backend(name="bogus", capabilities=frozenset({"telepathy"}))


# ----------------------------------------------------------------------
# policy resolution: interpret / donation / staging
# ----------------------------------------------------------------------
def test_interpret_resolution_matches_seed_defaults_on_cpu():
    plat = current_platform()
    for name in SEED_BACKENDS:
        be = resolve(name)
        # explicit values always win
        assert be.resolve_interpret(True) is True
        assert be.resolve_interpret(False) is False
        # None defers to nativeness; on CPU every seed interprets,
        # which is exactly the old compile_graph(interpret=True) default
        assert be.resolve_interpret(None) == (plat not in
                                              be.native_platforms)
    if plat != "tpu":
        assert PALLAS.resolve_interpret(None) is True


def test_donation_policy_matches_old_microbatcher_probe():
    for name in SEED_BACKENDS:
        be = resolve(name)
        assert be.resolve_donate(True, "cpu") is False
        assert be.resolve_donate(True, "tpu") is True
        assert be.resolve_donate(False, "tpu") is False
    never = dataclasses.replace(XLA, name="never", donation="never")
    assert never.resolve_donate(True, "tpu") is False


def test_staging_depth_keeps_historical_slack():
    for name in SEED_BACKENDS:
        assert resolve(name).staging_depth(2) == 3   # old inflight + 1


# ----------------------------------------------------------------------
# capability matrix: every backend x every Table-I app
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def oracle_outputs():
    """xla-compiled outputs per app, the bit-exactness oracle."""
    rng = np.random.default_rng(7)
    out = {}
    for name in sorted(APPS):
        g = build_app(name, H, W)
        inputs = {c.name: rng.normal(size=c.shape).astype(np.float32)
                  for c in g.graph_inputs}
        outs = compile_graph(build_app(name, H, W), backend="xla")(**inputs)
        out[name] = (inputs, {k: np.asarray(v) for k, v in outs.items()})
    return out


@pytest.mark.parametrize("backend", sorted(
    set().union(*[{n} for n in ("xla", "xla_staged", "pallas",
                                "pallas_gpu")])))
@pytest.mark.parametrize("app", sorted(APPS))
def test_capability_matrix(app, backend, oracle_outputs):
    inputs, expected = oracle_outputs[app]
    try:
        compiled = compile_graph(build_app(app, H, W), backend=backend)
    except UnsupportedBackendError as e:
        # the ONE typed rejection: it must name the backend and what is
        # missing (a capability, the platform gate, or the lower stub)
        assert e.backend == backend
        assert e.missing, f"{backend} rejection names nothing missing"
        return
    got = compiled(**inputs)
    assert sorted(got) == sorted(expected)
    for k in expected:                               # atol=0: bit-exact
        np.testing.assert_array_equal(np.asarray(got[k]), expected[k],
                                      err_msg=f"{app}/{backend}/{k}")


def test_seed_backends_share_graph_signature():
    sigs = set()
    for b in SEED_BACKENDS:
        app = compile_graph(build_app("sobel", H, W), backend=b)
        sigs.add(app.graph.signature())
        assert app.signature().endswith(resolve(b).cache_key())
    assert len(sigs) == 1, "lowering must not perturb the canonical graph"


def test_pallas_gpu_stub_is_gated_not_crashing():
    be = resolve("pallas_gpu")
    assert be.capabilities >= {"point", "pointN", "split"}
    assert be.requires_platform == "gpu"
    if current_platform() not in ("gpu", "cuda", "rocm"):
        assert not be.available()
    with pytest.raises(UnsupportedBackendError):
        compile_graph(build_app("sobel", H, W), backend="pallas_gpu")


# ----------------------------------------------------------------------
# cache keying on the backend digest
# ----------------------------------------------------------------------
def test_compile_cache_splits_on_backend_digest():
    from repro.runtime.cache import CompileCache
    cache = CompileCache()
    g = build_app("square", H, W)
    a1 = cache.get(g, backend="xla")
    # same name, different constants => different digest => a recompile
    variant = dataclasses.replace(XLA, default_max_tile=(128, 512))
    a2 = cache.get(g, backend=variant)
    assert a1 is not a2
    assert cache.stats.misses == 2
    assert cache.get(g, backend="xla") is a1         # still hot


def test_tuning_key_carries_backend_digest():
    from repro.tune.store import TuningKey
    g = build_app("square", H, W)
    key = TuningKey.for_graph(g, "xla", "cpu")
    assert key.backend == XLA.cache_key()
    variant = dataclasses.replace(XLA, lane=256)
    key2 = TuningKey.for_graph(g, variant, "cpu")
    assert key2.backend != key.backend
    assert key2.digest() != key.digest()


def test_dataflow_fn_memoizes_backend_structurally():
    from repro.frontend import dataflow_fn

    @dataflow_fn
    def double(img):
        return img * 2.0

    x = np.ones((8, 128), np.float32)
    a1 = double.compile(x, backend=resolve("xla"))
    a2 = double.compile(x, backend=dataclasses.replace(XLA))  # equal copy
    assert a1 is a2                    # keyed by cache_key, not id()


# ----------------------------------------------------------------------
# kernels' impl= knob rides the same registry probe
# ----------------------------------------------------------------------
def test_use_pallas_kernels_resolution():
    assert use_pallas_kernels("pallas") is True
    assert use_pallas_kernels("ref") is False
    assert use_pallas_kernels("auto") == resolve("pallas").is_native()
    assert use_pallas_kernels("auto", auto_native=False) is False
    assert use_pallas_kernels("pallas", auto_native=False) is True


# ----------------------------------------------------------------------
# replication kwarg routing is derived, and covers compile_graph
# ----------------------------------------------------------------------
def test_replication_routing_covers_every_compile_kwarg():
    """Fails when compile_graph grows a kwarg replication ignores.

    Every keyword of ``compile_graph`` (beyond graph/backend) must be
    either routed into the scheduler/lowering/tuner by
    ``replication_kwarg_routing`` or explicitly declared in
    ``UNROUTED_COMPILE_KWARGS``.  Add a new compile knob and this test
    names it until replication takes a position on it.
    """
    import inspect
    from repro.parallel.replicate import (UNROUTED_COMPILE_KWARGS,
                                          replication_kwarg_routing)
    all_kwargs = set(
        inspect.signature(compile_graph).parameters) - {"graph", "backend"}
    known, sched, lower = replication_kwarg_routing()
    unclassified = all_kwargs - known - UNROUTED_COMPILE_KWARGS
    assert not unclassified, (
        f"compile_graph kwargs {sorted(unclassified)} are neither routed "
        f"by replicate_app nor declared in UNROUTED_COMPILE_KWARGS — "
        f"decide how replication treats them")
    # the historical hand-maintained set stays supported
    assert known >= {"canonicalize", "strict", "passes", "spec",
                     "vector_factor", "interpret", "tune", "tune_cache",
                     "max_tile"}
    assert sched and lower


def test_replicate_app_rejects_unknown_kwargs():
    from repro.parallel.replicate import replicate_app
    with pytest.raises(TypeError, match="unsupported compile kwargs"):
        replicate_app(build_app("square", H, W), 1, bogus_option=1)


def test_replicate_requires_replication_capability():
    from repro.parallel.replicate import replicate_app
    gated = dataclasses.replace(
        XLA, name="no_repl",
        capabilities=frozenset(STAGE_KINDS) | {"tuning"})
    with pytest.raises(UnsupportedBackendError) as ei:
        replicate_app(build_app("square", H, W), 1, backend=gated)
    assert "replication" in ei.value.missing


# ----------------------------------------------------------------------
# lint-as-test: no backend string-literal dispatch outside backends/
# ----------------------------------------------------------------------
_BACKEND_LIT = r'["\'](?:xla|xla_staged|pallas|pallas_gpu)["\']'
_LITERAL_DISPATCH = re.compile(
    rf'(?:==|!=)\s*{_BACKEND_LIT}'
    rf'|{_BACKEND_LIT}\s*(?:==|!=)'
    rf'|\b(?:in|not\s+in)\s+[\(\[{{]\s*{_BACKEND_LIT}')


def test_no_backend_literal_comparisons_outside_registry():
    """grep src/ for `== "pallas"`-style dispatch; zero allowed.

    Backend behaviour differences must live on the Backend record
    (capabilities, constants, hooks) — an if/elif on the name anywhere
    else reintroduces exactly the drift the registry removed.
    """
    offenders = []
    for path in sorted(SRC.rglob("*.py")):
        if "backends" in path.relative_to(SRC).parts:
            continue
        for i, line in enumerate(path.read_text().splitlines(), 1):
            if _LITERAL_DISPATCH.search(line):
                offenders.append(f"{path.relative_to(SRC)}:{i}: "
                                 f"{line.strip()}")
    assert not offenders, (
        "backend string-literal comparisons outside src/repro/backends/ "
        "(dispatch through the registry instead):\n" + "\n".join(offenders))
