"""Per-architecture smoke tests (reduced configs) + consistency.

Every assigned arch: one forward/train step on CPU asserting output
shapes + finite values, and teacher-forced decode == full forward.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke
from repro.models import model as M
from repro.models.config import SHAPES

RNG = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=24):
    toks = jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            RNG, (B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["extra_embeds"] = jax.random.normal(
            RNG, (B, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    params = M.init(cfg, RNG)
    batch = _batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        M.loss_fn, has_aux=True)(params, cfg, batch)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2))
                for g in jax.tree.leaves(grads)) ** 0.5
    assert np.isfinite(gnorm) and gnorm > 0
    # logits shape check via forward
    logits, _ = M.forward(params, cfg, batch["tokens"],
                          extra_embeds=batch.get("extra_embeds"),
                          enc_embeds=batch.get("enc_embeds"))
    S_total = batch["tokens"].shape[1] + (
        cfg.n_frontend_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, S_total, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_serve_path(arch):
    cfg = dataclasses.replace(get_smoke(arch), capacity_factor=8.0)
    params = M.init(cfg, RNG)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    toks = batch["tokens"]
    logits_full, _ = M.forward(params, cfg, toks,
                               extra_embeds=batch.get("extra_embeds"),
                               enc_embeds=batch.get("enc_embeds"))
    off = cfg.n_frontend_tokens if cfg.family == "vlm" else 0
    cache = M.init_cache(cfg, B, 48, dtype=jnp.float32)
    lg, cache = M.prefill(params, cfg, toks[:, :8], cache,
                          enc_embeds=batch.get("enc_embeds"),
                          extra_embeds=batch.get("extra_embeds"))
    errs = []
    if cfg.family != "vlm":
        errs.append(float(jnp.abs(lg - logits_full[:, off + 7]).max()))
    for t in range(8, S):
        lg, cache = M.decode_step(params, cfg, toks[:, t], cache)
        if cfg.family != "vlm":
            errs.append(float(jnp.abs(lg - logits_full[:, off + t]).max()))
    if cfg.family == "vlm":
        # vlm prefill includes the vision prefix; check finiteness only
        assert bool(jnp.isfinite(lg).all())
    else:
        assert max(errs) < 1e-3, f"decode drift {max(errs)}"


@pytest.mark.parametrize("arch", ["granite_3_2b", "qwen3_moe_235b_a22b",
                                  "mamba2_2p7b", "zamba2_1p2b",
                                  "whisper_base"])
def test_scan_vs_unrolled(arch):
    """The calibration (unrolled) path computes the same function."""
    cfg = dataclasses.replace(get_smoke(arch), capacity_factor=8.0)
    params = M.init(cfg, RNG)
    batch = _batch(cfg)
    l1, _ = M.loss_fn(params, cfg, batch)
    l2, _ = M.loss_fn(params, dataclasses.replace(cfg, scan_layers=False),
                      batch)
    assert abs(float(l1) - float(l2)) < 1e-5


def test_full_configs_match_assignment():
    """The exact assigned architecture hyperparameters."""
    c = get_config("qwen3-moe-235b-a22b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.n_experts, c.experts_per_token) == \
        (94, 4096, 64, 4, 1536, 151936, 128, 8)
    c = get_config("qwen1.5-32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size, c.qkv_bias) == \
        (64, 5120, 40, 40, 27392, 152064, True)
    c = get_config("granite-20b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (52, 6144, 48, 1, 24576, 49152)
    c = get_config("mamba2-2.7b")
    assert (c.n_layers, c.d_model, c.ssm_state, c.vocab_size) == \
        (64, 2560, 128, 50280)
    c = get_config("zamba2-1.2b")
    assert (c.n_layers, c.d_model, c.ssm_state, c.vocab_size) == \
        (38, 2048, 64, 32000)
    c = get_config("whisper-base")
    assert (c.n_layers, c.n_enc_layers, c.d_model, c.n_heads, c.d_ff,
            c.vocab_size) == (6, 6, 512, 8, 2048, 51865)
    c = get_config("internvl2-26b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (48, 6144, 48, 8, 16384, 92553)
    c = get_config("granite-moe-3b-a800m")
    assert (c.n_layers, c.d_model, c.n_experts, c.experts_per_token) == \
        (32, 1536, 40, 8)
    c = get_config("granite-3-2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff) == \
        (40, 2048, 32, 8, 8192)
    c = get_config("minicpm3-4b")
    assert (c.n_layers, c.d_model, c.use_mla, c.kv_lora_rank) == \
        (62, 2560, True, 256)


def test_param_counts_in_range():
    """Analytic parameter counts should be near the advertised sizes."""
    expect = {"qwen3-moe-235b-a22b": (200e9, 245e9),
              "qwen1.5-32b": (30e9, 38e9),
              "mamba2-2.7b": (2.4e9, 3.0e9),
              "zamba2-1.2b": (0.9e9, 1.4e9),
              "minicpm3-4b": (3.5e9, 4.8e9),
              "whisper-base": (0.06e9, 0.12e9)}
    for name, (lo, hi) in expect.items():
        n = get_config(name).n_params()
        assert lo <= n <= hi, (name, n)


def test_mla_cache_is_compressed():
    cfg = get_config("minicpm3-4b")
    cache = jax.eval_shape(lambda: M.init_cache(cfg, 1, 1024))
    per_tok = (cache["attn"]["c_kv"].shape[-1]
               + cache["attn"]["k_rope"].shape[-1])
    full = 2 * cfg.n_heads * cfg.hd      # standard MHA cache
    assert per_tok * 17 < full            # ~17.8x smaller


def test_moe_capacity_drops_are_bounded():
    """With cf=1.25 some tokens drop, but the layer stays finite and
    the residual carries them."""
    cfg = get_smoke("qwen3_moe_235b_a22b")   # cf = 1.25 default
    params = M.init(cfg, RNG)
    batch = _batch(cfg, B=4, S=32)
    loss, _ = M.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))
