"""FIFO-pipeline latency model: reproduces the paper's Fig. 1 law."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import TaskTiming, analytic_latency, simulate_pipeline


def test_fig1_five_tasks():
    """5 matched tasks: dataflow ~= 5x faster (paper Fig. 1)."""
    tasks = [TaskTiming(f"t{i}", ii=1.0, fill=10.0) for i in range(5)]
    r = analytic_latency(tasks, 1 << 20)
    assert 4.9 < r["speedup"] <= 5.0


def test_bottleneck_task_dominates():
    tasks = [TaskTiming("fast", ii=1.0), TaskTiming("slow", ii=4.0),
             TaskTiming("fast2", ii=1.0)]
    r = analytic_latency(tasks, 10_000)
    # pipeline drains at the slow task's rate
    assert abs(r["dataflow"] - (4.0 * 10_000 + 24.0)) < 1.0


def test_simulation_matches_analytic_steady_state():
    tasks = [TaskTiming(f"t{i}", ii=float(ii), fill=8.0)
             for i, ii in enumerate([1, 2, 1, 3])]
    n = 4096
    sim = simulate_pipeline(tasks, n, depth=2)
    ana = analytic_latency(tasks, n)
    assert abs(sim["dataflow_sim"] - ana["dataflow"]) / ana["dataflow"] < 0.05
    assert abs(sim["steady_rate"] - 3.0) < 0.05


@given(st.lists(st.floats(0.5, 4.0), min_size=2, max_size=6),
       st.integers(256, 2048))
@settings(max_examples=20, deadline=None)
def test_dataflow_never_slower_and_bounded(iis, n):
    tasks = [TaskTiming(f"t{i}", ii=v, fill=4.0) for i, v in enumerate(iis)]
    sim = simulate_pipeline(tasks, n, depth=2)
    ana = analytic_latency(tasks, n)
    # pipelined <= sequential; >= the slowest-stage bound
    assert sim["dataflow_sim"] <= ana["sequential"] * 1.01
    assert sim["dataflow_sim"] >= max(iis) * n - 1e-6


def test_depth_one_still_progresses():
    tasks = [TaskTiming("a", ii=1.0), TaskTiming("b", ii=1.0)]
    r = simulate_pipeline(tasks, 1024, depth=1)
    assert r["dataflow_sim"] < r["sequential"]


def test_jitter_absorbed_by_fifo():
    """Stalls in one task are absorbed while FIFOs have data (paper
    Section II-A) — jittered pipeline stays near the jitter-free rate
    plus the injected jitter itself, far below the sequential bound."""
    tasks = [TaskTiming(f"t{i}", ii=1.0) for i in range(4)]
    jit = simulate_pipeline(tasks, 4096, depth=2, jitter=0.05, seed=1)
    assert jit["dataflow_sim"] < 0.5 * jit["sequential"]
