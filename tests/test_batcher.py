"""Continuous-batching correctness: ragged slots == isolated decoding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.models import model as M
from repro.runtime.batcher import ContinuousBatcher, Request


def _greedy_isolated(cfg, params, prompt, n_new, max_len=64):
    cache = M.init_cache(cfg, 1, max_len, dtype=jnp.float32)
    lg, cache = M.prefill(params, cfg, jnp.asarray(prompt)[None], cache)
    toks = []
    t = jnp.argmax(lg, -1).astype(jnp.int32)
    for _ in range(n_new):
        toks.append(int(t[0]))
        lg, cache = M.decode_step(params, cfg, t, cache)
        t = jnp.argmax(lg, -1).astype(jnp.int32)
    return toks


def test_continuous_batching_matches_isolated():
    cfg = dataclasses.replace(get_smoke("granite_3_2b"),
                              capacity_factor=8.0)
    params = M.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=(n,)).astype(np.int32)
               for n in (5, 9, 7, 4)]
    n_new = 6

    batcher = ContinuousBatcher(cfg, params, n_slots=2, max_len=64)
    for i, p in enumerate(prompts):
        batcher.submit(Request(rid=i, prompt=p, max_new_tokens=n_new))
    finished = batcher.run_to_completion()
    assert len(finished) == len(prompts)

    for req in finished:
        ref = _greedy_isolated(cfg, params, req.prompt, n_new)
        assert req.tokens == ref, (req.rid, req.tokens, ref)


def test_batcher_overlaps_requests():
    """More requests than slots: later requests are admitted as soon
    as earlier ones retire (continuous, not lock-step)."""
    cfg = dataclasses.replace(get_smoke("mamba2_2p7b"),
                              capacity_factor=8.0)
    params = M.init(cfg, jax.random.PRNGKey(1))
    batcher = ContinuousBatcher(cfg, params, n_slots=2, max_len=48)
    rng = np.random.default_rng(1)
    for i in range(5):
        batcher.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, size=(4 + i,)
                                ).astype(np.int32),
            max_new_tokens=3 + i))
    finished = batcher.run_to_completion()
    assert sorted(r.rid for r in finished) == [0, 1, 2, 3, 4]
    for r in finished:
        assert len(r.tokens) == r.max_new_tokens
        assert all(0 <= t < cfg.vocab_size for t in r.tokens)
