"""Keep the public-API docstring examples runnable.

CI additionally runs ``pytest --doctest-modules`` on these files; this
module folds the same examples into the tier-1 suite so a drifting
docstring fails `python -m pytest -x -q` too, not just the extra step.
"""
from __future__ import annotations

import doctest

import pytest

import repro.core.compiler
import repro.core.schedule
import repro.frontend.ops
import repro.frontend.tracer
import repro.obs.drift
import repro.obs.metrics
import repro.tune.search
import repro.tune.store

_MODULES = [repro.core.compiler, repro.core.schedule,
            repro.frontend.ops, repro.frontend.tracer,
            repro.obs.drift, repro.obs.metrics,
            repro.tune.search, repro.tune.store]


@pytest.mark.parametrize("module", _MODULES,
                         ids=[m.__name__ for m in _MODULES])
def test_docstring_examples(module):
    result = doctest.testmod(module, verbose=False)
    assert result.attempted > 0, f"{module.__name__} lost its examples"
    assert result.failed == 0
