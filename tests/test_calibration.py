"""Calibrated cost model: the fit, its invariants, and the wiring.

The property harness at the top is the PR's proof obligation: on
synthetic drift rows generated from a *known* spec, :func:`calibrate`
must recover that spec's constants (exactly when noiseless, within
tolerance under noise), must be invariant to row order and
duplication, and must fall back to the seed spec — warning, never
NaN — whenever the data cannot identify the constants (too few rows,
rank-deficient design, jit-polluted measurements).

The harness is seed-driven (``numpy.random.default_rng`` over many
seeds) so it runs everywhere; when ``hypothesis`` is installed an
extra ``@given`` layer drives the same checks over generated cases.

Then the integration story, end to end:

- the checked-in golden fixture (``tests/fixtures/
  drift_bench_parallel.jsonl``, real bench_parallel measurements on a
  CPU host) where the seed spec *misorders* workloads (Spearman <= 0)
  and the fitted spec orders them (> 0.8) with near-1 bias — ROADMAP
  item 3's exit criterion pinned as a regression test;
- ``tune_graph(calibrate=...)`` reaching the same winner in strictly
  fewer measurements (the calibrated prior prunes candidates; an
  uncalibrated spec never does);
- feature round-trips: ``predict_features`` is bit-identical to the
  compiler's ``modeled_schedule_time``, and the serving engine's
  drift rows re-predict exactly;
- persistence: :class:`CalibrationStore` atomic round-trip,
  ``calibrate="auto"`` resolution, and backend digest stability
  (uncalibrated compiles keep their exact cache identity).
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import warnings

import numpy as np
import pytest

from repro.core.vectorize import TPUSpec, V5E
from repro.obs.drift import DriftLog, DriftRow, drift_report, predict_features
from repro.tune.calibrate import (CALIBRATION_VERSION, CalibratedSpec,
                                  CalibrationStore, MIN_ROWS, calibrate,
                                  calibrate_backend, load_calibration,
                                  resolve_calibration, spec_from_json,
                                  spec_to_json)

_FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                        "drift_bench_parallel.jsonl")


# ----------------------------------------------------------------------
# synthetic-recovery property harness
# ----------------------------------------------------------------------
def _true_spec() -> CalibratedSpec:
    """Ground truth deliberately far from every V5E seed constant."""
    return CalibratedSpec(clock_hz=5e8, hbm_bw=2e11, step_overhead_s=3e-5,
                          ii_scale=(("point", 1.0), ("stencil", 2.5)))


def _synth_rows(rng: np.random.Generator, true_spec: TPUSpec,
                n: int = 24, noise: float = 0.0,
                kind: str = "trial") -> list[DriftRow]:
    """Drift rows whose measured time IS the true spec's prediction.

    Cycles through the four regimes that make every constant
    identifiable: overhead-dominated (many tiny grid steps), DMA-bound
    (pins ``hbm_bw``), and compute-bound per stage kind (pins each
    ``alpha_kind``).  A generator that only produced one regime would
    be rank-deficient by construction — which is its own test below.
    """
    rows = []
    regimes = ("overhead", "dma", "compute_point", "compute_stencil")
    for i in range(n):
        regime = regimes[i % len(regimes)]
        grid = int(rng.integers(1, 6))
        if regime == "overhead":
            g = {"grid": int(rng.integers(64, 256)),
                 "bytes_step": float(rng.integers(100, 1000)),
                 "steps": {"point": float(rng.integers(50, 500))}}
        elif regime == "dma":
            g = {"grid": grid,
                 "bytes_step": float(rng.integers(10, 80)) * 2.0 ** 20,
                 "steps": {"point": float(rng.integers(100, 1000))}}
        elif regime == "compute_point":
            g = {"grid": grid,
                 "bytes_step": float(rng.integers(100, 1000)),
                 "steps": {"point": float(rng.integers(4, 40)) * 1e6}}
        else:
            g = {"grid": grid,
                 "bytes_step": float(rng.integers(100, 1000)),
                 "steps": {"stencil": float(rng.integers(4, 40)) * 1e6}}
        feats = {"groups": [g]}
        measured = predict_features(feats, true_spec)
        if noise:
            measured *= float(np.exp(rng.normal(0.0, noise)))
        rows.append(DriftRow(kind, f"sig{i % 5}", [[64, 128]], "pallas",
                             1e-5, measured, {"features": feats}))
    return rows


def _assert_recovered(result, true_spec: TPUSpec, rel: float) -> None:
    """Constants match ground truth in gauge-invariant form.

    ``clock_hz`` and ``ii_scale`` are only identified jointly (the fit
    pins the reference kind's multiplier to 1.0), so compare the
    per-kind ``alpha = ii_scale / clock`` — the quantity the model
    actually multiplies by — plus overhead and 1/bandwidth directly.
    """
    assert result.fitted, result.warning
    s = result.spec
    assert s.step_overhead_s == pytest.approx(true_spec.step_overhead_s,
                                              rel=rel)
    assert s.hbm_bw == pytest.approx(true_spec.hbm_bw, rel=rel)
    true_scale = dict(true_spec.ii_scale)
    for kind, mult in s.ii_scale:
        alpha = mult / s.clock_hz
        true_alpha = true_scale[kind] / true_spec.clock_hz
        assert alpha == pytest.approx(true_alpha, rel=rel), kind


@pytest.mark.parametrize("seed", range(6))
def test_noiseless_recovery_is_exact(seed):
    true = _true_spec()
    rows = _synth_rows(np.random.default_rng(seed), true)
    result = calibrate(rows)
    _assert_recovered(result, true, rel=1e-6)
    # and the fitted spec re-predicts every measurement essentially
    # exactly — the model family contains the generator
    for r in rows:
        pred = predict_features(r.features, result.spec)
        assert pred == pytest.approx(r.measured_s, rel=1e-6)


@pytest.mark.parametrize("seed", range(4))
def test_noisy_recovery_within_tolerance(seed):
    true = _true_spec()
    rows = _synth_rows(np.random.default_rng(100 + seed), true,
                       n=48, noise=0.02)
    result = calibrate(rows)
    _assert_recovered(result, true, rel=0.35)


def test_row_order_and_duplication_invariance():
    true = _true_spec()
    rows = _synth_rows(np.random.default_rng(7), true)
    base = calibrate(rows).spec
    shuffled = list(reversed(rows)) + rows[::3] + rows   # perm + dupes
    again = calibrate(shuffled)
    # bit-identical, not approximately equal: canonicalization sorts
    # and dedupes before the solver ever sees the rows
    assert again.spec == base
    assert again.n_duplicates == len(shuffled) - len(rows)


def test_too_few_rows_falls_back_with_warning():
    true = _true_spec()
    rows = _synth_rows(np.random.default_rng(3), true, n=MIN_ROWS - 1)
    with pytest.warns(RuntimeWarning, match="fell back"):
        result = calibrate(rows)
    assert not result.fitted
    assert result.spec is V5E                 # the seed, untouched
    assert "min_rows" in result.warning
    for f in dataclasses.fields(TPUSpec):
        assert math.isfinite(float(getattr(result.spec, f.name)))


def test_rank_deficient_design_falls_back():
    # every row has the same per-step compute mass, so the overhead and
    # compute columns are exactly proportional: no amount of rows can
    # split them, and the fit must say so rather than invent constants
    true = _true_spec()
    rows = []
    for grid in range(2, 14):
        feats = {"groups": [{"grid": grid, "bytes_step": 64.0,
                             "steps": {"point": 1000.0}}]}
        rows.append(DriftRow("trial", "sig", [[8, 128]], "pallas", 1e-5,
                             predict_features(feats, true),
                             {"features": feats}))
    with pytest.warns(RuntimeWarning, match="rank-deficient"):
        result = calibrate(rows)
    assert not result.fitted and result.spec is V5E


def test_unusable_rows_skipped_never_nan():
    true = _true_spec()
    rows = _synth_rows(np.random.default_rng(11), true)
    junk = [
        DriftRow("trial", "s", None, "pallas", 1e-5, float("nan"),
                 {"features": {"groups": [{"grid": 1, "bytes_step": 1.0,
                                           "steps": {"point": 1.0}}]}}),
        DriftRow("trial", "s", None, "pallas", 1e-5, float("inf"),
                 {"features": {"groups": [{"grid": 1, "bytes_step": 1.0,
                                           "steps": {"point": 1.0}}]}}),
        DriftRow("trial", "s", None, "pallas", 1e-5, 1e-4, None),
        DriftRow("trial", "s", None, "pallas", 1e-5, 1e-4,
                 {"features": {"groups": [{"grid": -2, "bytes_step": 1.0,
                                           "steps": {"point": 1.0}}]}}),
    ]
    result = calibrate(rows + junk)
    assert result.n_unusable == len(junk)
    _assert_recovered(result, true, rel=1e-6)


def test_compile_rows_excluded_by_default():
    # engine `compile` rows carry jit time in measured_s; 80x-polluted
    # rows must not shift the fit because the default excludes the kind
    true = _true_spec()
    rng = np.random.default_rng(5)
    clean = _synth_rows(rng, true, n=16)
    polluted = _synth_rows(rng, true, n=8, kind="compile")
    for r in polluted:
        r.measured_s *= 80.0
    result = calibrate(clean + polluted)
    assert result.n_excluded == len(polluted)
    _assert_recovered(result, true, rel=1e-6)
    # the exclusion is total: the fit is bit-identical to one that
    # never saw the polluted rows at all
    assert result.spec == calibrate(clean).spec
    # opting in (exclude_kinds=()) really does consume them
    everything = calibrate(clean + polluted, exclude_kinds=())
    assert everything.n_excluded == 0
    assert everything.n_rows == len(clean) + len(polluted)
    assert everything.spec != result.spec


def test_huber_resists_outliers():
    true = _true_spec()
    rows = _synth_rows(np.random.default_rng(9), true, n=40)
    for r in rows[::10]:                       # a few preempted trials
        r.measured_s *= 25.0
    robust = calibrate(rows, huber_delta=3.0)
    _assert_recovered(robust, true, rel=0.35)


# optional deeper layer: same harness driven by hypothesis when the
# dependency exists (it is not baked into the CI image)
try:
    from hypothesis import given, settings, strategies as st
    _HYP = True
except ImportError:                                    # pragma: no cover
    _HYP = False

if _HYP:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2 ** 31 - 1),
           n=st.integers(MIN_ROWS, 64))
    def test_hypothesis_noiseless_recovery(seed, n):
        true = _true_spec()
        rows = _synth_rows(np.random.default_rng(seed), true, n=n)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", RuntimeWarning)
            result = calibrate(rows)
        if result.fitted:                  # small n may be deficient
            _assert_recovered(result, true, rel=1e-5)
        else:
            assert result.spec is V5E


# ----------------------------------------------------------------------
# the golden fixture: real measurements, seed misorders, fit orders
# ----------------------------------------------------------------------
def _fixture_rows() -> list[DriftRow]:
    with open(_FIXTURE) as f:
        return [DriftRow.from_dict(json.loads(line)) for line in f]


def test_golden_fixture_seed_model_misorders():
    rep = drift_report(_fixture_rows())
    assert rep["n"] >= MIN_ROWS
    assert rep["spearman"] <= 0, rep["spearman"]
    assert rep["bias"] > 2          # and it is absolutely way off, too


def test_golden_fixture_fit_restores_ordering():
    rows = _fixture_rows()
    result = calibrate(rows)
    assert result.fitted, result.warning
    after = drift_report(rows, spec=result.spec)["with_spec"]
    assert after["n"] == len(rows)
    assert after["spearman"] > 0.8, after
    assert abs(math.log10(after["bias"])) < 0.3, after
    # the fitted constants tell the CPU-host story: a per-grid-step
    # overhead orders of magnitude above the seed's token 1us
    assert result.spec.step_overhead_s > 10 * V5E.step_overhead_s


# ----------------------------------------------------------------------
# calibrated tuning: same winner, strictly fewer measurements
# ----------------------------------------------------------------------
def _blur_graph():
    from repro.core.apps import build_app
    return build_app("gaussian_blur", 96, 256)


def test_calibrated_search_prunes_to_same_winner(tmp_path):
    from repro.tune import TuningCache, tune_graph

    # measured truth: wider vectors are faster (matches what the
    # overhead-dominated calibrated spec predicts)
    def measured(cfg):
        return 1.0 / (cfg.group_vf[0] or 1)

    cal_spec = CalibratedSpec(step_overhead_s=1e-3,
                              ii_scale=(("stencil", 1.0),), n_rows=9)
    uncal = tune_graph(_blur_graph(), "xla",
                       cache=TuningCache(str(tmp_path / "a")),
                       measure=measured)
    cal = tune_graph(_blur_graph(), "xla",
                     cache=TuningCache(str(tmp_path / "b")),
                     measure=measured, calibrate=cal_spec)
    assert uncal.source == cal.source == "measured"
    assert cal.config == uncal.config            # same winner
    assert cal.n_measurements < uncal.n_measurements, \
        (cal.n_measurements, uncal.n_measurements)
    assert cal.n_pruned >= 1
    assert uncal.n_pruned == 0       # seed spec has not earned pruning
    assert cal.record.n_pruned == cal.n_pruned
    assert any("pruned" in line for line in cal.notes())
    # the pruning provenance survives the on-disk record round-trip
    rec = TuningCache(str(tmp_path / "b")).get(cal.key)
    assert rec is not None and rec.n_pruned == cal.n_pruned


def test_uncalibrated_spec_never_prunes(tmp_path):
    from repro.tune import TuningCache, tune_graph
    res = tune_graph(_blur_graph(), "xla",
                     cache=TuningCache(str(tmp_path / "c")),
                     measure=lambda cfg: 1.0 / (cfg.group_vf[0] or 1),
                     prior_ratio=0.0)       # maximally aggressive ratio
    assert res.n_pruned == 0                # ...still gated on evidence


# ----------------------------------------------------------------------
# feature round-trips
# ----------------------------------------------------------------------
@pytest.mark.parametrize("app", ["gaussian_blur", "filter_chain"])
def test_predict_features_matches_compiler_model(app):
    from repro.core import build_schedule
    from repro.core.apps import build_app
    from repro.core.vectorize import modeled_schedule_time
    sched = build_schedule(build_app(app, 64, 256))
    feats = sched.features()
    assert predict_features(feats, V5E) == modeled_schedule_time(sched, V5E)
    # items multiplies through exactly
    feats3 = sched.features(items=3)
    assert predict_features(feats3, V5E) == pytest.approx(
        3 * modeled_schedule_time(sched, V5E), rel=1e-12)


def test_engine_drift_rows_repredict_exactly(tmp_path):
    from repro.core import DataflowGraph
    from repro.runtime import StreamEngine
    g = DataflowGraph("cal_pw")
    x = g.input("x", (8, 128))
    g.output(g.point(x, lambda v: v + 1.0, name="inc"), "y")
    path = str(tmp_path / "drift.jsonl")
    with StreamEngine(backend="xla", max_batch=2, drift=path) as eng:
        for i in range(3):
            eng.submit(g, {"x": np.full((8, 128), i, np.float32)}
                       ).result(timeout=60)
    rows = DriftLog(path).rows()
    assert rows and all(r.features is not None for r in rows)
    for r in rows:
        assert predict_features(r.features, V5E) == pytest.approx(
            r.modeled_s, rel=1e-12)
    # too few rows for a fit — but the jit-polluted compile rows are
    # visibly excluded, not silently mixed in
    with pytest.warns(RuntimeWarning):
        result = calibrate(rows)
    assert not result.fitted
    assert result.n_excluded == sum(r.kind == "compile" for r in rows)


# ----------------------------------------------------------------------
# persistence + resolution + digest stability
# ----------------------------------------------------------------------
def test_spec_json_roundtrip_exact():
    s = CalibratedSpec(clock_hz=3.217e8, hbm_bw=7.7e10,
                       step_overhead_s=1.12e-5,
                       ii_scale=(("point", 1.0), ("stencil", 3.25)),
                       n_rows=14)
    assert spec_from_json(json.loads(json.dumps(spec_to_json(s)))) == s


def test_calibration_store_roundtrip(tmp_path):
    store = CalibrationStore(str(tmp_path))
    spec = CalibratedSpec(clock_hz=2e8, ii_scale=(("stencil", 1.0),),
                          n_rows=10)
    assert store.get("pallas@abc", "cpu") is None
    store.put("pallas@abc", "cpu", spec)
    assert store.get("pallas@abc", "cpu") == spec
    # fresh handle re-reads disk; other keys stay empty
    assert CalibrationStore(str(tmp_path)).get("pallas@abc", "cpu") == spec
    assert store.get("pallas@abc", "tpu-v5e") is None
    store.invalidate("pallas@abc", "cpu")
    assert CalibrationStore(str(tmp_path)).get("pallas@abc", "cpu") is None


def test_calibration_store_skips_other_versions(tmp_path):
    store = CalibrationStore(str(tmp_path))
    spec = CalibratedSpec(clock_hz=2e8, n_rows=10)
    path = store.put("p@x", "cpu", spec)
    with open(path) as f:
        raw = json.load(f)
    raw["version"] = CALIBRATION_VERSION + 1
    with open(path, "w") as f:
        json.dump(raw, f)
    assert CalibrationStore(str(tmp_path)).get("p@x", "cpu") is None


def test_calibrate_backend_persists_and_auto_resolves(tmp_path):
    store = CalibrationStore(str(tmp_path))
    rows = _synth_rows(np.random.default_rng(2), _true_spec())
    result = calibrate_backend("pallas", rows, store=store,
                               device_kind="testdev")
    assert result.fitted
    loaded = load_calibration("pallas", store=store, device_kind="testdev")
    assert loaded == result.spec
    via_auto = resolve_calibration("pallas", "auto", store=store,
                                   device_kind="testdev")
    assert via_auto == result.spec
    # the protocol's edges
    assert resolve_calibration("pallas", None, store=store) is None
    assert resolve_calibration("pallas", False, store=store) is None
    passthrough = resolve_calibration("pallas", result.spec, store=store)
    assert passthrough is result.spec
    with pytest.raises(TypeError):
        resolve_calibration("pallas", "atuo", store=store)


def test_auto_fits_from_drift_log_when_store_empty(tmp_path):
    store = CalibrationStore(str(tmp_path / "s"))
    log = DriftLog(str(tmp_path / "d.jsonl"))
    for r in _synth_rows(np.random.default_rng(4), _true_spec()):
        log.record(r.kind, r.signature, r.shapes, r.backend, r.modeled_s,
                   r.measured_s, **r.attrs)
    log.flush()
    spec = resolve_calibration("pallas", "auto", store=store,
                               device_kind="testdev", drift=log.path)
    assert isinstance(spec, CalibratedSpec)
    # ...and the fit was persisted: a second resolve is a pure load
    assert load_calibration("pallas", store=store,
                            device_kind="testdev") == spec


def test_uncalibrated_backend_identity_and_digest_split():
    from repro.backends import resolve, resolve_calibrated
    be = resolve("pallas")
    # opting out returns the registered record itself — the compile
    # and tuning cache digests of every uncalibrated run are untouched
    assert resolve_calibrated("pallas", None) is be
    assert resolve_calibrated("pallas", False) is be
    assert resolve_calibrated(be, None) is be
    cal = resolve_calibrated("pallas", CalibratedSpec(
        clock_hz=2e8, ii_scale=(("stencil", 1.0),), n_rows=9))
    assert cal.cache_key() != be.cache_key()   # calibrated: own namespace
    assert cal.name == be.name
    assert resolve("pallas") is be             # registry not mutated


def test_compile_graph_calibrate_spec_is_semantics_preserving():
    from repro.core import compile_graph
    cal_spec = CalibratedSpec(step_overhead_s=1e-3,
                              ii_scale=(("stencil", 1.0),), n_rows=9)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(96, 256)).astype(np.float32)
    ref = np.asarray(compile_graph(_blur_graph(), "pallas")(img=x)["out"])
    out = np.asarray(compile_graph(_blur_graph(), "pallas",
                                   calibrate=cal_spec)(img=x)["out"])
    assert np.array_equal(ref, out)
