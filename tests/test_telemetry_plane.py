"""Production telemetry plane: exporter, health, sentinel, rotation.

PR 10's acceptance surface in one place:

- the OpenMetrics renderer round-trips through its own strict parser
  (names, label escaping, counter ``_total``, ``# EOF``) and the live
  scrape endpoint serves monotone counters;
- the SLO health monitor's hysteresis is pinned white-box: a metric
  alternating pass/fail at its threshold parks in ``degraded`` and
  can never flap ``healthy <-> breach``;
- the drift log rotates at its row cap without losing the rolling
  window (``rows()`` and ``drift_report`` span the rotation);
- the versioned :class:`CalibrationStore` bumps ``seq``, keeps stale
  ancestors in ``history``, and reads pre-versioning records;
- the END-TO-END loop: an engine serving real traffic whose drift
  rows were generated under a deliberately mis-scaled spec has its
  sentinel flag staleness, run :func:`calibrate`, and persist a
  versioned fit — after which ``compile_graph(calibrate="auto")``
  resolves the refit spec with **no manual calibrate() call** — and
  the same live engine's ``/metrics`` scrape parses clean with
  per-app labels.
"""
from __future__ import annotations

import importlib.util
import json
import math
import os
import threading
import time
import urllib.request

import numpy as np
import pytest

from repro.core import DataflowGraph, compile_graph
from repro.core.apps import JACOBI3, LAPLACE3, _conv
from repro.obs.drift import (DriftLog, DriftRow, drift_report,
                             predict_features)
from repro.obs.exporter import (CONTENT_TYPE, MetricFamily,
                                MetricsHTTPServer, flatten_report,
                                parse_openmetrics, registry_families,
                                render_openmetrics, validate_openmetrics,
                                write_openmetrics)
from repro.obs.health import SLO, STATES, HealthMonitor
from repro.obs.metrics import MetricsRegistry
from repro.obs.sentinel import DriftSentinel, SentinelPolicy
from repro.runtime import StreamEngine, Telemetry
from repro.tune.calibrate import (CALIBRATION_VERSION, CalibratedSpec,
                                  CalibrationStore, spec_to_json)
from repro.tune.store import detect_device_kind

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _load_compare():
    """benchmarks/ is not a package; load the gate module by path."""
    path = os.path.join(_ROOT, "benchmarks", "compare.py")
    spec = importlib.util.spec_from_file_location("bench_compare", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _diamond(h=32, w=128, name="diamond"):
    g = DataflowGraph(name)
    x = g.input("x", (h, w))
    s1 = g.stencil(x, (3, 3), _conv(LAPLACE3), name="lap")
    s2 = g.stencil(x, (3, 3), _conv(JACOBI3), name="jac")
    g.output(g.point2(s1, s2, lambda u, v: u - v, name="merge"), "y")
    return g


def _true_spec() -> CalibratedSpec:
    """Ground truth deliberately far from every seed constant."""
    return CalibratedSpec(clock_hz=5e8, hbm_bw=2e11, step_overhead_s=3e-5,
                          ii_scale=(("point", 1.0), ("stencil", 2.5)))


def _alpha(spec, kind: str = "point") -> float:
    """Gauge-invariant per-kind cost: the fit pins the reference
    kind's multiplier to 1.0, so only ``ii_scale / clock`` compares."""
    return dict(spec.ii_scale)[kind] / spec.clock_hz


def _trial_features(i: int) -> dict:
    """Cycle the four regimes that make every constant identifiable.

    The grid multiplier varies with ``i`` so dedup inside
    :func:`calibrate` keeps enough distinct rows for a full-rank fit.
    """
    regime = ("overhead", "dma", "compute_point", "compute_stencil")[i % 4]
    grid = 1 + (i % 6)
    if regime == "overhead":
        g = {"grid": 64 * grid, "bytes_step": 512.0,
             "steps": {"point": 200.0}}
    elif regime == "dma":
        g = {"grid": grid, "bytes_step": 32.0 * 2.0 ** 20,
             "steps": {"point": 500.0}}
    elif regime == "compute_point":
        g = {"grid": grid, "bytes_step": 512.0,
             "steps": {"point": 2e7}}
    else:
        g = {"grid": grid, "bytes_step": 512.0,
             "steps": {"stencil": 2e7}}
    return {"groups": [g]}


def _write_trials(log: DriftLog, *, backend_key: str, n: int = 24,
                  mis_scale: float = 10.0, measured_scale: float = 1.0,
                  backend: str = "xla") -> None:
    """Append trial rows: modeled under a mis-scaled spec, measured
    under the true one (scaled by ``measured_scale`` to simulate the
    machine drifting after a fit)."""
    true = _true_spec()
    for i in range(n):
        feats = _trial_features(i)
        measured = predict_features(feats, true) * measured_scale
        log.record("trial", f"sig{i % 5}", [[32, 128]], backend,
                   predict_features(feats, true) / mis_scale, measured,
                   features=feats, backend_key=backend_key)
    log.flush()


# ----------------------------------------------------------------------
# OpenMetrics exporter: render <-> strict parse
# ----------------------------------------------------------------------
def test_openmetrics_round_trip_label_escaping():
    fam = MetricFamily("weird", "gauge", 'help with "quotes"\nand lines')
    nasty = {"app": 'say "hi"', "path": "a\\b", "msg": "line\nbreak"}
    fam.add(1.5, nasty)
    text = render_openmetrics([fam])
    parsed = parse_openmetrics(text)
    assert parsed["weird"]["type"] == "gauge"
    (suffix, labels, value), = parsed["weird"]["samples"]
    assert suffix == "" and value == 1.5
    assert labels == nasty          # escaping survived the round trip


def test_openmetrics_counter_total_and_summary_series():
    reg = MetricsRegistry()
    reg.counter("served").inc(7)
    reg.histogram("latency_s").extend([0.01, 0.02, 0.04])
    reg.histogram("empty_s")        # registered, never observed
    text = render_openmetrics(registry_families(reg, labels={"app": "a"}))
    parsed = parse_openmetrics(text)
    assert parsed["repro_served"]["type"] == "counter"
    (suffix, labels, value), = parsed["repro_served"]["samples"]
    assert suffix == "_total" and value == 7 and labels["app"] == "a"
    lat = parsed["repro_latency_s"]
    assert lat["type"] == "summary"
    series = {s for s, _, _ in lat["samples"]}
    assert {"_count", "_sum"} <= series
    quantiles = {l["quantile"] for _, l, v in lat["samples"]
                 if "quantile" in l}
    assert quantiles == {"0.5", "0.9", "0.99"}
    # the empty reservoir exports its count of 0 and NO quantiles —
    # never a fake 0.0 percentile
    empty = parsed["repro_empty_s"]["samples"]
    assert {s for s, _, _ in empty} == {"_count", "_sum"}
    assert all(v == 0 for _, _, v in empty)


def test_openmetrics_skips_none_and_nonfinite_values():
    fam = MetricFamily("g", "gauge")
    fam.add(None, {"k": "none"})
    fam.add(float("nan"), {"k": "nan"})
    fam.add(float("inf"), {"k": "inf"})
    fam.add(2.0, {"k": "ok"})
    parsed = parse_openmetrics(render_openmetrics([fam]))
    assert [l["k"] for _, l, _ in parsed["g"]["samples"]] == ["ok"]


def test_openmetrics_rules_fold_phase_histograms():
    reg = MetricsRegistry()
    reg.histogram("phase_launch_s").observe(0.01)
    reg.histogram("phase_form_s").observe(0.02)
    rules = {f"phase_{p}_s": ("phase_seconds", {"phase": p})
             for p in ("launch", "form")}
    fams = registry_families(reg, rules=rules)
    assert set(fams) == {"repro_phase_seconds"}
    phases = {l["phase"] for s in fams["repro_phase_seconds"].samples
              for l in [s.labels]}
    assert phases == {"launch", "form"}
    parse_openmetrics(render_openmetrics(fams))   # and it renders clean


def test_openmetrics_validator_rejections():
    good = render_openmetrics([MetricFamily("x", "gauge")])
    # missing EOF sentinel
    with pytest.raises(ValueError, match="EOF"):
        parse_openmetrics(good.replace("# EOF\n", ""))
    # counter sample without the mandatory _total suffix
    with pytest.raises(ValueError, match="_total"):
        parse_openmetrics("# TYPE c counter\nc 1\n# EOF\n")
    # sample preceding its TYPE line
    with pytest.raises(ValueError, match="precedes"):
        parse_openmetrics("y 1\n# TYPE y gauge\n# EOF\n")
    # malformed label block
    with pytest.raises(ValueError, match="label"):
        parse_openmetrics('# TYPE z gauge\nz{bad-name="v"} 1\n# EOF\n')
    # duplicate family is a render-time error
    with pytest.raises(ValueError, match="duplicate"):
        render_openmetrics([MetricFamily("d", "gauge"),
                            MetricFamily("d", "counter")])
    stats = validate_openmetrics(good)
    assert stats["families"] == 1


def test_openmetrics_file_export_and_flatten(tmp_path):
    path = str(tmp_path / "metrics.prom")
    write_openmetrics(path, render_openmetrics(
        [MetricFamily("up", "gauge")]))
    with open(path) as f:
        parse_openmetrics(f.read())
    flat = flatten_report({"a": {"b": {"c": 1}}, "d": 2})
    assert flat == {"a.b.c": 1, "d": 2}


def test_metrics_http_server_scrape_monotone_counters():
    reg = MetricsRegistry()
    reg.counter("hits").inc(3)
    with MetricsHTTPServer(
            lambda: render_openmetrics(registry_families(reg))) as srv:
        def scrape():
            with urllib.request.urlopen(srv.url, timeout=10) as resp:
                assert resp.headers["Content-Type"] == CONTENT_TYPE
                return parse_openmetrics(resp.read().decode())
        first = scrape()
        reg.counter("hits").inc(2)
        second = scrape()
        v1 = first["repro_hits"]["samples"][0][2]
        v2 = second["repro_hits"]["samples"][0][2]
        assert (v1, v2) == (3, 5)       # monotone across scrapes
        assert srv.scrapes >= 2


# ----------------------------------------------------------------------
# SLO health monitor: hysteresis, white-box
# ----------------------------------------------------------------------
def test_health_alternating_violation_never_flaps_to_breach():
    """A metric oscillating at its threshold parks in ``degraded``."""
    mon = HealthMonitor(SLO(max_shed_rate=None, max_queue_depth=4),
                        breach_after=3, recover_after=3)
    states = []
    for i in range(12):
        out = mon.evaluate(queue_depth=10 if i % 2 else 0)
        states.append(out["state"])
    assert "breach" not in states
    assert states[-1] == "degraded"
    # no healthy<->breach edge exists anywhere in the audit trail
    for _, frm, to, _ in mon.transitions:
        assert {frm, to} != {"healthy", "breach"}


def test_health_breach_and_recovery_pass_through_degraded():
    mon = HealthMonitor(SLO(max_shed_rate=None, max_queue_depth=4),
                        breach_after=3, recover_after=3)
    for _ in range(3):
        mon.evaluate(queue_depth=10)
    assert mon.state == "breach"
    for _ in range(2):
        mon.evaluate(queue_depth=0)
    assert mon.state == "degraded"      # recovering, not yet healthy
    mon.evaluate(queue_depth=0)
    assert mon.state == "healthy"
    assert [(f, t) for _, f, t, _ in mon.transitions] == [
        ("healthy", "degraded"), ("degraded", "breach"),
        ("breach", "degraded"), ("degraded", "healthy")]


def test_health_shed_rate_is_per_interval_not_cumulative():
    mon = HealthMonitor(SLO(max_shed_rate=0.05))
    assert mon.evaluate(submitted=100, shed=0)["violated"] == []
    out = mon.evaluate(submitted=100, shed=10)   # 10 sheds, 0 new subs
    assert out["violated"] == ["shed_rate"]
    assert out["objectives"]["shed_rate"]["value"] == 1.0
    # same counters again: no offered traffic -> objective goes quiet
    # (an engine that shed during a spike an hour ago is not unhealthy)
    out = mon.evaluate(submitted=100, shed=10)
    assert out["violated"] == []
    assert out["objectives"]["shed_rate"]["value"] is None


def test_health_latency_objective_waits_for_samples():
    mon = HealthMonitor(SLO(latency_p99_s=0.001, max_shed_rate=None),
                        min_latency_samples=20)
    mon.observe_latencies([1.0] * 5)
    assert mon.evaluate()["violated"] == []      # too few for a p99
    mon.observe_latencies([1.0] * 20)
    assert mon.evaluate()["violated"] == ["latency_p99"]


def test_health_registry_counters_and_state_gauge():
    reg = MetricsRegistry()
    mon = HealthMonitor(SLO(max_shed_rate=None, max_queue_depth=1),
                        breach_after=2, registry=reg)
    assert reg.gauge("health_state").value == 0.0
    mon.evaluate(queue_depth=5)
    mon.evaluate(queue_depth=5)
    assert reg.gauge("health_state").value == float(STATES.index("breach"))
    assert reg.counter("health_evaluations").value == 2
    assert reg.counter("health_violation_queue_depth").value == 2
    assert reg.counter("health_transitions").value == 2


def test_engine_health_defaults_to_latency_budget_slo():
    g = _diamond()
    x = np.zeros((32, 128), np.float32)
    with StreamEngine(backend="xla", latency_budget=10.0,
                      max_batch=4) as eng:
        eng.submit(g, {"x": x}).result(timeout=600)
        out = eng.health()
    assert out["state"] == "healthy"
    assert set(out["objectives"]) == {"latency_p99", "shed_rate"}


# ----------------------------------------------------------------------
# drift log rotation
# ----------------------------------------------------------------------
def _n_rows(log: DriftLog, n: int, start: int = 0) -> None:
    for i in range(start, start + n):
        feats = _trial_features(i)
        log.record("trial", f"s{i}", [[8, 8]], "pallas", 1e-5,
                   predict_features(feats, _true_spec()), features=feats)
    log.flush()


def test_drift_log_rotation_caps_disk_and_counts_retired(tmp_path):
    log = DriftLog(str(tmp_path / "d.jsonl"), max_rows=10)
    _n_rows(log, 8)
    assert not os.path.exists(log.rotated_path)
    assert log.rotated_rows == 0
    _n_rows(log, 8, start=8)                 # 16 > 10: first rotation
    assert os.path.exists(log.rotated_path)
    assert log.rotated_rows == 0             # nothing dropped yet
    assert len(log.rows()) == 16             # both generations visible
    _n_rows(log, 8, start=16)
    _n_rows(log, 8, start=24)                # second rotation: 16 retired
    assert log.rotated_rows == 16
    rows = log.rows()
    assert len(rows) == 16                   # bounded: <= 2 * max_rows
    # the *newest* rows survived, oldest-first order preserved
    assert [r.signature for r in rows] == [f"s{i}" for i in range(16, 32)]
    assert len(log) == 16


def test_drift_report_and_window_span_rotation(tmp_path):
    log = DriftLog(str(tmp_path / "d.jsonl"), max_rows=6)
    for i in range(18):                      # several rotations deep
        feats = _trial_features(i)
        log.record("trial", f"s{i % 4}", [[8, 8]], "pallas", 1e-5,
                   predict_features(feats, _true_spec()), features=feats)
        log.flush()
    visible = log.rows()
    rep = drift_report(log, spec=_true_spec())
    assert rep["n"] == len(visible) > 0
    assert rep["with_spec"]["spearman"] > 0.9   # window is coherent
    # a sentinel window over the same log sees the same visible rows
    sent = DriftSentinel(log, "pallas",
                         store=CalibrationStore(str(tmp_path / "s")))
    assert len(sent.window_rows()) == len(visible)


def test_drift_log_rejects_bad_cap_and_clear_removes_both(tmp_path):
    with pytest.raises(ValueError):
        DriftLog(str(tmp_path / "x.jsonl"), max_rows=0)
    log = DriftLog(str(tmp_path / "d.jsonl"), max_rows=2)
    _n_rows(log, 7)
    assert os.path.exists(log.rotated_path)
    log.clear()
    assert not os.path.exists(log.path)
    assert not os.path.exists(log.rotated_path)
    assert len(log) == 0 and log.rows() == []


# ----------------------------------------------------------------------
# telemetry JSON hygiene
# ----------------------------------------------------------------------
def test_telemetry_empty_snapshot_is_null_safe_json():
    snap = Telemetry().snapshot()
    assert snap["latency_samples"] == 0
    assert snap["latency_p50_ms"] is None
    assert snap["latency_p99_ms"] is None
    assert snap["latency_mean_ms"] is None
    assert snap["queue_depth_mean"] == 0.0
    text = json.dumps(snap)                  # JSON-safe, and no NaN/inf
    assert "NaN" not in text and "Infinity" not in text


def test_telemetry_nonfinite_latencies_filtered():
    t = Telemetry()
    now = time.perf_counter()
    t.observe_batches([(now, 2, {}, [0.01, float("nan")], None),
                       (now, 2, {}, [float("inf"), 0.03], None)])
    snap = t.snapshot()
    assert snap["completed"] == 4            # counted as completions...
    assert snap["latency_samples"] == 2      # ...but never aggregated
    assert math.isfinite(snap["latency_p99_ms"])
    flat = t.snapshot(flat=True)
    assert flat["latency_samples"] == 2      # dotted view, same hygiene


def test_telemetry_snapshot_concurrent_with_flush():
    """snapshot() must be safe against the worker's bulk-ingest."""
    t = Telemetry()
    stop = threading.Event()
    errors: list[BaseException] = []

    def reader():
        try:
            while not stop.is_set():
                json.dumps(t.snapshot())
        except BaseException as e:    # noqa: BLE001 - surfacing to main
            errors.append(e)

    th = threading.Thread(target=reader)
    th.start()
    now = time.perf_counter()
    for i in range(300):
        t.observe_batches([(now + i * 1e-4, 3,
                            {"launch": [1e-4], "form": 2e-4},
                            [1e-3, 2e-3], 1e-3)])
    stop.set()
    th.join()
    assert not errors
    assert t.snapshot()["completed"] == 600


# ----------------------------------------------------------------------
# versioned calibration store
# ----------------------------------------------------------------------
def test_store_put_bumps_seq_and_keeps_history(tmp_path):
    store = CalibrationStore(str(tmp_path))
    s1 = CalibratedSpec(clock_hz=1e8, ii_scale=(("point", 1.0),), n_rows=9)
    s2 = CalibratedSpec(clock_hz=2e8, ii_scale=(("point", 1.0),), n_rows=9)
    store.put("be@x", "cpu", s1)
    assert store.latest("be@x", "cpu")["seq"] == 1
    store.put("be@x", "cpu", s2)
    raw = store.latest("be@x", "cpu")
    assert raw["seq"] == 2 and raw["stale"] is False
    chain = store.versions("be@x", "cpu")
    assert [e["seq"] for e in chain] == [2, 1]
    assert store.get("be@x", "cpu") == s2


def test_store_mark_stale_hides_fit_until_refit(tmp_path):
    store = CalibrationStore(str(tmp_path))
    s1 = CalibratedSpec(clock_hz=1e8, ii_scale=(("point", 1.0),), n_rows=9)
    store.put("be@x", "cpu", s1)
    assert store.mark_stale("be@x", "cpu")
    assert store.get("be@x", "cpu") is None       # kept but skipped
    assert store.latest("be@x", "cpu")["stale"] is True
    s2 = CalibratedSpec(clock_hz=2e8, ii_scale=(("point", 1.0),), n_rows=9)
    store.put("be@x", "cpu", s2)
    raw = store.latest("be@x", "cpu")
    assert raw["seq"] == 2                        # stale fits still count
    assert raw["history"][0]["stale"] is True     # ancestry preserved
    assert store.get("be@x", "cpu") == s2
    assert not store.mark_stale("missing", "cpu")


def test_store_reads_pre_versioning_records(tmp_path):
    """A record written before seq/stale existed reads as seq 0."""
    store = CalibrationStore(str(tmp_path))
    spec = CalibratedSpec(clock_hz=3e8, ii_scale=(("point", 1.0),),
                          n_rows=12)
    old = {"version": CALIBRATION_VERSION, "backend": "be@y",
           "device_kind": "cpu", "created_at": 0.0,
           "spec": spec_to_json(spec)}
    store._write(store._path("be@y", "cpu"), old)
    assert store.get("be@y", "cpu") == spec
    s2 = CalibratedSpec(clock_hz=4e8, ii_scale=(("point", 1.0),), n_rows=9)
    store.put("be@y", "cpu", s2)
    raw = store.latest("be@y", "cpu")
    assert raw["seq"] == 1
    assert raw["history"][0]["seq"] == 0          # legacy demoted as v0
    assert store.get("be@y", "cpu") == s2


# ----------------------------------------------------------------------
# drift sentinel: staleness policy
# ----------------------------------------------------------------------
def _sentinel(tmp_path, log, **kw):
    from repro.backends import resolve
    store = kw.pop("store", None) or CalibrationStore(str(tmp_path / "s"))
    policy = kw.pop("policy", SentinelPolicy(min_interval_s=0.0))
    return DriftSentinel(log, "xla", store=store, policy=policy, **kw), \
        store, resolve("xla").cache_key()


def test_sentinel_short_window_never_stale(tmp_path):
    log = DriftLog(str(tmp_path / "d.jsonl"))
    sent, _, key = _sentinel(tmp_path, log)
    _write_trials(log, backend_key=key, n=4)
    out = sent.check()
    assert out["n_rows"] == 4 and not out["stale"]


def test_sentinel_uncalibrated_then_fit_then_quiet(tmp_path):
    log = DriftLog(str(tmp_path / "d.jsonl"))
    sent, store, key = _sentinel(tmp_path, log)
    _write_trials(log, backend_key=key, n=24)
    out = sent.poll()
    assert out["reasons"] == ["uncalibrated"]
    assert out["refit"]["fitted"]
    kind = detect_device_kind()
    assert store.latest(key, kind)["seq"] == 1
    # the recovered constants are the ground truth (noise-free rows)
    fit = store.get(key, kind)
    assert abs(_alpha(fit) - _alpha(_true_spec())) / _alpha(
        _true_spec()) < 0.05
    # next poll: fresh fit predicts the window -> nothing to do
    again = sent.poll()
    assert not again["stale"] and again["active_seq"] == 1
    assert sent.refits == 1


def test_sentinel_bias_drift_marks_stale_and_reversions(tmp_path):
    log = DriftLog(str(tmp_path / "d.jsonl"))
    sent, store, key = _sentinel(tmp_path, log)
    _write_trials(log, backend_key=key, n=24)
    assert sent.poll()["refit"]["fitted"]
    kind = detect_device_kind()
    # the machine drifts 3x slower: re-scored bias ~ log10(3) >> 0.15
    log.clear()
    _write_trials(log, backend_key=key, n=24, measured_scale=3.0)
    out = sent.poll()
    assert "bias" in out["reasons"]
    assert abs(out["log10_bias"] - math.log10(3.0)) < 0.1
    raw = store.latest(key, kind)
    assert raw["seq"] == 2
    assert raw["history"][0]["stale"] is True     # decayed fit retired
    # the refit tracks the 3x-slower machine, gauge-invariantly
    ratio = _alpha(store.get(key, kind)) / _alpha(_true_spec())
    assert abs(ratio - 3.0) < 0.2


def test_sentinel_new_rows_trigger_and_rate_limit(tmp_path):
    log = DriftLog(str(tmp_path / "d.jsonl"))
    sent, _, key = _sentinel(
        tmp_path, log, policy=SentinelPolicy(min_interval_s=100.0,
                                             refit_rows=8))
    _write_trials(log, backend_key=key, n=24)
    out = sent.poll(now=0.0)
    assert out["refit"]["fitted"]
    assert sent.poll(now=1.0) is None             # inside min_interval_s
    _write_trials(log, backend_key=key, n=8)
    out = sent.poll(now=200.0)
    assert out["reasons"] == ["new_rows"]         # fresh evidence
    assert out["n_new"] >= 8


def test_sentinel_ignores_other_backends_and_excluded_kinds(tmp_path):
    log = DriftLog(str(tmp_path / "d.jsonl"))
    sent, _, key = _sentinel(tmp_path, log)
    _write_trials(log, backend_key=key, n=8)
    _write_trials(log, backend_key="other@deadbeef", n=8)
    log.record("compile", "sigc", [[8, 8]], "xla", 1e-3, 2e-3,
               backend_key=key)
    log.flush()
    assert len(sent.window_rows()) == 8
    # pre-PR-10 rows (no backend_key attr) still match by name
    log.record("trial", "legacy", [[8, 8]], "xla", 1e-5, 2e-5,
               features=_trial_features(0))
    log.flush()
    assert len(sent.window_rows()) == 9


def test_sentinel_registry_counters(tmp_path):
    reg = MetricsRegistry()
    log = DriftLog(str(tmp_path / "d.jsonl"))
    sent, _, key = _sentinel(tmp_path, log, registry=reg)
    _write_trials(log, backend_key=key, n=24)
    sent.poll()
    assert reg.counter("sentinel_checks").value == 1
    assert reg.counter("sentinel_stale").value == 1
    assert reg.counter("sentinel_refits").value == 1
    assert reg.gauge("sentinel_rows").value == 24.0


def test_engine_sentinel_argument_validation(tmp_path):
    with StreamEngine(backend="xla", autostart=False) as eng:
        assert eng.sentinel is None
    with pytest.raises(ValueError, match="drift"):
        StreamEngine(backend="xla", sentinel=True, autostart=False)
    with pytest.raises(TypeError):
        StreamEngine(backend="xla", sentinel="yes", autostart=False,
                     drift=str(tmp_path / "d.jsonl"))
    eng = StreamEngine(backend="xla", sentinel=SentinelPolicy(),
                       drift=str(tmp_path / "d.jsonl"), autostart=False)
    try:
        assert isinstance(eng.sentinel, DriftSentinel)
    finally:
        eng.close()


# ----------------------------------------------------------------------
# ACCEPTANCE: end-to-end auto-recalibration + live scrape
# ----------------------------------------------------------------------
def test_engine_auto_recalibrates_and_scrapes_clean(tmp_path, monkeypatch):
    """Serve real traffic; the sentinel (not a human) closes the loop.

    Drift rows generated under a deliberately mis-scaled spec are
    flagged stale by the engine's own worker loop, ``calibrate()``
    runs, a *versioned* store entry lands — and a subsequent
    ``compile_graph(calibrate="auto")`` resolves the refit spec with
    no manual ``calibrate()`` call anywhere in this test.  The same
    live engine's OpenMetrics endpoint must parse clean with per-app
    labels.
    """
    from repro.backends import resolve, resolve_calibrated
    # the engine's default store AND compile_graph's auto-resolution
    # must agree on a root: both read $REPRO_TUNE_CACHE
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path))
    key = resolve("xla").cache_key()
    kind = detect_device_kind()
    log = DriftLog(str(tmp_path / "drift.jsonl"))
    # serving history recorded under a 10x mis-scaled cost model
    _write_trials(log, backend_key=key, n=24, mis_scale=10.0)
    store = CalibrationStore(str(tmp_path))
    sentinel = DriftSentinel(
        log, "xla", store=store,
        policy=SentinelPolicy(min_interval_s=0.0),
        # the engine's own wall-clock rows must not dilute the
        # deterministic synthetic fit
        exclude_kinds=("compile", "launch"))

    g = _diamond()
    x = np.arange(32 * 128, dtype=np.float32).reshape(32, 128) / 100.0
    with StreamEngine(backend="xla", drift=log, sentinel=sentinel,
                      max_batch=4, max_queue=32) as eng:
        for _ in range(4):
            eng.submit(g, {"x": x}).result(timeout=600)
        # the worker's idle loop polls the sentinel; wait for the fit
        deadline = time.time() + 60.0
        while store.latest(key, kind) is None and time.time() < deadline:
            time.sleep(0.05)
        raw = store.latest(key, kind)
        assert raw is not None, "sentinel never persisted a fit"
        assert raw["seq"] == 1 and raw["stale"] is False
        assert raw["fit"]["n_rows"] >= 8
        assert sentinel.refits >= 1

        # live scrape: parses clean, per-app labels present
        srv = eng.serve_metrics()
        with urllib.request.urlopen(srv.url, timeout=10) as resp:
            assert resp.headers["Content-Type"] == CONTENT_TYPE
            parsed = parse_openmetrics(resp.read().decode())
        served = parsed["repro_app_served"]["samples"]
        labels = {l["app"]: l for _, l, _ in served}
        assert "diamond" in labels
        assert labels["diamond"]["backend"] == key
        assert labels["diamond"]["device"] == kind
        assert len(labels["diamond"]["signature"]) == 12
        assert any(v >= 4 for _, l, v in served if l["app"] == "diamond")
        # sentinel + health metrics ride the same exposition
        assert "repro_sentinel_refits" in parsed
        assert "repro_health_state" in parsed

    # ...and the compiler resolves the auto-refit spec from here on
    be = resolve_calibrated("xla", "auto")
    fitted = store.get(key, kind)
    assert isinstance(fitted, CalibratedSpec)
    assert be.spec == fitted
    assert abs(_alpha(fitted) - _alpha(_true_spec())) / _alpha(
        _true_spec()) < 0.05                         # ground truth
    app = compile_graph(g, backend="xla", calibrate="auto")
    ref = app.schedule.graph.reference_eval({"x": x})["y"]
    np.testing.assert_allclose(np.asarray(app(x=x)["y"]),
                               np.asarray(ref), rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------
# benchmark regression gate
# ----------------------------------------------------------------------
def test_compare_rows_matches_by_identity_and_direction():
    compare = _load_compare()
    base = [{"name": "a", "h": 64, "us": 100.0, "throughput_rps": 50.0},
            {"name": "a", "h": 256, "us": 400.0}]
    # smoke-shaped fresh run: only the h=64 row exists; 10x slower
    fresh = [{"name": "a", "h": 64, "us": 1000.0, "throughput_rps": 5.0}]
    out = compare.compare_rows(base, fresh, tol=2.0)
    assert out["matched"] == 1 and out["unmatched_baseline"] == 1
    verdicts = {d["metric"]: d["ok"] for d in out["deltas"]}
    assert verdicts == {"us": False, "throughput_rps": False}
    # within tolerance both directions pass
    ok = compare.compare_rows(base, [dict(base[0], us=250.0,
                                          throughput_rps=20.0)], tol=2.0)
    assert not ok["failures"]


def test_compare_ignores_modeled_metrics_and_formats_table():
    compare = _load_compare()
    base = [{"name": "r", "us": 10.0, "modeled_us": 1.0}]
    fresh = [{"name": "r", "us": 10.0, "modeled_us": 99.0}]
    out = compare.compare_rows(base, fresh)
    assert {d["metric"] for d in out["deltas"]} == {"us"}
    out.update(baseline_path="b.json", fresh_path="f.json",
               baseline_smoke=False, fresh_smoke=True)
    table = compare.format_table(out)
    assert "REGRESSION" not in table and "1 matched" in table


def test_compare_main_gates_regressions(tmp_path, capsys):
    compare = _load_compare()
    base = str(tmp_path / "base.json")
    fresh = str(tmp_path / "fresh.json")
    rows = [{"name": "k", "n": 8, "us": 100.0}]
    with open(base, "w") as f:
        json.dump({"rows": rows}, f)
    with open(fresh, "w") as f:
        json.dump({"rows": [dict(rows[0], us=120.0)], "smoke": True}, f)
    assert compare.main([f"{base}:{fresh}"]) == 0
    with open(fresh, "w") as f:
        json.dump({"rows": [dict(rows[0], us=900.0)], "smoke": True}, f)
    assert compare.main([f"{base}:{fresh}"]) == 1
    assert "REGRESSION" in capsys.readouterr().out
    # missing files: skipped with a warning, fatal only under --strict
    missing = str(tmp_path / "nope.json")
    assert compare.main([f"{base}:{missing}"]) == 0
    assert compare.main(["--strict", f"{base}:{missing}"]) == 1


def test_checked_in_baselines_parse_for_the_gate():
    """CI diffs experiments/ against these; they must stay loadable."""
    compare = _load_compare()
    for name, _ in compare.DEFAULT_PAIRS:
        path = os.path.join(_ROOT, name)
        with open(path) as f:
            payload = json.load(f)
        rows = payload["rows"]
        assert rows, f"{name} has no rows"
        keys = [compare.row_key(r) for r in rows]
        assert len(keys) == len(set(keys)), f"{name}: ambiguous row identity"
