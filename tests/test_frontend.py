"""Tracing frontend: single-source programs == hand-built graphs.

Acceptance tests for the frontend subsystem:

- every Table-I app traced from plain array code has the SAME
  canonical signature as its hand-built oracle graph, and agrees
  bit-exactly (atol=0) on the xla and pallas backends;
- hypothesis: tracing a random expression DAG and running
  ``reference_eval`` equals evaluating the same expressions directly
  on arrays, and trace-time CSE never changes results;
- trace diagnostics carry the USER'S source location and the
  stage-validation errors name the offending stage;
- a ``@dataflow_fn``-decorated function compiles, serves through the
  StreamEngine and tunes via ``tune="auto"`` with no explicit graph,
  channel or split construction in user code.
"""
import jax.numpy as jnp
import numpy as np
import pytest

import repro.frontend as fe
from repro.core.apps import APPS, HAND_BUILT
from repro.core.compiler import compile_graph
from repro.core.graph import DataflowGraph, GraphError
from repro.core.transform import default_pipeline
from repro.frontend import lib
from repro.frontend.diagnostics import (TraceControlFlowError,
                                        TraceDtypeError, TraceError,
                                        TraceLeakError, TraceShapeError)

H, W = 48, 256


def _canonical(g: DataflowGraph) -> DataflowGraph:
    g, _ = default_pipeline().run(g)
    return g


# ----------------------------------------------------------------------
# Table-I equivalence: traced == hand-built
# ----------------------------------------------------------------------
@pytest.mark.parametrize("name", sorted(APPS))
def test_traced_signature_equals_handbuilt(name):
    traced = APPS[name][0](H, W)
    manual = _canonical(HAND_BUILT[name](H, W))
    assert traced.signature() == manual.signature()


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("name", sorted(APPS))
def test_traced_bit_exact_vs_handbuilt(name, backend, rng):
    traced = APPS[name][0](H, W)
    manual = HAND_BUILT[name](H, W)
    inputs = {c.name: rng.normal(size=c.shape).astype(np.float32)
              for c in traced.graph_inputs}
    out_t = compile_graph(traced, backend=backend)(**inputs)
    out_m = compile_graph(manual, backend=backend)(**inputs)
    assert sorted(out_t) == sorted(out_m)
    for k in out_t:                    # atol=0: bit-exact
        np.testing.assert_array_equal(np.asarray(out_t[k]),
                                      np.asarray(out_m[k]))


def test_traced_graphs_are_canonical():
    """trace() returns a validated, already-canonicalized graph."""
    g = APPS["harris"][0](H, W)
    g.validate()                       # no multi-reader channels left
    assert any(s.kind == "split" for s in g.stages)
    assert isinstance(g.frontend_log, list)


# ----------------------------------------------------------------------
# hypothesis: random expression DAGs (skipped when hypothesis is absent
# — the rest of this module must still run)
# ----------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    _HAVE_HYPOTHESIS = False

_BIN = {
    "add": lambda a, b: a + b,
    "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b,
    "max": fe.maximum,
    "min": fe.minimum,
}
_UN = {
    "neg": lambda a: -a,
    "abs": lambda a: abs(a),
    "sqrt_abs": lambda a: fe.sqrt(abs(a)),
    "scale": lambda a: a * 1.7,
    "offset": lambda a: a + 0.25,
    "tanh": fe.tanh,
}


if _HAVE_HYPOTHESIS:
    @st.composite
    def _recipes(draw):
        n = draw(st.integers(1, 10))
        steps, pool = [], 2            # two graph inputs seed the pool
        for _ in range(n):
            if draw(st.booleans()):
                steps.append(("bin", draw(st.sampled_from(sorted(_BIN))),
                              draw(st.integers(0, pool - 1)),
                              draw(st.integers(0, pool - 1))))
            else:
                steps.append(("un", draw(st.sampled_from(sorted(_UN))),
                              draw(st.integers(0, pool - 1))))
            pool += 1
        return steps


def _run_recipe(steps, a, b):
    pool = [a, b]
    for s in steps:
        if s[0] == "bin":
            pool.append(_BIN[s[1]](pool[s[2]], pool[s[3]]))
        else:
            pool.append(_UN[s[1]](pool[s[2]]))
    return pool[-1]


if _HAVE_HYPOTHESIS:
    @given(steps=_recipes(), seed=st.integers(0, 2**32 - 1))
    @settings(max_examples=25, deadline=None)
    def test_trace_reference_eval_equals_direct_eval(steps, seed):
        rng = np.random.default_rng(seed)
        av = rng.normal(size=(8, 128)).astype(np.float32)
        bv = rng.normal(size=(8, 128)).astype(np.float32)
        g = fe.trace(lambda a, b: _run_recipe(steps, a, b),
                     (8, 128), (8, 128), name="dag")
        out = np.asarray(g.reference_eval({"a": av, "b": bv})["out"])
        ref = np.asarray(_run_recipe(steps, jnp.asarray(av),
                                     jnp.asarray(bv)))
        np.testing.assert_array_equal(out, ref)

    @given(steps=_recipes(), seed=st.integers(0, 2**32 - 1))
    @settings(max_examples=25, deadline=None)
    def test_cse_never_changes_results(steps, seed):
        rng = np.random.default_rng(seed)
        inputs = {"a": rng.normal(size=(8, 128)).astype(np.float32),
                  "b": rng.normal(size=(8, 128)).astype(np.float32)}
        fn = lambda a, b: _run_recipe(steps, a, b)   # noqa: E731
        with_cse = fe.trace(fn, (8, 128), (8, 128), name="dag")
        without = fe.trace(fn, (8, 128), (8, 128), name="dag", cse=False)
        np.testing.assert_array_equal(
            np.asarray(with_cse.reference_eval(inputs)["out"]),
            np.asarray(without.reference_eval(inputs)["out"]))
        assert len(with_cse.stages) <= len(without.stages)


# ----------------------------------------------------------------------
# trace-time canonicalization
# ----------------------------------------------------------------------
def test_cse_merges_reused_subexpression():
    def prog(img):
        a = fe.conv(img, lib.GAUSS3)
        b = fe.conv(img, lib.GAUSS3)    # structurally identical record
        return a + b

    g = fe.trace(prog, (8, 128), canonicalize=False)
    assert sum(1 for s in g.stages if s.kind == "stencil") == 1
    assert any(line.startswith("cse:") for line in g.frontend_log)


def test_constant_folding_elides_identities():
    def prog(img):
        return (img * 1.0) + 0.0        # both ops are identities

    g = fe.trace(prog, (8, 128), canonicalize=False)
    # only the identity wrap that gives the returned input a producer
    assert [s.kind for s in g.stages] == ["point"]
    assert sum(1 for line in g.frontend_log
               if line.startswith("fold:")) == 2


def test_scalar_only_subtrees_fold_in_python():
    def prog(img):
        return img * (0.5 * 4.0)        # scalar subtree never traced

    g = fe.trace(prog, (8, 128), canonicalize=False)
    assert len(g.stages) == 1
    out = g.reference_eval({"img": np.ones((8, 128), np.float32)})["out"]
    assert float(np.asarray(out)[0, 0]) == 2.0


def test_where_reduce_and_comparison(rng):
    def prog(img):
        mask = img > 0.0
        pos = fe.where(mask, img, 0.0)
        total = fe.reduce(pos, jnp.sum)
        return {"pos": pos, "total": total}

    xv = rng.normal(size=(16, 128)).astype(np.float32)
    g = fe.trace(prog, (16, 128))
    app = compile_graph(g, backend="xla")
    out = app(img=xv)
    ref = np.where(xv > 0.0, xv, 0.0).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(out["pos"]), ref)
    np.testing.assert_allclose(float(out["total"]), ref.sum(), rtol=1e-6)


def test_custom_stage_with_eval_shape_inference(rng):
    def prog(img):
        s = fe.custom(lambda x: jnp.sum(x, axis=1, keepdims=True), img)
        return fe.custom(lambda v, m: v - jnp.broadcast_to(m, v.shape),
                         img, s)

    xv = rng.normal(size=(16, 128)).astype(np.float32)
    g = fe.trace(prog, (16, 128))
    out = np.asarray(compile_graph(g, backend="xla")(img=xv)["out"])
    np.testing.assert_allclose(out, xv - xv.sum(axis=1, keepdims=True),
                               rtol=1e-5, atol=1e-5)


def test_reflected_pow_and_integer_where(rng):
    def prog(img):
        decay = 0.5 ** img                       # __rpow__ records
        ints = fe.where(img > 0.0, 1, 0)         # scalar branches stay int
        return {"decay": decay, "ints": ints}

    xv = rng.normal(size=(8, 128)).astype(np.float32)
    g = fe.trace(prog, (8, 128))
    out = g.reference_eval({"img": xv})
    np.testing.assert_array_equal(np.asarray(out["decay"]),
                                  np.asarray(0.5 ** jnp.asarray(xv)))
    assert np.issubdtype(np.asarray(out["ints"]).dtype, np.integer)
    np.testing.assert_array_equal(np.asarray(out["ints"]),
                                  (xv > 0.0).astype(np.int32))


def test_custom_explicit_single_output_returns_plane(rng):
    def prog(img):
        y = fe.custom(lambda x: x * 2.0, img,
                      out_shapes=[(8, 128)], out_dtypes=[jnp.float32])
        return y + 1.0                           # Plane, not a 1-tuple

    xv = rng.normal(size=(8, 128)).astype(np.float32)
    g = fe.trace(prog, (8, 128))
    np.testing.assert_array_equal(
        np.asarray(g.reference_eval({"img": xv})["out"]), xv * 2.0 + 1.0)


def test_integer_planes_promote_like_arrays(rng):
    """Int-Plane arithmetic matches plain-array jnp semantics: true
    division and float scalars promote to float instead of silently
    truncating in the int dtype."""
    def prog(a, b):
        return {"ratio": a / b, "scaled": a * 0.5, "ident": (a / 1) + 0}

    ispec = fe.spec((4, 128), jnp.int32)
    g = fe.trace(prog, ispec, ispec)
    av = np.full((4, 128), 3, np.int32)
    bv = np.full((4, 128), 2, np.int32)
    out = g.reference_eval({"a": av, "b": bv})
    assert float(np.asarray(out["ratio"])[0, 0]) == 1.5
    assert float(np.asarray(out["scaled"])[0, 0]) == 1.5
    # x/1 must not fold on an int plane (the result dtype changes)
    assert np.issubdtype(np.asarray(out["ident"]).dtype, np.floating)
    # ... but int scalars on int planes stay integral
    g2 = fe.trace(lambda a: a * 2 + 1, ispec)
    out2 = np.asarray(g2.reference_eval({"a": av})["out"])
    assert np.issubdtype(out2.dtype, np.integer)
    np.testing.assert_array_equal(out2, av * 2 + 1)


def test_where_accepts_numpy_scalar_branches(rng):
    def prog(img):
        return fe.where(img > 0.0, img, np.float32(0.0))

    xv = rng.normal(size=(8, 128)).astype(np.float32)
    out = fe.trace(prog, (8, 128)).reference_eval({"img": xv})["out"]
    np.testing.assert_array_equal(np.asarray(out),
                                  np.where(xv > 0.0, xv, 0.0))
    with pytest.raises(TraceError):
        fe.trace(lambda img: fe.where(img > 0.0, img, np.ones((8, 128))),
                 (8, 128))


def test_empty_return_raises():
    with pytest.raises(TraceLeakError):
        fe.trace(lambda img: {}, (8, 128))
    with pytest.raises(TraceLeakError):
        fe.trace(lambda img: (), (8, 128))


def test_returning_an_input_gets_identity_stage(rng):
    g = fe.trace(lambda img: img, (8, 128))
    xv = rng.normal(size=(8, 128)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(g.reference_eval({"img": xv})["out"]), xv)


# ----------------------------------------------------------------------
# diagnostics: errors point at USER code
# ----------------------------------------------------------------------
def test_shape_mismatch_reports_user_line():
    def bad(a, b):
        return a + b                    # <- the offending user line

    with pytest.raises(TraceShapeError) as ei:
        fe.trace(bad, (8, 128), (16, 128))
    msg = str(ei.value)
    assert "(8, 128)" in msg and "(16, 128)" in msg
    assert "test_frontend.py" in msg    # user file, not tracer.py


def test_data_dependent_control_flow_raises():
    def bad(img):
        if img > 0.0:                   # bool(Plane)
            return img
        return -img

    with pytest.raises(TraceControlFlowError) as ei:
        fe.trace(bad, (8, 128))
    assert "fe.where" in str(ei.value)
    assert "test_frontend.py" in str(ei.value)


def test_arithmetic_on_bool_plane_raises():
    with pytest.raises(TraceDtypeError) as ei:
        fe.trace(lambda img: (img > 0.0) + 1.0, (8, 128))
    assert "fe.where" in str(ei.value)


def test_plane_leak_into_numpy_raises():
    with pytest.raises(TraceLeakError):
        fe.trace(lambda img: np.asarray(img), (8, 128))


def test_non_plane_return_raises():
    with pytest.raises(TraceLeakError) as ei:
        fe.trace(lambda img: 3.0, (8, 128))
    assert "must return Plane" in str(ei.value)


def test_indexing_hints_at_window():
    with pytest.raises(TraceLeakError) as ei:
        fe.trace(lambda img: img[0], (8, 128))
    assert "fe.window" in str(ei.value)


def test_traced_stages_carry_src():
    g = fe.trace(lambda img: fe.conv(img, lib.GAUSS3), (8, 128))
    stencil = next(s for s in g.stages if s.kind == "stencil")
    assert "test_frontend.py" in stencil.meta["src"]


def test_mixed_pointfn_call_raises():
    with pytest.raises(TraceError) as ei:
        fe.trace(lambda img: lib.luma_rec601(img, img, 1.0), (8, 128))
    assert "factory" in str(ei.value)


def test_spec_count_mismatch_raises():
    with pytest.raises(TraceError) as ei:
        fe.trace(lambda a, b: a + b, (8, 128))
    assert "2 inputs" in str(ei.value)


# ----------------------------------------------------------------------
# stage validation errors (satellite: name + expected vs got + src)
# ----------------------------------------------------------------------
def test_point2_error_names_stage_and_shapes():
    g = DataflowGraph("v")
    a = g.input("a", (8, 128))
    b = g.input("b", (16, 128))
    with pytest.raises(GraphError) as ei:
        g.point2(a, b, lambda x, y: x + y, name="merge")
    msg = str(ei.value)
    assert "'merge'" in msg and "(8, 128)" in msg and "(16, 128)" in msg


def test_stencil_error_names_stage_and_window():
    g = DataflowGraph("v")
    x = g.input("x", (8, 128))
    with pytest.raises(GraphError) as ei:
        g.stencil(x, (2, 3), lambda p: p[0], name="blur")
    assert "'blur'" in str(ei.value) and "odd" in str(ei.value)
    r = g.reduce(x, jnp.sum, out_shape=(), name="total")
    with pytest.raises(GraphError) as ei2:
        g.stencil(r, (3, 3), lambda p: p[0], name="win0d")
    assert "2-D" in str(ei2.value) and "'win0d'" in str(ei2.value)


def test_stage_error_carries_traced_src():
    g = DataflowGraph("v")
    a = g.input("a", (8, 128))
    b = g.input("b", (16, 128))
    with pytest.raises(GraphError) as ei:
        g.point2(a, b, lambda x, y: x + y, name="merge",
                 meta={"src": "user_prog.py:42"})
    assert "user_prog.py:42" in str(ei.value)


# ----------------------------------------------------------------------
# @dataflow_fn: compile, serve, tune — no explicit graph anywhere
# ----------------------------------------------------------------------
def test_dataflow_fn_call_compiles_and_memoizes(rng):
    @fe.dataflow_fn(backend="xla")
    def doubler(img):
        return img * 2.0

    xv = rng.normal(size=(8, 128)).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(doubler(xv)), xv * 2.0)
    assert doubler.compile(xv) is doubler.compile(xv)     # memoized
    assert doubler.trace(xv).signature() == \
        doubler.graph_for({"img": xv}).signature()


def test_dataflow_fn_multi_output_returns_dict(rng):
    @fe.dataflow_fn(backend="xla")
    def pair(img):
        return {"twice": img + img, "sq": img * img}

    xv = rng.normal(size=(8, 128)).astype(np.float32)
    out = pair(xv)
    np.testing.assert_array_equal(np.asarray(out["twice"]), xv + xv)
    np.testing.assert_array_equal(np.asarray(out["sq"]), xv * xv)


def test_dataflow_fn_serves_through_engine(rng):
    from repro.runtime import StreamEngine

    @fe.dataflow_fn
    def edge(img):
        blur = fe.conv(img, lib.GAUSS3)
        return img - blur

    frames = [rng.normal(size=(16, 128)).astype(np.float32)
              for _ in range(4)]
    with StreamEngine(backend="xla", max_batch=2) as eng:
        handles = [eng.submit(edge.graph_for({"img": f}), {"img": f})
                   for f in frames]
        results = [h.result(timeout=60.0) for h in handles]
    for f, res in zip(frames, results):
        ref = np.asarray(
            edge.trace(f).reference_eval({"img": f})["out"])
        np.testing.assert_array_equal(res["out"], ref)


def test_dataflow_fn_tunes_with_auto(tmp_path, rng):
    from repro.tune import TuningCache

    @fe.dataflow_fn(backend="xla", tune="auto")
    def smooth(img):
        return fe.conv(img, lib.GAUSS3)

    cache = TuningCache(str(tmp_path))
    xv = rng.normal(size=(64, 512)).astype(np.float32)
    app = smooth.compile(xv, tune_cache=cache)
    assert app.schedule.groups[0].tile_source in ("measured", "cache")
    ref = np.asarray(
        smooth.trace(xv).reference_eval({"img": xv})["out"])
    np.testing.assert_array_equal(np.asarray(app(img=xv)["out"]), ref)
