"""End-to-end pass-based pipeline: convex DAG fusion + bit-exactness.

Acceptance tests for the compiler restructure: diamond-shaped graphs
(explicit or auto-split) must land in ONE fused kernel group and stay
bit-exact (atol=0) against ``reference_eval`` on all three backends.

Bit-exactness note: the stencil taps below are powers of two, so every
product is exact and XLA's FMA contraction under jit cannot change a
single bit vs the op-by-op reference.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BACKENDS, DataflowGraph, build_schedule,
                        compile_graph, lower_graph)
from repro.core.apps import APPS, JACOBI3, LAPLACE3, _conv, compile_app

H, W = 300, 640   # not tile-aligned: exercises grid padding + masking


def _diamond_explicit(h=H, w=W):
    """split -> two stencil branches -> point merge (explicit split)."""
    g = DataflowGraph("diamond")
    x = g.input("x", (h, w))
    a, b = g.split(x, 2)
    s1 = g.stencil(a, (3, 3), _conv(LAPLACE3), name="lap")
    s2 = g.stencil(b, (3, 3), _conv(JACOBI3), name="jac")
    g.output(g.point2(s1, s2, lambda u, v: u - v, name="merge"), "y")
    return g


def _diamond_autosplit(h=H, w=W):
    """Same diamond but non-canonical: x read twice, no split stage."""
    g = DataflowGraph("diamond_auto")
    x = g.input("x", (h, w))
    s1 = g.stencil(x, (3, 3), _conv(LAPLACE3), name="lap")
    s2 = g.stencil(x, (3, 3), _conv(JACOBI3), name="jac")
    g.output(g.point2(s1, s2, lambda u, v: u - v, name="merge"), "y")
    return g


@pytest.mark.parametrize("builder", [_diamond_explicit, _diamond_autosplit],
                         ids=["explicit-split", "auto-split"])
@pytest.mark.parametrize("backend", BACKENDS)
def test_diamond_single_group_bit_exact(builder, backend, rng):
    g = builder()
    xv = rng.normal(size=(H, W)).astype(np.float32)
    app = compile_graph(g, backend=backend)
    assert len(app.schedule.groups) == 1, app.schedule.describe()
    # reference on the canonicalized graph (the non-canonical original
    # would be rejected by validate(), by design)
    ref = np.asarray(app.schedule.graph.reference_eval({"x": xv})["y"])
    # ... and identical to the explicit-split program's semantics
    np.testing.assert_array_equal(
        ref, np.asarray(_diamond_explicit().reference_eval({"x": xv})["y"]))
    out = np.asarray(app(x=xv)["y"])
    np.testing.assert_array_equal(out, ref)   # atol=0: bit-exact


def test_deep_diamond_with_interleaved_branches(rng):
    """Branches of different depth + a second diamond nested inside."""
    g = DataflowGraph("deep")
    x = g.input("x", (H, W))
    s1 = g.stencil(x, (3, 3), _conv(LAPLACE3), name="a1")
    s2 = g.stencil(s1, (3, 3), _conv(JACOBI3), name="a2")   # deep branch
    s3 = g.stencil(x, (5, 5), _conv(np.ones((5, 5), np.float32) / 32.0),
                   name="b1")                               # shallow branch
    m = g.point2(s2, s3, lambda u, v: u + v, name="m1")
    g.output(g.point2(m, x, lambda u, v: u - v, name="m2"), "y")
    xv = rng.normal(size=(H, W)).astype(np.float32)
    app = compile_graph(g, backend="pallas")
    assert len(app.schedule.groups) == 1
    ref = np.asarray(app.schedule.graph.reference_eval({"x": xv})["y"])
    np.testing.assert_array_equal(np.asarray(app(x=xv)["y"]), ref)


def test_reduce_breaks_convexity(rng):
    """A reduce on one branch must NOT be fused; the merge stage joins
    the fusible group only if the union stays convex."""
    g = DataflowGraph("nonconvex")
    x = g.input("x", (48, 128))
    a, b = g.split(x, 2)
    p = g.point(a, lambda v: v * 2.0, name="p")
    r = g.reduce(b, jnp.sum, out_shape=(), name="r")
    g.output(p, "y")
    g.output(r, "total")
    sched = build_schedule(g)
    kinds = [{s.kind for s in grp.stages} for grp in sched.groups]
    assert {"reduce"} in kinds
    # reduce is alone; split+point fused together
    fused = [grp for grp in sched.groups if "reduce" not in
             {s.kind for s in grp.stages}]
    assert len(fused) == 1 and len(fused[0].stages) == 2
    out = compile_graph(g, backend="pallas")(x=rng.normal(
        size=(48, 128)).astype(np.float32))
    assert out["y"].shape == (48, 128) and out["total"].shape == ()


def test_group_order_respects_cross_group_deps(rng):
    """Producer groups must run before consumer groups even when the
    DAG interleaves fusible and non-fusible stages."""
    g = DataflowGraph("xdep")
    x = g.input("x", (48, 128))
    a, b = g.split(x, 2)
    r = g.reduce(a, lambda v: jnp.sum(v, axis=1, keepdims=True) * 0.0,
                 out_shape=(48, 1), name="rsum")
    rb = g.custom([r], lambda v: jnp.broadcast_to(v, (48, 128)),
                  [(48, 128)], name="bcast")[0]
    g.output(g.point2(b, rb, lambda u, v: u + v, name="mix"), "y")
    sched = build_schedule(g)
    produced = set()
    for grp in sched.groups:
        for st in grp.stages:
            for ch in st.inputs:
                assert ch.producer is None or ch.producer in produced, \
                    f"{st.name} runs before its producer"
            produced.add(st)
    xv = rng.normal(size=(48, 128)).astype(np.float32)
    ref = np.asarray(g.reference_eval({"x": xv})["y"])
    np.testing.assert_array_equal(
        np.asarray(compile_graph(g, backend="pallas")(x=xv)["y"]), ref)


@pytest.mark.parametrize("name", ["harris", "unsharp_mask",
                                  "optical_flow_lk"])
def test_branchy_apps_fuse_to_one_kernel(name):
    g = APPS[name][0](48, 256)
    sched = build_schedule(g)
    assert len(sched.groups) == 1, sched.describe()


@pytest.mark.parametrize("backend", BACKENDS)
def test_harris_matches_reference_all_backends(backend, rng):
    g = APPS["harris"][0](48, 256)
    inputs = {c.name: rng.normal(size=c.shape).astype(np.float32)
              for c in g.graph_inputs}
    ref = g.reference_eval(inputs)
    run, sched = lower_graph(g, backend)
    out = run(inputs)
    assert len(sched.groups) == 1
    np.testing.assert_allclose(np.asarray(out["out"]),
                               np.asarray(ref["out"]), atol=2e-4, rtol=2e-4)


def test_compile_app_helper(rng):
    app = compile_app("gaussian_blur", 48, 256, backend="xla")
    xv = rng.normal(size=(48, 256)).astype(np.float32)
    assert app(img=xv)["out"].shape == (48, 256)


def test_vmem_budget_limits_fusion():
    """With a tiny VMEM spec the fusion search must stop merging
    instead of producing an unlowerable group."""
    from repro.core import TPUSpec
    tiny = TPUSpec(vmem_bytes=64 * 1024)
    g = APPS["filter_chain"][0](256, 1024)
    sched = build_schedule(g, spec=tiny)
    assert len(sched.groups) >= 2
    big = build_schedule(APPS["filter_chain"][0](256, 1024))
    assert len(big.groups) == 1


def test_cost_keys_on_compiled_diamond():
    """cost() exposes exactly the documented keys; "bytes" is the
    EXACT top-level "bytes accessed" entry (regression: the old filter
    `startswith and ==` was contradictory), "bytes_total" sums every
    per-operand entry and therefore dominates it."""
    app = compile_graph(_diamond_explicit(48, 256), backend="xla")
    c = app.cost()
    assert set(c) == {"flops", "bytes", "bytes_total", "transcendentals"}
    assert all(isinstance(v, float) for v in c.values())
    ca = app.compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    assert c["bytes"] == float(ca.get("bytes accessed", 0.0))
    assert c["bytes"] > 0.0                  # the old filter summed nothing
    assert c["bytes_total"] >= c["bytes"]


def test_cycle_error_names_stages_and_channels():
    """The CycleError message lists the cycle's channels, not just the
    stuck stages."""
    from repro.core import CycleError
    g = DataflowGraph("cyc")
    c1 = g.channel((8, 128), name="loop_a")
    c2 = g.channel((8, 128), name="loop_b")
    g.task("a", "point", jnp.abs, [c1], [c2])
    g.task("b", "point", jnp.abs, [c2], [c1])
    with pytest.raises(CycleError) as ei:
        g.toposort()
    msg = str(ei.value)
    assert "loop_a" in msg and "loop_b" in msg
    assert "'a'" in msg and "'b'" in msg


def test_toposort_deque_determinism():
    """Kahn with deque keeps insertion-order tie-breaking."""
    g = DataflowGraph("order")
    ins = [g.input(f"i{k}", (8, 128)) for k in range(5)]
    for k, c in enumerate(ins):
        g.output(g.point(c, jnp.abs, name=f"p{k}"), f"o{k}")
    assert [s.name for s in g.toposort()] == [f"p{k}" for k in range(5)]
