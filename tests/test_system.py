"""End-to-end behaviour: train->learn->checkpoint->resume, serve."""
import dataclasses
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.data.pipeline import SyntheticLM
from repro.models import model as M
from repro.optim.adamw import AdamWConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def test_train_learns_and_resumes(tmp_path):
    ckpt_dir = str(tmp_path / "ckpt")
    cfg = get_smoke("granite_3_2b")
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32,
                       global_batch=8)
    opt = AdamWConfig(lr_peak=1e-2, warmup_steps=5, decay_steps=40)
    tc = TrainerConfig(total_steps=25, ckpt_every=10, ckpt_dir=ckpt_dir,
                       log_every=1000)
    tr = Trainer(cfg, opt, tc, data)
    hist = tr.run()
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.5, "did not learn"

    # resume picks up from the last checkpoint
    tr2 = Trainer(cfg, opt, tc, data)
    assert tr2.step >= 20
    h2 = tr2.run(steps=28)
    assert h2, "no steps after resume"
    assert h2[-1]["loss"] < hist[0]["loss"]


def test_train_all_families_one_step():
    """One optimizer step on every family (weights actually move)."""
    for arch in ("granite_moe_3b_a800m", "mamba2_2p7b", "zamba2_1p2b",
                 "whisper_base", "internvl2_26b", "minicpm3_4b"):
        cfg = get_smoke(arch)
        data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16,
                           global_batch=4)
        from repro.optim.adamw import adamw_init
        from repro.runtime.steps import make_train_step
        params = M.init(cfg, jax.random.PRNGKey(0))
        state = {"params": params, "opt": adamw_init(params)}
        batch = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
        if cfg.family == "encdec":
            batch["enc_embeds"] = jnp.zeros(
                (4, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            batch["extra_embeds"] = jnp.zeros(
                (4, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
        step = jax.jit(make_train_step(cfg, AdamWConfig(lr_peak=1e-3,
                                                        warmup_steps=1,
                                                        decay_steps=10)))
        new_state, metrics = step(state, batch)
        assert np.isfinite(metrics["loss"]), arch
        moved = any(
            float(jnp.abs(a.astype(jnp.float32)
                          - b.astype(jnp.float32)).max()) > 0
            for a, b in zip(jax.tree.leaves(params),
                            jax.tree.leaves(new_state["params"])))
        assert moved, arch


def test_greedy_generation_is_deterministic():
    cfg = dataclasses.replace(get_smoke("granite_3_2b"),
                              capacity_factor=8.0)
    params = M.init(cfg, jax.random.PRNGKey(0))
    prompt = jnp.ones((2, 8), jnp.int32)

    def gen():
        cache = M.init_cache(cfg, 2, 32, dtype=jnp.float32)
        lg, cache = M.prefill(params, cfg, prompt, cache)
        toks = []
        t = jnp.argmax(lg, -1).astype(jnp.int32)
        for _ in range(8):
            toks.append(np.asarray(t))
            lg, cache = M.decode_step(params, cfg, t, cache)
            t = jnp.argmax(lg, -1).astype(jnp.int32)
        return np.stack(toks)

    a, b = gen(), gen()
    np.testing.assert_array_equal(a, b)


def test_dryrun_skip_rule():
    """long_500k skipped for full-attention archs, runs for ssm/hybrid."""
    from repro.launch.dryrun import skip_reason
    from repro.configs import get_config
    from repro.models.config import SHAPES
    assert skip_reason(get_config("qwen1.5-32b"), SHAPES["long_500k"])
    assert skip_reason(get_config("whisper-base"), SHAPES["long_500k"])
    assert skip_reason(get_config("mamba2-2.7b"), SHAPES["long_500k"]) is None
    assert skip_reason(get_config("zamba2-1.2b"), SHAPES["long_500k"]) is None
    assert skip_reason(get_config("qwen1.5-32b"), SHAPES["train_4k"]) is None
