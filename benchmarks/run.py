"""Benchmark driver: one module per paper table/figure (+ beyond-paper).

Prints ``name,us_per_call,derived`` CSV per the harness contract; full
row dicts go to experiments/bench_results.json.

``--trace out.json`` installs the process-global flight recorder
(:mod:`repro.obs`) for the whole run and exports a Chrome trace-event
file loadable in Perfetto / ``chrome://tracing`` — every compile pass,
vectorize sweep, tuner trial and engine phase across every benchmark
module lands in one timeline.  See ``docs/observability.md``.
"""
from __future__ import annotations

import json
import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

MODULES = [
    "benchmarks.fig1_dataflow_latency",
    "benchmarks.fig5_app_latency",
    "benchmarks.fig6_opt_ladder",
    "benchmarks.fig8_backends",
    "benchmarks.table3_resources",
    "benchmarks.bench_kernels",
    "benchmarks.bench_serving",
    "benchmarks.bench_parallel",
    "benchmarks.bench_tuning",
    "benchmarks.lm_roofline",
]


def smoke() -> None:
    """Import every benchmark module and check its contract (--smoke).

    Keeps the scripts import-clean in CI without paying for the full
    measurement sweep.
    """
    import importlib
    failed = []
    for mod_name in MODULES:
        try:
            mod = importlib.import_module(mod_name)
            assert callable(getattr(mod, "run", None)), \
                f"{mod_name} has no run()"
            print(f"{mod_name}: import ok")
        except Exception:
            failed.append(mod_name)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"smoke failed for {failed}")
    print(f"smoke ok: {len(MODULES)} benchmark modules import clean")


def main() -> None:
    import importlib
    all_rows = []
    print("name,us_per_call,derived")
    for mod_name in MODULES:
        try:
            mod = importlib.import_module(mod_name)
            rows = mod.run()
        except Exception:
            print(f"{mod_name},nan,ERROR")
            traceback.print_exc()
            continue
        for r in rows:
            us = r.get("us", r.get("cpu_wall_us", r.get("ms", 0.0)))
            if "ms" in r and "us" not in r and "cpu_wall_us" not in r:
                us = r["ms"] * 1e3
            derived = ";".join(f"{k}={v}" for k, v in r.items()
                               if k not in ("name", "us", "cpu_wall_us"))
            print(f"{r['name']},{float(us):.1f},{derived}")
        all_rows.extend(rows)
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/bench_results.json", "w") as f:
        json.dump(all_rows, f, indent=1, default=str)


def _trace_arg(argv: list[str]) -> str | None:
    """Pull the ``--trace out.json`` output path from argv (None if absent)."""
    if "--trace" not in argv:
        return None
    i = argv.index("--trace")
    if i + 1 >= len(argv):
        raise SystemExit("--trace requires an output path")
    return argv[i + 1]


if __name__ == "__main__":
    _trace_out = _trace_arg(sys.argv)
    _tracer = None
    if _trace_out is not None:
        from repro.obs import install
        _tracer = install()
    if "--smoke" in sys.argv:
        smoke()
    else:
        main()
    if _tracer is not None:
        from repro.obs import export_chrome_trace
        _payload = export_chrome_trace(_tracer, _trace_out)
        print(f"trace: {len(_payload['traceEvents'])} events "
              f"({_tracer.dropped} dropped) -> {_trace_out}")
