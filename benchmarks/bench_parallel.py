"""Hardware-parallelism sweep: vector factor x replica count.

The paper's third transformation pillar, measured end-to-end:

- **vectorization** — compile one stencil app per vector factor
  (tile minor dim = ``128 * vf``) and time the fused pallas kernel;
  the cost model's prediction (:func:`repro.core.vectorize.
  modeled_plane_time`) rides along so the sweep validates the model
  that drives automatic selection.
- **replication** — serve one request stream through
  ``StreamEngine(replicas=k)`` for k = 1, 2, 4 (the batch-parallel
  farm) and through :func:`repro.parallel.replicate.replicate_app`
  (spatial row partitioning), recording measured throughput next to
  the model's predicted linear scaling.  Multi-device rows run in a
  subprocess with forced host devices, like tests/test_distribution.

``--smoke`` (CI) asserts the two correctness properties cheaply: the
vector-factor sweep is monotone-feasible with exact ``128*vf`` minor
dims, and replicated serving (the 1-replica shard_map fallback)
matches single-device outputs bit-for-bit.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import build_schedule, compile_graph, sweep_vector_factor
from repro.core.apps import build_app

_ROOT = os.path.join(os.path.dirname(__file__), "..")
_APP = "gaussian_blur"


def _time_call(fn, reps: int) -> float:
    fn()                                            # warmup (compiles)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6  # us/call


def vf_rows(smoke: bool) -> list[dict]:
    from repro.obs.drift import DriftLog, drift_report
    from repro.tune.calibrate import calibrate

    # a ladder of shapes, not one: the calibration fit needs rows where
    # the grid-step count and the padded element count move separately,
    # or step overhead and per-element cost are not identifiable
    shapes = ([(96, 256), (64, 512), (64, 1024)] if smoke
              else [(256, 640), (64, 1024), (256, 1024), (128, 2048)])
    h, w = shapes[0]
    reps = 2 if smoke else 5
    rng = np.random.default_rng(0)

    # every (modeled, measured) pair from the sweep goes to the on-disk
    # drift log — with the cost-model features behind each modeled time
    # — so drift_report() is the model's report card and calibrate()
    # can refit its constants; $REPRO_DRIFT_LOG redirects (CI does)
    drift = DriftLog(os.environ.get("REPRO_DRIFT_LOG", "").strip()
                     or os.path.join(_ROOT, "experiments",
                                     "bench_parallel_drift.jsonl"))

    rows = []
    primary_records = None
    for hh, ww in shapes:
        x = rng.normal(size=(hh, ww)).astype(np.float32)
        sched = build_schedule(build_app(_APP, hh, ww))
        records = sweep_vector_factor(sched.groups[0])
        if primary_records is None:
            primary_records = records
        sig = sched.graph.signature()
        baseline = None
        for rec in records:
            if not rec["feasible"]:
                continue
            vf = rec["vector_factor"]
            app = compile_graph(build_app(_APP, hh, ww), backend="pallas",
                                vector_factor=vf)
            out = np.asarray(app(img=x)["out"])
            if baseline is None:
                baseline = out
            assert np.array_equal(out, baseline), f"vf={vf} changed bits"
            us = _time_call(lambda: np.asarray(app(img=x)["out"]), reps)
            drift.record("vf_sweep", sig, [[hh, ww]], "pallas",
                         rec["modeled_s"], us / 1e6, vector_factor=vf,
                         tile=list(rec["tile"]), app=_APP,
                         features={"groups": [rec["features"]]})
            name = (f"parallel_vf{vf}" if (hh, ww) == (h, w)
                    else f"parallel_vf{vf}_{hh}x{ww}")
            rows.append({"name": name, "us": us,
                         "vector_factor": vf, "tile": rec["tile"],
                         "modeled_us": rec["modeled_s"] * 1e6,
                         "h": hh, "w": ww, "app": _APP})
    drift.flush()
    report = drift_report(drift)
    auto = build_schedule(build_app(_APP, h, w)).groups[0]
    rows.append({"name": "parallel_vf_auto", "us": 0.0,
                 "vector_factor": auto.vector_factor, "tile": auto.tile,
                 "h": h, "w": w, "app": _APP,
                 "drift_spearman": report["spearman"],
                 "drift_bias": report["bias"],
                 "drift_log": drift.path,
                 "sweep": [{k: r[k] for k in
                            ("vector_factor", "feasible", "modeled_s")}
                           for r in primary_records]})
    rows.append(calibration_row(drift, report, calibrate, drift_report))
    return rows


def calibration_row(drift, report, calibrate, drift_report) -> dict:
    """Fit the cost model from the accumulated drift log and report the
    before/after rank correlation — ROADMAP item 3's exit criterion as
    a benchmark row."""
    result = calibrate(drift)
    row = {"name": "parallel_calibration", "us": 0.0,
           "fitted": result.fitted, "n_rows": result.n_rows,
           "seed_spearman": report["spearman"],
           "seed_bias": report["bias"]}
    if result.fitted:
        after = drift_report(drift, spec=result.spec)["with_spec"]
        s = result.spec
        row.update({"fitted_spearman": after["spearman"],
                    "fitted_bias": after["bias"],
                    "clock_hz": s.clock_hz, "hbm_bw": s.hbm_bw,
                    "step_overhead_s": s.step_overhead_s,
                    "ii_scale": [list(p) for p in s.ii_scale]})
    else:
        row["warning"] = result.warning
    return row


_REPLICA_SUB = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys, json, time
sys.path.insert(0, "src")
import numpy as np
from repro.core import compile_graph
from repro.core.apps import build_app
from repro.parallel.replicate import replicate_app
from repro.runtime import StreamEngine

H, W, N = 64, 256, 96
rng = np.random.default_rng(0)
frames = [rng.normal(size=(H, W)).astype(np.float32) for _ in range(N)]
g = build_app("filter_chain", H, W)
app = compile_graph(build_app("filter_chain", H, W), backend="xla")
ref = np.asarray(app(img=frames[0])["out"])

rows = []
for k in (1, 2, 4):
    with StreamEngine(backend="xla", max_batch=8, replicas=k,
                      max_queue=N) as eng:
        eng.submit(g, {"img": frames[0]}).result()        # warm
        t0 = time.perf_counter()
        hs = [eng.submit(g, {"img": f}) for f in frames]
        outs = [h.result() for h in hs]
        dt = time.perf_counter() - t0
        rep = eng.report(n_items=N)
    assert np.array_equal(np.asarray(outs[0]["out"]), ref), k
    mod = next(iter(rep["modeled"].values()))
    rows.append({"name": f"parallel_engine_r{k}", "us": dt / N * 1e6,
                 "replicas": k, "throughput_rps": N / dt,
                 "throughput_per_replica_rps": N / dt / k,
                 "modeled_scaling": mod.get("replica_scaling_modeled", 1.0),
                 "h": H, "w": W, "n": N})

for k in (1, 2, 4):
    rapp = replicate_app(app, k)
    out = np.asarray(rapp(img=frames[0])["out"])
    assert np.array_equal(out, ref), k
    t0 = time.perf_counter()
    for f in frames[:32]:
        np.asarray(rapp(img=f)["out"])
    dt = time.perf_counter() - t0
    rows.append({"name": f"parallel_spatial_r{k}", "us": dt / 32 * 1e6,
                 "replicas": k, "throughput_rps": 32 / dt,
                 "halo_rows": rapp.halo_rows, "h": H, "w": W})
print(json.dumps(rows))
"""


def replica_rows(smoke: bool) -> list[dict]:
    if smoke:
        # in-process 1-replica fallback: same shard_map code path,
        # asserts replicated == single-device bit-exactly
        from repro.parallel.replicate import replicate_app
        h, w = 32, 128
        rng = np.random.default_rng(0)
        x = rng.normal(size=(h, w)).astype(np.float32)
        app = compile_graph(build_app("filter_chain", h, w), backend="xla")
        rapp = replicate_app(app)
        a, b = np.asarray(app(img=x)["out"]), np.asarray(rapp(img=x)["out"])
        assert np.array_equal(a, b), "replicated != single-device"
        return [{"name": "parallel_spatial_r1_smoke", "us": 0.0,
                 "replicas": 1, "bit_exact": True,
                 "halo_rows": rapp.halo_rows, "h": h, "w": w}]
    r = subprocess.run([sys.executable, "-c", _REPLICA_SUB],
                       capture_output=True, text=True, timeout=560,
                       cwd=_ROOT)
    if r.returncode != 0:
        raise RuntimeError(f"replica sweep failed:\n{r.stderr[-3000:]}")
    return json.loads(r.stdout.strip().splitlines()[-1])


def run(smoke: bool = False) -> list[dict]:
    rows = vf_rows(smoke)
    if smoke:
        recs = next(r for r in rows if r["name"] == "parallel_vf_auto")
        feas = [s["feasible"] for s in recs["sweep"]]
        assert feas == sorted(feas, reverse=True), \
            f"vector-factor feasibility not monotone: {feas}"
        assert recs["tile"][1] == 128 * recs["vector_factor"], recs
    rows += replica_rows(smoke)
    return rows


def main() -> None:
    smoke = "--smoke" in sys.argv
    rows = run(smoke=smoke)
    for r in rows:
        extra = {k: v for k, v in r.items() if k not in ("name", "us")}
        print(f"{r['name']}: {r['us']:.1f} us/call {extra}")
    payload = {"rows": rows, "smoke": smoke}
    os.makedirs(os.path.join(_ROOT, "experiments"), exist_ok=True)
    with open(os.path.join(_ROOT, "experiments", "bench_parallel.json"),
              "w") as f:
        json.dump(payload, f, indent=1)
    if not smoke:
        with open(os.path.join(_ROOT, "BENCH_parallel.json"), "w") as f:
            json.dump(payload, f, indent=1)
    if smoke:
        print("smoke ok: monotone-feasible vector sweep, replicated "
              "serving bit-exact vs single-device")


if __name__ == "__main__":
    main()
