"""Shared benchmark helpers."""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np


def wall_us(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall-clock microseconds per call (jitted fns block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def csv_row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"
