"""Paper Fig. 5 / Table I: per-application latency, FLOWER pipelines.

The paper reports synthesis latency (cycles at 300 MHz, 1024x1024) for
each application, non-vectorized and vectorized x4.  Our analogue: the
cycle model over the scheduled task graph (each stage II=1 over
pixels/vector-lane items, stencils carry fill latency), plus the
fused-kernel structure check (#kernels after the dataflow transform).
"""
from __future__ import annotations

from repro.core import TaskTiming, analytic_latency, build_schedule
from repro.core.apps import APPS

F_MHZ = 300.0
H = W = 1024


def app_latency_cycles(name: str, vector: int) -> tuple[float, int, int]:
    # build_schedule runs the full canonicalization pipeline (auto-split,
    # dead-channel elimination, point fusion) before convex DAG fusion,
    # so the modeled task list is the post-pass stage set.
    g = APPS[name][0](H, W)
    sched = build_schedule(g)
    n_items = (H * W) // vector
    total = 0.0
    for grp in sched.groups:
        # read + compute tasks + write, all streaming at II=1
        tasks = [TaskTiming("read", ii=1.0, fill=32.0)]
        for st in grp.stages:
            fill = 8.0
            if st.kind == "stencil":
                # line-buffer fill: halo rows must arrive first
                fill = st.halo[0] * W / vector + 8.0
            tasks.append(TaskTiming(st.name, ii=st.ii, fill=fill))
        tasks.append(TaskTiming("write", ii=1.0, fill=32.0))
        total += analytic_latency(tasks, n_items)["dataflow"]
    return total, len(sched.groups), len(sched.graph.stages)


def run() -> list[dict]:
    rows = []
    for name, (_, n_stages, _) in APPS.items():
        c1, k1, s1 = app_latency_cycles(name, 1)
        c4, _, _ = app_latency_cycles(name, 4)
        rows.append({
            "name": f"fig5/{name}", "tableI_stages": n_stages,
            "stages_after_passes": s1,
            "kernels_after_fusion": k1,
            "cycles_v1": int(c1), "ms_v1": round(c1 / (F_MHZ * 1e3), 3),
            "cycles_v4": int(c4), "ms_v4": round(c4 / (F_MHZ * 1e3), 3),
            "vector_speedup": round(c1 / c4, 2),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
