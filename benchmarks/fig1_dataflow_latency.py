"""Paper Fig. 1: latency of a 5-task kernel, with and without the
dataflow transformation (f = 200 MHz).

The paper's bars: each task alone, the 5 tasks run sequentially under
one FSM (no dataflow), and the dataflow-transformed kernel whose
latency collapses to ~ the slowest task.  We reproduce both the
analytic law and the cycle-level simulation, and convert cycles to ms
at the paper's 200 MHz.
"""
from __future__ import annotations

from repro.core import TaskTiming, analytic_latency, simulate_pipeline

F_MHZ = 200.0
N_ITEMS = 1 << 20          # one 1024x1024 image, 1 pixel/cycle/task


def run() -> list[dict]:
    tasks = [TaskTiming(f"task{i}", ii=1.0, fill=16.0) for i in range(5)]
    rows = []
    for t in tasks:
        cyc = t.fill + N_ITEMS * t.ii
        rows.append({"name": f"fig1/{t.name}", "cycles": cyc,
                     "ms": cyc / (F_MHZ * 1e3)})
    ana = analytic_latency(tasks, N_ITEMS)
    sim = simulate_pipeline(tasks, 1 << 14, depth=2)
    rows.append({"name": "fig1/no_dataflow(kernel)",
                 "cycles": ana["sequential"],
                 "ms": ana["sequential"] / (F_MHZ * 1e3)})
    rows.append({"name": "fig1/dataflow(kernel)",
                 "cycles": ana["dataflow"],
                 "ms": ana["dataflow"] / (F_MHZ * 1e3),
                 "speedup_vs_no_dataflow": round(ana["speedup"], 3),
                 "sim_speedup@16k": round(sim["speedup"], 3)})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
