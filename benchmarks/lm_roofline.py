"""Beyond-paper: the 40-cell LM roofline table from the dry-run sweep.

Reads experiments/dryrun/*.json (produced by
``python -m repro.launch.dryrun --sweep``) and emits the §Roofline
table: three terms, dominant bottleneck, useful-compute ratio.
"""
from __future__ import annotations

import glob
import json
import os

OUT_DIR = "experiments/dryrun"


def run() -> list[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(OUT_DIR, "*__pod.json"))):
        d = json.load(open(f))
        if d.get("status") == "skip":
            rows.append({"name": f"roofline/{d['arch']}/{d['shape']}",
                         "status": "skip", "reason": d["reason"][:60]})
            continue
        if d.get("status") != "ok" or "t_compute" not in d:
            rows.append({"name": f"roofline/{d.get('arch')}/{d.get('shape')}",
                         "status": d.get("status", "?")})
            continue
        rows.append({
            "name": f"roofline/{d['arch']}/{d['shape']}",
            "Tc_ms": round(d["t_compute"] * 1e3, 3),
            "Tm_ms": round(d["t_memory"] * 1e3, 3),
            "Tx_ms": round(d["t_collective"] * 1e3, 3),
            "dominant": d["dominant"],
            "useful_ratio": round(d["useful_ratio"], 4),
            "temp_gb_per_chip": round(
                d["bytes_per_chip"].get("temp_size_in_bytes", 0) / 2**30, 2),
        })
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
