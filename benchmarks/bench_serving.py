"""Serving benchmark: equal-width dispatch ladder + latency-budget sweep.

Closed-loop ladder — the same compiled diamond app dispatched four
ways, with micro-batch widths compared at EQUAL width so the engine's
scheduling overhead is visible next to the raw batched launch it
amortizes:

- ``sequential`` — one ``CompiledApp.__call__`` per request, forced
  to host memory before the next (the bare-callable baseline),
- ``launch_pipelined`` — async ``CompiledApp.launch`` with a depth-2
  in-flight window (double buffering without batching),
- ``direct micro-batch[b]`` — ``MicroBatcher.launch`` over width-``b``
  slices: stacking + one vmapped kernel, no queue/threads/futures,
- ``engine[b]`` — the full :class:`repro.runtime.engine.StreamEngine`
  submit→form→dispatch→complete path at ``max_batch=b``.

Open-loop sweep — requests arrive paced below capacity while the
engine forms batches under a per-request ``latency_budget``; each row
records the offered load next to achieved throughput and p50/p99, so
the deadline-based batch formation is visible: p99 tracks the budget
(plus service + scheduler noise), not the queue depth.

The benchmark runs in the overhead-dominated regime (small planes):
that is where per-launch host overhead is the bottleneck and
micro-batching pays.  On large planes a vmapped stencil batch becomes
compute/bandwidth-bound and batching itself stops winning — no
scheduler can recover that, so benchmarking there would measure XLA
codegen, not the serving runtime.

Full mode writes ``experiments/bench_serving.json`` plus the repo-root
``BENCH_serving.json`` baseline; ``--smoke`` runs a small
configuration in CI and asserts:

- micro-batched dispatch beats one-at-a-time dispatch,
- batching pays through the FULL engine path: ``engine[b=8]`` beats
  ``engine[b=1]`` by >= 1.4x (this is the continuous-batching claim —
  the seed engine lost its batching win to fixed-width padding and
  lock-step draining),
- under paced open-loop load, p99 stays bounded by the configured
  latency budget plus service/scheduler slack.

``--trace out.json`` records the whole run into the flight recorder
(:mod:`repro.obs`) and exports a Perfetto-loadable Chrome trace;
``$REPRO_DRIFT_LOG=path`` additionally appends a modeled-vs-measured
drift row per engine launch (see ``docs/observability.md``).

Single-core caveat: engine-vs-direct at equal width is recorded
(``vs_direct_equal_batch``) but not asserted — on a 1-core host the
submit path, worker loop and caller futures all serialize with the
kernel, so the engine cannot reach direct-dispatch throughput no
matter how it schedules; on multi-core hosts the worker overlaps with
submitters and the ratio approaches 1.
"""
from __future__ import annotations

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import DataflowGraph, compile_graph
from repro.core.apps import JACOBI3, LAPLACE3, _conv
from repro.runtime import MicroBatcher, StreamEngine, modeled_latency

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _diamond(h: int, w: int) -> DataflowGraph:
    g = DataflowGraph("diamond")
    x = g.input("x", (h, w))
    s1 = g.stencil(x, (3, 3), _conv(LAPLACE3), name="lap")
    s2 = g.stencil(x, (3, 3), _conv(JACOBI3), name="jac")
    g.output(g.point2(s1, s2, lambda u, v: u - v, name="merge"), "y")
    return g


def _requests(h: int, w: int, n: int) -> list[np.ndarray]:
    rng = np.random.default_rng(0)
    return [rng.normal(size=(h, w)).astype(np.float32) for _ in range(n)]


def _sequential(app, reqs) -> float:
    """One-at-a-time __call__ dispatch; returns items/sec."""
    np.asarray(app(x=reqs[0])["y"])                    # warmup
    t0 = time.perf_counter()
    for x in reqs:
        np.asarray(app(x=x)["y"])
    return len(reqs) / (time.perf_counter() - t0)


def _launch_pipelined(app, reqs, depth: int = 2) -> float:
    """Async launch() with a bounded in-flight window; items/sec."""
    app.launch(x=reqs[0]).result()                     # warmup
    inflight: list = []
    t0 = time.perf_counter()
    for x in reqs:
        if len(inflight) >= depth:
            inflight.pop(0).result()
        inflight.append(app.launch(x=x))
    for h in inflight:
        h.result()
    return len(reqs) / (time.perf_counter() - t0)


class _Req:
    def __init__(self, x):
        self.inputs = {"x": x}


def _microbatched(app, mb, reqs, b: int) -> float:
    """Direct width-``b`` micro-batched dispatch (no engine); items/sec."""
    wrapped = [_Req(x) for x in reqs]
    np.asarray(mb.launch(app, wrapped[:b])["y"])       # warmup
    t0 = time.perf_counter()
    outs = [mb.launch(app, wrapped[i:i + b], check_shapes=False)
            for i in range(0, len(wrapped), b)]
    for o in outs:
        np.asarray(o["y"])
    return len(reqs) / (time.perf_counter() - t0)


def _warm_engine(eng, g, reqs, max_batch: int) -> None:
    """Compile every power-of-two bucket the engine can launch."""
    w = 1
    while w <= max_batch:
        handles = [eng.submit(g, {"x": reqs[i]}) for i in range(w)]
        for hd in handles:
            hd.result(timeout=600)
        w <<= 1


def _engine_round(eng, g, reqs) -> float:
    """One closed-loop round through a warm engine; items/sec."""
    t0 = time.perf_counter()
    handles = [eng.submit(g, {"x": x}) for x in reqs]
    for hd in handles:
        hd.result(timeout=600)
    return len(reqs) / (time.perf_counter() - t0)


def _engine_paced(g, reqs, backend: str, budget_s: float,
                  rate_rps: float, burst: int = 8) -> dict:
    """Open-loop round: paced arrivals against a latency budget.

    Submits ``burst`` requests every ``burst/rate`` seconds (offered
    load below capacity) into a FRESH engine, so the recorded p50/p99
    reflect deadline-based batch formation, not queue backlog.
    """
    with StreamEngine(backend=backend, max_batch=8,
                      max_queue=len(reqs) + 16, inflight=2,
                      latency_budget=budget_s) as eng:
        _warm_engine(eng, g, reqs, 8)
        eng.telemetry.reset()      # drop warmup compile latencies
        period = burst / rate_rps
        next_t = time.perf_counter()
        t0 = next_t
        handles = []
        for i in range(0, len(reqs), burst):
            now = time.perf_counter()
            if now < next_t:
                time.sleep(next_t - now)
            for x in reqs[i:i + burst]:
                handles.append(eng.submit(g, {"x": x}))
            next_t += period
        for hd in handles:
            hd.result(timeout=600)
        wall = time.perf_counter() - t0
        rep = eng.report()
    m = rep["measured"]
    return {
        "budget_ms": budget_s * 1e3,
        "offered_load_rps": rate_rps,
        "achieved_rps": len(reqs) / wall,
        "latency_p50_ms": m["latency_p50_ms"],
        "latency_p99_ms": m["latency_p99_ms"],
        "batch_size_mean": m["batch_size_mean"],
    }


def run(smoke: bool = False) -> list[dict]:
    # small planes: the overhead-dominated regime micro-batching
    # amortizes (see module docstring).  Modes are measured in
    # interleaved rounds (best-of-k per mode) so machine-load swings
    # hit every mode alike.
    h, w = (8, 128)
    n = 128 if smoke else 512
    rounds = 3
    backend = "xla"
    batch_widths = (1, 8) if smoke else (1, 2, 4, 8)
    reqs = _requests(h, w, n)
    g = _diamond(h, w)
    app = compile_graph(_diamond(h, w), backend=backend)
    model = modeled_latency(app, n)

    engines = {b: StreamEngine(backend=backend, max_batch=b,
                               max_queue=n + 16, inflight=2,
                               latency_budget=0.002)
               for b in batch_widths}
    for b, eng in engines.items():
        _warm_engine(eng, g, reqs, b)
    mbs = {b: MicroBatcher(max_batch=b) for b in batch_widths}
    seq_tput = pipe_tput = 0.0
    mb_tput = {b: 0.0 for b in batch_widths}
    eng_tput = {b: 0.0 for b in batch_widths}
    for _ in range(rounds):
        seq_tput = max(seq_tput, _sequential(app, reqs))
        pipe_tput = max(pipe_tput, _launch_pipelined(app, reqs))
        for b in batch_widths:
            mb_tput[b] = max(mb_tput[b], _microbatched(app, mbs[b], reqs, b))
            eng_tput[b] = max(eng_tput[b], _engine_round(engines[b], g, reqs))

    rows: list[dict] = []
    rows.append({"name": "serving_sequential", "us": 1e6 / seq_tput,
                 "throughput_rps": seq_tput, "mode": "one-at-a-time",
                 "h": h, "w": w, "n": n,
                 "modeled_speedup": model["speedup"]})
    rows.append({"name": "serving_launch_pipelined", "us": 1e6 / pipe_tput,
                 "throughput_rps": pipe_tput, "mode": "async-depth2",
                 "h": h, "w": w, "n": n})
    for b in batch_widths:
        rows.append({"name": f"serving_microbatch_b{b}",
                     "us": 1e6 / mb_tput[b], "throughput_rps": mb_tput[b],
                     "mode": f"direct micro-batch={b}",
                     "h": h, "w": w, "n": n,
                     "speedup_vs_sequential": mb_tput[b] / seq_tput})
    for b, eng in engines.items():
        rep = eng.report(n_items=n)
        eng.close()
        m = rep["measured"]
        tput = eng_tput[b]
        rows.append({"name": f"serving_engine_b{b}", "us": 1e6 / tput,
                     "throughput_rps": tput, "mode": f"engine batch={b}",
                     "h": h, "w": w, "n": n,
                     "latency_p50_ms": m["latency_p50_ms"],
                     "latency_p99_ms": m["latency_p99_ms"],
                     "batch_size_mean": m["batch_size_mean"],
                     "compiles": rep["cache"]["misses"],
                     "cache_requests": rep["cache"]["requests"],
                     "buckets": {str(k): v
                                 for k, v in rep["buckets"].items()},
                     "speedup_vs_sequential": tput / seq_tput,
                     "vs_direct_equal_batch": tput / mb_tput[b]})

    # open-loop latency-budget sweep at ~half the closed-loop capacity
    cap = max(eng_tput.values())
    budgets = (0.002,) if smoke else (0.0005, 0.002, 0.008)
    for budget in budgets:
        r = _engine_paced(g, reqs, backend, budget, rate_rps=0.5 * cap)
        r["name"] = f"serving_budget_{r['budget_ms']:g}ms"
        r["mode"] = "engine open-loop"
        r.update(h=h, w=w, n=n)
        rows.append(r)
    return rows


def _trace_arg(argv: list[str]) -> str | None:
    """Pull the ``--trace out.json`` output path from argv (None if absent)."""
    if "--trace" not in argv:
        return None
    i = argv.index("--trace")
    if i + 1 >= len(argv):
        raise SystemExit("--trace requires an output path")
    return argv[i + 1]


def main() -> None:
    smoke = "--smoke" in sys.argv
    trace_out = _trace_arg(sys.argv)
    tracer = None
    if trace_out is not None:
        # install the process-global recorder: every engine and compile
        # in run() resolves trace=None to it (see docs/observability.md)
        from repro.obs import install
        tracer = install()
    rows = run(smoke=smoke)
    if tracer is not None:
        from repro.obs import export_chrome_trace
        payload = export_chrome_trace(tracer, trace_out)
        print(f"trace: {len(payload['traceEvents'])} events "
              f"({tracer.dropped} dropped) -> {trace_out}")
    for r in rows:
        extra = ""
        if "speedup_vs_sequential" in r:
            extra += f" ({r['speedup_vs_sequential']:.2f}x vs sequential)"
        if "vs_direct_equal_batch" in r:
            extra += f" ({r['vs_direct_equal_batch']:.2f}x vs direct@b)"
        if "offered_load_rps" in r:
            extra += (f" (offered {r['offered_load_rps']:.0f} rps, "
                      f"p99 {r['latency_p99_ms']:.1f}ms @ budget "
                      f"{r['budget_ms']:g}ms)")
        print(f"{r['name']}: {r['throughput_rps']:.1f} items/s{extra}"
              if "throughput_rps" in r else
              f"{r['name']}: {r['achieved_rps']:.1f} items/s{extra}")
    payload = {"rows": rows, "smoke": smoke}
    os.makedirs(os.path.join(_ROOT, "experiments"), exist_ok=True)
    with open(os.path.join(_ROOT, "experiments", "bench_serving.json"),
              "w") as f:
        json.dump(payload, f, indent=1)
    # the repo-root baseline is what benchmarks/compare.py gates CI
    # against — a smoke run must never overwrite it with itself, or
    # the gate compares a fresh run to a copy of the fresh run
    if not smoke:
        with open(os.path.join(_ROOT, "BENCH_serving.json"), "w") as f:
            json.dump(payload, f, indent=1)
    if smoke:
        by_name = {r["name"]: r for r in rows}
        seq = by_name["serving_sequential"]["throughput_rps"]
        best_mb = max(r["throughput_rps"] for r in rows
                      if r["name"].startswith("serving_microbatch"))
        assert best_mb > seq, (
            f"micro-batched dispatch ({best_mb:.1f} items/s) did not beat "
            f"one-at-a-time dispatch ({seq:.1f} items/s)")
        e1 = by_name["serving_engine_b1"]["throughput_rps"]
        e8 = by_name["serving_engine_b8"]["throughput_rps"]
        assert e8 >= 1.4 * e1, (
            f"continuous batching regressed: engine[b=8] {e8:.1f} items/s "
            f"< 1.4x engine[b=1] {e1:.1f} items/s")
        paced = next(r for r in rows if "budget_ms" in r)
        slack_ms = 50.0            # service + GIL/scheduler noise on CI
        assert paced["latency_p99_ms"] <= paced["budget_ms"] + slack_ms, (
            f"open-loop p99 {paced['latency_p99_ms']:.1f}ms exceeds "
            f"budget {paced['budget_ms']:g}ms + {slack_ms:g}ms slack")
        print(f"smoke ok: micro-batch {best_mb:.0f} > sequential "
              f"{seq:.0f} items/s; engine b8/b1 {e8 / e1:.2f}x; "
              f"paced p99 {paced['latency_p99_ms']:.1f}ms within "
              f"budget+slack")


if __name__ == "__main__":
    main()
